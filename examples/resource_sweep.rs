//! Resource-constraint sweeps: the paper's Fig. 3 (BRAM vs input size)
//! and Table IV (speedup vs DSP budget), plus a device sweep showing how
//! the same model maps onto edge vs cloud parts.
//!
//! ```bash
//! cargo run --release --example resource_sweep
//! ```

use anyhow::Result;

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dataflow::build::build_streaming_design;
use ming::dse::ilp::{solve, DseConfig};
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::tiling::compile_tiled;
use ming::util::prng;
use ming::util::tables::TextTable;

fn main() -> Result<()> {
    let kv260 = DeviceSpec::kv260();

    // ---- Fig. 3: BRAM vs input size ------------------------------------
    println!("== Fig. 3: single-layer BRAM vs input size (KV260 has {}) ==", kv260.bram18k);
    let mut t = TextTable::new(vec!["input", "vanilla", "streamhls", "ming"]);
    for n in [32usize, 64, 96, 128, 160, 192, 224] {
        let g = models::conv_relu(n, models::CONV_C, models::CONV_F);
        let mut row = vec![format!("{n}x{n}")];
        for fw in [FrameworkKind::Vanilla, FrameworkKind::StreamHls, FrameworkKind::Ming] {
            let d = compile_with(fw, &g, &kv260)?;
            row.push(estimate(&d, &kv260).bram18k.to_string());
        }
        t.row(row);
    }
    println!("{}", t.render());

    // ---- Table IV: DSP budget sweep ------------------------------------
    println!("== Table IV: Conv+ReLU 32x32 under DSP budgets ==");
    let g = models::conv_relu(32, models::CONV_C, models::CONV_F);
    let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect();
    let dv = compile_with(FrameworkKind::Vanilla, &g, &kv260)?;
    let base = simulate(&dv, &x, SimMode::of(dv.style))?.expect_complete().cycles;
    let mut t = TextTable::new(vec!["DSP budget", "cycles", "speedup", "DSP used", "E_DSP"]);
    for cap in [1248u64, 250, 50, 10] {
        let dev = kv260.with_dsp_limit(cap);
        let d = compile_with(FrameworkKind::Ming, &g, &dev)?;
        let rep = simulate(&d, &x, SimMode::Dataflow)?.expect_complete();
        let r = estimate(&d, &dev);
        assert!(r.fits(), "DSE must respect the cap: {r}");
        let sp = base as f64 / rep.cycles as f64;
        t.row(vec![
            format!("{cap} ({}%)", 100 * cap / 1248),
            rep.cycles.to_string(),
            format!("{sp:.1}"),
            r.dsp.to_string(),
            format!("{:.2}", sp / r.dsp.max(1) as f64),
        ]);
    }
    println!("{}", t.render());

    // ---- device sweep ----------------------------------------------------
    println!("== cascade 224x224 across devices ==");
    let g = models::cascade(224, models::CONV_C, models::CONV_F);
    let mut t = TextTable::new(vec!["device", "framework", "BRAM", "DSP", "fits"]);
    for dev in [DeviceSpec::kv260(), DeviceSpec::zcu104(), DeviceSpec::u250()] {
        for fw in [FrameworkKind::StreamHls, FrameworkKind::Ming] {
            let d = compile_with(fw, &g, &dev)?;
            let r = estimate(&d, &dev);
            t.row(vec![
                dev.name.clone(),
                fw.name().to_string(),
                r.bram18k.to_string(),
                r.dsp.to_string(),
                if r.fits() { "yes".to_string() } else { "NO".to_string() },
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "Note: at 224x224 the StreamHLS-style design exceeds even the\n\
         cloud-grade U250 — the paper's §V-B remark that the issue\n\
         persists on cloud FPGAs when scaling up."
    );

    // ---- oversized workload: infeasible untiled, placed by tiling -------
    println!("\n== oversized: vgg3 (3x conv3x3 @256ch) on a 512x512 input, KV260 ==");
    let g = models::vgg_block(512, 256, 3);
    let cfg = DseConfig::new(kv260.clone());
    let mut flat = build_streaming_design(&g)?;
    match solve(&mut flat, &cfg) {
        Ok(_) => println!("unexpected: untiled DSE found a feasible point"),
        Err(e) => println!("untiled DSE: {e:#}"),
    }
    let tc = compile_tiled(&g, &cfg)?;
    println!("{}", tc.describe());
    let r = estimate(&tc.cell, &kv260);
    println!("cell resources: {r}");
    assert!(
        r.bram18k <= kv260.bram18k,
        "tiled cell must fit the stock KV260 BRAM budget"
    );
    println!(
        "estimated tiled latency: {:.2} MCycles across {} grid cells (gather overlapped)",
        tc.estimated_cycles() as f64 / 1e6,
        tc.grid.n_cells()
    );
    Ok(())
}
