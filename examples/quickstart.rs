//! Quickstart: compile one CNN layer with MING, inspect the streaming
//! architecture, estimate resources, simulate, and emit the HLS C++.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use ming::analysis::{classify_iterators, detect_sliding_window};
use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::codegen::emit_design;
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::util::prng;

fn main() -> Result<()> {
    // 1. The model: a single Conv+ReLU layer at 32x32x8 (paper Fig. 2).
    let g = models::conv_relu(32, models::CONV_C, models::CONV_F);
    println!("== model graph ==");
    for op in &g.ops {
        println!("{op}\n");
    }

    // 2. Kernel analysis (paper Algorithms 1 & 2).
    let conv = g.op("conv0")?;
    let sw = detect_sliding_window(conv).expect("conv must be sliding-window");
    println!("Algorithm 1: sliding window, stride={} dilation={}", sw.stride, sw.dilation);
    let sets = classify_iterators(conv);
    println!("Algorithm 2: P={:?} R={:?} O={:?} W={:?}\n", sets.p, sets.r, sets.o, sets.w);

    // 3. Compile with MING (streaming build + ILP DSE) for the KV260.
    let device = DeviceSpec::kv260();
    let design = compile_with(FrameworkKind::Ming, &g, &device)?;
    println!("== streaming design ==");
    for n in &design.nodes {
        println!(
            "node {:<6} [{:<17}] MAC-lanes={:<4} II={} unroll=({}, {})",
            n.name,
            n.geo.class.name(),
            n.timing.mac_lanes,
            n.timing.ii,
            n.timing.unroll_par,
            n.timing.unroll_red
        );
    }
    for c in &design.channels {
        println!("chan {:<12} {} tokens, depth {}", c.name, c.tokens_total, c.depth);
    }
    let report = estimate(&design, &device);
    println!("\nresources: {report}");

    // 4. Simulate on a deterministic input image.
    let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect();
    let rep = simulate(&design, &x, SimMode::Dataflow)?.expect_complete();
    println!(
        "simulated: {} cycles ({:.2} MAC/cycle), output[..8] = {:?}",
        rep.cycles,
        rep.macs_per_cycle(design.total_macs()),
        &rep.output[..8]
    );

    // 5. Emit the Vitis-HLS C++ (what MING hands to the vendor tool).
    let cpp = emit_design(&design);
    let path = std::env::temp_dir().join("ming_quickstart.cpp");
    std::fs::write(&path, &cpp)?;
    println!("\nHLS C++ written to {} ({} lines)", path.display(), cpp.lines().count());
    Ok(())
}
