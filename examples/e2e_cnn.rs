//! End-to-end driver (DESIGN.md §6): exercises the full three-layer
//! stack on a real small workload and reports the paper's headline
//! metric.
//!
//! For every Table-II workload this driver:
//!   1. builds the `linalg`-style graph (L3 front-end),
//!   2. compiles it with all four framework strategies,
//!   3. functionally simulates each design cycle-by-cycle on a
//!      deterministic int8 image,
//!   4. verifies MING's streaming output **bit-exactly** against the
//!      JAX/Pallas golden model executed through PJRT (L2/L1 artifacts
//!      built by `make artifacts`),
//!   5. prints the headline metric: speedup over Vanilla + resource fit
//!      on the Kria KV260.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cnn
//! ```

use anyhow::Result;

use ming::baselines::framework::FrameworkKind;
use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, SweepConfig};
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::runtime::golden::GoldenModel;
use ming::util::prng;

fn main() -> Result<()> {
    let device = DeviceSpec::kv260();
    println!("MING end-to-end driver — device {} (BRAM {}, DSP {})\n", device.name, device.bram18k, device.dsp);

    // 1-3: the full Table-II sweep over the multithreaded compile service.
    let svc = CompileService::default();
    let t0 = std::time::Instant::now();
    let results = svc.run_sweep(&SweepConfig::table2(device.clone()));
    let cells: Vec<Cell> = results
        .iter()
        .filter_map(|r| match r {
            Ok(jr) => Some(report::cell(jr)),
            Err(e) => {
                eprintln!("job failed: {e}");
                None
            }
        })
        .collect();
    println!("{}", report::render_table2(&cells));
    println!("(sweep wall time: {:.2?}, {} designs)\n", t0.elapsed(), cells.len());

    // 4: golden verification of the MING designs against JAX/Pallas HLO.
    println!("== golden-model verification (simulator vs JAX/Pallas via PJRT) ==");
    let gm = match GoldenModel::open_default() {
        Ok(gm) => gm,
        Err(e) => {
            println!("SKIPPED: {e:#} — run `make artifacts` first");
            return Ok(());
        }
    };
    let mut verified = 0;
    let mut failed = 0;
    for r in &results {
        let Ok(jr) = r else { continue };
        if jr.job.framework != FrameworkKind::Ming {
            continue;
        }
        let key = GoldenModel::key(&jr.job.kernel, jr.job.size);
        if !gm.available(&key) {
            println!("{key:<18} SKIP (artifact missing)");
            continue;
        }
        let g = models::paper_kernel(&jr.job.kernel, jr.job.size)?;
        let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect();
        let sim = jr.sim.as_ref().expect("sweep ran with simulation");
        let bad = gm.verify(&key, &x, &sim.output)?;
        println!(
            "{key:<18} {} ({} output values)",
            if bad == 0 { "OK — bit-exact" } else { "MISMATCH" },
            sim.output.len()
        );
        if bad == 0 {
            verified += 1;
        } else {
            failed += 1;
        }
    }

    // 5: headline metric.
    let ming_cells: Vec<&Cell> =
        cells.iter().filter(|c| c.framework == FrameworkKind::Ming).collect();
    let speedups: Vec<f64> =
        ming_cells.iter().filter_map(|c| report::speedup(&cells, c)).collect();
    let single: Vec<f64> = cells
        .iter()
        .filter(|c| c.framework == FrameworkKind::Ming && c.kernel == "conv_relu")
        .filter_map(|c| report::speedup(&cells, c))
        .collect();
    let geo = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("\n== headline ==");
    println!(
        "MING geo-mean speedup over Vanilla: {geo:.0}x  (paper: ~50x overall, up to ~580x single-layer)"
    );
    println!(
        "MING single-layer speedups: {:?}  (paper: 504x / 582x)",
        single.iter().map(|s| format!("{s:.0}x")).collect::<Vec<_>>()
    );
    println!(
        "MING fits the KV260 in {}/{} workloads (every other framework exceeds it at 224x224)",
        ming_cells.iter().filter(|c| c.fits).count(),
        ming_cells.len()
    );
    println!("golden verification: {verified} bit-exact, {failed} mismatching");
    anyhow::ensure!(failed == 0, "golden verification failed");
    Ok(())
}
