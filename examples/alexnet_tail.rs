//! AlexNet-tail scenario: the paper's Linear / Feed-Forward workloads
//! ("in some CNN applications a linear or feed-forward layer is appended
//! at the end of the network … such as in AlexNet", §V-A) — plus a JSON
//! front-end import showing how a custom classifier head is compiled.
//!
//! Demonstrates the Table-II failure mode: StreamHLS's DSP-unaware
//! reduction unrolling explodes on linears, while MING's BRAM+DSP-aware
//! DSE produces feasible designs.
//!
//! ```bash
//! cargo run --release --example alexnet_tail
//! ```

use anyhow::Result;

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::ir::builder::models;
use ming::ir::json::import_model;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::util::prng;

fn main() -> Result<()> {
    let device = DeviceSpec::kv260();

    println!("== Linear / Feed-Forward on {} ==", device.name);
    for kernel in ["linear", "feedforward"] {
        let g = models::paper_kernel(kernel, 0)?;
        println!("\n-- {kernel} ({} MACs) --", g.total_macs());
        for fw in [FrameworkKind::Vanilla, FrameworkKind::StreamHls, FrameworkKind::Ming] {
            let d = compile_with(fw, &g, &device)?;
            let r = estimate(&d, &device);
            let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
                .iter()
                .map(|&v| v as i32)
                .collect();
            let rep = simulate(&d, &x, SimMode::of(d.style))?;
            let cyc = rep.deadlock.is_none().then_some(rep.cycles);
            println!(
                "{:<10} cycles={:<10} DSP={:<6} BRAM={:<5} {}",
                fw.name(),
                cyc.map(|c| c.to_string()).unwrap_or_else(|| "deadlock".into()),
                r.dsp,
                r.bram18k,
                if r.fits() { "fits" } else { "EXCEEDS DEVICE" }
            );
        }
    }

    // A custom classifier head via the JSON front-end (ONNX stand-in).
    println!("\n== custom MLP head via JSON import ==");
    let g = import_model(
        r#"{
          "name": "alexnet_head",
          "input": {"shape": [64, 256], "dtype": "i8"},
          "layers": [
            {"op": "linear", "features": 128, "seed": 11},
            {"op": "linear", "features": 64, "seed": 12},
            {"op": "linear", "features": 10, "seed": 13, "activation": "none"}
          ]
        }"#,
    )?;
    let d = compile_with(FrameworkKind::Ming, &g, &device)?;
    let r = estimate(&d, &device);
    println!("{} ops, {} MACs, resources: {r}", g.ops.len(), g.total_macs());
    let x: Vec<i32> =
        prng::det_tensor(prng::SEED_INPUT, 64 * 256).iter().map(|&v| v as i32).collect();
    let rep = simulate(&d, &x, SimMode::Dataflow)?.expect_complete();
    println!("simulated {} cycles; logits[..10] = {:?}", rep.cycles, &rep.output[..10]);
    Ok(())
}
