//! Cost models for tiled execution: per-strip BRAM lower bounds (used to
//! prune the tile-count search before paying for a full strip DSE) and
//! the tiled latency estimate.

use crate::dataflow::design::Design;
use crate::resources::bram::bram_blocks;

use super::plan::TilePlan;

/// Control overhead charged per strip launch: draining the DATAFLOW
/// region, resetting line-buffer fill counters and re-arming the host
/// DMA. Line buffers and weight ROMs themselves stay resident — strips
/// reuse the same storage, which is the whole point of the uniform strip
/// width.
pub const TILE_RESTART_CYCLES: u64 = 64;

/// BRAM lower bound for running `d`'s workload on a width-`w_local`
/// strip: unpartitioned line buffers rescaled to the strip width — the
/// cheapest any DSE assignment can get. `full_w` is the feature-map
/// width `d` was built for.
pub fn strip_bram_lower_bound(d: &Design, full_w: usize, w_local: usize) -> u64 {
    d.nodes
        .iter()
        .filter_map(|n| n.geo.line_buffer.as_ref())
        .map(|lb| {
            let s = lb.at_width(full_w, w_local);
            s.rows as u64 * bram_blocks(s.row_len as u64 * s.elem_bits, 1)
        })
        .sum()
}

/// Total tiled-execution latency estimate: every strip pays the strip
/// design's overlapped estimate plus the restart overhead. Conservative:
/// no overlap between consecutive strips is assumed (the host gathers
/// strip `t+1` only after strip `t` drains).
pub fn tiled_cycles_estimate(plan: &TilePlan, strip: &Design) -> u64 {
    plan.tiles.len() as u64 * (strip.overlapped_cycles_estimate() + TILE_RESTART_CYCLES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::resources::bram::design_bram;
    use crate::tiling::plan::{retile_width, TilePlan};

    #[test]
    fn lower_bound_matches_scalar_strip_line_buffers() {
        // The fast bound (rescaled geometry) must equal the line-buffer
        // BRAM of an actually rebuilt scalar strip design.
        let g = models::cascade(256, 16, 16);
        let d = build_streaming_design(&g).unwrap();
        for w_local in [256usize, 130, 66] {
            let bound = strip_bram_lower_bound(&d, 256, w_local);
            let sd = build_streaming_design(&retile_width(&g, w_local).unwrap()).unwrap();
            let lb_bram: u64 = sd
                .nodes
                .iter()
                .filter_map(|n| n.geo.line_buffer.as_ref())
                .map(|lb| lb.rows as u64 * bram_blocks(lb.row_len as u64 * lb.elem_bits, 1))
                .sum();
            assert_eq!(bound, lb_bram, "width {w_local}");
            // and it is a true lower bound on the whole scalar design
            assert!(bound <= design_bram(&sd), "width {w_local}");
        }
    }

    #[test]
    fn lower_bound_shrinks_with_strip_width() {
        let g = models::conv_relu(512, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let full = strip_bram_lower_bound(&d, 512, 512);
        let half = strip_bram_lower_bound(&d, 512, 258);
        assert!(half < full, "strip line buffers must shrink: {half} vs {full}");
    }

    #[test]
    fn tiled_estimate_scales_with_tile_count() {
        let g = models::conv_relu(32, 8, 8);
        let p2 = TilePlan::build(&g, 2).unwrap();
        let p4 = TilePlan::build(&g, 4).unwrap();
        let s2 = build_streaming_design(&retile_width(&g, p2.local_width).unwrap()).unwrap();
        let s4 = build_streaming_design(&retile_width(&g, p4.local_width).unwrap()).unwrap();
        let e2 = tiled_cycles_estimate(&p2, &s2);
        let e4 = tiled_cycles_estimate(&p4, &s4);
        assert!(e2 > 0 && e4 > 0);
        // more, narrower strips process more total halo columns and pay
        // more restart overhead, so the estimate must grow with T
        assert!(e4 > e2, "e4 {e4} vs e2 {e2}");
    }
}
