//! Cost models for tiled execution: per-cell BRAM lower bounds (used to
//! prune the grid search before paying for a full cell DSE) and the
//! tiled latency estimate with gather/drain overlap.

use crate::analysis::shapes::tensor_tokens;
use crate::dataflow::design::Design;
use crate::dse::space::unroll_timings;
use crate::resources::model::ResourceModel;

use super::plan::TileGrid;

/// Control overhead charged per cell launch: draining the DATAFLOW
/// region, resetting line-buffer fill counters and re-arming the host
/// DMA. Line buffers and weight ROMs themselves stay resident — cells
/// reuse the same storage, which is the whole point of the uniform cell
/// extent.
pub const TILE_RESTART_CYCLES: u64 = 64;

/// BRAM lower bound for running `d`'s workload on a grid cell whose
/// per-tensor local extents are `local_ext` (as computed by
/// [`crate::tiling::plan::local_extents`] for the cell's input window):
/// the same unified [`ResourceModel`] the cell DSE will charge,
/// minimized per node over its unroll lattice — line buffers rescaled
/// to each node's *own* local input width (strided chains shrink
/// downstream widths by the cumulative stride), weight ROMs and FIFO
/// base depths unchanged, diamond depth floors dropped (they shrink
/// with the cell extent). Admissible: no cell assignment can use fewer
/// blocks, so pruning on this bound agrees with the solver's
/// feasibility verdict.
pub fn cell_bram_lower_bound(d: &Design, local_ext: &[Option<[usize; 2]>]) -> u64 {
    let model = ResourceModel::new(d);
    let nodes: u64 = (0..d.nodes.len())
        .map(|nid| {
            let op = &d.graph.ops[d.nodes[nid].op_index];
            let t = d.graph.tensor(op.inputs[0]);
            // rank-3 sliding/elementwise inputs rescale by their local
            // width; rank-2 (regular reduction) inputs have no width axis
            let full_w = t.ty.shape.get(1).copied().unwrap_or(1);
            let new_w = local_ext
                .get(t.id.0)
                .and_then(|e| e.map(|e| e[1]))
                .unwrap_or(full_w);
            unroll_timings(d, nid)
                .iter()
                .map(|tm| model.node_vec_at_width(nid, tm, full_w, new_w).bram())
                .min()
                .unwrap_or(0)
        })
        .sum();
    model.input_fifo_floor() + nodes
}

/// Host gather cost for one cell: the outer tile loop streams one input
/// token (pixel) per cycle into the cell's input window.
pub fn cell_gather_cycles(cell: &Design) -> u64 {
    let (tokens, _) = tensor_tokens(&cell.graph.inputs()[0].ty.shape);
    tokens
}

/// Serialized tiled latency (the pre-overlap model): every cell pays
/// its gather, its full execution, and the restart overhead back to
/// back — the host only gathers cell `t+1` after cell `t` drains.
pub fn serialized_tiled_cycles(grid: &TileGrid, cell: &Design) -> u64 {
    grid.n_cells() as u64
        * (cell_gather_cycles(cell) + cell.overlapped_cycles_estimate() + TILE_RESTART_CYCLES)
}

/// Overlapped tiled latency estimate: with a double-buffered input
/// window, the gather of cell `t+1` hides behind cell `t`'s execution
/// and drain, so only the first gather is exposed. Strictly below
/// [`serialized_tiled_cycles`] for any multi-cell grid.
pub fn tiled_cycles_estimate(grid: &TileGrid, cell: &Design) -> u64 {
    cell_gather_cycles(cell)
        + grid.n_cells() as u64 * (cell.overlapped_cycles_estimate() + TILE_RESTART_CYCLES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::{build_cell_design, build_streaming_design};
    use crate::dse::ilp::DseConfig;
    use crate::ir::builder::models;
    use crate::resources::bram::{bram_blocks, design_bram};
    use crate::resources::device::DeviceSpec;
    use crate::tiling::plan::{local_extents, TileGrid};
    use crate::tiling::schedule::compile_tiled_fixed;

    #[test]
    fn lower_bound_admissible_against_solved_cells() {
        // The bound must never exceed the BRAM of the actually solved
        // cell design for any grid the search would accept.
        let g = models::conv_relu(64, 8, 8);
        let base = build_streaming_design(&g).unwrap();
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for (rows, cols) in [(1usize, 2usize), (1, 4), (2, 2)] {
            let tc = compile_tiled_fixed(&g, &cfg, rows, cols).unwrap();
            let ext = local_extents(&g, tc.grid.h.local_in, tc.grid.w.local_in).unwrap();
            let bound = cell_bram_lower_bound(&base, &ext);
            assert!(
                bound <= design_bram(&tc.cell),
                "{rows}x{cols}: bound {bound} exceeds solved cell {}",
                design_bram(&tc.cell)
            );
        }
    }

    #[test]
    fn lower_bound_admissible_for_strided_chains() {
        // Strided chains shrink downstream widths by the cumulative
        // stride; the bound must track each node's own local width.
        let g = models::conv_pool_conv(64, 8);
        let base = build_streaming_design(&g).unwrap();
        let cfg = DseConfig::new(DeviceSpec::kv260());
        let tc = compile_tiled_fixed(&g, &cfg, 1, 2).unwrap();
        let ext = local_extents(&g, tc.grid.h.local_in, tc.grid.w.local_in).unwrap();
        let bound = cell_bram_lower_bound(&base, &ext);
        assert!(bound <= design_bram(&tc.cell), "{bound} > {}", design_bram(&tc.cell));
    }

    #[test]
    fn lower_bound_covers_at_least_unpartitioned_line_buffers() {
        // The unified bound subsumes a line-buffer-only bound: the
        // rescaled, partition-1 line buffers floor every node's vector.
        let g = models::cascade(256, 16, 16);
        let d = build_streaming_design(&g).unwrap();
        for w_local in [256usize, 130, 66] {
            let ext = local_extents(&g, 256, w_local).unwrap();
            let line_only: u64 = d
                .nodes
                .iter()
                .filter_map(|n| n.geo.line_buffer.as_ref())
                .map(|lb| {
                    let s = lb.at_width(256, w_local);
                    s.rows as u64 * bram_blocks(s.row_len as u64 * s.elem_bits, 1)
                })
                .sum();
            let bound = cell_bram_lower_bound(&d, &ext);
            assert!(bound >= line_only, "width {w_local}: {bound} < {line_only}");
            // and the rescale is exact: rebuilding the cell graph gives
            // the same line-buffer geometry the bound assumed
            let sd = build_cell_design(&g, 256, w_local).unwrap();
            let rebuilt: u64 = sd
                .nodes
                .iter()
                .filter_map(|n| n.geo.line_buffer.as_ref())
                .map(|lb| lb.rows as u64 * bram_blocks(lb.row_len as u64 * lb.elem_bits, 1))
                .sum();
            assert_eq!(line_only, rebuilt, "width {w_local}");
        }
    }

    #[test]
    fn lower_bound_shrinks_with_cell_width() {
        let g = models::conv_relu(512, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let full = cell_bram_lower_bound(&d, &local_extents(&g, 512, 512).unwrap());
        let half = cell_bram_lower_bound(&d, &local_extents(&g, 512, 258).unwrap());
        assert!(half < full, "cell line buffers must shrink: {half} vs {full}");
    }

    #[test]
    fn overlapped_estimate_beats_serialized_for_multi_cell_grids() {
        // The gather-overlap regression: hiding cell t+1's gather behind
        // cell t's drain must be strictly cheaper than serializing, for
        // any plan with more than one cell.
        let g = models::conv_relu(32, 8, 8);
        for (r, c) in [(1usize, 2usize), (2, 2), (1, 4)] {
            let grid = TileGrid::build(&g, r, c).unwrap();
            let cell = build_cell_design(&g, grid.h.local_in, grid.w.local_in).unwrap();
            let overlapped = tiled_cycles_estimate(&grid, &cell);
            let serialized = serialized_tiled_cycles(&grid, &cell);
            assert!(
                overlapped < serialized,
                "{r}x{c}: overlapped {overlapped} must beat serialized {serialized}"
            );
            // exactly (n_cells - 1) gathers are hidden
            assert_eq!(
                serialized - overlapped,
                (grid.n_cells() as u64 - 1) * cell_gather_cycles(&cell)
            );
        }
        // a single-cell grid has nothing to overlap
        let grid = TileGrid::build(&g, 1, 1).unwrap();
        let cell = build_cell_design(&g, 32, 32).unwrap();
        assert_eq!(tiled_cycles_estimate(&grid, &cell), serialized_tiled_cycles(&grid, &cell));
    }

    #[test]
    fn tiled_estimate_scales_with_cell_count() {
        let g = models::conv_relu(32, 8, 8);
        let g2 = TileGrid::build(&g, 1, 2).unwrap();
        let g4 = TileGrid::build(&g, 1, 4).unwrap();
        let s2 = build_cell_design(&g, g2.h.local_in, g2.w.local_in).unwrap();
        let s4 = build_cell_design(&g, g4.h.local_in, g4.w.local_in).unwrap();
        let e2 = tiled_cycles_estimate(&g2, &s2);
        let e4 = tiled_cycles_estimate(&g4, &s4);
        assert!(e2 > 0 && e4 > 0);
        // more, narrower cells process more total halo columns and pay
        // more restart overhead, so the estimate must grow with the count
        assert!(e4 > e2, "e4 {e4} vs e2 {e2}");
    }
}
