//! Cost models for tiled execution: per-strip BRAM lower bounds (used to
//! prune the tile-count search before paying for a full strip DSE) and
//! the tiled latency estimate.

use crate::dataflow::design::Design;
use crate::dse::space::unroll_timings;
use crate::resources::model::ResourceModel;

use super::plan::TilePlan;

/// Control overhead charged per strip launch: draining the DATAFLOW
/// region, resetting line-buffer fill counters and re-arming the host
/// DMA. Line buffers and weight ROMs themselves stay resident — strips
/// reuse the same storage, which is the whole point of the uniform strip
/// width.
pub const TILE_RESTART_CYCLES: u64 = 64;

/// BRAM lower bound for running `d`'s workload on a width-`w_local`
/// strip: the same unified [`ResourceModel`] the strip DSE will charge,
/// minimized per node over its unroll lattice — line buffers rescaled to
/// the strip width, weight ROMs and FIFO base depths unchanged, diamond
/// depth floors dropped (they shrink with width). `full_w` is the
/// feature-map width `d` was built for. Admissible: no strip assignment
/// can use fewer blocks, so pruning on this bound agrees with the
/// solver's feasibility verdict.
pub fn strip_bram_lower_bound(d: &Design, full_w: usize, w_local: usize) -> u64 {
    let model = ResourceModel::new(d);
    let nodes: u64 = (0..d.nodes.len())
        .map(|nid| {
            unroll_timings(d, nid)
                .iter()
                .map(|t| model.node_vec_at_width(nid, t, full_w, w_local).bram())
                .min()
                .unwrap_or(0)
        })
        .sum();
    model.input_fifo_floor() + nodes
}

/// Total tiled-execution latency estimate: every strip pays the strip
/// design's overlapped estimate plus the restart overhead. Conservative:
/// no overlap between consecutive strips is assumed (the host gathers
/// strip `t+1` only after strip `t` drains).
pub fn tiled_cycles_estimate(plan: &TilePlan, strip: &Design) -> u64 {
    plan.tiles.len() as u64 * (strip.overlapped_cycles_estimate() + TILE_RESTART_CYCLES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::dse::ilp::DseConfig;
    use crate::ir::builder::models;
    use crate::resources::bram::{bram_blocks, design_bram};
    use crate::resources::device::DeviceSpec;
    use crate::tiling::plan::{retile_width, TilePlan};
    use crate::tiling::schedule::compile_tiled_fixed;

    #[test]
    fn lower_bound_admissible_against_solved_strips() {
        // The bound must never exceed the BRAM of the actually solved
        // strip design for any tile count the search would accept.
        let g = models::conv_relu(64, 8, 8);
        let base = build_streaming_design(&g).unwrap();
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for n_tiles in [2usize, 4] {
            let tc = compile_tiled_fixed(&g, &cfg, n_tiles).unwrap();
            let bound = strip_bram_lower_bound(&base, 64, tc.plan.local_width);
            assert!(
                bound <= design_bram(&tc.strip),
                "T={n_tiles}: bound {bound} exceeds solved strip {}",
                design_bram(&tc.strip)
            );
        }
    }

    #[test]
    fn lower_bound_covers_at_least_unpartitioned_line_buffers() {
        // The unified bound subsumes the old line-buffer-only bound: the
        // rescaled, partition-1 line buffers are a floor on every node's
        // vector, so the new bound can only be tighter (larger).
        let g = models::cascade(256, 16, 16);
        let d = build_streaming_design(&g).unwrap();
        for w_local in [256usize, 130, 66] {
            let line_only: u64 = d
                .nodes
                .iter()
                .filter_map(|n| n.geo.line_buffer.as_ref())
                .map(|lb| {
                    let s = lb.at_width(256, w_local);
                    s.rows as u64 * bram_blocks(s.row_len as u64 * s.elem_bits, 1)
                })
                .sum();
            let bound = strip_bram_lower_bound(&d, 256, w_local);
            assert!(bound >= line_only, "width {w_local}: {bound} < {line_only}");
            // and the rescale is exact: rebuilding the strip graph gives
            // the same line-buffer geometry the bound assumed
            let sd = build_streaming_design(&retile_width(&g, w_local).unwrap()).unwrap();
            let rebuilt: u64 = sd
                .nodes
                .iter()
                .filter_map(|n| n.geo.line_buffer.as_ref())
                .map(|lb| lb.rows as u64 * bram_blocks(lb.row_len as u64 * lb.elem_bits, 1))
                .sum();
            assert_eq!(line_only, rebuilt, "width {w_local}");
        }
    }

    #[test]
    fn lower_bound_shrinks_with_strip_width() {
        let g = models::conv_relu(512, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let full = strip_bram_lower_bound(&d, 512, 512);
        let half = strip_bram_lower_bound(&d, 512, 258);
        assert!(half < full, "strip line buffers must shrink: {half} vs {full}");
    }

    #[test]
    fn tiled_estimate_scales_with_tile_count() {
        let g = models::conv_relu(32, 8, 8);
        let p2 = TilePlan::build(&g, 2).unwrap();
        let p4 = TilePlan::build(&g, 4).unwrap();
        let s2 = build_streaming_design(&retile_width(&g, p2.local_width).unwrap()).unwrap();
        let s4 = build_streaming_design(&retile_width(&g, p4.local_width).unwrap()).unwrap();
        let e2 = tiled_cycles_estimate(&p2, &s2);
        let e4 = tiled_cycles_estimate(&p4, &s4);
        assert!(e2 > 0 && e4 > 0);
        // more, narrower strips process more total halo columns and pay
        // more restart overhead, so the estimate must grow with T
        assert!(e4 > e2, "e4 {e4} vs e2 {e2}");
    }
}
