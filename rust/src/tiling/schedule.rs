//! The outer tile schedule: search for the cheapest feasible tile count,
//! compile one uniform strip design, and execute/stitch strips.
//!
//! [`compile_tiled`] is the feasibility fallback entry point: when the
//! untiled DSE has no feasible point (line buffers exceed the BRAM
//! budget even at minimal unroll), it walks the tile-count candidate
//! axis ([`crate::dse::space::tile_counts`]) from fewest strips upward,
//! prunes counts whose strip BRAM lower bound cannot fit, and accepts
//! the first tile count whose strip design solves the DSE *and* fits
//! the device BRAM budget end to end. Fewer strips means less halo
//! recompute and restart overhead, so the first hit is the best.
//!
//! [`simulate_tiled`] then runs the strip design once per tile over the
//! halo-overlapped input windows and stitches the cropped cores — the
//! result is bit-exact against the untiled design (and therefore against
//! the JAX/Pallas golden model).

use anyhow::{bail, ensure, Result};

use crate::dataflow::build::build_streaming_design;
use crate::dataflow::design::Design;
use crate::dse::ilp::{solve, DseConfig, DseSolution};
use crate::dse::space::tile_counts;
use crate::ir::graph::ModelGraph;
use crate::sim::{simulate, SimMode};

use super::cost::{strip_bram_lower_bound, tiled_cycles_estimate, TILE_RESTART_CYCLES};
use super::halo::{check_tilable, graph_halo};
use super::plan::TilePlan;

/// A width-tiled compilation: one DSE-solved strip design reused by
/// every tile of the plan.
#[derive(Debug, Clone)]
pub struct TiledCompilation {
    /// The original (untiled) model graph.
    pub graph: ModelGraph,
    pub plan: TilePlan,
    /// The solved uniform-width strip design.
    pub strip: Design,
    pub solution: DseSolution,
}

impl TiledCompilation {
    /// Conservative total latency estimate across all strips.
    pub fn estimated_cycles(&self) -> u64 {
        tiled_cycles_estimate(&self.plan, &self.strip)
    }

    pub fn describe(&self) -> String {
        let r = &self.solution.resources;
        format!(
            "{}\nstrip objective {} cycles, {} DSP / {} BRAM \
             ({} line + {} rom + {} fifo; unified resource model)",
            self.plan.describe(),
            self.solution.objective,
            self.solution.dsp_used,
            self.solution.bram_used,
            r.line_bram,
            r.weight_bram,
            r.fifo_bram
        )
    }
}

/// Compile `g` with a fixed tile count (no search). Used by tests, by
/// front-end tiling hints, and by the automatic search.
pub fn compile_tiled_fixed(
    g: &ModelGraph,
    cfg: &DseConfig,
    n_tiles: usize,
) -> Result<TiledCompilation> {
    let plan = TilePlan::build(g, n_tiles)?;
    let mut strip = crate::dataflow::build::build_strip_design(g, plan.local_width)?;
    let solution = solve(&mut strip, cfg)?;
    let report = crate::resources::estimate(&strip, &cfg.device);
    ensure!(
        report.bram18k <= cfg.device.bram18k,
        "strip width {}: estimated BRAM {} exceeds device budget {}",
        plan.local_width,
        report.bram18k,
        cfg.device.bram18k
    );
    Ok(TiledCompilation { graph: g.clone(), plan, strip, solution })
}

/// Feasibility fallback: find the smallest tile count whose strip design
/// fits the device, preferring a front-end [`crate::ir::graph::TilingHint`]
/// when the graph carries one.
pub fn compile_tiled(g: &ModelGraph, cfg: &DseConfig) -> Result<TiledCompilation> {
    let base = build_streaming_design(g)?;
    compile_tiled_from(g, &base, cfg)
}

/// Like [`compile_tiled`], reusing an already-built untiled design for
/// the strip BRAM lower bounds — `solve_with_tiling_fallback` hands in
/// the design whose DSE just failed instead of paying for the (large)
/// untiled build a second time.
pub fn compile_tiled_from(
    g: &ModelGraph,
    base: &Design,
    cfg: &DseConfig,
) -> Result<TiledCompilation> {
    let (_, width) = check_tilable(g)?;
    let halo = graph_halo(g)?;
    // The full device budget: the strip lower bound and the strip DSE
    // charge the same unified resource model (no FIFO reserve fudge).
    let budget = cfg.device.bram18k;

    let mut max_tiles = width as u64;
    let mut candidates: Vec<u64> = Vec::new();
    if let Some(hint) = &g.tiling {
        if let Some(cap) = hint.max_tiles {
            max_tiles = cap as u64;
        }
        if let Some(tw) = hint.tile_width {
            if tw > 0 && width % tw == 0 {
                candidates.push((width / tw) as u64);
            }
        }
    }
    candidates.extend(tile_counts(width as u64));
    candidates.retain(|&t| t <= max_tiles);

    let mut last_err = anyhow::anyhow!(
        "no tile count divides width {width} into strips that fit device {} \
         (halo {halo} per side)",
        cfg.device.name
    );
    let mut tried = std::collections::HashSet::new();
    for t in candidates {
        if !tried.insert(t) {
            continue;
        }
        let n_tiles = t as usize;
        let tile_width = width / n_tiles;
        let local_width = tile_width + 2 * halo;
        if local_width >= width {
            continue; // no narrower than the full map — tiling buys nothing
        }
        // cheap prune: the unified-model lower bound (rescaled line
        // buffers + weight ROMs + FIFO floors, minimized per node over
        // the unroll lattice) must fit before paying for a strip DSE
        if strip_bram_lower_bound(base, width, local_width) > budget {
            continue;
        }
        match compile_tiled_fixed(g, cfg, n_tiles) {
            Ok(tc) => return Ok(tc),
            Err(e) => last_err = e,
        }
    }
    Err(last_err.context(format!("width-tiling fallback failed for graph {}", g.name)))
}

/// Result of a tiled simulation.
#[derive(Debug)]
pub struct TiledSimReport {
    /// Total cycles across all strips (including restart overhead).
    pub cycles: u64,
    /// Stitched full-size output tensor (row-major `(H, W, F)`).
    pub output: Vec<i32>,
    /// Per-strip simulated cycle counts.
    pub tile_cycles: Vec<u64>,
}

/// Execute every strip of `tc` on the cycle-level simulator and stitch
/// the cropped cores into the full output feature map.
pub fn simulate_tiled(tc: &TiledCompilation, input: &[i32]) -> Result<TiledSimReport> {
    let g = &tc.graph;
    let plan = &tc.plan;
    let in_shape = &g.inputs()[0].ty.shape;
    ensure!(in_shape.len() == 3, "tiled input must be (H, W, C)");
    let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
    ensure!(w == plan.width && h == plan.height, "plan does not match graph shape");
    ensure!(
        input.len() == h * w * c,
        "input has {} values, graph expects {}",
        input.len(),
        h * w * c
    );
    let f = *g.outputs()[0].ty.shape.last().unwrap();
    let lw = plan.local_width;

    let mut output = vec![0i32; h * w * f];
    let mut tile_cycles = Vec::with_capacity(plan.tiles.len());
    let mut cycles = 0u64;
    for tile in &plan.tiles {
        // gather the halo-overlapped input window, row by row
        let mut strip_in = Vec::with_capacity(h * lw * c);
        for r in 0..h {
            let base = (r * w + tile.in_lo) * c;
            strip_in.extend_from_slice(&input[base..base + lw * c]);
        }
        let rep = simulate(&tc.strip, &strip_in, SimMode::of(tc.strip.style))?;
        if let Some(blocked) = &rep.deadlock {
            bail!("strip {} deadlocked:\n  {}", tile.index, blocked.join("\n  "));
        }
        // scatter the cropped core columns into the full output
        let crop = tile.crop_lo();
        let keep = tile.core_width();
        for r in 0..h {
            let src = (r * lw + crop) * f;
            let dst = (r * w + tile.out_lo) * f;
            output[dst..dst + keep * f].copy_from_slice(&rep.output[src..src + keep * f]);
        }
        cycles += rep.cycles + TILE_RESTART_CYCLES;
        tile_cycles.push(rep.cycles);
    }
    Ok(TiledSimReport { cycles, output, tile_cycles })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use crate::util::prng;

    fn det_input(g: &ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    fn untiled_output(g: &ModelGraph, x: &[i32]) -> Vec<i32> {
        let d = build_streaming_design(g).unwrap();
        simulate(&d, x, SimMode::Dataflow).unwrap().expect_complete().output
    }

    #[test]
    fn tiled_conv_relu_is_bit_exact() {
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for n_tiles in [2usize, 4, 8] {
            let tc = compile_tiled_fixed(&g, &cfg, n_tiles).unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "T={n_tiles} output mismatch");
            assert_eq!(rep.tile_cycles.len(), n_tiles);
            assert!(rep.cycles > 0);
        }
    }

    #[test]
    fn tiled_cascade_is_bit_exact() {
        let g = models::cascade(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 4).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn tiled_residual_diamond_is_bit_exact() {
        let g = models::residual(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn fallback_rescues_bram_starved_conv() {
        // Full-width: the cheapest assignment needs 4 line-buffer blocks
        // plus 1 weight-ROM block = 5 > 4 => untiled DSE is infeasible;
        // half-width strips halve the line buffers and fit in 4.
        let g = models::conv_relu(80, 32, 8);
        let dev = DeviceSpec::kv260().with_bram_limit(4);
        let cfg = DseConfig::new(dev.clone());
        let mut flat = build_streaming_design(&g).unwrap();
        assert!(solve(&mut flat, &cfg).is_err(), "untiled must be infeasible");

        let tc = compile_tiled(&g, &cfg).unwrap();
        assert!(tc.plan.tiles.len() >= 2);
        let r = crate::resources::estimate(&tc.strip, &dev);
        assert!(
            r.bram18k <= dev.bram18k,
            "strip BRAM {} must fit budget {}",
            r.bram18k,
            dev.bram18k
        );
        // and the tiled execution is still bit-exact
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn tiling_hint_is_preferred() {
        let mut g = models::conv_relu(32, 8, 8);
        g.tiling = Some(crate::ir::graph::TilingHint {
            tile_width: Some(8),
            max_tiles: None,
        });
        let tc = compile_tiled(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        assert_eq!(tc.plan.tiles.len(), 4);
        assert_eq!(tc.plan.tile_width, 8);
    }

    #[test]
    fn untilable_graphs_report_cleanly() {
        let g = models::linear();
        let err = compile_tiled(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap_err();
        assert!(format!("{err:#}").contains("width"), "{err:#}");
    }

    #[test]
    fn oversized_vgg_block_compiles_only_tiled_on_kv260() {
        // The headline scenario: three 3x3 conv layers at 256 channels on
        // a 512x512 input. Untiled, the minimal line buffers alone need
        // ~342 BRAM18K > the KV260's 288; width-tiling turns the hard
        // infeasibility into a latency/resource trade-off. (Estimate
        // only — 4.6e12 MACs are not simulated here.)
        let g = models::vgg_block(512, 256, 3);
        let dev = DeviceSpec::kv260();
        let cfg = DseConfig::new(dev.clone());
        let mut flat = build_streaming_design(&g).unwrap();
        let err = solve(&mut flat, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("infeasible"), "{err:#}");

        let tc = compile_tiled(&g, &cfg).unwrap();
        assert!(tc.plan.tiles.len() >= 2);
        assert_eq!(tc.plan.halo, 3);
        let r = crate::resources::estimate(&tc.strip, &dev);
        assert!(
            r.bram18k <= dev.bram18k,
            "tiled BRAM {} must fit the stock KV260 ({})",
            r.bram18k,
            dev.bram18k
        );
        assert!(tc.estimated_cycles() > 0);
    }
}
