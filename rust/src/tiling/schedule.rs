//! The outer tile schedule: search the (rows × cols) grid lattice for
//! the cheapest feasible cell count, compile one uniform cell design,
//! and execute/stitch cells.
//!
//! [`compile_tiled`] is the feasibility fallback entry point: when the
//! untiled DSE has no feasible point (line buffers exceed the BRAM
//! budget even at minimal unroll), it walks the grid candidate lattice
//! ([`crate::dse::space::grid_counts`]) from fewest cells upward,
//! prunes grids whose cell BRAM lower bound cannot fit, and accepts the
//! first grid whose cell design solves the DSE *and* fits the device
//! BRAM budget end to end. Fewer cells means less halo recompute and
//! restart overhead, so the first hit is the best; among equal cell
//! counts, width-major splits come first (narrower cells shrink line
//! buffers, the dominant BRAM term). With `DseConfig::workers > 1` the
//! candidates surviving the cheap prunes are cell-solved
//! **speculatively in parallel** ([`speculative_grid_search`]); the
//! committed grid is provably the one the serial walk would pick, so
//! the two paths are interchangeable byte for byte.
//!
//! [`simulate_tiled`] then runs the cell design once per grid cell over
//! the halo-overlapped 2-D input windows and stitches the cropped cores
//! — the result is bit-exact against the untiled design (and therefore
//! against the JAX/Pallas golden model), strided and pooled chains
//! included: the stride-aware coordinate remap of
//! [`crate::tiling::plan::TileGrid`] keeps every cell's local output
//! lattice aligned with the global one.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::sched::{self, SchedHandle};
use crate::dataflow::build::{build_cell_design, build_streaming_design};
use crate::dataflow::design::Design;
use crate::dse::ilp::{DseConfig, DseSolution};
use crate::dse::space::grid_counts;
use crate::ir::graph::ModelGraph;
use crate::sim::SimMode;

use super::cost::{cell_bram_lower_bound, tiled_cycles_estimate, TILE_RESTART_CYCLES};
use super::halo::{check_tilable, AXIS_H, AXIS_W};
use super::plan::{local_extents, Seg, TileGrid};

/// A grid-tiled compilation: one DSE-solved cell design reused by every
/// cell of the grid.
#[derive(Debug, Clone)]
pub struct TiledCompilation {
    /// The original (untiled) model graph.
    pub graph: ModelGraph,
    pub grid: TileGrid,
    /// The solved uniform-extent cell design.
    pub cell: Design,
    pub solution: DseSolution,
}

impl TiledCompilation {
    /// Total latency estimate across all cells, with cell `t+1`'s
    /// gather overlapped against cell `t`'s drain
    /// ([`crate::tiling::cost::tiled_cycles_estimate`]).
    pub fn estimated_cycles(&self) -> u64 {
        tiled_cycles_estimate(&self.grid, &self.cell)
    }

    pub fn describe(&self) -> String {
        let r = &self.solution.resources;
        format!(
            "{}\ncell objective {} cycles, {} DSP / {} BRAM \
             ({} line + {} rom + {} fifo; unified resource model)",
            self.grid.describe(),
            self.solution.objective,
            self.solution.dsp_used,
            self.solution.bram_used,
            r.line_bram,
            r.weight_bram,
            r.fifo_bram
        )
    }
}

/// Compile `g` with a fixed `rows × cols` grid (no search). Used by
/// tests and by external callers with a known split.
pub fn compile_tiled_fixed(
    g: &ModelGraph,
    cfg: &DseConfig,
    rows: usize,
    cols: usize,
) -> Result<TiledCompilation> {
    compile_tiled_with_grid(g, cfg, TileGrid::build(g, rows, cols)?)
}

/// Compile `g` for an already-planned grid — the search loop builds each
/// candidate grid once (for the shrink check and the BRAM lower bound)
/// and hands it straight in instead of re-deriving it.
///
/// The cell DSE goes through [`crate::coordinator::cache::solve_cached`]:
/// when the config carries a design cache, a cell geometry that was
/// already solved — by an earlier grid candidate of this search, by a
/// previous workload sharing the chain shape, or by another process —
/// is applied instead of re-solved.
fn compile_tiled_with_grid(
    g: &ModelGraph,
    cfg: &DseConfig,
    grid: TileGrid,
) -> Result<TiledCompilation> {
    Ok(compile_tiled_with_grid_cancellable(g, cfg, grid, &|| false)?
        .expect("uncancellable grid compile returned None"))
}

/// [`compile_tiled_with_grid`] with cooperative cancellation for the
/// speculative grid search: `cancelled` is probed at the stage
/// boundaries (before the cell DSE and before the estimate check), and
/// a `true` answer abandons the candidate with `Ok(None)`. The probes
/// never interrupt a stage mid-flight, so any candidate that runs to
/// completion produces exactly what the serial search would have.
fn compile_tiled_with_grid_cancellable(
    g: &ModelGraph,
    cfg: &DseConfig,
    grid: TileGrid,
    cancelled: &dyn Fn() -> bool,
) -> Result<Option<TiledCompilation>> {
    let mut cell = build_cell_design(g, grid.h.local_in, grid.w.local_in)?;
    // the planner's affine local-output prediction must match the cell
    // graph's actual forward shape propagation
    {
        let out = &cell.graph.outputs()[0].ty.shape;
        ensure!(
            out[0] == grid.h.local_out && out[1] == grid.w.local_out,
            "cell graph produces {}x{} but the grid planned {}x{}",
            out[0],
            out[1],
            grid.h.local_out,
            grid.w.local_out
        );
    }
    if cancelled() {
        return Ok(None);
    }
    let _sp = crate::obs::span_with("cell_solve", || {
        format!("cell {}x{} ({})", grid.h.local_in, grid.w.local_in, g.name)
    });
    let solution = crate::coordinator::cache::solve_cached(&mut cell, cfg)?;
    if cancelled() {
        return Ok(None);
    }
    let report = crate::resources::estimate(&cell, &cfg.device);
    ensure!(
        report.bram18k <= cfg.device.bram18k,
        "cell {}x{}: estimated BRAM {} exceeds device budget {}",
        grid.h.local_in,
        grid.w.local_in,
        report.bram18k,
        cfg.device.bram18k
    );
    Ok(Some(TiledCompilation { graph: g.clone(), grid, cell, solution }))
}

/// Why one grid candidate was rejected, and at which funnel stage
/// (`plan`, `no-shrink`, `bram-lower-bound`, or `cell-compile`).
#[derive(Debug, Clone)]
pub struct GridRejection {
    pub rows: u64,
    pub cols: u64,
    pub stage: &'static str,
    pub reason: String,
}

/// Cap on stored per-candidate details — large output lattices can
/// reject hundreds of grids, and triage only needs the leading edge of
/// the funnel plus the total count.
const MAX_REJECTION_DETAILS: usize = 12;

/// Bounded per-candidate rejection summary for one grid search. Every
/// rejection bumps `tiling.candidates_rejected` and the total; only the
/// first [`MAX_REJECTION_DETAILS`] keep their full (grid, stage,
/// reason) triple. Rendered under `--profile` and appended to the
/// all-candidates-failed error so infeasible-workload triage does not
/// require a re-run with tracing enabled.
#[derive(Debug, Default)]
struct RejectionLog {
    details: Vec<GridRejection>,
    total: u64,
}

impl RejectionLog {
    fn push(&mut self, rows: u64, cols: u64, stage: &'static str, reason: String) {
        crate::obs::metrics::global().incr("tiling.candidates_rejected");
        self.total += 1;
        if self.details.len() < MAX_REJECTION_DETAILS {
            self.details.push(GridRejection { rows, cols, stage, reason });
        }
    }

    fn render(&self, graph: &str) -> String {
        let mut out = format!("grid search rejected {} candidate(s) for {graph}:", self.total);
        for d in &self.details {
            out.push_str(&format!("\n  {}x{} [{}] {}", d.rows, d.cols, d.stage, d.reason));
        }
        let shown = self.details.len() as u64;
        if self.total > shown {
            out.push_str(&format!("\n  ... and {} more", self.total - shown));
        }
        out
    }
}

/// Feasibility fallback: find the smallest grid whose cell design fits
/// the device, preferring a front-end [`crate::ir::graph::TilingHint`]
/// when the graph carries one.
pub fn compile_tiled(g: &ModelGraph, cfg: &DseConfig) -> Result<TiledCompilation> {
    let base = build_streaming_design(g)?;
    compile_tiled_from(g, &base, cfg)
}

/// Like [`compile_tiled`], reusing an already-built untiled design for
/// the cell BRAM lower bounds — `solve_with_tiling_fallback` hands in
/// the design whose DSE just failed instead of paying for the (large)
/// untiled build a second time.
pub fn compile_tiled_from(
    g: &ModelGraph,
    base: &Design,
    cfg: &DseConfig,
) -> Result<TiledCompilation> {
    let geom = check_tilable(g)?;
    let (out_h, out_w) = (geom.out_extent[AXIS_H], geom.out_extent[AXIS_W]);
    // The full device budget: the cell lower bound and the cell DSE
    // charge the same unified resource model (no FIFO reserve fudge).
    let budget = cfg.device.bram18k;

    let mut max_cells = (out_h as u64) * (out_w as u64);
    let mut candidates: Vec<(u64, u64)> = Vec::new();
    if let Some(hint) = &g.tiling {
        if let Some(cap) = hint.max_tiles {
            max_cells = cap as u64;
        }
        let rows = match hint.tile_height {
            Some(th) if th > 0 && out_h % th == 0 => Some((out_h / th) as u64),
            Some(_) => None, // non-dividing hint: fall through to the search
            None => Some(1),
        };
        let cols = match hint.tile_width {
            Some(tw) if tw > 0 && out_w % tw == 0 => Some((out_w / tw) as u64),
            Some(_) => None,
            None => Some(1),
        };
        if let (Some(r), Some(c)) = (rows, cols) {
            if r * c > 1 {
                candidates.push((r, c));
            }
        }
    }
    candidates.extend(grid_counts(out_h as u64, out_w as u64));
    candidates.retain(|&(r, c)| r * c <= max_cells);

    let mut last_err = anyhow::anyhow!(
        "no grid divides the {out_h}x{out_w} output into cells that fit device {} \
         (input cone h -{}/+{}, w -{}/+{})",
        cfg.device.name,
        geom.cone[AXIS_H].lo,
        geom.cone[AXIS_H].hi,
        geom.cone[AXIS_W].lo,
        geom.cone[AXIS_W].hi
    );
    let metrics = crate::obs::metrics::global();
    let _sp = crate::obs::span_with("grid_search", || g.name.clone());
    let mut rejections = RejectionLog::default();
    let mut tried = std::collections::HashSet::new();
    // Phase 1 — the cheap serial funnel: plan each candidate grid once
    // and run the free prunes, in fewest-cells order. Survivors are the
    // (ordered) grids worth a cell DSE.
    let mut survivors: Vec<TileGrid> = Vec::new();
    for (r, c) in candidates {
        if !tried.insert((r, c)) {
            continue;
        }
        metrics.incr("tiling.candidates_tried");
        let grid = match TileGrid::build(g, r as usize, c as usize) {
            Ok(grid) => grid,
            Err(e) => {
                rejections.push(r, c, "plan", format!("{e:#}"));
                last_err = e;
                continue;
            }
        };
        // every split axis must actually shrink its local extent,
        // otherwise the grid only adds halo recompute
        if (grid.rows() > 1 && !grid.h.shrinks()) || (grid.cols() > 1 && !grid.w.shrinks()) {
            rejections.push(r, c, "no-shrink", "split axis does not shrink local extent".into());
            continue;
        }
        // cheap prune: the unified-model lower bound (line buffers
        // rescaled to each node's local width, weight ROMs + FIFO
        // floors, minimized per node over the unroll lattice) must fit
        // before paying for a cell DSE
        let ext = local_extents(g, grid.h.local_in, grid.w.local_in)?;
        let lb = cell_bram_lower_bound(base, &ext);
        if lb > budget {
            let reason = format!("cell BRAM lower bound {lb} exceeds budget {budget}");
            rejections.push(r, c, "bram-lower-bound", reason);
            continue;
        }
        survivors.push(grid);
    }

    // Phase 2 — cell DSE over the survivors: speculative fan-out when
    // the config has workers to spare, the plain serial walk otherwise
    // (or when only one candidate survived the funnel).
    let winner = if cfg.workers > 1 && survivors.len() > 1 {
        speculative_grid_search(g, cfg, survivors, &mut rejections, &mut last_err)
    } else {
        serial_grid_search(g, cfg, survivors, &mut rejections, &mut last_err)
    };
    if crate::obs::trace::global().is_profiling() && rejections.total > 0 {
        eprintln!("{}", rejections.render(&g.name));
    }
    match winner {
        Some(tc) => {
            metrics.incr("tiling.grids_accepted");
            Ok(tc)
        }
        None => {
            let err = if rejections.total > 0 {
                last_err.context(rejections.render(&g.name))
            } else {
                last_err
            };
            Err(err.context(format!("tile-grid fallback failed for graph {}", g.name)))
        }
    }
}

/// Walk the surviving grids in fewest-cells order and commit the first
/// whose cell design solves and fits — the original (and reference)
/// search semantics.
fn serial_grid_search(
    g: &ModelGraph,
    cfg: &DseConfig,
    survivors: Vec<TileGrid>,
    rejections: &mut RejectionLog,
    last_err: &mut anyhow::Error,
) -> Option<TiledCompilation> {
    for grid in survivors {
        let (r, c) = (grid.rows() as u64, grid.cols() as u64);
        match compile_tiled_with_grid(g, cfg, grid) {
            Ok(tc) => return Some(tc),
            Err(e) => {
                rejections.push(r, c, "cell-compile", format!("{e:#}"));
                *last_err = e;
            }
        }
    }
    None
}

/// Evaluate the surviving grids concurrently but commit the **first
/// acceptable grid in the existing fewest-cells order** — exactly what
/// [`serial_grid_search`] returns.
///
/// Protocol: jobs share a `committed` cell holding the lowest
/// successful candidate index (`usize::MAX` until someone succeeds). A
/// job observing a smaller committed index abandons its grid (at start
/// or at a [`compile_tiled_with_grid_cancellable`] stage boundary); a
/// success publishes its own index with `fetch_min`. Determinism: the
/// winner is the minimum-index success, and every candidate ranked
/// below it can never observe a smaller committed index — so each ran
/// to completion and failed for real, exactly as the serial walk would
/// have. Their failures land in the rejection log; later-ranked
/// completions are counted as `tiling.speculative_wasted` and
/// abandoned ones as `tiling.speculative_cancelled`.
///
/// Per-cell solves still dedupe through the design cache (same
/// fingerprints as the serial path), and nested cell DSE keeps its
/// configured parallelism: every level submits into the same
/// work-stealing scheduler, so a wide cell solve becomes stealable
/// subtree tasks instead of oversubscribed threads — idle workers here
/// drain a straggler grid's solves rather than spinning.
///
/// Warm-start state ([`crate::dse::WarmStart`] in `cfg.warm`) rides
/// into every cell solve through the `cfg.clone()` below: grid
/// candidates of one search probe dozens of cell geometries whose node
/// fronts recur across grids (and whose shapes are identical, so each
/// solved cell seeds the next grid's incumbent) — the highest-leverage
/// consumer of cross-problem reuse, and still bit-identical because
/// both warm tiers are solution-invariant.
fn speculative_grid_search(
    g: &ModelGraph,
    cfg: &DseConfig,
    survivors: Vec<TileGrid>,
    rejections: &mut RejectionLog,
    last_err: &mut anyhow::Error,
) -> Option<TiledCompilation> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let metrics = crate::obs::metrics::global();
    let dims: Vec<(u64, u64)> =
        survivors.iter().map(|gr| (gr.rows() as u64, gr.cols() as u64)).collect();
    let committed = AtomicUsize::new(usize::MAX);
    let committed_ref = &committed;
    let cell_cfg_ref = cfg;
    let jobs: Vec<_> = survivors
        .into_iter()
        .enumerate()
        .map(|(i, grid)| {
            move || -> Result<Option<TiledCompilation>> {
                let _sp = crate::obs::span_with("grid_try", || {
                    format!("grid {}x{} ({})", grid.rows(), grid.cols(), g.name)
                });
                if committed_ref.load(Ordering::Relaxed) < i {
                    return Ok(None);
                }
                let cancelled = || committed_ref.load(Ordering::Relaxed) < i;
                let out = compile_tiled_with_grid_cancellable(g, cell_cfg_ref, grid, &cancelled)?;
                if out.is_some() {
                    committed_ref.fetch_min(i, Ordering::Relaxed);
                }
                Ok(out)
            }
        })
        .collect();
    let results = sched::current_or_global().run_all_scoped(jobs, |_, _| {});
    let mut winner: Option<TiledCompilation> = None;
    for (idx, r) in results {
        let (rows, cols) = dims[idx];
        match r.map_err(anyhow::Error::msg).and_then(|inner| inner) {
            Ok(Some(tc)) => {
                if winner.is_none() {
                    winner = Some(tc);
                } else {
                    metrics.incr("tiling.speculative_wasted");
                }
            }
            Ok(None) => {
                metrics.incr("tiling.speculative_cancelled");
            }
            Err(e) => {
                if winner.is_none() {
                    rejections.push(rows, cols, "cell-compile", format!("{e:#}"));
                    *last_err = e;
                } else {
                    metrics.incr("tiling.speculative_wasted");
                }
            }
        }
    }
    winner
}

/// Result of a tiled simulation.
#[derive(Debug)]
pub struct TiledSimReport {
    /// Total cycles across all cells (including restart overhead).
    pub cycles: u64,
    /// Stitched full-size output tensor (row-major `(H_out, W_out, F)`).
    pub output: Vec<i32>,
    /// Per-cell simulated cycle counts (row-major over the grid).
    pub tile_cycles: Vec<u64>,
    /// Total node firings summed over all cell runs (simulator
    /// throughput metric, mirrors `SimReport::total_firings`).
    pub total_firings: u64,
    /// Total FIFO pushes + pops summed over all cell runs.
    pub token_ops: u64,
    /// How many `SimContext`s were built for this run — 1 on the serial
    /// path; at most the worker count on the parallel path, where the
    /// shared context pool reuses them across chunks (the pool-proof
    /// metric, mirrored to `sim.ctx_builds`).
    pub ctx_builds: u64,
    /// Steady-state fast-forward statistics summed over all cell runs.
    pub ff: crate::sim::FfStats,
}

impl TiledSimReport {
    /// Repackage as a plain [`crate::sim::SimReport`] so sweep results
    /// keep output parity between flat and tiled cells: per-node traces
    /// and FIFO high-water marks are per-cell quantities with no
    /// meaningful whole-grid stitching, so they stay empty.
    pub fn into_sim_report(self) -> crate::sim::SimReport {
        crate::sim::SimReport {
            cycles: self.cycles,
            output: self.output,
            traces: Vec::new(),
            fifo_high_water: Vec::new(),
            deadlock: None,
            total_firings: self.total_firings,
            token_ops: self.token_ops,
            fifo_profile: None,
            ff: self.ff,
        }
    }
}

/// Checked geometry of one tiled run, shared by the serial and parallel
/// execution paths.
struct TiledGeometry {
    w_in: usize,
    c: usize,
    w_out: usize,
    f: usize,
    /// Local input extents (halo included).
    lh: usize,
    lw: usize,
    /// Local output width of the cell design.
    low: usize,
    out_len: usize,
}

fn tiled_geometry(tc: &TiledCompilation, input: &[i32]) -> Result<TiledGeometry> {
    let g = &tc.graph;
    let grid = &tc.grid;
    let in_shape = &g.inputs()[0].ty.shape;
    ensure!(in_shape.len() == 3, "tiled input must be (H, W, C)");
    let (h_in, w_in, c) = (in_shape[0], in_shape[1], in_shape[2]);
    ensure!(
        h_in == grid.h.in_extent && w_in == grid.w.in_extent,
        "grid does not match graph shape"
    );
    ensure!(
        input.len() == h_in * w_in * c,
        "input has {} values, graph expects {}",
        input.len(),
        h_in * w_in * c
    );
    let out_shape = &g.outputs()[0].ty.shape;
    let (h_out, w_out, f) = (out_shape[0], out_shape[1], out_shape[2]);
    Ok(TiledGeometry {
        w_in,
        c,
        w_out,
        f,
        lh: grid.h.local_in,
        lw: grid.w.local_in,
        low: grid.w.local_out,
        out_len: h_out * w_out * f,
    })
}

/// Gather one cell's halo-overlapped 2-D input window into `buf`
/// (cleared first; capacity is reused across cells).
fn gather_cell(
    input: &[i32],
    geo: &TiledGeometry,
    rs: &Seg,
    cs: &Seg,
    buf: &mut Vec<i32>,
) {
    buf.clear();
    for r in 0..geo.lh {
        let base = ((rs.in_lo + r) * geo.w_in + cs.in_lo) * geo.c;
        buf.extend_from_slice(&input[base..base + geo.lw * geo.c]);
    }
}

/// What one cell run contributes to the stitched report.
struct CellRun {
    cycles: u64,
    firings: u64,
    token_ops: u64,
    ff: crate::sim::FfStats,
    /// The cropped core block, `h.core` rows of `w.core * f` values.
    core: Vec<i32>,
}

/// Run one cell on a (reusable) context and crop its core block.
fn run_cell(
    ctx: &mut crate::sim::SimContext<'_>,
    tc: &TiledCompilation,
    geo: &TiledGeometry,
    input: &[i32],
    rs: &Seg,
    cs: &Seg,
    cell_in: &mut Vec<i32>,
) -> Result<CellRun> {
    let grid = &tc.grid;
    let _sp = crate::obs::span_with("sim_cell", || format!("cell r{} c{}", rs.index, cs.index));
    gather_cell(input, geo, rs, cs, cell_in);
    let rep = ctx.run(cell_in)?;
    if let Some(blocked) = &rep.deadlock {
        bail!(
            "cell ({}, {}) deadlocked:\n  {}",
            rs.index,
            cs.index,
            blocked.join("\n  ")
        );
    }
    let mut core = Vec::with_capacity(grid.h.core * grid.w.core * geo.f);
    for r in 0..grid.h.core {
        let src = ((rs.crop_lo + r) * geo.low + cs.crop_lo) * geo.f;
        core.extend_from_slice(&rep.output[src..src + grid.w.core * geo.f]);
    }
    Ok(CellRun {
        cycles: rep.cycles,
        firings: rep.total_firings,
        token_ops: rep.token_ops,
        ff: rep.ff,
        core,
    })
}

/// Stitch per-cell results (in row-major cell order) into the report.
fn stitch(
    tc: &TiledCompilation,
    geo: &TiledGeometry,
    runs: Vec<CellRun>,
    ctx_builds: u64,
) -> TiledSimReport {
    let grid = &tc.grid;
    let mut output = vec![0i32; geo.out_len];
    let mut tile_cycles = Vec::with_capacity(grid.n_cells());
    let (mut cycles, mut total_firings, mut token_ops) = (0u64, 0u64, 0u64);
    let mut ff = crate::sim::FfStats::default();
    let mut it = runs.into_iter();
    for rs in &grid.h.segs {
        for cs in &grid.w.segs {
            let run = it.next().expect("one run per cell");
            for r in 0..grid.h.core {
                let src = r * grid.w.core * geo.f;
                let dst = ((rs.out_lo + r) * geo.w_out + cs.out_lo) * geo.f;
                output[dst..dst + grid.w.core * geo.f]
                    .copy_from_slice(&run.core[src..src + grid.w.core * geo.f]);
            }
            cycles += run.cycles + TILE_RESTART_CYCLES;
            total_firings += run.firings;
            token_ops += run.token_ops;
            ff.periods += run.ff.periods;
            ff.skipped_cycles += run.ff.skipped_cycles;
            ff.batched_firings += run.ff.batched_firings;
            ff.checkpoints += run.ff.checkpoints;
            tile_cycles.push(run.cycles);
        }
    }
    crate::obs::metrics::global().add("sim.ctx_builds", ctx_builds);
    TiledSimReport { cycles, output, tile_cycles, total_firings, token_ops, ctx_builds, ff }
}

/// Execute every cell of `tc` on the cycle-level simulator and stitch
/// the cropped cores into the full output feature map.
///
/// Serial path: one [`crate::sim::SimContext`] is built for the cell
/// design and reused for every cell, so weights are transposed and
/// line-buffer state allocated **once per design** instead of once per
/// cell. For multi-core execution see [`simulate_tiled_parallel`].
pub fn simulate_tiled(tc: &TiledCompilation, input: &[i32]) -> Result<TiledSimReport> {
    simulate_tiled_with(tc, input, crate::sim::SimConfig::default())
}

/// [`simulate_tiled`] with explicit fast-path knobs (`--exact-sim`
/// forces [`crate::sim::SimConfig::exact`]).
pub fn simulate_tiled_with(
    tc: &TiledCompilation,
    input: &[i32],
    cfg: crate::sim::SimConfig,
) -> Result<TiledSimReport> {
    let geo = tiled_geometry(tc, input)?;
    let grid = &tc.grid;
    let mut ctx = crate::sim::SimContext::new(&tc.cell, SimMode::of(tc.cell.style))?;
    ctx.set_config(cfg);
    let mut cell_in = Vec::with_capacity(geo.lh * geo.lw * geo.c);
    let mut runs = Vec::with_capacity(grid.n_cells());
    for rs in &grid.h.segs {
        for cs in &grid.w.segs {
            runs.push(run_cell(&mut ctx, tc, &geo, input, rs, cs, &mut cell_in)?);
        }
    }
    Ok(stitch(tc, &geo, runs, 1))
}

/// Like [`simulate_tiled`], fanning the independent grid cells out as a
/// task group on `sched`'s workers. Cells are split into small
/// contiguous row-major chunks (several per worker, for load balance);
/// chunk jobs draw a `SimContext` from a **shared context pool** —
/// pop-or-build on entry, return on exit — so weights are transposed at
/// most once per concurrently-active worker no matter how many chunks
/// run ([`TiledSimReport::ctx_builds`] counts the builds, proving
/// reuse). Cropped cores are stitched in deterministic cell order — the
/// report is identical to the serial path's, cycle counts included
/// (asserted by the equivalence tests and the `BENCH_sim.json` smoke
/// check).
pub fn simulate_tiled_parallel(
    tc: &TiledCompilation,
    input: &[i32],
    sched: &SchedHandle,
) -> Result<TiledSimReport> {
    simulate_tiled_parallel_with(tc, input, sched, crate::sim::SimConfig::default())
}

/// [`simulate_tiled_parallel`] with explicit fast-path knobs.
pub fn simulate_tiled_parallel_with(
    tc: &TiledCompilation,
    input: &[i32],
    sched: &SchedHandle,
    cfg: crate::sim::SimConfig,
) -> Result<TiledSimReport> {
    use std::sync::atomic::{AtomicU64, Ordering};

    let geo = tiled_geometry(tc, input)?;
    let grid = &tc.grid;
    let cells: Vec<(&Seg, &Seg)> = grid
        .h
        .segs
        .iter()
        .flat_map(|rs| grid.w.segs.iter().map(move |cs| (rs, cs)))
        .collect();
    if sched.workers() <= 1 || cells.len() <= 1 {
        return simulate_tiled_with(tc, input, cfg);
    }
    // ~4 chunks per worker: fine-grained enough that a slow chunk does
    // not straggle, and the context pool makes extra chunks free.
    let chunk = cells.len().div_ceil(sched.workers() * 4).max(1);
    let geo_ref = &geo;
    // one weight extraction + transposition for the whole pool: every
    // worker context shares the bank's Arc'd storage
    let bank = crate::sim::WeightBank::build(&tc.cell)?;
    let ctx_pool: std::sync::Mutex<Vec<crate::sim::SimContext<'_>>> =
        std::sync::Mutex::new(Vec::new());
    let ctx_builds = AtomicU64::new(0);
    let jobs: Vec<_> = cells
        .chunks(chunk)
        .map(|chunk_cells| {
            let ctx_pool = &ctx_pool;
            let ctx_builds = &ctx_builds;
            let bank = &bank;
            move || -> Result<Vec<CellRun>> {
                let pooled = ctx_pool.lock().unwrap().pop();
                let mut ctx = match pooled {
                    Some(ctx) => ctx,
                    None => {
                        ctx_builds.fetch_add(1, Ordering::Relaxed);
                        let mut ctx = crate::sim::SimContext::with_bank(
                            &tc.cell,
                            SimMode::of(tc.cell.style),
                            bank,
                        )?;
                        ctx.set_config(cfg);
                        ctx
                    }
                };
                let mut cell_in = Vec::with_capacity(geo_ref.lh * geo_ref.lw * geo_ref.c);
                let runs: Result<Vec<CellRun>> = chunk_cells
                    .iter()
                    .map(|(rs, cs)| {
                        run_cell(&mut ctx, tc, geo_ref, input, rs, cs, &mut cell_in)
                    })
                    .collect();
                ctx_pool.lock().unwrap().push(ctx);
                runs
            }
        })
        .collect();
    let results = sched.run_all_scoped(jobs, |_, _| {});
    let mut runs = Vec::with_capacity(cells.len());
    for (idx, r) in results {
        let chunk_runs = r
            .map_err(anyhow::Error::msg)
            .and_then(|inner| inner)
            .with_context(|| format!("tiled simulation chunk {idx} failed"))?;
        runs.extend(chunk_runs);
    }
    ensure!(runs.len() == cells.len(), "cell runs lost in the pool");
    Ok(stitch(tc, &geo, runs, ctx_builds.load(Ordering::Relaxed)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::ilp::solve;
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use crate::sim::simulate;
    use crate::util::prng;

    fn det_input(g: &ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    fn untiled_output(g: &ModelGraph, x: &[i32]) -> Vec<i32> {
        let d = build_streaming_design(g).unwrap();
        simulate(&d, x, SimMode::Dataflow).unwrap().expect_complete().output
    }

    #[test]
    fn tiled_conv_relu_is_bit_exact() {
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for (rows, cols) in [(1usize, 2usize), (1, 4), (2, 1), (2, 2), (4, 4)] {
            let tc = compile_tiled_fixed(&g, &cfg, rows, cols).unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "{rows}x{cols} output mismatch");
            assert_eq!(rep.tile_cycles.len(), rows * cols);
            assert!(rep.cycles > 0);
        }
    }

    #[test]
    fn tiled_cascade_is_bit_exact() {
        let g = models::cascade(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2, 4).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn tiled_residual_diamond_is_bit_exact() {
        let g = models::residual(32, 8, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 1, 2).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn tiled_strided_pooled_chain_is_bit_exact() {
        // The stride-aware remap end to end: conv -> 2x2 pool -> conv,
        // where cell output lattices must stay aligned with the global
        // stride lattice and pool windows must never straddle a seam.
        let g = models::conv_pool_conv(64, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for (rows, cols) in [(1usize, 2usize), (2, 1), (2, 2), (1, 4)] {
            let tc = compile_tiled_fixed(&g, &cfg, rows, cols).unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "{rows}x{cols} strided output mismatch");
        }
    }

    #[test]
    fn tiled_double_pooled_cnn_is_bit_exact() {
        // Two pooling stages (cumulative stride 4) through the full
        // conv-pool-conv-pool extension CNN.
        let g = models::tiny_cnn(32, 4, 8);
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for (rows, cols) in [(1usize, 2usize), (2, 2)] {
            let tc = compile_tiled_fixed(&g, &cfg, rows, cols).unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "{rows}x{cols} pooled output mismatch");
        }
    }

    #[test]
    fn parallel_tiled_simulation_matches_serial_exactly() {
        // The fan-out contract: any worker count produces the identical
        // report — stitched output, total/per-cell cycles, firings and
        // token ops — because cells are independent and the stitch
        // order is deterministic.
        let cfg = DseConfig::new(DeviceSpec::kv260());
        for (g, rows, cols) in [
            (models::tiny_cnn(32, 4, 8), 2usize, 2usize),
            (models::cascade(32, 8, 8), 2, 4),
            (models::conv_pool_conv(64, 8), 2, 2),
        ] {
            let x = det_input(&g);
            let tc = compile_tiled_fixed(&g, &cfg, rows, cols).unwrap();
            let serial = simulate_tiled(&tc, &x).unwrap();
            for workers in [2usize, 3, 8] {
                let par =
                    simulate_tiled_parallel(&tc, &x, &crate::coordinator::Scheduler::new(workers)).unwrap();
                assert_eq!(par.output, serial.output, "{}@{workers}: output", g.name);
                assert_eq!(par.cycles, serial.cycles, "{}@{workers}: cycles", g.name);
                assert_eq!(par.tile_cycles, serial.tile_cycles, "{}@{workers}", g.name);
                assert_eq!(par.total_firings, serial.total_firings, "{}", g.name);
                assert_eq!(par.token_ops, serial.token_ops, "{}", g.name);
            }
        }
    }

    #[test]
    fn context_pool_bounds_builds_by_worker_count() {
        // 4x4 = 16 cells split into ~4 chunks per worker: without the
        // shared pool every chunk would build its own SimContext; with
        // it, builds are bounded by the number of concurrently-active
        // workers (and the serial path always reports exactly one).
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 4, 4).unwrap();
        let serial = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(serial.ctx_builds, 1, "serial path builds one context");
        // the pool's contexts share one weight bank: same Arc'd bytes,
        // not per-context copies
        let bank = crate::sim::WeightBank::build(&tc.cell).unwrap();
        let mode = SimMode::of(tc.cell.style);
        let a = crate::sim::SimContext::with_bank(&tc.cell, mode, &bank).unwrap();
        let b = crate::sim::SimContext::with_bank(&tc.cell, mode, &bank).unwrap();
        assert!(a.shares_weights_with(&b), "bank contexts must share weight storage");
        let fresh = crate::sim::SimContext::new(&tc.cell, mode).unwrap();
        assert!(
            !a.shares_weights_with(&fresh),
            "independently built contexts must not share storage"
        );
        for workers in [2usize, 4] {
            let par = simulate_tiled_parallel(&tc, &x, &crate::coordinator::Scheduler::new(workers)).unwrap();
            assert_eq!(par.output, serial.output);
            assert!(par.ctx_builds >= 1);
            assert!(
                par.ctx_builds <= workers as u64,
                "{} builds for {workers} workers — context pool not reusing",
                par.ctx_builds
            );
        }
    }

    #[test]
    fn parallel_tiled_simulation_with_one_worker_is_serial() {
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2, 2).unwrap();
        let a = simulate_tiled(&tc, &x).unwrap();
        let b = simulate_tiled_parallel(&tc, &x, &crate::coordinator::Scheduler::new(1)).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn fallback_rescues_bram_starved_conv() {
        // Full-width: the cheapest assignment needs 4 line-buffer blocks
        // plus 1 weight-ROM block = 5 > 4 => untiled DSE is infeasible;
        // half-width cells halve the line buffers and fit in 4.
        let g = models::conv_relu(80, 32, 8);
        let dev = DeviceSpec::kv260().with_bram_limit(4);
        let cfg = DseConfig::new(dev.clone());
        let mut flat = build_streaming_design(&g).unwrap();
        assert!(solve(&mut flat, &cfg).is_err(), "untiled must be infeasible");

        let tc = compile_tiled(&g, &cfg).unwrap();
        assert!(tc.grid.n_cells() >= 2);
        let r = crate::resources::estimate(&tc.cell, &dev);
        assert!(
            r.bram18k <= dev.bram18k,
            "cell BRAM {} must fit budget {}",
            r.bram18k,
            dev.bram18k
        );
        // and the tiled execution is still bit-exact
        let x = det_input(&g);
        let want = untiled_output(&g, &x);
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want);
    }

    #[test]
    fn speculative_grid_search_matches_serial_choice() {
        // Same starved device as fallback_rescues_bram_starved_conv:
        // several survivors reach the cell-DSE stage, so the parallel
        // path actually speculates — and must still commit the exact
        // grid (and byte-identical cell design) the serial walk picks.
        let g = models::conv_relu(80, 32, 8);
        let dev = DeviceSpec::kv260().with_bram_limit(4);
        let serial = compile_tiled(&g, &DseConfig::new(dev.clone()).with_workers(1)).unwrap();
        for workers in [2usize, 4] {
            let cfg = DseConfig::new(dev.clone()).with_workers(workers);
            let spec = compile_tiled(&g, &cfg).unwrap();
            assert_eq!(
                (spec.grid.rows(), spec.grid.cols()),
                (serial.grid.rows(), serial.grid.cols()),
                "workers {workers}: committed grid diverged"
            );
            assert_eq!(spec.solution.objective, serial.solution.objective);
            assert_eq!(spec.solution.chosen, serial.solution.chosen);
            assert_eq!(
                format!("{:?}", spec.cell),
                format!("{:?}", serial.cell),
                "workers {workers}: cell design diverged"
            );
        }
    }

    #[test]
    fn failed_grid_search_reports_bounded_rejection_summary() {
        // A zero-BRAM device rejects every candidate at the lower-bound
        // prune; the error must carry the bounded per-candidate summary
        // so triage does not need a re-run with tracing.
        let g = models::conv_relu(32, 8, 8);
        let cfg = DseConfig::new(DeviceSpec::kv260().with_bram_limit(0));
        let err = compile_tiled(&g, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fallback"), "{msg}");
        assert!(msg.contains("rejected"), "{msg}");
        assert!(msg.contains("bram-lower-bound"), "{msg}");
    }

    #[test]
    fn tiling_hint_is_preferred() {
        let mut g = models::conv_relu(32, 8, 8);
        g.tiling = Some(crate::ir::graph::TilingHint {
            tile_width: Some(8),
            tile_height: None,
            max_tiles: None,
        });
        let tc = compile_tiled(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        assert_eq!(tc.grid.cols(), 4);
        assert_eq!(tc.grid.rows(), 1);
        assert_eq!(tc.grid.w.core, 8);

        // a 2-D hint pins both axes
        let mut g = models::conv_relu(32, 8, 8);
        g.tiling = Some(crate::ir::graph::TilingHint {
            tile_width: Some(16),
            tile_height: Some(16),
            max_tiles: None,
        });
        let tc = compile_tiled(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        assert_eq!((tc.grid.rows(), tc.grid.cols()), (2, 2));
    }

    #[test]
    fn untilable_graphs_report_cleanly() {
        let g = models::linear();
        let err = compile_tiled(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap_err();
        assert!(format!("{err:#}").contains("width"), "{err:#}");
    }

    #[test]
    fn oversized_vgg_block_compiles_only_tiled_on_kv260() {
        // The headline scenario: three 3x3 conv layers at 256 channels on
        // a 512x512 input. Untiled, the minimal line buffers alone need
        // ~342 BRAM18K > the KV260's 288; grid tiling turns the hard
        // infeasibility into a latency/resource trade-off. (Estimate
        // only — 4.6e12 MACs are not simulated here.)
        let g = models::vgg_block(512, 256, 3);
        let dev = DeviceSpec::kv260();
        let cfg = DseConfig::new(dev.clone());
        let mut flat = build_streaming_design(&g).unwrap();
        let err = solve(&mut flat, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("infeasible"), "{err:#}");

        let tc = compile_tiled(&g, &cfg).unwrap();
        assert!(tc.grid.n_cells() >= 2);
        assert_eq!(tc.grid.w.cone.radius(), 3);
        let r = crate::resources::estimate(&tc.cell, &dev);
        assert!(
            r.bram18k <= dev.bram18k,
            "tiled BRAM {} must fit the stock KV260 ({})",
            r.bram18k,
            dev.bram18k
        );
        assert!(tc.estimated_cycles() > 0);
    }

    #[test]
    fn oversized_pooled_chain_compiles_only_tiled_on_kv260() {
        // The strided showcase the stride-1 subsystem hard-rejected: a
        // conv -> 2x2 pool -> conv chain at 384 channels on a 512x512
        // input. Untiled, the minimal line buffers need ~344 BRAM18K >
        // the KV260's 288; the grid fallback places it. (Estimate only.)
        let g = models::conv_pool_conv(512, 384);
        let dev = DeviceSpec::kv260();
        let cfg = DseConfig::new(dev.clone());
        let mut flat = build_streaming_design(&g).unwrap();
        let err = solve(&mut flat, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("infeasible"), "{err:#}");

        let tc = compile_tiled(&g, &cfg).unwrap();
        assert!(tc.grid.n_cells() >= 2);
        assert_eq!(tc.grid.w.cone.scale, 2, "pool halves the output lattice");
        // the unified-model invariant holds for the cell design
        assert_eq!(
            tc.solution.bram_used,
            crate::resources::bram::design_bram(&tc.cell)
        );
        assert!(
            tc.solution.bram_used <= dev.bram18k,
            "tiled BRAM {} must fit the stock KV260 ({})",
            tc.solution.bram_used,
            dev.bram18k
        );
        assert!(tc.estimated_cycles() > 0);
    }
}
