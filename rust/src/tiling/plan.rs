//! Tile plans: decompose a feature map's width into halo-overlapped
//! strips of one uniform local width.
//!
//! Every tile owns `tile_width` *core* output columns; its input window
//! is the core plus `halo` columns per side, **shifted inward** at the
//! image borders so that all strips share a single local width
//! `tile_width + 2·halo`. Inward shifting (instead of clamping the
//! window) is what makes one strip design reusable for every tile: at a
//! true image border the strip's own zero-padding coincides with the
//! global padding, and everywhere else the kept core columns sit at
//! least `halo` columns away from any fake strip edge, outside the
//! contamination cone of the wrong local padding.

use anyhow::{ensure, Context, Result};

use crate::ir::graph::{ModelGraph, TensorKind};

use super::halo::{check_tilable, graph_halo};

/// One width strip: global output core `[out_lo, out_hi)` computed from
/// global input columns `[in_lo, in_lo + local_width)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub index: usize,
    pub out_lo: usize,
    pub out_hi: usize,
    pub in_lo: usize,
}

impl Tile {
    /// Local column of the first kept output value.
    pub fn crop_lo(&self) -> usize {
        self.out_lo - self.in_lo
    }

    /// Kept output columns.
    pub fn core_width(&self) -> usize {
        self.out_hi - self.out_lo
    }
}

/// A complete width-tiling plan for one graph.
#[derive(Debug, Clone)]
pub struct TilePlan {
    /// Feature-map height (common to all activation tensors).
    pub height: usize,
    /// Full feature-map width.
    pub width: usize,
    /// Core output columns per tile (`width / tiles.len()`).
    pub tile_width: usize,
    /// Per-side halo columns (graph dependency-cone radius).
    pub halo: usize,
    /// Uniform strip width: `tile_width + 2·halo`, capped at `width`.
    pub local_width: usize,
    pub tiles: Vec<Tile>,
}

impl TilePlan {
    /// Build the plan splitting `g`'s width into `n_tiles` strips.
    /// `n_tiles` must divide the width, and the strips must be narrower
    /// than the full map for the plan to be useful.
    pub fn build(g: &ModelGraph, n_tiles: usize) -> Result<TilePlan> {
        let (height, width) = check_tilable(g)?;
        let halo = graph_halo(g)?;
        ensure!(n_tiles >= 1, "tile count must be positive");
        ensure!(
            width % n_tiles == 0,
            "tile count {n_tiles} must divide feature-map width {width}"
        );
        let tile_width = width / n_tiles;
        let local_width = if n_tiles == 1 { width } else { tile_width + 2 * halo };
        ensure!(
            local_width <= width,
            "strips of width {local_width} (core {tile_width} + 2x{halo} halo) \
             are no narrower than the {width}-wide map"
        );
        let tiles = (0..n_tiles)
            .map(|i| {
                let out_lo = i * tile_width;
                let out_hi = out_lo + tile_width;
                // inward-shifted window: [in_lo, in_lo + local_width) ⊆ [0, width)
                let in_lo = out_lo.saturating_sub(halo).min(width - local_width);
                Tile { index: i, out_lo, out_hi, in_lo }
            })
            .collect();
        Ok(TilePlan { height, width, tile_width, halo, local_width, tiles })
    }

    /// Human-readable plan summary.
    pub fn describe(&self) -> String {
        let strips: Vec<String> = self
            .tiles
            .iter()
            .map(|t| {
                format!(
                    "  strip {}: in cols [{}, {})  ->  out cols [{}, {})",
                    t.index,
                    t.in_lo,
                    t.in_lo + self.local_width,
                    t.out_lo,
                    t.out_hi
                )
            })
            .collect();
        format!(
            "tile plan: {} strips of {} cols (core {} + halo {} per side) over a {}x{} map\n{}",
            self.tiles.len(),
            self.local_width,
            self.tile_width,
            self.halo,
            self.height,
            self.width,
            strips.join("\n")
        )
    }
}

/// Rebuild `g` as a width-`w_local` strip graph: every activation tensor
/// narrows to `w_local` columns and every op's width-axis trip count
/// follows. Weights (and therefore per-node compute structure) are
/// untouched — the strip design reuses the same resident ROMs across
/// tiles.
pub fn retile_width(g: &ModelGraph, w_local: usize) -> Result<ModelGraph> {
    ensure!(w_local >= 1, "strip width must be positive");
    let (_, width) = check_tilable(g)?;
    ensure!(w_local <= width, "strip width {w_local} exceeds map width {width}");
    let mut s = g.clone();
    s.name = format!("{}_w{}", g.name, w_local);
    for t in &mut s.tensors {
        if t.kind != TensorKind::Weight {
            t.ty.shape[1] = w_local;
        }
    }
    for op in &mut s.ops {
        // The loop dimension indexing the output's width axis (axis 1 of
        // the rank-3 map) carries the new trip count.
        let w_dim = {
            let out_map = op.indexing_maps.last().context("op without maps")?;
            ensure!(
                out_map.results.len() == 3,
                "op {}: rank-{} output is not a feature map",
                op.name,
                out_map.results.len()
            );
            out_map.results[1]
                .single_dim()
                .with_context(|| format!("op {}: output width axis must be a plain dim", op.name))?
        };
        op.dims[w_dim] = w_local;
    }
    s.validate()
        .with_context(|| format!("retiled strip graph (width {w_local}) is inconsistent"))?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn two_strip_plan_geometry() {
        let g = models::cascade(32, 8, 8); // halo 2
        let p = TilePlan::build(&g, 2).unwrap();
        assert_eq!(p.halo, 2);
        assert_eq!(p.tile_width, 16);
        assert_eq!(p.local_width, 20);
        assert_eq!(p.tiles.len(), 2);
        // left strip starts at the true border; right strip shifts inward
        assert_eq!(p.tiles[0].in_lo, 0);
        assert_eq!(p.tiles[0].crop_lo(), 0);
        assert_eq!(p.tiles[1].in_lo, 12);
        assert_eq!(p.tiles[1].crop_lo(), 4);
        // every window stays inside the map
        for t in &p.tiles {
            assert!(t.in_lo + p.local_width <= p.width);
        }
    }

    #[test]
    fn interior_strips_have_full_halo_margin() {
        let g = models::conv_relu(64, 8, 8); // halo 1
        let p = TilePlan::build(&g, 4).unwrap();
        assert_eq!(p.local_width, 18);
        for t in &p.tiles {
            // the kept core never sits closer than `halo` to a fake edge
            let left_true = t.in_lo == 0;
            let right_true = t.in_lo + p.local_width == p.width;
            if !left_true {
                assert!(t.crop_lo() >= p.halo, "tile {}", t.index);
            }
            if !right_true {
                assert!(
                    p.local_width - (t.crop_lo() + t.core_width()) >= p.halo,
                    "tile {}",
                    t.index
                );
            }
        }
    }

    #[test]
    fn cores_partition_the_width() {
        let g = models::conv_relu(32, 8, 8);
        for n in [1usize, 2, 4, 8] {
            let p = TilePlan::build(&g, n).unwrap();
            let mut covered = 0;
            for t in &p.tiles {
                assert_eq!(t.out_lo, covered);
                covered = t.out_hi;
            }
            assert_eq!(covered, p.width);
        }
    }

    #[test]
    fn bad_tile_counts_rejected() {
        let g = models::conv_relu(32, 8, 8);
        assert!(TilePlan::build(&g, 3).is_err(), "3 does not divide 32");
        assert!(TilePlan::build(&g, 0).is_err());
        // 32 strips of core 1 + halo 2 = 3 > ... still narrower than 32; but
        // 16 tiles: core 2 + 2 = 4 <= 32, fine. Degenerate overlap is allowed
        // as long as strips are narrower than the map.
        assert!(TilePlan::build(&g, 16).is_ok());
    }

    #[test]
    fn retile_width_rebuilds_consistent_strip() {
        let g = models::cascade(32, 8, 8);
        let s = retile_width(&g, 20).unwrap();
        s.validate().unwrap();
        assert_eq!(s.inputs()[0].ty.shape, vec![32, 20, 8]);
        assert_eq!(s.outputs()[0].ty.shape, vec![32, 20, 8]);
        for op in &s.ops {
            // conv dims: [h, w, f, k, k, c]; elementwise dims: [h, w, c]
            assert_eq!(op.dims[1], 20, "op {}", op.name);
        }
        // weights untouched
        assert_eq!(s.weights().len(), g.weights().len());
        for (a, b) in s.weights().iter().zip(g.weights()) {
            assert_eq!(a.ty.shape, b.ty.shape);
        }
    }

    #[test]
    fn retile_residual_diamond() {
        let g = models::residual(16, 8, 8);
        let s = retile_width(&g, 12).unwrap();
        s.validate().unwrap();
        assert_eq!(s.outputs()[0].ty.shape, vec![16, 12, 8]);
    }
}
