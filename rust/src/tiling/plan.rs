//! Tile grids: decompose a feature map into a `rows × cols` grid of
//! halo-overlapped cells of one uniform local size.
//!
//! The grid is planned in the **final-output** coordinate system: every
//! cell owns a `core_h × core_w` block of output positions; its input
//! window is the backward image of that block under the graph's
//! dependency cone ([`crate::tiling::halo::AxisCone`]), padded per side
//! and **shifted inward** at the image borders so that all cells share
//! a single local input extent per axis. Inward shifting (instead of
//! clamping the window) is what makes one cell design reusable for
//! every cell: at a true image border the cell's own zero-padding
//! coincides with the global padding, and everywhere else the kept core
//! sits outside the contamination cone of the wrong local padding.
//!
//! Stride-awareness adds two constraints the stride-1 planner never
//! saw: window origins must be multiples of the cumulative stride
//! `scale` (so cell-local outputs land on the global output lattice),
//! and the local extent must be congruent to the full extent modulo
//! `scale` (so every sliding stage divides exactly in the cell graph
//! too). Both are handled per axis by [`GridAxis::build`]; the two axes
//! are independent, so a 2-D cell is just the cross product of one row
//! segment and one column segment.

use anyhow::{bail, ensure, Context, Result};

use crate::ir::graph::{ModelGraph, TensorKind};

use super::halo::{check_tilable, op_axis_window, AxisCone, AXIS_H, AXIS_W};

/// One 1-D grid segment along an axis: global output core
/// `[out_lo, out_lo + core)` computed from global input positions
/// `[in_lo, in_lo + local_in)`, keeping local outputs starting at
/// `crop_lo`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seg {
    pub index: usize,
    /// First global final-output position of this segment's core.
    pub out_lo: usize,
    /// Global input position of the window origin (multiple of `scale`).
    pub in_lo: usize,
    /// Cell-local final-output position of the first kept value
    /// (`out_lo − in_lo / scale`).
    pub crop_lo: usize,
}

/// Grid decomposition of one spatial axis.
#[derive(Debug, Clone)]
pub struct GridAxis {
    /// Axis label for diagnostics ("rows" / "cols").
    pub label: &'static str,
    /// Global input extent on this axis.
    pub in_extent: usize,
    /// Global final-output extent.
    pub out_extent: usize,
    /// Input-space dependency cone (scale = cumulative stride).
    pub cone: AxisCone,
    /// Final-output positions per cell (`out_extent / segs.len()`).
    pub core: usize,
    /// Uniform local input extent (halo included).
    pub local_in: usize,
    /// Local final-output extent the cell graph produces.
    pub local_out: usize,
    pub segs: Vec<Seg>,
}

impl GridAxis {
    /// Split an axis into `n` segments. The local extent starts at the
    /// cone-derived minimum and grows in `scale` steps until every
    /// segment satisfies the halo-coverage invariants (first fit wins).
    pub fn build(
        label: &'static str,
        in_extent: usize,
        out_extent: usize,
        cone: AxisCone,
        n: usize,
    ) -> Result<GridAxis> {
        ensure!(n >= 1, "{label}: cell count must be positive");
        ensure!(
            out_extent % n == 0,
            "{label}: cell count {n} must divide output extent {out_extent}"
        );
        let core = out_extent / n;
        if n == 1 {
            return Ok(GridAxis {
                label,
                in_extent,
                out_extent,
                cone,
                core,
                local_in: in_extent,
                local_out: out_extent,
                segs: vec![Seg { index: 0, out_lo: 0, in_lo: 0, crop_lo: 0 }],
            });
        }
        let s = cone.scale;
        // round the halo sides up to stride multiples, and keep
        // local_in ≡ in_extent (mod scale) so every sliding stage
        // divides exactly inside the cell graph
        let a_bar = cone.lo.div_ceil(s) * s;
        let b_bar = cone.hi.div_ceil(s) * s;
        let base = s * core + a_bar + b_bar + in_extent % s;
        let mut local_in = base;
        while local_in <= in_extent {
            if let Some(segs) = Self::try_segs(in_extent, out_extent, &cone, core, n, local_in) {
                let local_out = out_extent - (in_extent - local_in) / s;
                return Ok(GridAxis {
                    label,
                    in_extent,
                    out_extent,
                    cone,
                    core,
                    local_in,
                    local_out,
                    segs,
                });
            }
            local_in += s;
        }
        bail!(
            "{label}: no local extent ≤ {in_extent} covers {n} cores of {core} \
             with halo ({}, {}) at stride {s}",
            cone.lo,
            cone.hi
        )
    }

    /// Place the `n` segments for candidate extent `local_in`, verifying
    /// the halo-coverage invariants; `None` when any segment fails.
    fn try_segs(
        in_extent: usize,
        out_extent: usize,
        cone: &AxisCone,
        core: usize,
        n: usize,
        local_in: usize,
    ) -> Option<Vec<Seg>> {
        let s = cone.scale as i64;
        let a_bar = (cone.lo.div_ceil(cone.scale) * cone.scale) as i64;
        let local_out = out_extent.checked_sub((in_extent - local_in) / cone.scale)?;
        let mut segs = Vec::with_capacity(n);
        for i in 0..n {
            let out_lo = i * core;
            let desired = s * out_lo as i64 - a_bar;
            let in_lo = desired.clamp(0, (in_extent - local_in) as i64) as usize;
            // multiples of scale in, multiples of scale out of the clamp
            debug_assert_eq!(in_lo % cone.scale, 0);
            let origin = in_lo / cone.scale;
            if origin > out_lo {
                return None;
            }
            let crop_lo = out_lo - origin;
            if crop_lo + core > local_out {
                return None;
            }
            // fake-edge contamination margins: the kept core's cone must
            // stay inside the genuinely loaded window
            let fake_left = in_lo > 0;
            if fake_left && cone.scale * crop_lo < cone.lo {
                return None;
            }
            let fake_right = in_lo + local_in < in_extent;
            if fake_right && cone.scale * (crop_lo + core - 1) + cone.hi > local_in - 1 {
                return None;
            }
            segs.push(Seg { index: i, out_lo, in_lo, crop_lo });
        }
        Some(segs)
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.segs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    /// Whether splitting this axis actually shrank the local extent.
    pub fn shrinks(&self) -> bool {
        self.local_in < self.in_extent
    }
}

/// A complete 2-D tile grid for one graph: independent row/column axes;
/// the cells are the cross product of the two segment lists.
#[derive(Debug, Clone)]
pub struct TileGrid {
    /// Height (row) axis.
    pub h: GridAxis,
    /// Width (column) axis.
    pub w: GridAxis,
}

impl TileGrid {
    /// Build the `rows × cols` grid for `g` (cell counts in final-output
    /// coordinates; each must divide the respective output extent).
    pub fn build(g: &ModelGraph, rows: usize, cols: usize) -> Result<TileGrid> {
        let geom = check_tilable(g)?;
        let h = GridAxis::build(
            "rows",
            geom.in_extent[AXIS_H],
            geom.out_extent[AXIS_H],
            geom.cone[AXIS_H],
            rows,
        )?;
        let w = GridAxis::build(
            "cols",
            geom.in_extent[AXIS_W],
            geom.out_extent[AXIS_W],
            geom.cone[AXIS_W],
            cols,
        )?;
        Ok(TileGrid { h, w })
    }

    pub fn rows(&self) -> usize {
        self.h.len()
    }

    pub fn cols(&self) -> usize {
        self.w.len()
    }

    /// Total cell count.
    pub fn n_cells(&self) -> usize {
        self.rows() * self.cols()
    }

    /// Human-readable grid summary.
    pub fn describe(&self) -> String {
        let axis = |a: &GridAxis| -> String {
            let segs: Vec<String> = a
                .segs
                .iter()
                .map(|sg| {
                    format!(
                        "[in {}..{} -> out {}..{} crop {}]",
                        sg.in_lo,
                        sg.in_lo + a.local_in,
                        sg.out_lo,
                        sg.out_lo + a.core,
                        sg.crop_lo
                    )
                })
                .collect();
            format!(
                "  {}: {} x core {} (local {} of {}, stride x{}, halo -{}/+{}) {}",
                a.label,
                a.len(),
                a.core,
                a.local_in,
                a.in_extent,
                a.cone.scale,
                a.cone.lo,
                a.cone.hi,
                segs.join(" ")
            )
        };
        format!(
            "tile grid: {}x{} cells of {}x{} input ({}x{} -> {}x{} map)\n{}\n{}",
            self.rows(),
            self.cols(),
            self.h.local_in,
            self.w.local_in,
            self.h.in_extent,
            self.w.in_extent,
            self.h.out_extent,
            self.w.out_extent,
            axis(&self.h),
            axis(&self.w)
        )
    }
}

/// Per-tensor local `[H, W]` extents of the cell graph whose input is
/// `local_h × local_w` — forward window arithmetic over the op DAG
/// (`None` for weights). Shared by [`rewindow`] and the tiling cost
/// model's per-cell BRAM bounds.
pub fn local_extents(
    g: &ModelGraph,
    local_h: usize,
    local_w: usize,
) -> Result<Vec<Option<[usize; 2]>>> {
    let order = g.toposort()?;
    let mut ext: Vec<Option<[usize; 2]>> = vec![None; g.tensors.len()];
    for t in &g.tensors {
        if t.kind == TensorKind::Input {
            ext[t.id.0] = Some([local_h, local_w]);
        }
    }
    for &oi in &order {
        let op = &g.ops[oi];
        let mut in_ext = None;
        for &inp in &op.inputs {
            if g.tensor(inp).kind == TensorKind::Weight {
                continue;
            }
            let e = ext[inp.0]
                .with_context(|| format!("op {}: input extent unknown", op.name))?;
            match in_ext {
                None => in_ext = Some(e),
                Some(prev) => ensure!(
                    prev == e,
                    "op {}: activation inputs disagree on local extents",
                    op.name
                ),
            }
        }
        let in_ext = in_ext.with_context(|| format!("op {} has no activation input", op.name))?;
        let mut out = [0usize; 2];
        for ax in [AXIS_H, AXIS_W] {
            let w = op_axis_window(op, ax)?;
            out[ax] = w
                .out_extent(in_ext[ax])
                .with_context(|| format!("op {} axis {ax} at local extents", op.name))?;
        }
        ext[op.output.0] = Some(out);
    }
    Ok(ext)
}

/// Rebuild `g` as a cell graph on a `local_h × local_w` input window:
/// every activation tensor's spatial extents follow the per-op window
/// arithmetic, and every op's spatial trip counts follow its output
/// tensor. Weights (and therefore per-node compute structure) are
/// untouched — the cell design reuses the same resident ROMs across all
/// grid cells.
pub fn rewindow(g: &ModelGraph, local_h: usize, local_w: usize) -> Result<ModelGraph> {
    ensure!(local_h >= 1 && local_w >= 1, "cell extents must be positive");
    let geom = check_tilable(g)?;
    ensure!(
        local_h <= geom.in_extent[AXIS_H] && local_w <= geom.in_extent[AXIS_W],
        "cell {local_h}x{local_w} exceeds the {}x{} map",
        geom.in_extent[AXIS_H],
        geom.in_extent[AXIS_W]
    );
    let ext = local_extents(g, local_h, local_w)?;
    let mut s = g.clone();
    s.name = format!("{}_c{}x{}", g.name, local_h, local_w);
    for t in &mut s.tensors {
        if t.kind != TensorKind::Weight {
            let e = ext[t.id.0].with_context(|| format!("tensor {} unreached", t.name))?;
            t.ty.shape[0] = e[0];
            t.ty.shape[1] = e[1];
        }
    }
    for op in &mut s.ops {
        let e = ext[op.output.0].context("op output unreached")?;
        for ax in [AXIS_H, AXIS_W] {
            let d = op
                .indexing_maps
                .last()
                .context("op without maps")?
                .results[ax]
                .single_dim()
                .with_context(|| format!("op {}: output axis {ax} not a plain dim", op.name))?;
            op.dims[d] = e[ax];
        }
    }
    s.validate()
        .with_context(|| format!("cell graph ({local_h}x{local_w}) is inconsistent"))?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn width_strip_grid_matches_stride1_geometry() {
        // 1 x 2 grid over the stride-1 cascade (halo 2 per side): the
        // classic width-strip plan falls out of the grid machinery.
        let g = models::cascade(32, 8, 8);
        let grid = TileGrid::build(&g, 1, 2).unwrap();
        assert_eq!(grid.n_cells(), 2);
        assert_eq!(grid.h.local_in, 32, "single row segment spans the map");
        assert_eq!(grid.w.core, 16);
        assert_eq!(grid.w.local_in, 20);
        assert_eq!(grid.w.local_out, 20);
        // left strip starts at the true border; right strip shifts inward
        assert_eq!(grid.w.segs[0].in_lo, 0);
        assert_eq!(grid.w.segs[0].crop_lo, 0);
        assert_eq!(grid.w.segs[1].in_lo, 12);
        assert_eq!(grid.w.segs[1].crop_lo, 4);
        for sg in &grid.w.segs {
            assert!(sg.in_lo + grid.w.local_in <= grid.w.in_extent);
        }
    }

    #[test]
    fn interior_segments_have_full_halo_margin() {
        let g = models::conv_relu(64, 8, 8); // halo 1
        let grid = TileGrid::build(&g, 1, 4).unwrap();
        let a = &grid.w;
        assert_eq!(a.local_in, 18);
        for sg in &a.segs {
            let left_true = sg.in_lo == 0;
            let right_true = sg.in_lo + a.local_in == a.in_extent;
            if !left_true {
                assert!(a.cone.scale * sg.crop_lo >= a.cone.lo, "seg {}", sg.index);
            }
            if !right_true {
                assert!(
                    a.cone.scale * (sg.crop_lo + a.core - 1) + a.cone.hi <= a.local_in - 1,
                    "seg {}",
                    sg.index
                );
            }
        }
    }

    #[test]
    fn cores_partition_both_axes() {
        let g = models::conv_relu(32, 8, 8);
        for (r, c) in [(1usize, 2usize), (2, 1), (2, 2), (4, 8), (8, 8)] {
            let grid = TileGrid::build(&g, r, c).unwrap();
            for a in [&grid.h, &grid.w] {
                let mut covered = 0;
                for sg in &a.segs {
                    assert_eq!(sg.out_lo, covered, "{}", a.label);
                    covered += a.core;
                }
                assert_eq!(covered, a.out_extent, "{}", a.label);
            }
        }
    }

    #[test]
    fn strided_grid_aligns_windows_to_the_stride_lattice() {
        // conv -> pool(2) -> conv at 64: scale 2, cone (3, 4).
        let g = models::conv_pool_conv(64, 8);
        let grid = TileGrid::build(&g, 1, 2).unwrap();
        let a = &grid.w;
        assert_eq!(a.cone.scale, 2);
        assert_eq!((a.cone.lo, a.cone.hi), (3, 4));
        assert_eq!(a.out_extent, 32);
        assert_eq!(a.core, 16);
        // local_in = 2*16 + 4 + 4 (halo rounded to stride multiples)
        assert_eq!(a.local_in, 40);
        assert_eq!(a.local_out, 32 - (64 - 40) / 2);
        for sg in &a.segs {
            assert_eq!(sg.in_lo % 2, 0, "origin off the stride lattice");
            assert!(sg.in_lo + a.local_in <= a.in_extent);
        }
        // the right segment shifts inward and crops past the fake edge
        assert_eq!(a.segs[1].in_lo, 64 - 40);
        assert_eq!(a.segs[1].crop_lo, 16 - (64 - 40) / 2);
    }

    #[test]
    fn bad_cell_counts_rejected() {
        let g = models::conv_relu(32, 8, 8);
        assert!(TileGrid::build(&g, 1, 3).is_err(), "3 does not divide 32");
        assert!(TileGrid::build(&g, 3, 1).is_err());
        assert!(TileGrid::build(&g, 0, 2).is_err());
        assert!(TileGrid::build(&g, 16, 16).is_ok());
    }

    #[test]
    fn rewindow_rebuilds_consistent_cell_graph() {
        let g = models::cascade(32, 8, 8);
        let s = rewindow(&g, 24, 20).unwrap();
        s.validate().unwrap();
        assert_eq!(s.inputs()[0].ty.shape, vec![24, 20, 8]);
        assert_eq!(s.outputs()[0].ty.shape, vec![24, 20, 8]);
        for op in &s.ops {
            assert_eq!(op.dims[0], 24, "op {}", op.name);
            assert_eq!(op.dims[1], 20, "op {}", op.name);
        }
        // weights untouched
        assert_eq!(s.weights().len(), g.weights().len());
        for (a, b) in s.weights().iter().zip(g.weights()) {
            assert_eq!(a.ty.shape, b.ty.shape);
        }
    }

    #[test]
    fn rewindow_propagates_strided_shapes() {
        let g = models::tiny_cnn(32, 4, 8);
        let s = rewindow(&g, 20, 12).unwrap();
        s.validate().unwrap();
        assert_eq!(s.inputs()[0].ty.shape, vec![20, 12, 4]);
        // 20x12 -> conv (same) -> pool/2 -> 10x6 -> conv -> pool/2 -> 5x3
        assert_eq!(s.outputs()[0].ty.shape, vec![5, 3, 8]);
        // odd local extents that break pool divisibility are rejected
        assert!(rewindow(&g, 20, 13).is_err());
    }

    #[test]
    fn rewindow_residual_diamond() {
        let g = models::residual(16, 8, 8);
        let s = rewindow(&g, 16, 12).unwrap();
        s.validate().unwrap();
        assert_eq!(s.outputs()[0].ty.shape, vec![16, 12, 8]);
    }

    #[test]
    fn local_extents_follow_the_window_chain() {
        let g = models::conv_pool_conv(64, 8);
        let ext = local_extents(&g, 64, 40).unwrap();
        let at = |name: &str| {
            let op = g.op(name).unwrap();
            ext[op.output.0].unwrap()
        };
        assert_eq!(at("conv0"), [64, 40]);
        assert_eq!(at("pool0"), [32, 20]);
        assert_eq!(at("conv1"), [32, 20]);
    }
}
