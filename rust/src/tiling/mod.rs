//! Stride-aware 2-D tile-grid decomposition for oversized CNN layers.
//!
//! MING's streaming architecture keeps line buffers of `(K-1) × W·C`
//! values per sliding-window node — linear in the input width, which is
//! what lets it scale past ScaleHLS/StreamHLS. But a big enough layer
//! (wide maps × many channels × deep chains) still exceeds an edge
//! device's BRAM even at minimal unroll, and then the DSE of
//! [`crate::dse::ilp::solve`] simply has no feasible point. This module
//! turns that hard infeasibility into a latency/resource trade-off:
//!
//! 1. [`halo`] checks the graph is grid-tilable and computes per-axis
//!    dependency cones with **stride-aware coordinate remapping** —
//!    strided convolutions and pooled chains propagate halos and crop
//!    offsets through the chain instead of being rejected;
//! 2. [`plan`] splits the final output into a `rows × cols`
//!    [`plan::TileGrid`] of equal cores with inward-shifted,
//!    stride-aligned input windows, so every cell shares **one** local
//!    extent and one reusable cell design ([`plan::rewindow`]);
//! 3. [`cost`] prices cells (BRAM lower bounds at each node's local
//!    width, tiled latency with gather/drain overlap);
//! 4. [`schedule`] searches the grid lattice
//!    ([`crate::dse::space::grid_counts`]) for the fewest cells whose
//!    DSE-solved design fits the device, and executes/stitches cells
//!    bit-exactly on the cycle simulator.
//!
//! Entry points: [`compile_tiled`] (automatic fallback, used by
//! [`crate::dse::ilp::solve_with_tiling_fallback`], the coordinator
//! sweeps and the `ming` CLI) and [`simulate_tiled`].

pub mod halo;
pub mod plan;
pub mod cost;
pub mod schedule;

pub use cost::{serialized_tiled_cycles, tiled_cycles_estimate, TILE_RESTART_CYCLES};
pub use halo::{check_tilable, graph_halo, op_axis_window, AxisCone, AxisWindow, GridGeom};
pub use plan::{local_extents, rewindow, GridAxis, Seg, TileGrid};
pub use schedule::{
    compile_tiled, compile_tiled_fixed, compile_tiled_from, simulate_tiled,
    simulate_tiled_parallel, simulate_tiled_parallel_with, simulate_tiled_with,
    TiledCompilation, TiledSimReport,
};
