//! Halo-aware width tiling for oversized CNN layers.
//!
//! MING's streaming architecture keeps line buffers of `(K-1) × W·C`
//! values per sliding-window node — linear in the input width, which is
//! what lets it scale past ScaleHLS/StreamHLS. But a big enough layer
//! (wide maps × many channels × deep chains) still exceeds an edge
//! device's BRAM even at minimal unroll, and then the DSE of
//! [`crate::dse::ilp::solve`] simply has no feasible point. This module
//! turns that hard infeasibility into a latency/resource trade-off:
//!
//! 1. [`halo`] checks the graph is width-preserving and computes the
//!    per-side halo (dependency-cone radius) of the whole chain;
//! 2. [`plan`] splits the width into equal cores with inward-shifted
//!    halo windows, so every strip shares **one** local width and one
//!    reusable strip design;
//! 3. [`cost`] prices strips (BRAM lower bounds, tiled latency);
//! 4. [`schedule`] searches the tile-count axis
//!    ([`crate::dse::space::tile_counts`]) for the fewest strips whose
//!    DSE-solved design fits the device, and executes/stitches strips
//!    bit-exactly on the cycle simulator.
//!
//! Entry points: [`compile_tiled`] (automatic fallback, used by
//! [`crate::dse::ilp::solve_with_tiling_fallback`], the coordinator
//! sweeps and the `ming` CLI) and [`simulate_tiled`].

pub mod halo;
pub mod plan;
pub mod cost;
pub mod schedule;

pub use cost::TILE_RESTART_CYCLES;
pub use halo::{check_tilable, graph_halo, op_halo};
pub use plan::{retile_width, Tile, TilePlan};
pub use schedule::{
    compile_tiled, compile_tiled_fixed, compile_tiled_from, simulate_tiled, TiledCompilation,
    TiledSimReport,
};
