//! Halo (ghost-region) analysis for width-wise strip tiling.
//!
//! A strip of a feature map can only be computed independently if it
//! carries enough *halo* — extra boundary columns — to feed every
//! sliding window that overlaps the strip edge. The halo a whole graph
//! needs is the worst-case sum of per-op halos along any producer path:
//! each stride-1 same-padded K×K convolution widens the dependency cone
//! of one output column by `(K_eff − 1) / 2 = pad` columns per side,
//! while pure-parallel (elementwise) ops add nothing.
//!
//! Only *width-preserving* chains are tilable this way: stride-1
//! same-padded sliding windows and identity-map elementwise ops. Strided
//! convs, pooling and matrix ops are rejected with a descriptive error —
//! the fallback then simply reports the workload as untilable.

use anyhow::{bail, ensure, Result};

use crate::analysis::classify::{classify, KernelClass};
use crate::ir::generic::GenericOp;
use crate::ir::graph::{ModelGraph, TensorKind};

/// Per-side halo columns `op` adds to the dependency cone of one output
/// column. Errors when the op is not width-preserving.
pub fn op_halo(op: &GenericOp) -> Result<usize> {
    match classify(op) {
        KernelClass::PureParallel => {
            for m in &op.indexing_maps {
                ensure!(
                    m.is_identity(),
                    "op {}: non-identity elementwise map is not width-tilable",
                    op.name
                );
            }
            Ok(0)
        }
        KernelClass::SlidingWindow(sw) => {
            ensure!(
                sw.stride == 1,
                "op {}: stride-{} sliding window is not width-tilable (stride 1 required)",
                op.name,
                sw.stride
            );
            let k = op.dims[sw.reduction_dim];
            let keff = (k - 1) * sw.dilation as usize + 1;
            ensure!(
                2 * op.pad + 1 == keff,
                "op {}: tiling requires same-padding (K_eff {keff}, pad {})",
                op.name,
                op.pad
            );
            Ok(op.pad)
        }
        KernelClass::RegularReduction => {
            bail!("op {}: regular reductions have no spatial width to tile", op.name)
        }
    }
}

/// Check that `g` is a width-tilable graph — every activation tensor is a
/// rank-3 `(H, W, C)` feature map with one common height and width, and
/// every op is width-preserving. Returns `(height, width)`.
pub fn check_tilable(g: &ModelGraph) -> Result<(usize, usize)> {
    let mut hw: Option<(usize, usize)> = None;
    for t in &g.tensors {
        if t.kind == TensorKind::Weight {
            continue;
        }
        ensure!(
            t.ty.rank() == 3,
            "tensor {} is rank {} — width tiling needs (H, W, C) feature maps",
            t.name,
            t.ty.rank()
        );
        let cur = (t.ty.shape[0], t.ty.shape[1]);
        match hw {
            None => hw = Some(cur),
            Some(prev) => ensure!(
                prev == cur,
                "tensor {} is {}x{} but the graph works on {}x{} maps — \
                 only height/width-preserving chains are tilable",
                t.name,
                cur.0,
                cur.1,
                prev.0,
                prev.1
            ),
        }
    }
    for op in &g.ops {
        op_halo(op)?;
    }
    hw.ok_or_else(|| anyhow::anyhow!("graph {} has no activation tensors", g.name))
}

/// Total per-side halo the graph output needs: the maximum over all
/// producer paths of the summed per-op halos (longest-path DP over the
/// toposorted DAG, so residual diamonds are handled).
pub fn graph_halo(g: &ModelGraph) -> Result<usize> {
    let order = g.toposort()?;
    let mut halo = vec![0usize; g.tensors.len()];
    for &oi in &order {
        let op = &g.ops[oi];
        let h_op = op_halo(op)?;
        let mut upstream = 0;
        for &inp in &op.inputs {
            if g.tensor(inp).kind != TensorKind::Weight {
                upstream = upstream.max(halo[inp.0]);
            }
        }
        halo[op.output.0] = upstream + h_op;
    }
    Ok(halo[g.outputs()[0].id.0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn conv_relu_halo_is_one() {
        let g = models::conv_relu(32, 8, 8);
        assert_eq!(op_halo(g.op("conv0").unwrap()).unwrap(), 1);
        assert_eq!(op_halo(g.op("rr0").unwrap()).unwrap(), 0);
        assert_eq!(graph_halo(&g).unwrap(), 1);
        assert_eq!(check_tilable(&g).unwrap(), (32, 32));
    }

    #[test]
    fn cascade_halo_accumulates_per_conv() {
        let g = models::cascade(32, 8, 8);
        assert_eq!(graph_halo(&g).unwrap(), 2);
    }

    #[test]
    fn residual_halo_is_deep_path_max() {
        // skip path contributes 0; conv-conv path contributes 2
        let g = models::residual(32, 8, 8);
        assert_eq!(graph_halo(&g).unwrap(), 2);
    }

    #[test]
    fn vgg_block_halo_is_layer_count() {
        let g = models::vgg_block(64, 8, 5);
        assert_eq!(graph_halo(&g).unwrap(), 5);
    }

    #[test]
    fn pooling_and_matmul_rejected() {
        let g = models::tiny_cnn(32, 4, 8);
        assert!(graph_halo(&g).is_err(), "stride-2 pooling must not be tilable");
        let g = models::linear();
        assert!(check_tilable(&g).is_err(), "rank-2 matrices must not be tilable");
    }
}
