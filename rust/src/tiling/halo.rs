//! Halo (ghost-region) analysis for 2-D tile-grid decomposition, with
//! per-op stride-aware coordinate remapping.
//!
//! A cell of a feature map can only be computed independently if its
//! input window carries enough *halo* — extra boundary rows/columns —
//! to feed every sliding window that overlaps a cell edge. Because ops
//! may be strided (strided conv, 2×2 pooling), the dependency cone of
//! one final-output position is an *affine interval* in every upstream
//! tensor's own coordinate system, not a fixed radius: a final output
//! index `o` needs tensor positions `[S·o − A, S·o + B]`, where `S` is
//! the product of the strides downstream of that tensor and `(A, B)`
//! accumulate kernel extents and paddings along the deepest path.
//!
//! Composing one sliding op `(s, K_eff, pad)` onto a downstream cone
//! `(S, A, B)` gives the input-side cone
//! `(s·S, s·A + pad, s·B + K_eff − 1 − pad)` — the coordinate remapping
//! rule the whole tile-grid subsystem is built on. Elementwise identity
//! ops leave the cone unchanged; residual diamonds take the
//! elementwise max over paths (`AxisCone::join`).
//!
//! Tilable graphs are rank-3 `(H, W, C)` chains/DAGs of sliding-window
//! and identity elementwise ops whose window arithmetic is *exact* at
//! every stage (`(extent + 2·pad − K_eff) % stride == 0`) — floor-
//! truncating windows would make cells disagree with the full map at
//! the right/bottom borders and are rejected with a descriptive error.
//! Matrix ops (rank-2) are rejected: they have no spatial axes.

use anyhow::{bail, ensure, Context, Result};

use crate::analysis::classify::{classify, KernelClass};
use crate::ir::generic::GenericOp;
use crate::ir::graph::{ModelGraph, TensorKind};

/// Spatial axes of an `(H, W, C)` feature map.
pub const AXIS_H: usize = 0;
pub const AXIS_W: usize = 1;

/// Per-axis sliding-window parameters of one op: output index `q` reads
/// input positions `[stride·q − pad, stride·q − pad + keff − 1]`.
/// Identity (elementwise) ops are `stride = 1, keff = 1, pad = 0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxisWindow {
    pub stride: usize,
    /// Effective kernel extent `(K − 1)·dilation + 1` along this axis.
    pub keff: usize,
    pub pad: usize,
}

impl AxisWindow {
    pub fn identity() -> Self {
        AxisWindow { stride: 1, keff: 1, pad: 0 }
    }

    pub fn is_identity(&self) -> bool {
        *self == Self::identity()
    }

    /// Output extent produced from `in_extent` input positions. Errors
    /// unless the window arithmetic is exact (no floor truncation) —
    /// the tilability requirement.
    pub fn out_extent(&self, in_extent: usize) -> Result<usize> {
        let padded = in_extent + 2 * self.pad;
        ensure!(
            padded >= self.keff,
            "extent {in_extent} (+2x{} pad) is smaller than the {} window",
            self.pad,
            self.keff
        );
        let span = padded - self.keff;
        ensure!(
            span % self.stride == 0,
            "stride {} does not tile extent {in_extent} exactly \
             (K_eff {}, pad {}) — floor-truncating windows are not tilable",
            self.stride,
            self.keff,
            self.pad
        );
        Ok(span / self.stride + 1)
    }
}

/// Dependency cone of the graph output into one tensor, along one axis:
/// final output index `o` needs tensor positions `[scale·o − lo, scale·o + hi]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxisCone {
    /// Product of the strides downstream of the tensor.
    pub scale: usize,
    pub lo: usize,
    pub hi: usize,
}

impl AxisCone {
    /// The output tensor's own cone.
    pub fn identity() -> Self {
        AxisCone { scale: 1, lo: 0, hi: 0 }
    }

    /// Cone of an op's *input*, given the cone of its output and the
    /// op's window on this axis — the stride-aware coordinate remap.
    pub fn through(&self, w: &AxisWindow) -> AxisCone {
        AxisCone {
            scale: w.stride * self.scale,
            lo: w.stride * self.lo + w.pad,
            hi: w.stride * self.hi + w.keff - 1 - w.pad,
        }
    }

    /// Worst-case union at a fan-out tensor (residual diamonds): the
    /// deepest path per side wins. Scales must agree — all paths from a
    /// tensor to the output cross the same strided ops.
    pub fn join(&self, o: &AxisCone) -> Result<AxisCone> {
        ensure!(
            self.scale == o.scale,
            "inconsistent downstream stride products {} vs {} — paths with \
             different cumulative strides cannot reconverge on a valid DAG",
            self.scale,
            o.scale
        );
        Ok(AxisCone { scale: self.scale, lo: self.lo.max(o.lo), hi: self.hi.max(o.hi) })
    }

    /// Per-side radius in the tensor's own coordinates (max of the two
    /// sides) — the scalar "halo" summary.
    pub fn radius(&self) -> usize {
        self.lo.max(self.hi)
    }
}

/// Per-axis window of `op` for spatial axis `ax` (0 = height,
/// 1 = width). Errors when the op has no grid-tilable form on that axis.
pub fn op_axis_window(op: &GenericOp, ax: usize) -> Result<AxisWindow> {
    match classify(op) {
        KernelClass::PureParallel => {
            for m in &op.indexing_maps {
                ensure!(
                    m.is_identity(),
                    "op {}: non-identity elementwise map is not grid-tilable",
                    op.name
                );
            }
            Ok(AxisWindow::identity())
        }
        KernelClass::SlidingWindow(_) => {
            let out_dim = op.output_map().results[ax]
                .single_dim()
                .with_context(|| format!("op {}: output axis {ax} is not a plain dim", op.name))?;
            // input 0 is the streamed activation by construction
            let expr = &op.indexing_maps[0].results[ax];
            let (terms, konst) = expr
                .linear_terms()
                .with_context(|| format!("op {}: non-linear access on axis {ax}", op.name))?;
            match terms.len() {
                1 => {
                    let (d, c) = terms[0];
                    ensure!(
                        d == out_dim && c == 1 && konst == 0,
                        "op {}: axis {ax} access {expr} is neither identity nor a \
                         sliding window",
                        op.name
                    );
                    Ok(AxisWindow::identity())
                }
                2 => {
                    let (d_a, c_a) = terms[0];
                    let (d_b, c_b) = terms[1];
                    let (stride, r, dil) = if d_a == out_dim {
                        (c_a, d_b, c_b)
                    } else if d_b == out_dim {
                        (c_b, d_a, c_a)
                    } else {
                        bail!(
                            "op {}: axis {ax} access {expr} does not use the \
                             output's axis iterator d{out_dim}",
                            op.name
                        );
                    };
                    ensure!(
                        stride > 0 && dil > 0 && konst <= 0,
                        "op {}: axis {ax} window needs positive stride/dilation \
                         and non-positive pad offset, got {expr}",
                        op.name
                    );
                    ensure!(
                        crate::ir::generic::IterType::Reduction == op.iter_types[r],
                        "op {}: axis {ax} window dim d{r} is not a reduction iterator",
                        op.name
                    );
                    let k = op.dims[r];
                    let keff = (k - 1) * dil as usize + 1;
                    let pad = (-konst) as usize;
                    ensure!(
                        pad < keff,
                        "op {}: axis {ax} pad {pad} is not smaller than the \
                         effective window {keff}",
                        op.name
                    );
                    Ok(AxisWindow { stride: stride as usize, keff, pad })
                }
                n => bail!("op {}: axis {ax} access {expr} has {n} terms", op.name),
            }
        }
        KernelClass::RegularReduction => {
            bail!("op {}: regular reductions have no spatial axes to tile", op.name)
        }
    }
}

/// Grid geometry of a tilable graph: per-axis input/output extents and
/// the input-space dependency cone.
#[derive(Debug, Clone, Copy)]
pub struct GridGeom {
    /// Graph-input extent per axis `[H, W]`.
    pub in_extent: [usize; 2],
    /// Graph-output extent per axis `[H_out, W_out]`.
    pub out_extent: [usize; 2],
    /// Graph-input dependency cone per axis.
    pub cone: [AxisCone; 2],
}

/// The graph-output cone into every tensor along axis `ax` (`None` for
/// weights). Reverse-toposort DP, so residual diamonds take the deepest
/// path per side.
pub fn tensor_cones(g: &ModelGraph, ax: usize) -> Result<Vec<Option<AxisCone>>> {
    let order = g.toposort()?;
    let mut cones: Vec<Option<AxisCone>> = vec![None; g.tensors.len()];
    cones[g.outputs()[0].id.0] = Some(AxisCone::identity());
    for &oi in order.iter().rev() {
        let op = &g.ops[oi];
        let out = cones[op.output.0].with_context(|| {
            format!("op {} does not reach the graph output", op.name)
        })?;
        let w = op_axis_window(op, ax)?;
        let inc = out.through(&w);
        for &inp in &op.inputs {
            if g.tensor(inp).kind == TensorKind::Weight {
                continue;
            }
            cones[inp.0] = Some(match cones[inp.0] {
                Some(prev) => prev.join(&inc)?,
                None => inc,
            });
        }
    }
    Ok(cones)
}

/// Check that `g` is grid-tilable — every activation tensor is a rank-3
/// `(H, W, C)` feature map, every op is an exact sliding window or
/// identity elementwise op on both spatial axes, and the declared tensor
/// shapes agree with the window arithmetic. Returns the grid geometry.
pub fn check_tilable(g: &ModelGraph) -> Result<GridGeom> {
    for t in &g.tensors {
        if t.kind == TensorKind::Weight {
            continue;
        }
        ensure!(
            t.ty.rank() == 3,
            "tensor {} is rank {} — grid tiling needs rank-3 (height, width, \
             channels) feature maps",
            t.name,
            t.ty.rank()
        );
    }
    for op in &g.ops {
        let out_t = g.tensor(op.output);
        for ax in [AXIS_H, AXIS_W] {
            let w = op_axis_window(op, ax)?;
            let mut in_extent = None;
            for &inp in &op.inputs {
                let t = g.tensor(inp);
                if t.kind == TensorKind::Weight {
                    continue;
                }
                match in_extent {
                    None => in_extent = Some(t.ty.shape[ax]),
                    Some(prev) => ensure!(
                        prev == t.ty.shape[ax],
                        "op {}: activation inputs disagree on axis {ax} \
                         ({prev} vs {})",
                        op.name,
                        t.ty.shape[ax]
                    ),
                }
            }
            let in_extent = in_extent
                .with_context(|| format!("op {} has no activation input", op.name))?;
            let got = w
                .out_extent(in_extent)
                .with_context(|| format!("op {} axis {ax}", op.name))?;
            ensure!(
                got == out_t.ty.shape[ax],
                "op {}: axis {ax} window arithmetic gives {got} but tensor {} \
                 declares {}",
                op.name,
                out_t.name,
                out_t.ty.shape[ax]
            );
        }
    }
    let inp = g.inputs()[0];
    let out = g.outputs()[0];
    let mut cone = [AxisCone::identity(), AxisCone::identity()];
    for ax in [AXIS_H, AXIS_W] {
        cone[ax] = tensor_cones(g, ax)?[inp.id.0]
            .with_context(|| format!("graph input does not reach the output on axis {ax}"))?;
    }
    Ok(GridGeom {
        in_extent: [inp.ty.shape[0], inp.ty.shape[1]],
        out_extent: [out.ty.shape[0], out.ty.shape[1]],
        cone,
    })
}

/// Per-side width-axis halo radius of the whole graph, in *input*
/// columns — the scalar summary the CLI and reports print. For stride-1
/// same-padded chains this is the classic summed-pads halo; for strided
/// chains it is the (asymmetric) input-space cone's larger side.
pub fn graph_halo(g: &ModelGraph) -> Result<usize> {
    Ok(check_tilable(g)?.cone[AXIS_W].radius())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn conv_relu_windows_and_halo() {
        let g = models::conv_relu(32, 8, 8);
        let w = op_axis_window(g.op("conv0").unwrap(), AXIS_W).unwrap();
        assert_eq!(w, AxisWindow { stride: 1, keff: 3, pad: 1 });
        assert!(op_axis_window(g.op("rr0").unwrap(), AXIS_W).unwrap().is_identity());
        assert_eq!(graph_halo(&g).unwrap(), 1);
        let geom = check_tilable(&g).unwrap();
        assert_eq!(geom.in_extent, [32, 32]);
        assert_eq!(geom.out_extent, [32, 32]);
        assert_eq!(geom.cone[AXIS_H], AxisCone { scale: 1, lo: 1, hi: 1 });
    }

    #[test]
    fn cascade_halo_accumulates_per_conv() {
        let g = models::cascade(32, 8, 8);
        assert_eq!(graph_halo(&g).unwrap(), 2);
    }

    #[test]
    fn residual_halo_is_deep_path_max() {
        // skip path contributes 0; conv-conv path contributes 2
        let g = models::residual(32, 8, 8);
        assert_eq!(graph_halo(&g).unwrap(), 2);
    }

    #[test]
    fn vgg_block_halo_is_layer_count() {
        let g = models::vgg_block(64, 8, 5);
        assert_eq!(graph_halo(&g).unwrap(), 5);
    }

    #[test]
    fn strided_pooled_chain_remaps_coordinates() {
        // conv(3x3,p1) -> pool(2x2,s2) -> conv(3x3,p1) -> pool(2x2,s2):
        // composing backward from the output,
        //   conv1..pool1: (2, 2, 3); conv1: (2, 3, 4) is the mid chain;
        // the full tiny_cnn input cone is (4, 3, 6).
        let g = models::tiny_cnn(32, 4, 8);
        let geom = check_tilable(&g).expect("stride-2 pooled chains are now tilable");
        for ax in [AXIS_H, AXIS_W] {
            assert_eq!(geom.cone[ax], AxisCone { scale: 4, lo: 3, hi: 6 }, "axis {ax}");
        }
        assert_eq!(geom.in_extent, [32, 32]);
        assert_eq!(geom.out_extent, [8, 8]);
        assert_eq!(graph_halo(&g).unwrap(), 6);
    }

    #[test]
    fn conv_pool_conv_cone() {
        let g = models::conv_pool_conv(512, 8);
        let geom = check_tilable(&g).unwrap();
        assert_eq!(geom.cone[AXIS_W], AxisCone { scale: 2, lo: 3, hi: 4 });
        assert_eq!(geom.out_extent, [256, 256]);
    }

    #[test]
    fn matmul_and_non_exact_windows_rejected() {
        let g = models::linear();
        let err = check_tilable(&g).unwrap_err();
        assert!(format!("{err:#}").contains("width"), "{err:#}");

        // 2x2/2 pooling over an odd extent floor-truncates -> rejected
        use crate::ir::builder::GraphBuilder;
        use crate::ir::types::DType;
        let mut b = GraphBuilder::new("odd");
        let x = b.input("x", vec![9, 9, 2], DType::I8);
        let y = b.maxpool2d("pool", x, 2, 2);
        b.mark_output(y);
        let g = b.finish();
        let err = check_tilable(&g).unwrap_err();
        assert!(format!("{err:#}").contains("exactly"), "{err:#}");
    }

    #[test]
    fn cone_composition_rules() {
        let out = AxisCone::identity();
        let conv = AxisWindow { stride: 1, keff: 3, pad: 1 };
        let pool = AxisWindow { stride: 2, keff: 2, pad: 0 };
        let c1 = out.through(&conv);
        assert_eq!(c1, AxisCone { scale: 1, lo: 1, hi: 1 });
        let c2 = c1.through(&pool);
        assert_eq!(c2, AxisCone { scale: 2, lo: 2, hi: 3 });
        let c3 = c2.through(&conv);
        assert_eq!(c3, AxisCone { scale: 2, lo: 3, hi: 4 });
        // join takes the per-side max and keeps the scale
        let j = c3.join(&AxisCone { scale: 2, lo: 5, hi: 1 }).unwrap();
        assert_eq!(j, AxisCone { scale: 2, lo: 5, hi: 4 });
        assert!(c3.join(&AxisCone { scale: 4, lo: 0, hi: 0 }).is_err());
    }

    #[test]
    fn exact_window_extent_math() {
        let conv = AxisWindow { stride: 1, keff: 3, pad: 1 };
        assert_eq!(conv.out_extent(32).unwrap(), 32);
        let pool = AxisWindow { stride: 2, keff: 2, pad: 0 };
        assert_eq!(pool.out_extent(32).unwrap(), 16);
        assert!(pool.out_extent(9).is_err(), "odd extents floor-truncate");
        let strided = AxisWindow { stride: 2, keff: 3, pad: 0 };
        assert_eq!(strided.out_extent(9).unwrap(), 4);
        assert!(strided.out_extent(10).is_err());
    }
}
