//! FIFO depth sizing from first-output-cycle estimates (paper §IV-C,
//! final paragraph): the estimated cycle at which each node emits its
//! first output token tells the DSE how much lag a reconvergent path can
//! accumulate; the shallow side of every diamond gets a FIFO deep enough
//! to absorb that lag, preventing deadlock in residual-style graphs.
//! Plain producer→consumer chains keep small depths (the paper notes the
//! estimates are conservative — future work integrates FIFOAdvisor).

use std::collections::HashMap;

use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::Design;

/// Margin tokens added on top of the computed lag.
pub const FIFO_MARGIN: usize = 4;
/// Depth of ordinary (non-diamond) streams.
pub const FIFO_BASE_DEPTH: usize = 4;

/// Estimated *input-token lag*: how many tokens a node consumes before
/// its first output appears (warm-up accumulated along the path).
fn lag(d: &Design, node: usize, memo: &mut HashMap<usize, u64>) -> u64 {
    if let Some(&v) = memo.get(&node) {
        return v;
    }
    let n = &d.nodes[node];
    let upstream = n
        .in_channels
        .iter()
        .map(|&c| match d.channel(c).src {
            Endpoint::Node(p) => lag(d, p, memo),
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    let v = upstream + n.geo.warmup_tokens;
    memo.insert(node, v);
    v
}

/// Assign depths to every channel: base depth everywhere, plus diamond
/// lag absorption on reconvergent inputs. Also aligns channel lanes with
/// the consuming node's reduction unroll (the stream constraint's width
/// coupling: streams are read `unroll` values at a time).
pub fn size_fifos(d: &mut Design) {
    let mut memo = HashMap::new();
    // compute all lags first (immutable pass)
    let lags: Vec<u64> = (0..d.nodes.len()).map(|i| lag(d, i, &mut memo)).collect();

    // Base depth covers the producer's pipeline latency: with II=1 the
    // producer keeps `depth` results in flight, and the FIFO must absorb
    // them for back-to-back streaming (this is the paper's "estimated
    // clock cycles for the first element to appear in the output stream"
    // sizing rule applied to straight edges).
    let mut new_depths: Vec<usize> = d
        .channels
        .iter()
        .map(|c| match c.src {
            Endpoint::Node(p) => {
                FIFO_BASE_DEPTH + d.nodes[p].timing.depth as usize + FIFO_MARGIN
            }
            _ => FIFO_BASE_DEPTH,
        })
        .collect();
    for n in &d.nodes {
        if n.in_channels.len() < 2 {
            continue;
        }
        let in_lags: Vec<u64> = n
            .in_channels
            .iter()
            .map(|&c| match d.channel(c).src {
                Endpoint::Node(p) => lags[p],
                _ => 0,
            })
            .collect();
        let max_lag = *in_lags.iter().max().unwrap();
        for (slot, &c) in n.in_channels.iter().enumerate() {
            let need = (max_lag - in_lags[slot]) as usize;
            if need > 0 {
                new_depths[c.0] = new_depths[c.0].max(need + FIFO_MARGIN);
            }
        }
    }
    for (c, depth) in d.channels.iter_mut().zip(new_depths) {
        c.depth = depth;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::dataflow::validate::check_diamond_depths;
    use crate::ir::builder::models;

    #[test]
    fn residual_skip_sized_to_cover_conv_lag() {
        let g = models::residual(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        let skip = d.channels.iter().find(|c| c.name == "add0_in0").unwrap();
        // two conv warm-ups upstream of the deep path ⇒ ≥ 2 rows of lag
        assert!(skip.depth as u64 >= 2 * 32, "skip depth {}", skip.depth);
        assert!(check_diamond_depths(&d).is_empty());
    }

    #[test]
    fn straight_chains_get_latency_covering_depth() {
        let g = models::cascade(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        for c in &d.channels {
            // small (latency-order), never tensor-order
            assert!(c.depth >= FIFO_BASE_DEPTH, "channel {}", c.name);
            assert!(c.depth < 64, "channel {} depth {} too deep", c.name, c.depth);
        }
    }

    #[test]
    fn sizing_is_idempotent() {
        let g = models::residual(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        let depths: Vec<usize> = d.channels.iter().map(|c| c.depth).collect();
        size_fifos(&mut d);
        let again: Vec<usize> = d.channels.iter().map(|c| c.depth).collect();
        assert_eq!(depths, again);
    }
}
