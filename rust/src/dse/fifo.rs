//! FIFO depth sizing from first-output-cycle estimates (paper §IV-C,
//! final paragraph): the estimated cycle at which each node emits its
//! first output token tells the DSE how much lag a reconvergent path can
//! accumulate; the shallow side of every diamond gets a FIFO deep enough
//! to absorb that lag, preventing deadlock in residual-style graphs.
//! Plain producer→consumer chains keep small depths (the paper notes the
//! estimates are conservative — future work integrates FIFOAdvisor).
//!
//! The sizing *policy* is exposed as pure functions ([`diamond_mins`],
//! [`planned_depth`]) so the unified resource model
//! ([`crate::resources::model`]) can price a channel's BRAM for any
//! candidate timing **before** the depths are committed — the solver's
//! per-candidate FIFO accounting and the depths [`size_fifos`] actually
//! assigns can never disagree, because they are the same computation.

use std::collections::HashMap;

use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::Design;

/// Margin tokens added on top of the computed lag.
pub const FIFO_MARGIN: usize = 4;
/// Depth of ordinary (non-diamond) streams.
pub const FIFO_BASE_DEPTH: usize = 4;

/// Estimated *input-token lag*: how many tokens a node consumes before
/// its first output appears (warm-up accumulated along the path).
fn lag(d: &Design, node: usize, memo: &mut HashMap<usize, u64>) -> u64 {
    if let Some(&v) = memo.get(&node) {
        return v;
    }
    let n = &d.nodes[node];
    let upstream = n
        .in_channels
        .iter()
        .map(|&c| match d.channel(c).src {
            Endpoint::Node(p) => lag(d, p, memo),
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    let v = upstream + n.geo.warmup_tokens;
    memo.insert(node, v);
    v
}

/// Base depth of a channel: covers the producer's pipeline latency (with
/// II=1 the producer keeps `depth` results in flight and the FIFO must
/// absorb them for back-to-back streaming). Channels fed by the graph
/// input have no producer pipeline and keep the bare base depth.
pub fn base_depth(producer_pipeline_depth: Option<u64>) -> usize {
    match producer_pipeline_depth {
        Some(depth) => FIFO_BASE_DEPTH + depth as usize + FIFO_MARGIN,
        None => FIFO_BASE_DEPTH,
    }
}

/// The depth [`size_fifos`] will assign to a channel whose producer has
/// the given pipeline depth and whose diamond-absorption floor is
/// `diamond_min` (0 when the channel is not a reconvergent input).
pub fn planned_depth(producer_pipeline_depth: Option<u64>, diamond_min: usize) -> usize {
    base_depth(producer_pipeline_depth).max(diamond_min)
}

/// Per-channel minimum depths imposed by reconvergent (diamond) joins:
/// the shallow side of every diamond must buffer the lag difference of
/// its sibling paths plus margin. Lags are pure streaming geometry
/// (line-buffer warm-ups), so this floor is independent of the DSE's
/// unroll choices — the resource model treats it as a per-design
/// constant.
pub fn diamond_mins(d: &Design) -> Vec<usize> {
    let mut memo = HashMap::new();
    let lags: Vec<u64> = (0..d.nodes.len()).map(|i| lag(d, i, &mut memo)).collect();
    let mut mins = vec![0usize; d.channels.len()];
    for n in &d.nodes {
        if n.in_channels.len() < 2 {
            continue;
        }
        let in_lags: Vec<u64> = n
            .in_channels
            .iter()
            .map(|&c| match d.channel(c).src {
                Endpoint::Node(p) => lags[p],
                _ => 0,
            })
            .collect();
        let max_lag = *in_lags.iter().max().unwrap();
        for (slot, &c) in n.in_channels.iter().enumerate() {
            let need = (max_lag - in_lags[slot]) as usize;
            if need > 0 {
                mins[c.0] = mins[c.0].max(need + FIFO_MARGIN);
            }
        }
    }
    mins
}

/// Assign depths to every channel from the shared policy: base depth
/// covering the producer's pipeline latency, raised to the diamond
/// absorption floor on reconvergent inputs.
pub fn size_fifos(d: &mut Design) {
    let mins = diamond_mins(d);
    let depths: Vec<usize> = d
        .channels
        .iter()
        .map(|c| {
            let src_depth = match c.src {
                Endpoint::Node(p) => Some(d.nodes[p].timing.depth),
                _ => None,
            };
            planned_depth(src_depth, mins[c.id.0])
        })
        .collect();
    for (c, depth) in d.channels.iter_mut().zip(depths) {
        c.depth = depth;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::dataflow::validate::check_diamond_depths;
    use crate::ir::builder::models;

    #[test]
    fn residual_skip_sized_to_cover_conv_lag() {
        let g = models::residual(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        let skip = d.channels.iter().find(|c| c.name == "add0_in0").unwrap();
        // two conv warm-ups upstream of the deep path ⇒ ≥ 2 rows of lag
        assert!(skip.depth as u64 >= 2 * 32, "skip depth {}", skip.depth);
        assert!(check_diamond_depths(&d).is_empty());
    }

    #[test]
    fn straight_chains_get_latency_covering_depth() {
        let g = models::cascade(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        for c in &d.channels {
            // small (latency-order), never tensor-order
            assert!(c.depth >= FIFO_BASE_DEPTH, "channel {}", c.name);
            assert!(c.depth < 64, "channel {} depth {} too deep", c.name, c.depth);
        }
    }

    #[test]
    fn sizing_is_idempotent() {
        let g = models::residual(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        let depths: Vec<usize> = d.channels.iter().map(|c| c.depth).collect();
        size_fifos(&mut d);
        let again: Vec<usize> = d.channels.iter().map(|c| c.depth).collect();
        assert_eq!(depths, again);
    }

    #[test]
    fn planned_depth_is_what_size_fifos_assigns() {
        // The policy functions must predict assigned depths exactly —
        // the unified resource model's FIFO pricing rests on this.
        let g = models::residual(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        let mins = diamond_mins(&d);
        let predicted: Vec<usize> = d
            .channels
            .iter()
            .map(|c| {
                let src = match c.src {
                    Endpoint::Node(p) => Some(d.nodes[p].timing.depth),
                    _ => None,
                };
                planned_depth(src, mins[c.id.0])
            })
            .collect();
        size_fifos(&mut d);
        let assigned: Vec<usize> = d.channels.iter().map(|c| c.depth).collect();
        assert_eq!(predicted, assigned);
    }
}
