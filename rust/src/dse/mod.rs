//! Automatic design-space exploration (paper §IV-C, Eq. (1)).
//!
//! The DSE picks per-node loop unroll factors (and the derived stream
//! widths, array partitionings and PIPELINE placements) minimizing the
//! total cycle estimate subject to:
//!
//! * **Unroll**: every unroll factor divides its loop trip count;
//! * **DSP**:  Σ ceil(lanes/2) ≤ D_total (int8 packing, `resources::dsp`);
//! * **BRAM**: Σ partition-scaled buffer blocks + FIFO blocks ≤ B_total;
//! * **Stream**: producer and consumer widths of every channel agree —
//!   enforced *by construction* here, since a [`crate::dataflow::Channel`]
//!   carries a single `lanes` field shared by both endpoints.
//!
//! The space is a product of divisor lattices (unroll | trip), small by
//! construction, solved exactly with branch-and-bound ([`ilp`]). FIFO
//! depths are then sized from first-output-cycle estimates ([`fifo`]),
//! preventing diamond deadlocks (residual blocks).

pub mod space;
pub mod ilp;
pub mod fifo;
pub mod warmstart;

pub use ilp::{solve, solve_with_tiling_fallback, Compiled, DseConfig, DseSolution};
pub use space::grid_counts;
pub use warmstart::WarmStart;
