//! Cross-problem DSE reuse: node-front memoization and repair-based
//! incumbent warm-starting.
//!
//! A sweep solves many problems that share almost all of their per-node
//! structure — the same `relu_requant` geometry recurs across layers of
//! one design and across workloads, and a tile-grid search probes dozens
//! of cell geometries that differ only in extents. This module holds the
//! two reuse tiers the solver (`dse::ilp::solve`) consults when a shared
//! [`WarmStart`] handle rides in its [`super::ilp::DseConfig`]:
//!
//! 1. **Node-front memoization.** Each node's canonical candidate list
//!    *and* its dominance-filtered Pareto front are keyed by
//!    [`WarmStart::front_key`] — a structural fingerprint of everything
//!    candidate enumeration reads ([`space::node_front_fingerprint`])
//!    folded with the device budgets — so each distinct layer geometry
//!    is enumerated, priced, and filtered once per process instead of
//!    once per job (`dse.front_hits` / `dse.front_misses`).
//!
//! 2. **Repair-based incumbent seeding.** Solved problems record their
//!    winning unroll assignment under a *shape* fingerprint that
//!    deliberately ignores extents and budgets
//!    ([`WarmStart::shape_fingerprint`]), so a structurally-similar
//!    neighbor (same op sequence, different sizes) can look up the
//!    nearest solution ([`WarmStart::nearest_seed`]) and *repair* it
//!    against its own lattice and resource model. A seed that
//!    re-validates is a feasible assignment of the *current* problem,
//!    so its objective is a sound initial upper bound for the shared
//!    branch-and-bound incumbent (`dse.warm_seeds`); one that does not
//!    is discarded (`dse.warm_seed_rejected`) and the search runs cold.
//!
//! Neither tier may move the solution — seeding preserves the strict
//! prune bound (see the proof at `dse::ilp::serial_search`), and a front
//! hit replays a byte-identical candidate vector — which is what lets
//! the design cache's byte-identity invariant survive warm-started
//! sweeps (pinned by `prop_parallel_dse_is_bit_identical_to_serial`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::dataflow::design::Design;
use crate::ir::fingerprint::{fold_device_budgets, Fnv64};
use crate::resources::device::DeviceSpec;
use crate::resources::model::ResourceModel;

use super::space::{self, Candidate};

/// One memoized node-front: the full canonical candidate list, its
/// dominance-filtered Pareto front, and how many candidates the filter
/// dropped. `full` is kept alongside `front` because incumbent-seed
/// validation must run against the *unfiltered* lattice (the filter may
/// drop the seed's exact pick even though a dominator of it survives),
/// and because configs with the filter disabled search `full` directly.
#[derive(Debug)]
pub struct FrontEntry {
    pub full: Vec<Candidate>,
    pub front: Vec<Candidate>,
    pub dropped: u64,
}

/// One recorded solution under a shape fingerprint: the extent vector it
/// was solved at (for nearest-neighbor distance) and the winning
/// per-node `(unroll_par, unroll_red)` assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
struct SeedEntry {
    extents: Vec<u64>,
    picks: Vec<(u64, u64)>,
}

/// Seeds retained per shape fingerprint, most recent first. Small on
/// purpose: a sweep visits each shape at a handful of extents, and a
/// stale seed costs a full (failed) re-validation per solve.
const SEED_CAP: usize = 8;

/// The shared warm-start state: a node-front cache and a seed store,
/// held in an `Arc` alongside the design cache (one per
/// `CompileService`, or per CLI invocation) and consulted by every
/// solve whose config carries it. Purely in-memory — unlike the design
/// cache there is no disk tier, because fronts hash process-local
/// `Debug` renderings and seeds are only worth microseconds each.
#[derive(Debug, Default)]
pub struct WarmStart {
    fronts: Mutex<HashMap<u64, Arc<FrontEntry>>>,
    seeds: Mutex<HashMap<u64, Vec<SeedEntry>>>,
}

impl WarmStart {
    pub fn new() -> Self {
        Self::default()
    }

    /// The node-front cache key: the structural fingerprint of
    /// everything candidate enumeration reads for node `nid`, folded
    /// with the device budgets. The budgets are included conservatively
    /// (candidate vectors do not actually depend on them today) so the
    /// key stays sound if pricing ever becomes budget-aware, mirroring
    /// `problem_fingerprint`'s budget fold.
    pub fn front_key(model: &ResourceModel, d: &Design, nid: usize, dev: &DeviceSpec) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(space::node_front_fingerprint(model, d, nid));
        fold_device_budgets(&mut h, dev);
        h.finish()
    }

    /// Look up a memoized front (counts `dse.front_hits` /
    /// `dse.front_misses`).
    pub fn front(&self, key: u64) -> Option<Arc<FrontEntry>> {
        let hit = self.fronts.lock().unwrap().get(&key).cloned();
        let m = crate::obs::metrics::global();
        match &hit {
            Some(_) => m.incr("dse.front_hits"),
            None => m.incr("dse.front_misses"),
        }
        hit
    }

    /// Memoize an enumerated front. Returns the stored entry; on a
    /// store race the first writer wins (both sides enumerated the same
    /// key, so the vectors are byte-identical either way).
    pub fn store_front(
        &self,
        key: u64,
        full: Vec<Candidate>,
        front: Vec<Candidate>,
        dropped: u64,
    ) -> Arc<FrontEntry> {
        Arc::clone(
            self.fronts
                .lock()
                .unwrap()
                .entry(key)
                .or_insert_with(|| Arc::new(FrontEntry { full, front, dropped })),
        )
    }

    /// The seed store's key: the design's op-sequence *shape* — per node
    /// its payload kind and kernel class, in node order — with extents,
    /// weights, and budgets deliberately excluded so neighboring sweep
    /// points (same chain, different sizes or budgets) collide. A loose
    /// key is safe: a looked-up seed is never trusted, only offered to
    /// re-validation against the current problem's own lattice.
    pub fn shape_fingerprint(d: &Design) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(d.nodes.len());
        for n in &d.nodes {
            h.write_str(d.graph.ops[n.op_index].payload.name());
            h.write_str(&format!("{:?}", n.geo.class));
        }
        h.finish()
    }

    /// The extent vector distances are measured in: per node its
    /// parallel and reduction trip counts, then the two budget axes the
    /// solver constrains. Nearer in this space means the recorded
    /// assignment is likelier to still lie on the lattice and fit.
    pub fn seed_extents(d: &Design, dev: &DeviceSpec) -> Vec<u64> {
        let mut v = Vec::with_capacity(2 * d.nodes.len() + 2);
        for n in &d.nodes {
            v.push(n.geo.out_token_len as u64);
            v.push(d.graph.ops[n.op_index].reduction_space().max(1));
        }
        v.push(dev.dsp);
        v.push(dev.bram18k);
        v
    }

    /// Record a solved assignment under its shape fingerprint:
    /// duplicates (same picks) are refreshed to the front, the store is
    /// capped at [`SEED_CAP`] most-recent entries.
    pub fn record_seed(&self, shape: u64, extents: Vec<u64>, picks: Vec<(u64, u64)>) {
        let mut seeds = self.seeds.lock().unwrap();
        let list = seeds.entry(shape).or_default();
        list.retain(|s| s.picks != picks);
        list.insert(0, SeedEntry { extents, picks });
        list.truncate(SEED_CAP);
    }

    /// The recorded assignment nearest to `extents` (L1 distance over
    /// same-length extent vectors; ties keep the most recent). `None`
    /// when no comparable seed exists. The caller must re-validate the
    /// picks — this is a hint, never an answer.
    pub fn nearest_seed(&self, shape: u64, extents: &[u64]) -> Option<Vec<(u64, u64)>> {
        let seeds = self.seeds.lock().unwrap();
        let mut best: Option<(u64, &SeedEntry)> = None;
        for s in seeds.get(&shape)?.iter().filter(|s| s.extents.len() == extents.len()) {
            let dist: u64 =
                s.extents.iter().zip(extents).map(|(&a, &b)| a.abs_diff(b)).sum();
            if best.as_ref().map_or(true, |(bd, _)| dist < *bd) {
                best = Some((dist, s));
            }
        }
        best.map(|(_, s)| s.picks.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn front_cache_hits_after_store_and_counts_metrics() {
        let m = crate::obs::metrics::global();
        let (h0, m0) = (m.get("dse.front_hits"), m.get("dse.front_misses"));
        let w = WarmStart::new();
        assert!(w.front(7).is_none());
        let stored = w.store_front(7, Vec::new(), Vec::new(), 3);
        assert_eq!(stored.dropped, 3);
        let hit = w.front(7).expect("stored front must hit");
        assert!(Arc::ptr_eq(&stored, &hit), "hits share the stored Arc");
        // monotone `>=`: the registry is global and concurrently-running
        // tests may bump the counters too
        assert!(m.get("dse.front_hits") - h0 >= 1);
        assert!(m.get("dse.front_misses") - m0 >= 1);
    }

    #[test]
    fn store_front_race_keeps_the_first_entry() {
        let w = WarmStart::new();
        let first = w.store_front(1, Vec::new(), Vec::new(), 1);
        let second = w.store_front(1, Vec::new(), Vec::new(), 2);
        assert!(Arc::ptr_eq(&first, &second), "first writer wins");
        assert_eq!(second.dropped, 1);
    }

    #[test]
    fn front_key_covers_structure_and_budgets() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let model = ResourceModel::new(&d);
        let kv = DeviceSpec::kv260();
        let conv = WarmStart::front_key(&model, &d, 0, &kv);
        assert_eq!(conv, WarmStart::front_key(&model, &d, 0, &DeviceSpec::kv260()), "stable");
        assert_ne!(conv, WarmStart::front_key(&model, &d, 1, &kv), "distinct nodes");
        assert_ne!(
            conv,
            WarmStart::front_key(&model, &d, 0, &kv.with_dsp_limit(64)),
            "budgets key the front"
        );
        // a same-shape graph with different weight *contents* must share
        // fronts: pricing reads ROM sizes, never values
        let g2 = {
            use crate::ir::builder::GraphBuilder;
            use crate::ir::types::DType;
            let mut b = GraphBuilder::new("reseeded");
            let x = b.input("x", vec![32, 32, 8], DType::I8);
            let w = b.det_weight("w", vec![8, 3, 3, 8], 4242);
            let acc = b.conv2d("conv0", x, w, 1, 1);
            let y = b.relu_requant("rr0", acc);
            b.mark_output(y);
            b.finish()
        };
        let d2 = build_streaming_design(&g2).unwrap();
        let model2 = ResourceModel::new(&d2);
        assert_eq!(conv, WarmStart::front_key(&model2, &d2, 0, &kv));
    }

    #[test]
    fn nearest_seed_picks_the_closest_and_respects_arity() {
        let w = WarmStart::new();
        assert!(w.nearest_seed(9, &[10, 10]).is_none(), "empty store");
        w.record_seed(9, vec![8, 8], vec![(1, 1)]);
        w.record_seed(9, vec![32, 32], vec![(2, 2)]);
        w.record_seed(9, vec![8, 8, 8], vec![(3, 3)]); // different arity
        assert_eq!(w.nearest_seed(9, &[10, 10]), Some(vec![(1, 1)]));
        assert_eq!(w.nearest_seed(9, &[30, 30]), Some(vec![(2, 2)]));
        assert_eq!(w.nearest_seed(9, &[1, 2, 3]), Some(vec![(3, 3)]));
        assert!(w.nearest_seed(1, &[10, 10]).is_none(), "unknown shape");
    }

    #[test]
    fn seed_store_dedupes_and_caps() {
        let w = WarmStart::new();
        for i in 0..20u64 {
            w.record_seed(5, vec![i], vec![(i, i)]);
        }
        // capped: the oldest picks are gone, the newest survive
        assert_eq!(w.nearest_seed(5, &[19]), Some(vec![(19, 19)]));
        assert!(w.nearest_seed(5, &[0]).is_some(), "some seed always matches");
        assert_eq!(w.nearest_seed(5, &[0]), Some(vec![(12, 12)]), "oldest kept is 20-8");
        // re-recording existing picks refreshes instead of duplicating
        w.record_seed(5, vec![100], vec![(19, 19)]);
        assert_eq!(w.nearest_seed(5, &[100]), Some(vec![(19, 19)]));
    }

    #[test]
    fn shape_fingerprint_ignores_extents_but_not_structure() {
        let d32 = build_streaming_design(&models::conv_relu(32, 8, 8)).unwrap();
        let d48 = build_streaming_design(&models::conv_relu(48, 8, 8)).unwrap();
        let dch = build_streaming_design(&models::conv_relu(32, 4, 8)).unwrap();
        let casc = build_streaming_design(&models::cascade(32, 8, 8)).unwrap();
        assert_eq!(
            WarmStart::shape_fingerprint(&d32),
            WarmStart::shape_fingerprint(&d48),
            "sizes are extents, not shape"
        );
        assert_eq!(
            WarmStart::shape_fingerprint(&d32),
            WarmStart::shape_fingerprint(&dch),
            "channel counts are extents, not shape"
        );
        assert_ne!(
            WarmStart::shape_fingerprint(&d32),
            WarmStart::shape_fingerprint(&casc),
            "op sequences differ"
        );
        // extents differ where shapes agree — the distance axis works
        let kv = DeviceSpec::kv260();
        assert_ne!(WarmStart::seed_extents(&d32, &kv), WarmStart::seed_extents(&dch, &kv));
        assert_ne!(
            WarmStart::seed_extents(&d32, &kv),
            WarmStart::seed_extents(&d32, &kv.with_dsp_limit(64))
        );
    }
}
