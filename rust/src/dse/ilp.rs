//! The lightweight ILP of paper Eq. (1), solved exactly.
//!
//!   minimize    Σ_v Cycles(v)
//!   subject to  u_ℓ | trip(ℓ)                    (Unroll)
//!               Σ u_ℓ·η_ℓd ≤ D_total             (DSP)
//!               Σ u_ℓ·η_ℓb ≤ B_total             (BRAM)
//!               κ_src(s),s = κ_dst(s),s          (Stream)
//!
//! Variables live on divisor lattices (`space::candidates`), so the
//! integer program is a finite assignment problem; we solve it with
//! depth-first branch-and-bound using per-node lower bounds on cycles,
//! DSP and BRAM for pruning. Exact — no heuristics — and fast: paper
//! kernels have ≤ 6 nodes × ≤ 96 candidates.
//!
//! Three cold-path accelerators sit on top of the exact search, all
//! **bit-identical** to the plain serial solver (the design cache's
//! byte-identity invariant depends on that):
//!
//! * a Pareto-dominance candidate filter
//!   ([`super::space::dominance_filter`]) drops lattice points that can
//!   never appear in the first-found optimum, before the search runs;
//! * a parallel branch-and-bound: lexicographic prefix subtrees fan out
//!   as a task group on the process-wide work-stealing scheduler
//!   ([`crate::coordinator::sched`]), sharing the incumbent
//!   objective through an `AtomicU64` so one worker's improvement
//!   tightens every other worker's pruning, with a deterministic final
//!   argmin (lowest subtree index wins ties — exactly the assignment
//!   the serial first-found DFS keeps);
//! * cross-problem warm-starting ([`super::warmstart`]): memoized
//!   per-node candidate fronts skip re-enumeration for recurring layer
//!   geometries, and a re-validated neighbor solution seeds the shared
//!   incumbent with a sound upper bound before the first leaf is ever
//!   visited — pruning starts tight instead of starting blind.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::coordinator::cache::{self, DesignCache};
use crate::coordinator::sched;
use crate::dataflow::build::{build_streaming_design, refresh_buffers};
use crate::dataflow::design::Design;
use crate::ir::fingerprint::problem_fingerprint;
use crate::ir::graph::ModelGraph;
use crate::resources::device::DeviceSpec;
use crate::resources::model::{ResourceModel, ResourceVec};
use crate::tiling::{compile_tiled_from, TiledCompilation};

use super::fifo::size_fifos;
use super::space::{self, candidates_with, Candidate};
use super::warmstart::{FrontEntry, WarmStart};

/// DSE configuration.
///
/// The former `bram_reserve` fudge (a flat block count subtracted from
/// the budget to approximate FIFO backing) is gone: every candidate's
/// [`ResourceVec`] prices its weight ROMs and output-FIFO depths
/// exactly, so the solver charges the true budget and the estimate can
/// never diverge from the built design.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub device: DeviceSpec,
    /// Optional content-addressed design cache
    /// ([`crate::coordinator::cache`]). When present,
    /// [`solve_with_tiling_fallback`] reuses whole compiled outcomes
    /// and the tile-grid search reuses per-cell solutions — the solver
    /// itself ([`solve`]) stays cache-oblivious.
    pub cache: Option<Arc<DesignCache>>,
    /// Parallelism for the branch-and-bound subtree fan-out and the
    /// speculative tile-grid search. `1` takes the exact serial code
    /// path; `> 1` submits task groups into the current scheduler
    /// ([`sched::current_or_global`]) — no site-local pool is spun up.
    /// The default is the calling context's parallelism
    /// ([`sched::current_workers`]). Not part of the problem
    /// fingerprint: worker count never changes the solution, only how
    /// fast it is found.
    pub workers: usize,
    /// Apply the Pareto-dominance candidate filter before searching
    /// (default on). Provably solution-invariant — the switch exists so
    /// tests and benches can measure the unfiltered lattice.
    pub dominance_filter: bool,
    /// Minimum assignment-lattice volume (product of per-node candidate
    /// counts) before the solver fans subtrees across workers. Below
    /// it, task submission costs more than the whole serial search; the
    /// threshold is deterministic in the problem, so it never affects
    /// bit-identity. Tests force tiny lattices onto the parallel path
    /// with [`DseConfig::with_parallel_min_volume`]`(1)`.
    pub parallel_min_volume: u64,
    /// Optional shared warm-start state ([`super::warmstart`]):
    /// node-front memoization plus repair-based incumbent seeding.
    /// Like `cache`, shared across the jobs of a sweep; like `workers`,
    /// never part of the problem fingerprint — warm-starting changes
    /// how fast the optimum is found, provably never which one.
    pub warm: Option<Arc<WarmStart>>,
}

/// Default parallel fan-out threshold: paper-kernel-sized lattices
/// (conv_relu: 48 assignments) stay serial; wide MLP lattices
/// (feedforward: ~260k) go wide.
pub const PARALLEL_MIN_VOLUME: u64 = 4096;

impl DseConfig {
    pub fn new(device: DeviceSpec) -> Self {
        Self {
            device,
            cache: None,
            workers: default_workers(),
            dominance_filter: true,
            parallel_min_volume: PARALLEL_MIN_VOLUME,
            warm: None,
        }
    }

    /// Attach a (shared) design cache to this configuration.
    pub fn with_cache(mut self, cache: Arc<DesignCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Size the solver's worker fan-out; `1` selects the serial solver.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Toggle the Pareto-dominance candidate filter.
    pub fn with_dominance_filter(mut self, on: bool) -> Self {
        self.dominance_filter = on;
        self
    }

    /// Override the parallel fan-out threshold (see
    /// [`DseConfig::parallel_min_volume`]).
    pub fn with_parallel_min_volume(mut self, v: u64) -> Self {
        self.parallel_min_volume = v;
        self
    }

    /// Attach shared warm-start state (front memoization + incumbent
    /// seeding). Cloned configs — including the per-cell configs the
    /// tile-grid search derives — share the same underlying store.
    pub fn with_warm_start(mut self, warm: Arc<WarmStart>) -> Self {
        self.warm = Some(warm);
        self
    }
}

/// Context-sized solver parallelism: the width of the scheduler that
/// owns the calling thread (so a solve nested inside a sweep job sizes
/// its fan-out to the shared pool), else the machine-sized global
/// default ([`sched::default_size`]).
fn default_workers() -> usize {
    sched::current_workers()
}

/// Outcome of the DSE.
#[derive(Debug, Clone)]
pub struct DseSolution {
    /// Chosen candidate per node (same order as `design.nodes`).
    pub chosen: Vec<Candidate>,
    /// ILP objective value (Σ standalone node cycles).
    pub objective: u64,
    pub dsp_used: u64,
    /// Exact BRAM of the solved design (line buffers + weight ROMs +
    /// FIFOs) — equal to `resources::bram::design_bram` of the design
    /// after the solution is applied (the unified-model invariant).
    pub bram_used: u64,
    /// Full resource breakdown of the solution.
    pub resources: ResourceVec,
    /// Candidate-sets explored (search-effort metric for benches).
    pub nodes_explored: u64,
}

/// Solve the ILP for `design`, assign the chosen timing to its nodes,
/// re-derive buffer partitioning, and size FIFO depths.
///
/// Fails if no assignment satisfies the device constraints (the paper's
/// "infeasible design" case — e.g. StreamHLS's Feed-Forward on KV260).
pub fn solve(design: &mut Design, cfg: &DseConfig) -> Result<DseSolution> {
    let _sp = crate::obs::span_with("ilp_solve", || design.graph.name.clone());
    // One resource model per design, shared across all nodes' candidate
    // enumeration. Candidate-independent BRAM — FIFOs hanging off the
    // graph input (including diamond skip channels) — is charged once up
    // front; every other FIFO's depth is a function of its producer's
    // candidate, so its blocks live in that candidate's ResourceVec.
    // The incremental FIFO re-sizing per partial assignment is exact
    // because each channel's depth depends only on its producer's
    // pipeline depth plus a timing-independent diamond floor.
    let metrics = crate::obs::metrics::global();
    let (mut cand, fronts, base_fifo) = {
        let model = ResourceModel::new(design);
        let base_fifo = model.input_fifo_bram();
        match &cfg.warm {
            // Warm path: per-node fronts memoized across problems (and
            // across the nodes of this one). A hit replays a prior
            // enumeration byte-for-byte; enumeration-side metrics
            // (`dse.candidates`, `dse.dominance_pruned`) count at
            // enumeration time only, so a warm sweep's deltas reflect
            // work actually done. The unfiltered lists ride along in
            // `fronts` for incumbent-seed validation below.
            Some(w) => {
                let n = design.nodes.len();
                let mut cand = Vec::with_capacity(n);
                let mut fronts: Vec<Arc<FrontEntry>> = Vec::with_capacity(n);
                for i in 0..n {
                    let key = WarmStart::front_key(&model, design, i, &cfg.device);
                    let entry = match w.front(key) {
                        Some(e) => e,
                        None => {
                            let full = candidates_with(&model, design, i);
                            metrics.add("dse.candidates", full.len() as u64);
                            let mut front = full.clone();
                            let dropped = space::dominance_filter(&mut front);
                            metrics.add("dse.dominance_pruned", dropped);
                            w.store_front(key, full, front, dropped)
                        }
                    };
                    cand.push(if cfg.dominance_filter {
                        entry.front.clone()
                    } else {
                        entry.full.clone()
                    });
                    fronts.push(entry);
                }
                (cand, Some(fronts), base_fifo)
            }
            None => {
                let cand: Vec<Vec<Candidate>> = (0..design.nodes.len())
                    .map(|i| candidates_with(&model, design, i))
                    .collect();
                (cand, None, base_fifo)
            }
        }
    };
    for (i, c) in cand.iter().enumerate() {
        ensure!(!c.is_empty(), "node {} has no candidates", design.nodes[i].name);
    }
    // The ordering invariant the DFS tail prune relies on: enforce it
    // here rather than trusting every enumeration path forever.
    debug_assert!(
        cand.iter().all(|c| space::is_canonical(c)),
        "candidate vectors must be in canonical (cycle-sorted) order"
    );

    if cfg.warm.is_none() {
        metrics.add("dse.candidates", cand.iter().map(|c| c.len() as u64).sum::<u64>());
        if cfg.dominance_filter {
            // Solution-invariant (see `space::dominance_filter`): shrinks
            // the lattice before the exponential part ever sees it.
            let dropped: u64 = cand.iter_mut().map(space::dominance_filter).sum();
            metrics.add("dse.dominance_pruned", dropped);
        }
    }

    let d_total = cfg.device.dsp;
    let b_total = cfg.device.bram18k;

    // Per-node minima for lower-bound pruning (suffix sums). Candidate
    // vectors are separable per node, so per-node minima remain
    // admissible lower bounds for the full BRAM/DSP sums.
    let n = cand.len();
    let mut min_cycles = vec![0u64; n + 1];
    let mut min_dsp = vec![0u64; n + 1];
    let mut min_bram = vec![0u64; n + 1];
    for i in (0..n).rev() {
        min_cycles[i] =
            min_cycles[i + 1] + cand[i].iter().map(|c| c.cycles).min().unwrap();
        min_dsp[i] = min_dsp[i + 1] + cand[i].iter().map(|c| c.res.dsp).min().unwrap();
        min_bram[i] = min_bram[i + 1] + cand[i].iter().map(|c| c.res.bram()).min().unwrap();
    }
    ensure!(
        min_dsp[0] <= d_total && base_fifo + min_bram[0] <= b_total,
        "infeasible: minimal design needs {} DSP / {} BRAM, device allows {} / {}",
        min_dsp[0],
        base_fifo + min_bram[0],
        d_total,
        b_total
    );

    let problem = Problem {
        cand: &cand,
        min_cycles: &min_cycles,
        min_dsp: &min_dsp,
        min_bram: &min_bram,
        d_total,
        b_total,
        base_fifo,
    };
    // Repair-based incumbent seeding: offer the nearest recorded
    // neighbor assignment to this problem's own lattice and budgets
    // (`validate_seed`); a surviving seed's objective is a sound initial
    // upper bound for the search's shared incumbent. Strictly after the
    // minima feasibility check above, so infeasibility errors stay
    // byte-identical to the cold solver's.
    let warm_shape = cfg.warm.as_ref().map(|w| {
        (w, WarmStart::shape_fingerprint(design), WarmStart::seed_extents(design, &cfg.device))
    });
    let mut seed = None;
    if let (Some((w, shape, extents)), Some(fronts)) = (&warm_shape, &fronts) {
        if let Some(picks) = w.nearest_seed(*shape, extents) {
            seed = validate_seed(&picks, fronts, base_fifo, d_total, b_total);
            match seed {
                Some(_) => metrics.incr("dse.warm_seeds"),
                None => metrics.incr("dse.warm_seed_rejected"),
            }
        }
    }
    let s = search(&problem, cfg, seed);
    metrics.incr("dse.solves");
    metrics.add("dse.nodes_explored", s.explored);
    metrics.add("dse.pruned", s.pruned);
    // Holds seeded too: a validated seed proves a feasible assignment
    // with objective <= the seed exists on the searched lattice (the
    // dominance filter keeps, for every full-list pick, a no-worse
    // candidate), and the strict bound U+1 cannot prune all of them.
    ensure!(s.best < u64::MAX, "DSE found no feasible assignment");

    let chosen: Vec<Candidate> =
        s.best_pick.iter().enumerate().map(|(i, &k)| cand[i][k]).collect();
    if let Some((w, shape, extents)) = warm_shape {
        // Record the winning assignment for future neighbors (repair
        // source). After the search so only real solutions are stored.
        w.record_seed(
            shape,
            extents,
            chosen.iter().map(|c| (c.unroll_par, c.unroll_red)).collect(),
        );
    }
    let mut resources = ResourceVec { fifo_bram: base_fifo, ..Default::default() };
    for c in &chosen {
        resources += c.res;
    }

    // Apply timing, re-derive buffers, size FIFOs (stream constraint is
    // honoured by construction: one `lanes` per channel).
    for (node, c) in design.nodes.iter_mut().zip(&chosen) {
        node.timing = c.timing;
    }
    refresh_buffers(design);
    size_fifos(design);
    // The unified-model invariant: what the solver charged is what the
    // design allocates — estimate and implementation cannot disagree.
    debug_assert_eq!(
        resources,
        ResourceModel::as_built(design),
        "solver accounting diverged from the built design"
    );

    Ok(DseSolution {
        objective: s.best,
        chosen,
        dsp_used: resources.dsp,
        bram_used: resources.bram(),
        resources,
        nodes_explored: s.explored,
    })
}

/// The immutable search problem: (filtered) candidate lists, suffix-
/// minima lower bounds and device totals, shared by the serial DFS and
/// every parallel subtree task.
struct Problem<'a> {
    cand: &'a [Vec<Candidate>],
    min_cycles: &'a [u64],
    min_dsp: &'a [u64],
    min_bram: &'a [u64],
    d_total: u64,
    b_total: u64,
    base_fifo: u64,
}

/// What a search returns: `best`/`best_pick` are bit-identical between
/// the serial and parallel paths (pinned by the property tests);
/// `explored`/`pruned` are effort metrics and may legitimately differ
/// (workers race the incumbent, so the visit counts are not
/// deterministic).
struct SearchOutcome {
    best: u64,
    best_pick: Vec<usize>,
    explored: u64,
    pruned: u64,
}

struct Search<'a> {
    p: &'a Problem<'a>,
    /// Cross-subtree incumbent objective — the parallel search, and the
    /// serial search when warm-seeded (the seed plays the role of an
    /// already-published sibling result). The prune bound derived from
    /// it is `shared + 1`, i.e. *strict*: an equal-objective assignment
    /// in a lexicographically earlier subtree must stay discoverable,
    /// or the deterministic argmin below would drift from the serial
    /// first-found pick.
    shared: Option<&'a AtomicU64>,
    best: u64,
    best_pick: Vec<usize>,
    pick: Vec<usize>,
    explored: u64,
    /// Subtrees cut by the cycle lower bound (whole sorted tail) or
    /// a resource lower bound (single candidate) — the
    /// branch-and-bound effectiveness metric (`dse.pruned`).
    pruned: u64,
}

impl Search<'_> {
    /// The effective prune bound: the local incumbent, tightened by the
    /// pool-wide one when present. On the unseeded serial path this is
    /// exactly `self.best` — the `--workers 1` cold code path is the
    /// historical serial solver, instruction for instruction.
    fn bound(&self) -> u64 {
        match self.shared {
            Some(s) => self.best.min(s.load(Ordering::Relaxed).saturating_add(1)),
            None => self.best,
        }
    }

    fn dfs(&mut self, i: usize, cycles: u64, dsp: u64, bram: u64) {
        self.explored += 1;
        if i == self.p.cand.len() {
            if cycles < self.best {
                self.best = cycles;
                self.best_pick = self.pick.clone();
                if let Some(s) = self.shared {
                    // publish the improvement: every other worker's
                    // bound tightens on its next loop iteration
                    s.fetch_min(cycles, Ordering::Relaxed);
                }
            }
            return;
        }
        for (k, c) in self.p.cand[i].iter().enumerate() {
            let cy = cycles + c.cycles;
            // candidates are cycle-sorted: once even the LB fails, stop
            if cy + self.p.min_cycles[i + 1] >= self.bound() {
                self.pruned += (self.p.cand[i].len() - k) as u64;
                break;
            }
            let ds = dsp + c.res.dsp;
            let br = bram + c.res.bram();
            if ds + self.p.min_dsp[i + 1] > self.p.d_total
                || br + self.p.min_bram[i + 1] > self.p.b_total
            {
                self.pruned += 1;
                continue;
            }
            self.pick.push(k);
            self.dfs(i + 1, cy, ds, br);
            self.pick.pop();
        }
    }
}

/// Re-validate a neighbor's unroll assignment against the *current*
/// problem: every pick must lie on its node's **unfiltered** lattice
/// (`FrontEntry::full` — the dominance filter may drop the exact pick
/// while keeping a dominator of it), and the summed resources must fit
/// the device. Feasible → `Some(objective)`: a true upper bound on the
/// optimum, safe to install as the initial shared incumbent. Any
/// mismatch — wrong arity, an off-lattice pick, a budget bust — is
/// `None` (`dse.warm_seed_rejected`) and the search runs cold.
fn validate_seed(
    picks: &[(u64, u64)],
    fronts: &[Arc<FrontEntry>],
    base_fifo: u64,
    d_total: u64,
    b_total: u64,
) -> Option<u64> {
    if picks.len() != fronts.len() {
        return None;
    }
    let (mut cycles, mut dsp, mut bram) = (0u64, 0u64, base_fifo);
    for (entry, pick) in fronts.iter().zip(picks) {
        let c = entry.full.iter().find(|c| (c.unroll_par, c.unroll_red) == *pick)?;
        cycles += c.cycles;
        dsp += c.res.dsp;
        bram += c.res.bram();
    }
    (dsp <= d_total && bram <= b_total).then_some(cycles)
}

/// Product of per-node candidate counts — the assignment-lattice size
/// (saturating; only compared against thresholds).
fn lattice_volume(cand: &[Vec<Candidate>]) -> u64 {
    cand.iter().fold(1u64, |v, c| v.saturating_mul(c.len() as u64))
}

/// Dispatch: the parallel branch-and-bound when the config asks for
/// workers and the lattice is big enough to amortize task fan-out,
/// the serial DFS otherwise. Both sides of the dispatch are
/// deterministic functions of the problem, so the returned
/// `best`/`best_pick` never depend on which path ran — nor on `seed`,
/// a validated upper bound that only tightens pruning.
fn search(p: &Problem<'_>, cfg: &DseConfig, seed: Option<u64>) -> SearchOutcome {
    if cfg.workers > 1 && lattice_volume(p.cand) >= cfg.parallel_min_volume {
        if let Some(out) = parallel_search(p, cfg.workers, seed) {
            return out;
        }
    }
    serial_search(p, seed)
}

fn serial_search(p: &Problem<'_>, seed: Option<u64>) -> SearchOutcome {
    // A warm seed — the objective U of a re-validated feasible
    // assignment, so U >= the optimum — arms the same shared-incumbent
    // machinery the parallel path uses instead of touching `best`: the
    // local incumbent stays MAX, so leaf recording (`cycles < best`)
    // still fires for the first-found optimum even when it *equals* U,
    // while the prune bound starts at U+1 instead of MAX. Strictness
    // argument: along the DFS path to the serial first-found optimum,
    // cy + LB <= opt <= U < U+1 at every level, so that leaf is always
    // reached and recorded — the argmin cannot drift; only subtrees
    // that provably exceed the optimum are cut earlier.
    let seeded = seed.map(AtomicU64::new);
    let mut s = Search {
        p,
        shared: seeded.as_ref(),
        best: u64::MAX,
        best_pick: Vec::new(),
        pick: Vec::new(),
        explored: 0,
        pruned: 0,
    };
    s.dfs(0, 0, 0, p.base_fifo);
    SearchOutcome { best: s.best, best_pick: s.best_pick, explored: s.explored, pruned: s.pruned }
}

/// One parallel subtree task: a fixed assignment of the first
/// `split_depth` nodes plus its accumulated cost; a worker searches it
/// to the leaves with the serial DFS.
struct PrefixTask {
    pick: Vec<usize>,
    cycles: u64,
    dsp: u64,
    bram: u64,
}

/// Smallest prefix of node levels whose assignment count gives every
/// worker several subtree tasks to steal — load balance without
/// enumerating a meaningful fraction of the space up front.
fn split_depth(cand: &[Vec<Candidate>], workers: usize) -> usize {
    let target = (workers * 4) as u64;
    let mut tasks = 1u64;
    let mut depth = 0;
    while depth < cand.len() && tasks < target {
        tasks = tasks.saturating_mul(cand[depth].len().max(1) as u64);
        depth += 1;
    }
    depth
}

/// Enumerates resource-feasible prefixes in lexicographic order — the
/// order the serial DFS visits them, so task index == lex rank and the
/// argmin tie-break below reproduces first-found semantics. The cycle
/// lower bound cannot prune here (no incumbent exists yet), but the
/// resource bounds are incumbent-independent and drop dead prefixes
/// before they ever become scheduler tasks.
struct PrefixEnum<'a> {
    p: &'a Problem<'a>,
    depth: usize,
    pick: Vec<usize>,
    out: Vec<PrefixTask>,
    pruned: u64,
}

impl PrefixEnum<'_> {
    fn rec(&mut self, i: usize, cycles: u64, dsp: u64, bram: u64) {
        if i == self.depth {
            self.out.push(PrefixTask { pick: self.pick.clone(), cycles, dsp, bram });
            return;
        }
        for (k, c) in self.p.cand[i].iter().enumerate() {
            let ds = dsp + c.res.dsp;
            let br = bram + c.res.bram();
            if ds + self.p.min_dsp[i + 1] > self.p.d_total
                || br + self.p.min_bram[i + 1] > self.p.b_total
            {
                self.pruned += 1;
                continue;
            }
            self.pick.push(k);
            self.rec(i + 1, cycles + c.cycles, ds, br);
            self.pick.pop();
        }
    }
}

/// The parallel branch-and-bound. Returns `None` when the prefix split
/// degenerates to fewer than two tasks (the caller falls back to the
/// serial DFS).
///
/// Bit-identity argument: every resource-feasible prefix becomes a task;
/// each task runs the serial-semantics DFS over its subtree, pruning
/// strictly against the shared incumbent (`>= shared + 1`), so any
/// assignment with objective ≤ the global optimum survives pruning in
/// whichever subtree lexicographically first contains one. Results come
/// back index-sorted and only a strictly better objective replaces the
/// running argmin, so the lowest-ranked subtree wins ties — exactly the
/// first-found optimum of the serial DFS, which visits subtrees in the
/// same lexicographic order.
fn parallel_search(p: &Problem<'_>, workers: usize, seed: Option<u64>) -> Option<SearchOutcome> {
    let depth = split_depth(p.cand, workers);
    let mut en =
        PrefixEnum { p, depth, pick: Vec::with_capacity(depth), out: Vec::new(), pruned: 0 };
    en.rec(0, 0, 0, p.base_fifo);
    let (prefixes, pre_pruned) = (en.out, en.pruned);
    if prefixes.len() < 2 {
        return None;
    }
    let metrics = crate::obs::metrics::global();
    metrics.incr("dse.par_solves");
    metrics.add("dse.subtree_tasks", prefixes.len() as u64);
    // A warm seed pre-loads the shared incumbent: every subtree prunes
    // against `seed + 1` from its very first node, exactly as if a
    // sibling worker had already published that objective. Same
    // strict-bound argument as the serial path — the lex-first optimal
    // leaf survives, so the deterministic argmin below is unchanged.
    let shared = AtomicU64::new(seed.unwrap_or(u64::MAX));
    let shared_ref = &shared;
    let jobs: Vec<_> = prefixes
        .into_iter()
        .enumerate()
        .map(|(ti, task)| {
            move || {
                let _sp = crate::obs::span_with("ilp_subtree", || format!("subtree {ti}"));
                let PrefixTask { pick, cycles, dsp, bram } = task;
                let mut s = Search {
                    p,
                    shared: Some(shared_ref),
                    best: u64::MAX,
                    best_pick: Vec::new(),
                    pick,
                    explored: 0,
                    pruned: 0,
                };
                s.dfs(depth, cycles, dsp, bram);
                (s.best, s.best_pick, s.explored, s.pruned)
            }
        })
        .collect();
    // Submit into the calling context's scheduler: nested under a sweep
    // job this lands on the sweep worker's own deque, where an idle
    // sibling steals subtrees off a straggler instead of idling.
    let results = sched::current_or_global().run_all_scoped(jobs, |_, _| {});
    let mut out = SearchOutcome {
        best: u64::MAX,
        best_pick: Vec::new(),
        explored: 0,
        pruned: pre_pruned,
    };
    for (ti, r) in results {
        let (best, best_pick, explored, pruned) =
            r.unwrap_or_else(|e| panic!("ILP subtree task {ti} failed: {e}"));
        out.explored += explored;
        out.pruned += pruned;
        // strict improvement only: ties go to the earlier subtree
        if best < out.best {
            out.best = best;
            out.best_pick = best_pick;
        }
    }
    Some(out)
}

/// Outcome of [`solve_with_tiling_fallback`].
#[derive(Debug)]
pub enum Compiled {
    /// The whole feature map fits on the device: one streaming design.
    Flat(Box<Design>, DseSolution),
    /// The untiled DSE had no feasible point; the workload was
    /// decomposed into a rows × cols grid of halo-overlapped cells
    /// (`crate::tiling`), stride-aware for pooled/strided chains.
    Tiled(Box<TiledCompilation>),
}

/// The feasibility fallback: build and solve the untiled streaming
/// design; when the ILP has no feasible point (the paper's "infeasible
/// design" case — oversized line buffers on a small device), fall back
/// to the stride-aware tile-grid subsystem, which searches the
/// (rows × cols) grid lattice for the fewest cells that fit. Errors
/// only when both paths fail.
///
/// When `cfg` carries a design cache, the whole outcome — flat *or*
/// tiled, grid shape included — is keyed by the problem fingerprint: a
/// repeat compilation of the same `(graph, device, config)` rebuilds
/// the solved design deterministically with zero ILP solves and zero
/// grid search. Unusable entries degrade to a normal compile.
///
/// A cached [`cache::CachedDesign::Infeasible`] verdict short-circuits
/// the flat branch-and-bound proof entirely: the fallback goes straight
/// to the tile-grid search (whose per-cell solves are themselves
/// negative-cached), so a workload whose tiling previously failed never
/// re-proves flat infeasibility, and one whose tiling succeeds upgrades
/// the entry to the tiled outcome.
pub fn solve_with_tiling_fallback(g: &ModelGraph, cfg: &DseConfig) -> Result<Compiled> {
    let fp = cfg.cache.as_ref().map(|c| (c, problem_fingerprint(g, &cfg.device)));
    let mut cached_flat_err: Option<String> = None;
    if let Some((c, fp)) = &fp {
        if let Some(entry) = c.lookup(*fp) {
            match &entry {
                cache::CachedDesign::Infeasible { msg } => {
                    // flat verdict already proven: skip solve(), keep
                    // the original error for the combined message
                    cached_flat_err = Some(msg.clone());
                }
                _ => match cache::rebuild_compiled(g, cfg, &entry) {
                    Ok(compiled) => return Ok(compiled),
                    Err(_) => c.note_corrupt(),
                },
            }
        }
    }
    let mut design = build_streaming_design(g)?;
    let flat_err = match &cached_flat_err {
        Some(msg) => Some(anyhow::anyhow!("{msg} (cached verdict)")),
        None => {
            if let Some((c, _)) = &fp {
                c.count_solve();
            }
            match solve(&mut design, cfg) {
                Ok(sol) => {
                    let compiled = Compiled::Flat(Box::new(design), sol);
                    if let Some((c, fp)) = &fp {
                        c.insert(*fp, cache::compiled_entry(&compiled));
                    }
                    return Ok(compiled);
                }
                Err(e) => {
                    // record the negative verdict *now*: even if the
                    // tiling fallback below also fails, the next run
                    // skips this branch-and-bound proof
                    if let Some((c, fp)) = &fp {
                        c.insert(
                            *fp,
                            cache::CachedDesign::Infeasible { msg: format!("{e:#}") },
                        );
                    }
                    Some(e)
                }
            }
        }
    };
    let flat_err = flat_err.expect("flat path either returned or produced an error");
    // a failed solve leaves the design's scalar timing untouched, so it
    // can seed the tiling planner's lower bounds directly
    match compile_tiled_from(g, &design, cfg) {
        Ok(tc) => {
            let compiled = Compiled::Tiled(Box::new(tc));
            if let Some((c, fp)) = &fp {
                // upgrade the infeasible-flat marker to the real outcome
                c.insert(*fp, cache::compiled_entry(&compiled));
            }
            Ok(compiled)
        }
        Err(tile_err) => bail!(
            "untiled DSE infeasible ({flat_err:#}); tile-grid fallback \
             also failed ({tile_err:#})"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::validate::{check_diamond_depths, validate_design};
    use crate::ir::builder::models;
    use crate::resources::estimate;

    fn solve_kernel(name: &str, size: usize, dev: DeviceSpec) -> (Design, DseSolution) {
        let g = models::paper_kernel(name, size).unwrap();
        let mut d = build_streaming_design(&g).unwrap();
        let sol = solve(&mut d, &DseConfig::new(dev)).unwrap();
        (d, sol)
    }

    #[test]
    fn conv_relu_full_unroll_on_kv260() {
        // DSP budget 1248 admits the full 576-lane unroll (288 DSPs).
        let (d, sol) = solve_kernel("conv_relu", 32, DeviceSpec::kv260());
        assert_eq!(d.nodes[0].timing.mac_lanes, 576);
        assert_eq!(sol.dsp_used, 288);
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(r.fits(), "{r}");
    }

    #[test]
    fn dsp_cap_reduces_parallelism_monotonically() {
        // Table IV: tighter DSP budgets → less unroll, higher objective.
        let mut last_obj = 0;
        let mut last_dsp = u64::MAX;
        for cap in [1248u64, 250, 50] {
            let (_, sol) =
                solve_kernel("conv_relu", 32, DeviceSpec::kv260().with_dsp_limit(cap));
            assert!(sol.dsp_used <= cap);
            assert!(sol.objective >= last_obj, "objective must not improve with less DSP");
            assert!(sol.dsp_used <= last_dsp);
            last_obj = sol.objective;
            last_dsp = sol.dsp_used;
        }
    }

    #[test]
    fn bram_constraint_limits_linear_partitioning() {
        // A tiny BRAM budget forces a smaller reduction unroll on the
        // 128-wide line buffer.
        let (d, sol) = solve_kernel("linear", 0, DeviceSpec::kv260().with_bram_limit(40));
        assert!(sol.bram_used <= 32, "bram {}", sol.bram_used);
        assert!(d.nodes[0].timing.unroll_red <= 32);
        let r = estimate(&d, &DeviceSpec::kv260().with_bram_limit(40));
        assert!(r.fits(), "{r}");
    }

    #[test]
    fn residual_design_is_deadlock_free_after_dse() {
        let (d, _) = solve_kernel("residual", 32, DeviceSpec::kv260());
        validate_design(&d).unwrap();
        assert!(
            check_diamond_depths(&d).is_empty(),
            "DSE must size the skip FIFO: {:?}",
            check_diamond_depths(&d)
        );
    }

    #[test]
    fn infeasible_when_dsp_below_minimum() {
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        // scalar conv still needs ≥1 DSP
        let err = solve(&mut d, &DseConfig::new(DeviceSpec::kv260().with_dsp_limit(0)));
        assert!(err.is_err());
    }

    #[test]
    fn all_paper_kernels_solve_on_kv260() {
        for (name, size) in models::table2_workloads() {
            let (d, sol) = solve_kernel(name, size.max(32), DeviceSpec::kv260());
            let r = estimate(&d, &DeviceSpec::kv260());
            assert!(r.fits(), "{name}: {r}");
            assert!(sol.objective > 0);
        }
    }

    #[test]
    fn fallback_returns_flat_when_feasible() {
        let g = models::conv_relu(32, 8, 8);
        match solve_with_tiling_fallback(&g, &DseConfig::new(DeviceSpec::kv260())).unwrap() {
            Compiled::Flat(d, sol) => {
                assert_eq!(d.nodes[0].timing.mac_lanes, 576);
                assert!(sol.objective > 0);
            }
            Compiled::Tiled(_) => panic!("feasible workload must not tile"),
        }
    }

    #[test]
    fn fallback_tiles_when_bram_starved() {
        // Exact accounting: the cheapest untiled assignment needs 5
        // blocks (4 line-buffer + 1 weight ROM), so a 4-block budget is
        // infeasible flat but solvable with half-width strips.
        let g = models::conv_relu(80, 32, 8);
        let cfg = DseConfig::new(DeviceSpec::kv260().with_bram_limit(4));
        match solve_with_tiling_fallback(&g, &cfg).unwrap() {
            Compiled::Tiled(tc) => assert!(tc.grid.n_cells() >= 2),
            Compiled::Flat(..) => panic!("BRAM-starved workload must tile"),
        }
    }

    #[test]
    fn rom_dominated_linear_no_longer_slips_past_the_budget() {
        // Regression for the estimate-vs-solve divergence: a weight-heavy
        // linear layer whose line buffer is tiny (1 block) but whose
        // weight ROM needs 8 blocks at low unroll. A DSP cap keeps the
        // solver below 32 lanes so the ROM cannot escape to LUTRAM. With
        // line-buffer-only accounting this "solved" flat and busted BRAM
        // in codegen; the unified model reports it infeasible (and the
        // rank-2 graph has no width axis, so the tiling fallback fails
        // loudly instead of mis-compiling).
        let g = models::linear();
        let dev = DeviceSpec::kv260().with_dsp_limit(8).with_bram_limit(8);
        let mut d = build_streaming_design(&g).unwrap();
        let err = solve(&mut d, &DseConfig::new(dev.clone())).unwrap_err();
        assert!(format!("{err:#}").contains("feasible"), "{err:#}");
        let err = solve_with_tiling_fallback(&g, &DseConfig::new(dev)).unwrap_err();
        assert!(format!("{err:#}").contains("fallback"), "{err:#}");

        // With a budget that admits the ROM, the flat solve succeeds and
        // the reported usage covers it exactly.
        let dev = DeviceSpec::kv260().with_dsp_limit(8).with_bram_limit(40);
        let mut d = build_streaming_design(&g).unwrap();
        let sol = solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        assert_eq!(sol.bram_used, crate::resources::bram::design_bram(&d));
        assert!(sol.resources.weight_bram > 0, "ROM must be charged");
        assert!(estimate(&d, &dev).fits());
    }

    #[test]
    fn solver_bram_equals_design_bram_for_paper_kernels() {
        // The unified-model invariant, end to end: the ILP's reported
        // bram_used is the design_bram of the emitted design.
        for (name, size) in models::table2_workloads() {
            let (d, sol) = solve_kernel(name, size.max(32), DeviceSpec::kv260());
            assert_eq!(
                sol.bram_used,
                crate::resources::bram::design_bram(&d),
                "{name}@{size}"
            );
            assert_eq!(sol.dsp_used, crate::resources::dsp::design_dsp(&d), "{name}@{size}");
        }
    }

    #[test]
    fn fallback_errors_when_untilable() {
        // linear is rank-2: no width axis to tile, and with 0 DSP the
        // flat solve is infeasible, so both paths fail.
        let g = models::linear();
        let cfg = DseConfig::new(DeviceSpec::kv260().with_dsp_limit(0));
        let err = solve_with_tiling_fallback(&g, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fallback"), "{msg}");
    }

    #[test]
    fn search_effort_is_small() {
        let (_, sol) = solve_kernel("feedforward", 0, DeviceSpec::kv260());
        assert!(sol.nodes_explored < 200_000, "explored {}", sol.nodes_explored);
    }

    #[test]
    fn parallel_solver_is_bit_identical_to_serial() {
        // The tentpole invariant: the same DseSolution and the same
        // rebuilt design, with and without the dominance filter, at any
        // worker count (forced past the volume threshold).
        let g = models::paper_kernel("feedforward", 0).unwrap();
        for dominance in [true, false] {
            let mut d1 = build_streaming_design(&g).unwrap();
            let serial = DseConfig::new(DeviceSpec::kv260())
                .with_workers(1)
                .with_dominance_filter(dominance);
            let s1 = solve(&mut d1, &serial).unwrap();
            for workers in [2usize, 4] {
                let mut d2 = build_streaming_design(&g).unwrap();
                let par = DseConfig::new(DeviceSpec::kv260())
                    .with_workers(workers)
                    .with_dominance_filter(dominance)
                    .with_parallel_min_volume(1);
                let s2 = solve(&mut d2, &par).unwrap();
                assert_eq!(s1.objective, s2.objective, "workers {workers}");
                assert_eq!(s1.chosen, s2.chosen, "workers {workers}");
                assert_eq!(s1.resources, s2.resources, "workers {workers}");
                assert_eq!(s1.dsp_used, s2.dsp_used, "workers {workers}");
                assert_eq!(s1.bram_used, s2.bram_used, "workers {workers}");
                assert_eq!(format!("{d1:?}"), format!("{d2:?}"), "designs diverged");
            }
        }
    }

    #[test]
    fn parallel_path_runs_and_counts_subtree_tasks() {
        let m = crate::obs::metrics::global();
        let before = m.get("dse.par_solves");
        let g = models::paper_kernel("feedforward", 0).unwrap();
        let mut d = build_streaming_design(&g).unwrap();
        let cfg = DseConfig::new(DeviceSpec::kv260()).with_workers(4).with_parallel_min_volume(1);
        solve(&mut d, &cfg).unwrap();
        assert!(m.get("dse.par_solves") > before, "forced fan-out must be counted");
        assert!(m.get("dse.subtree_tasks") > 0);
    }

    #[test]
    fn warm_front_memoization_is_bit_identical_and_hits() {
        // Cascade repeats its conv and requant geometries, so a warm
        // re-solve hits every node front; the solution and the rebuilt
        // design must match the cold solver's exactly.
        let m = crate::obs::metrics::global();
        let g = models::paper_kernel("cascade", 32).unwrap();
        let mut cold_d = build_streaming_design(&g).unwrap();
        let cold =
            solve(&mut cold_d, &DseConfig::new(DeviceSpec::kv260()).with_workers(1)).unwrap();

        let warm = Arc::new(WarmStart::new());
        let cfg = DseConfig::new(DeviceSpec::kv260())
            .with_workers(1)
            .with_warm_start(warm.clone());
        let mut d1 = build_streaming_design(&g).unwrap();
        let s1 = solve(&mut d1, &cfg).unwrap();
        let h0 = m.get("dse.front_hits");
        let mut d2 = build_streaming_design(&g).unwrap();
        let s2 = solve(&mut d2, &cfg).unwrap();
        // monotone `>=`: the registry is global and other tests may bump
        // the counter concurrently — same convention as dse.par_solves
        assert!(
            m.get("dse.front_hits") - h0 >= d2.nodes.len() as u64,
            "a repeat solve hits every node front"
        );

        for (tag, s, d) in [("warm1", &s1, &d1), ("warm2", &s2, &d2)] {
            assert_eq!(cold.chosen, s.chosen, "{tag}");
            assert_eq!(cold.objective, s.objective, "{tag}");
            assert_eq!(cold.resources, s.resources, "{tag}");
            assert_eq!(cold.dsp_used, s.dsp_used, "{tag}");
            assert_eq!(cold.bram_used, s.bram_used, "{tag}");
            assert_eq!(format!("{cold_d:?}"), format!("{d:?}"), "{tag}: designs diverged");
        }
    }

    #[test]
    fn warm_seed_from_a_neighbor_is_accepted_and_identical() {
        // conv_relu@32 and @48 share the shape fingerprint *and* the
        // unroll lattice (par trip 8, red trip 72 — image size is not a
        // lattice axis), so the recorded 32-solution re-validates as a
        // seed for 48 and the warm solve must still return exactly the
        // cold solution.
        let m = crate::obs::metrics::global();
        let warm = Arc::new(WarmStart::new());
        let cfg = DseConfig::new(DeviceSpec::kv260())
            .with_workers(1)
            .with_warm_start(warm.clone());
        let g32 = models::conv_relu(32, 8, 8);
        let mut d32 = build_streaming_design(&g32).unwrap();
        solve(&mut d32, &cfg).unwrap(); // records the seed

        let w0 = m.get("dse.warm_seeds");
        let g48 = models::conv_relu(48, 8, 8);
        let mut warm_d = build_streaming_design(&g48).unwrap();
        let warm_sol = solve(&mut warm_d, &cfg).unwrap();
        assert!(m.get("dse.warm_seeds") > w0, "neighbor seed must validate");

        let mut cold_d = build_streaming_design(&g48).unwrap();
        let cold =
            solve(&mut cold_d, &DseConfig::new(DeviceSpec::kv260()).with_workers(1)).unwrap();
        assert_eq!(cold.chosen, warm_sol.chosen);
        assert_eq!(cold.objective, warm_sol.objective);
        assert_eq!(cold.resources, warm_sol.resources);
        assert_eq!(format!("{cold_d:?}"), format!("{warm_d:?}"), "designs diverged");

        // The U == optimum edge: re-solving 48 finds its *own* recorded
        // optimum as the nearest seed (distance 0). The strict bound
        // U+1 must still let the first-found optimal leaf be recorded.
        let w1 = m.get("dse.warm_seeds");
        let mut again_d = build_streaming_design(&g48).unwrap();
        let again = solve(&mut again_d, &cfg).unwrap();
        assert!(m.get("dse.warm_seeds") > w1);
        assert_eq!(cold.chosen, again.chosen);
        assert_eq!(cold.objective, again.objective);
        assert_eq!(format!("{cold_d:?}"), format!("{again_d:?}"));
    }

    #[test]
    fn warm_seed_off_lattice_is_rejected_not_trusted() {
        // An injected seed whose picks lie on no lattice (unroll 0)
        // must be rejected by re-validation; the solve then runs cold
        // and still returns the exact solution.
        let m = crate::obs::metrics::global();
        let g = models::conv_relu(32, 8, 8);
        let probe = build_streaming_design(&g).unwrap();
        let dev = DeviceSpec::kv260();
        let warm = Arc::new(WarmStart::new());
        warm.record_seed(
            WarmStart::shape_fingerprint(&probe),
            WarmStart::seed_extents(&probe, &dev),
            vec![(0, 0); probe.nodes.len()],
        );
        let r0 = m.get("dse.warm_seed_rejected");
        let cfg = DseConfig::new(dev.clone()).with_workers(1).with_warm_start(warm);
        let mut warm_d = build_streaming_design(&g).unwrap();
        let warm_sol = solve(&mut warm_d, &cfg).unwrap();
        assert!(m.get("dse.warm_seed_rejected") > r0, "off-lattice seed must be rejected");

        let mut cold_d = build_streaming_design(&g).unwrap();
        let cold = solve(&mut cold_d, &DseConfig::new(dev).with_workers(1)).unwrap();
        assert_eq!(cold.chosen, warm_sol.chosen);
        assert_eq!(cold.objective, warm_sol.objective);
        assert_eq!(format!("{cold_d:?}"), format!("{warm_d:?}"));
    }

    #[test]
    fn warm_infeasible_error_matches_cold_byte_for_byte() {
        // Seeding happens after the minima feasibility check, so the
        // infeasibility message cannot pick up warm-state wording.
        let g = models::conv_relu(32, 8, 8);
        let dev = DeviceSpec::kv260().with_dsp_limit(0);
        let mut d1 = build_streaming_design(&g).unwrap();
        let cold_err = solve(&mut d1, &DseConfig::new(dev.clone()).with_workers(1)).unwrap_err();
        let warm = Arc::new(WarmStart::new());
        let cfg = DseConfig::new(dev).with_workers(1).with_warm_start(warm);
        let mut d2 = build_streaming_design(&g).unwrap();
        let warm_err = solve(&mut d2, &cfg).unwrap_err();
        // twice: front-cache cold, then fully warm
        let mut d3 = build_streaming_design(&g).unwrap();
        let warm_err2 = solve(&mut d3, &cfg).unwrap_err();
        assert_eq!(format!("{cold_err:#}"), format!("{warm_err:#}"));
        assert_eq!(format!("{cold_err:#}"), format!("{warm_err2:#}"));
    }

    #[test]
    fn dominance_filter_is_solution_invariant_on_paper_kernels() {
        // The filter is provably invisible to the chosen solution; it
        // must also actually fire (the nonzero-ratio acceptance claim).
        let m = crate::obs::metrics::global();
        let before = m.get("dse.dominance_pruned");
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(32)).unwrap();
            let mut d1 = build_streaming_design(&g).unwrap();
            let s1 = solve(&mut d1, &DseConfig::new(DeviceSpec::kv260()).with_workers(1)).unwrap();
            let mut d2 = build_streaming_design(&g).unwrap();
            let off = DseConfig::new(DeviceSpec::kv260())
                .with_workers(1)
                .with_dominance_filter(false);
            let s2 = solve(&mut d2, &off).unwrap();
            assert_eq!(s1.chosen, s2.chosen, "{name}");
            assert_eq!(s1.objective, s2.objective, "{name}");
            assert_eq!(s1.resources, s2.resources, "{name}");
            assert_eq!(format!("{d1:?}"), format!("{d2:?}"), "{name}: designs diverged");
        }
        assert!(
            m.get("dse.dominance_pruned") > before,
            "paper kernels must contain dominated candidates"
        );
    }
}
