//! Candidate enumeration over divisor lattices.

use crate::dataflow::design::Design;
use crate::dataflow::node::NodeTiming;
use crate::resources::model::{ResourceModel, ResourceVec};

/// All positive divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// Tile-count candidate axis for the width-tiling feasibility fallback
/// (`crate::tiling`): divisors of the feature-map width, ascending,
/// excluding 1 (the untiled case, which the caller has already tried).
/// `t == width` is a valid last resort — single-column cores with halo
/// margins — and is the only option for prime widths. The tiling
/// analogue of the unroll divisor lattice: tile counts that do not
/// divide the width would need ragged strips and are never enumerated.
pub fn tile_counts(width: u64) -> Vec<u64> {
    divisors(width).into_iter().filter(|&t| t > 1).collect()
}

/// One unroll candidate for a node, with its pre-computed cost/resources.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub unroll_par: u64,
    pub unroll_red: u64,
    pub timing: NodeTiming,
    /// Standalone cycle estimate with this timing (ILP objective term).
    pub cycles: u64,
    /// Full resource vector this candidate consumes: line-buffer BRAM,
    /// weight-ROM BRAM, output-FIFO BRAM at the depths the sizing pass
    /// will assign for this timing, and DSPs — priced by the unified
    /// [`ResourceModel`], so the solver's accounting equals the built
    /// design's by construction.
    pub res: ResourceVec,
}

/// All unroll timings on node `nid`'s divisor lattice, unpriced.
///
/// * MAC nodes (conv / matmul): `u_par | out_features`, `u_red | red_trip`;
///   pipeline depth grows with the log of the adder tree.
/// * Zero-MAC nodes (elementwise, pooling): fixed full-token-width ALU
///   (no DSPs), II = 1 — never the bottleneck, so a single timing.
///
/// Shared by [`candidates_with`] (which prices each timing into a full
/// [`Candidate`]) and the tiling lower bound
/// (`crate::tiling::cost::strip_bram_lower_bound`, which prices the
/// same lattice at strip width without paying for the full-width
/// vectors or the cycle sort).
pub fn unroll_timings(d: &Design, nid: usize) -> Vec<NodeTiming> {
    let n = &d.nodes[nid];
    if n.geo.macs_per_out_token == 0 {
        let lanes = n.geo.out_token_len as u64;
        return vec![NodeTiming { mac_lanes: lanes, ii: 1, depth: 2, unroll_par: lanes, unroll_red: 1 }];
    }
    let op = &d.graph.ops[n.op_index];
    let par_trip = n.geo.out_token_len as u64;
    let red_trip = op.reduction_space().max(1);
    let mut out = Vec::new();
    for &up in &divisors(par_trip) {
        for &ur in &divisors(red_trip) {
            let lanes = up * ur;
            let depth = 4 + (64 - (lanes.max(1)).leading_zeros() as u64); // log2 adder tree
            out.push(NodeTiming { mac_lanes: lanes, ii: 1, depth, unroll_par: up, unroll_red: ur });
        }
    }
    out
}

/// Enumerate candidates for node `nid` of `d`, cheapest-cycles first,
/// pricing each timing with the caller's [`ResourceModel`] — build the
/// model once per design and reuse it across nodes (as `dse::ilp::solve`
/// does) instead of re-deriving the diamond floors per node.
pub fn candidates_with(model: &ResourceModel, d: &Design, nid: usize) -> Vec<Candidate> {
    let n = &d.nodes[nid];
    let mut out: Vec<Candidate> = unroll_timings(d, nid)
        .into_iter()
        .map(|timing| {
            let mut node = n.clone();
            node.timing = timing;
            Candidate {
                unroll_par: timing.unroll_par,
                unroll_red: timing.unroll_red,
                timing,
                cycles: node.standalone_cycles(),
                res: model.node_vec(nid, &timing),
            }
        })
        .collect();
    out.sort_by_key(|c| (c.cycles, c.res.dsp, c.res.bram()));
    out
}

/// Convenience wrapper over [`candidates_with`] for one-off callers.
pub fn candidates(d: &Design, nid: usize) -> Vec<Candidate> {
    candidates_with(&ResourceModel::new(d), d, nid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::util::prop::forall;

    #[test]
    fn divisor_lattices() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(8), vec![1, 2, 4, 8]);
        assert_eq!(divisors(72).len(), 12);
        forall("divisors divide", 100, |g| g.rng.range(1, 512), |&n| {
            divisors(n).iter().all(|&d| n % d == 0)
        });
    }

    #[test]
    fn conv_candidates_cover_lattice() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // div(8)=4 × div(72)=12
        assert_eq!(cands.len(), 48);
        // every candidate satisfies the unroll-divides-trip constraint
        for c in &cands {
            assert_eq!(8 % c.unroll_par, 0);
            assert_eq!(72 % c.unroll_red, 0);
        }
        // cheapest-first ordering
        assert!(cands.windows(2).all(|w| w[0].cycles <= w[1].cycles));
        // full unroll exists and is fastest
        assert_eq!(cands[0].unroll_par, 8);
        assert_eq!(cands[0].unroll_red, 72);
        assert_eq!(cands[0].res.dsp, 288);
    }

    #[test]
    fn pure_parallel_single_candidate() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 1);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].res.dsp, 0);
    }

    #[test]
    fn tile_count_axis_is_a_proper_divisor_lattice() {
        assert_eq!(tile_counts(32), vec![2, 4, 8, 16, 32]);
        assert_eq!(tile_counts(1), Vec::<u64>::new(), "trip count 1 has no tilings");
        assert_eq!(tile_counts(2), vec![2]);
        assert_eq!(tile_counts(13), vec![13], "prime widths tile as 1-column cores");
        forall("tile counts divide", 100, |g| g.rng.range(1, 4096), |&w| {
            tile_counts(w).iter().all(|&t| w % t == 0 && t > 1 && t <= w)
        });
    }

    #[test]
    fn trip_count_one_yields_single_candidate_lattice() {
        // 1x1 "conv" degenerate: a graph whose MAC node has prime/unit
        // trips still enumerates a full (tiny) lattice.
        let g = models::conv_relu(8, 1, 1);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // par trip 1 (one filter), red trip 9 (3x3x1): div(1) x div(9) = 3
        assert_eq!(cands.len(), 3);
        for c in &cands {
            assert_eq!(c.unroll_par, 1);
            assert_eq!(9 % c.unroll_red, 0);
        }
    }

    #[test]
    fn prime_trip_candidates_are_one_or_full() {
        let g = models::conv_relu(8, 7, 5); // C=7, F=5: prime-ish trips
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // par trip 5 -> {1, 5}; red trip 3*3*7 = 63 -> {1,3,7,9,21,63}
        assert_eq!(cands.len(), 2 * 6);
        for c in &cands {
            assert!(c.unroll_par == 1 || c.unroll_par == 5);
            assert_eq!(63 % c.unroll_red, 0);
        }
    }

    #[test]
    fn zero_mac_nodes_have_exactly_one_free_candidate() {
        let g = models::residual(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        for (nid, n) in d.nodes.iter().enumerate() {
            if n.geo.macs_per_out_token == 0 {
                let cands = candidates(&d, nid);
                assert_eq!(cands.len(), 1, "node {}", n.name);
                assert_eq!(cands[0].res.dsp, 0);
                assert_eq!(cands[0].res.bram(), 0);
                assert_eq!(cands[0].timing.ii, 1);
            }
        }
    }

    #[test]
    fn property_every_candidate_respects_unroll_divides_trip() {
        // Across a family of conv/linear workloads, every enumerated
        // Candidate satisfies u_par | par_trip and u_red | red_trip.
        forall(
            "unroll | trip",
            25,
            |g| {
                let n = 8 << g.rng.below(2); // 8 or 16
                let c = 1 + g.rng.below(12) as usize;
                let f = 1 + g.rng.below(12) as usize;
                (n as usize, c, f)
            },
            |&(n, c, f)| {
                let g = models::conv_relu(n, c, f);
                let d = build_streaming_design(&g).unwrap();
                (0..d.nodes.len()).all(|nid| {
                    let node = &d.nodes[nid];
                    let par_trip = node.geo.out_token_len as u64;
                    let red_trip = d.graph.ops[node.op_index].reduction_space().max(1);
                    candidates(&d, nid).iter().all(|cand| {
                        par_trip % cand.unroll_par == 0
                            && red_trip % cand.unroll_red == 0
                            && cand.timing.mac_lanes == cand.unroll_par * cand.unroll_red
                    })
                })
            },
        );
    }

    #[test]
    fn more_unroll_more_resources_fewer_cycles() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        let scalar = cands.iter().find(|c| c.unroll_par == 1 && c.unroll_red == 1).unwrap();
        let full = cands.iter().find(|c| c.unroll_par == 128 && c.unroll_red == 128).unwrap();
        assert!(full.cycles < scalar.cycles);
        assert!(full.res.dsp > scalar.res.dsp);
        assert!(full.res.bram() >= scalar.res.bram());
    }
}
