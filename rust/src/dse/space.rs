//! Candidate enumeration over divisor lattices.

use crate::dataflow::design::Design;
use crate::dataflow::node::NodeTiming;
use crate::ir::fingerprint::Fnv64;
use crate::ir::graph::TensorKind;
use crate::resources::model::{ResourceModel, ResourceVec};

/// All positive divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// The 2-D grid candidate lattice for the tile-grid feasibility
/// fallback (`crate::tiling`): every `(rows, cols)` pair with
/// `rows | out_h`, `cols | out_w` and more than one cell, ordered by
/// total cell count (fewer cells = less halo recompute and restart
/// overhead), then width-major (narrower cells shrink line buffers —
/// the dominant BRAM term — while shorter cells mostly trade latency).
/// Counts that do not divide an output extent would need ragged cells
/// and are never enumerated; `(1, out_w)` — single-column cores with
/// halo margins — is the last resort for prime widths.
pub fn grid_counts(out_h: u64, out_w: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    for &r in &divisors(out_h) {
        for &c in &divisors(out_w) {
            if r * c > 1 {
                out.push((r, c));
            }
        }
    }
    out.sort_by_key(|&(r, c)| (r * c, r));
    out
}

/// One unroll candidate for a node, with its pre-computed cost/resources.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub unroll_par: u64,
    pub unroll_red: u64,
    pub timing: NodeTiming,
    /// Standalone cycle estimate with this timing (ILP objective term).
    pub cycles: u64,
    /// Full resource vector this candidate consumes: line-buffer BRAM,
    /// weight-ROM BRAM, output-FIFO BRAM at the depths the sizing pass
    /// will assign for this timing, and DSPs — priced by the unified
    /// [`ResourceModel`], so the solver's accounting equals the built
    /// design's by construction.
    pub res: ResourceVec,
}

/// All unroll timings on node `nid`'s divisor lattice, unpriced.
///
/// * MAC nodes (conv / matmul): `u_par | out_features`, `u_red | red_trip`;
///   pipeline depth grows with the log of the adder tree.
/// * Zero-MAC nodes (elementwise, pooling): fixed full-token-width ALU
///   (no DSPs), II = 1 — never the bottleneck, so a single timing.
///
/// Shared by [`candidates_with`] (which prices each timing into a full
/// [`Candidate`]) and the tiling lower bound
/// (`crate::tiling::cost::cell_bram_lower_bound`, which prices the
/// same lattice at each node's local cell width without paying for the
/// full-width vectors or the cycle sort).
pub fn unroll_timings(d: &Design, nid: usize) -> Vec<NodeTiming> {
    let n = &d.nodes[nid];
    if n.geo.macs_per_out_token == 0 {
        let lanes = n.geo.out_token_len as u64;
        return vec![NodeTiming { mac_lanes: lanes, ii: 1, depth: 2, unroll_par: lanes, unroll_red: 1 }];
    }
    let op = &d.graph.ops[n.op_index];
    let par_trip = n.geo.out_token_len as u64;
    let red_trip = op.reduction_space().max(1);
    let mut out = Vec::new();
    for &up in &divisors(par_trip) {
        for &ur in &divisors(red_trip) {
            let lanes = up * ur;
            let depth = 4 + (64 - (lanes.max(1)).leading_zeros() as u64); // log2 adder tree
            out.push(NodeTiming { mac_lanes: lanes, ii: 1, depth, unroll_par: up, unroll_red: ur });
        }
    }
    out
}

/// The canonical candidate ordering key: cycles first (the branch-and-
/// bound tail prune in `dse::ilp` requires non-decreasing cycles), then
/// resource footprint, then the unroll pair. `(unroll_par, unroll_red)`
/// is unique per node lattice, so the key is a **total** order —
/// [`canonicalize`] restores the exact enumeration order from any
/// permutation, not just *a* cycle-sorted order.
fn canonical_key(c: &Candidate) -> (u64, u64, u64, u64, u64) {
    (c.cycles, c.res.dsp, c.res.bram(), c.unroll_par, c.unroll_red)
}

/// Is `cands` in the canonical order [`candidates_with`] guarantees?
/// `dse::ilp::solve` `debug_assert!`s this before searching: the DFS
/// tail prune silently returns wrong optima on unsorted input.
pub fn is_canonical(cands: &[Candidate]) -> bool {
    cands.windows(2).all(|w| canonical_key(&w[0]) <= canonical_key(&w[1]))
}

/// Re-sort `cands` into the canonical (total) order. Idempotent; after
/// this, [`is_canonical`] holds.
pub fn canonicalize(cands: &mut [Candidate]) {
    cands.sort_by_key(canonical_key);
}

/// Pareto-dominance filter: drop every candidate that has an earlier
/// kept candidate no worse in cycles **and** no worse in any
/// [`ResourceVec`] component — such a candidate can never appear in the
/// serial DFS's first-found optimum (swapping in its dominator keeps
/// the objective and feasibility while lowering the lexicographic pick),
/// so removing it is invisible to the solution *and* to the suffix-
/// minima lower bounds (the dominator attains every per-field minimum
/// the dominated candidate did). Checking kept candidates only is
/// complete because dominance is transitive, and keeping the earlier of
/// two mutually-dominating (identical-cost) candidates matches the
/// serial tie-break exactly. Requires — and preserves — canonical
/// order. Returns the number of dropped candidates
/// (`dse.dominance_pruned`).
pub fn dominance_filter(cands: &mut Vec<Candidate>) -> u64 {
    debug_assert!(is_canonical(cands), "dominance filter requires canonical order");
    let mut kept: Vec<Candidate> = Vec::with_capacity(cands.len());
    let mut dropped = 0u64;
    for c in cands.iter() {
        if kept.iter().any(|a| a.cycles <= c.cycles && a.res.le(&c.res)) {
            dropped += 1;
        } else {
            kept.push(*c);
        }
    }
    *cands = kept;
    dropped
}

/// Enumerate candidates for node `nid` of `d`, pricing each timing with
/// the caller's [`ResourceModel`] — build the model once per design and
/// reuse it across nodes (as `dse::ilp::solve` does) instead of
/// re-deriving the diamond floors per node.
///
/// **Ordering contract:** the returned vector is sorted by the canonical
/// key `(cycles, dsp, bram, unroll_par, unroll_red)` — cheapest-cycles
/// first. The solver's DFS tail prune ("once even the lower bound
/// fails, every later candidate fails too") is only correct under this
/// order; [`is_canonical`] checks it and [`canonicalize`] restores it.
pub fn candidates_with(model: &ResourceModel, d: &Design, nid: usize) -> Vec<Candidate> {
    let n = &d.nodes[nid];
    let mut out: Vec<Candidate> = unroll_timings(d, nid)
        .into_iter()
        .map(|timing| {
            let mut node = n.clone();
            node.timing = timing;
            Candidate {
                unroll_par: timing.unroll_par,
                unroll_red: timing.unroll_red,
                timing,
                cycles: node.standalone_cycles(),
                res: model.node_vec(nid, &timing),
            }
        })
        .collect();
    canonicalize(&mut out);
    out
}

/// Convenience wrapper over [`candidates_with`] for one-off callers.
pub fn candidates(d: &Design, nid: usize) -> Vec<Candidate> {
    candidates_with(&ResourceModel::new(d), d, nid)
}

/// Structural fingerprint of everything [`candidates_with`] reads for
/// node `nid` — the memoization key of `dse::warmstart`'s node-front
/// cache (after the device budgets are folded on top). Covers:
///
/// * the node's streaming geometry (trip counts, token shapes, line
///   buffer, warmup) — the inputs of [`unroll_timings`] and of
///   `standalone_cycles`, hashed via its `Debug` rendering (the front
///   cache is in-memory, so the encoding only needs within-process
///   stability, unlike the on-disk problem fingerprint);
/// * the op's reduction space (the lattice's second axis);
/// * the channel count of the activation input (the partition clamp in
///   the line-buffer pricing);
/// * each weight operand's `(bits, numel)` — ROM pricing reads sizes
///   only, so layers differing just in weight *values* deliberately
///   share a front (unlike the whole-design cache, which bakes ROMs);
/// * each output channel's `(token_len, lanes, elem_bits,
///   externally_buffered)` plus its diamond depth floor — the FIFO
///   pricing inputs.
///
/// Two nodes with equal fingerprints therefore enumerate byte-identical
/// candidate vectors.
pub fn node_front_fingerprint(model: &ResourceModel, d: &Design, nid: usize) -> u64 {
    let n = &d.nodes[nid];
    let op = &d.graph.ops[n.op_index];
    let mut h = Fnv64::new();
    h.write_str(&format!("{:?}", n.geo));
    h.write_u64(op.reduction_space());
    h.write_usize(*d.graph.tensor(op.inputs[0]).ty.shape.last().unwrap_or(&1));
    for &inp in &op.inputs {
        let t = d.graph.tensor(inp);
        if t.kind == TensorKind::Weight {
            h.write_u64(t.ty.bits());
            h.write_usize(t.ty.numel());
        }
    }
    h.write_usize(n.out_channels.len());
    for &cid in &n.out_channels {
        let c = d.channel(cid);
        h.write_usize(c.token_len);
        h.write_usize(c.lanes);
        h.write_u64(c.elem_bits);
        h.write_u8(c.externally_buffered as u8);
        h.write_usize(model.diamond_floor(cid.0));
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::util::prop::forall;

    #[test]
    fn divisor_lattices() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(8), vec![1, 2, 4, 8]);
        assert_eq!(divisors(72).len(), 12);
        forall("divisors divide", 100, |g| g.rng.range(1, 512), |&n| {
            divisors(n).iter().all(|&d| n % d == 0)
        });
    }

    #[test]
    fn conv_candidates_cover_lattice() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // div(8)=4 × div(72)=12
        assert_eq!(cands.len(), 48);
        // every candidate satisfies the unroll-divides-trip constraint
        for c in &cands {
            assert_eq!(8 % c.unroll_par, 0);
            assert_eq!(72 % c.unroll_red, 0);
        }
        // cheapest-first ordering
        assert!(cands.windows(2).all(|w| w[0].cycles <= w[1].cycles));
        // full unroll exists and is fastest
        assert_eq!(cands[0].unroll_par, 8);
        assert_eq!(cands[0].unroll_red, 72);
        assert_eq!(cands[0].res.dsp, 288);
    }

    #[test]
    fn pure_parallel_single_candidate() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 1);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].res.dsp, 0);
    }

    #[test]
    fn grid_lattice_orders_cells_then_width_major() {
        let grids = grid_counts(4, 4);
        // (1,1) excluded; fewest cells first; width splits before height
        assert_eq!(grids[0], (1, 2));
        assert_eq!(grids[1], (2, 1));
        assert!(grids.contains(&(2, 2)) && grids.contains(&(4, 4)));
        assert!(!grids.contains(&(1, 1)));
        assert!(grids.windows(2).all(|w| w[0].0 * w[0].1 <= w[1].0 * w[1].1));
        // prime extents fall back to 1-wide cores; extent 1 has no splits
        assert_eq!(grid_counts(1, 13), vec![(1, 13)]);
        assert_eq!(grid_counts(1, 1), Vec::<(u64, u64)>::new());
        // rectangular outputs use each axis' own divisor lattice
        forall(
            "grid divides",
            50,
            |g| (g.rng.range(1, 128), g.rng.range(1, 128)),
            |&(h, w)| {
                grid_counts(h, w)
                    .iter()
                    .all(|&(r, c)| h % r == 0 && w % c == 0 && r * c > 1)
            },
        );
    }

    #[test]
    fn trip_count_one_yields_single_candidate_lattice() {
        // 1x1 "conv" degenerate: a graph whose MAC node has prime/unit
        // trips still enumerates a full (tiny) lattice.
        let g = models::conv_relu(8, 1, 1);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // par trip 1 (one filter), red trip 9 (3x3x1): div(1) x div(9) = 3
        assert_eq!(cands.len(), 3);
        for c in &cands {
            assert_eq!(c.unroll_par, 1);
            assert_eq!(9 % c.unroll_red, 0);
        }
    }

    #[test]
    fn prime_trip_candidates_are_one_or_full() {
        let g = models::conv_relu(8, 7, 5); // C=7, F=5: prime-ish trips
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // par trip 5 -> {1, 5}; red trip 3*3*7 = 63 -> {1,3,7,9,21,63}
        assert_eq!(cands.len(), 2 * 6);
        for c in &cands {
            assert!(c.unroll_par == 1 || c.unroll_par == 5);
            assert_eq!(63 % c.unroll_red, 0);
        }
    }

    #[test]
    fn zero_mac_nodes_have_exactly_one_free_candidate() {
        let g = models::residual(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        for (nid, n) in d.nodes.iter().enumerate() {
            if n.geo.macs_per_out_token == 0 {
                let cands = candidates(&d, nid);
                assert_eq!(cands.len(), 1, "node {}", n.name);
                assert_eq!(cands[0].res.dsp, 0);
                assert_eq!(cands[0].res.bram(), 0);
                assert_eq!(cands[0].timing.ii, 1);
            }
        }
    }

    #[test]
    fn property_every_candidate_respects_unroll_divides_trip() {
        // Across a family of conv/linear workloads, every enumerated
        // Candidate satisfies u_par | par_trip and u_red | red_trip.
        forall(
            "unroll | trip",
            25,
            |g| {
                let n = 8 << g.rng.below(2); // 8 or 16
                let c = 1 + g.rng.below(12) as usize;
                let f = 1 + g.rng.below(12) as usize;
                (n as usize, c, f)
            },
            |&(n, c, f)| {
                let g = models::conv_relu(n, c, f);
                let d = build_streaming_design(&g).unwrap();
                (0..d.nodes.len()).all(|nid| {
                    let node = &d.nodes[nid];
                    let par_trip = node.geo.out_token_len as u64;
                    let red_trip = d.graph.ops[node.op_index].reduction_space().max(1);
                    candidates(&d, nid).iter().all(|cand| {
                        par_trip % cand.unroll_par == 0
                            && red_trip % cand.unroll_red == 0
                            && cand.timing.mac_lanes == cand.unroll_par * cand.unroll_red
                    })
                })
            },
        );
    }

    #[test]
    fn shuffled_candidates_are_detected_and_canonicalized() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let orig = candidates(&d, 0);
        assert!(is_canonical(&orig));
        // a shuffled vector is rejected by the invariant check ...
        let mut shuffled = orig.clone();
        shuffled.reverse();
        assert!(!is_canonical(&shuffled), "reversed order must fail the invariant");
        // ... and canonicalize restores the exact enumeration order,
        // not merely a cycle-sorted one: the key is total, so every
        // position matches the original (unroll pair included)
        canonicalize(&mut shuffled);
        assert!(is_canonical(&shuffled));
        for (a, b) in shuffled.iter().zip(&orig) {
            assert_eq!((a.unroll_par, a.unroll_red), (b.unroll_par, b.unroll_red));
        }
    }

    #[test]
    fn dominance_filter_preserves_minima_order_and_front() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let orig = candidates(&d, 0);
        let mut filtered = orig.clone();
        let dropped = dominance_filter(&mut filtered);
        assert_eq!(dropped as usize + filtered.len(), orig.len());
        // equal-lane unroll splits (e.g. 1x2 vs 2x1) price identically
        // in cycles/DSP/ROM and differ only in comparable line-buffer
        // terms, so the 48-candidate conv lattice must contain dominated
        // points — the nonzero-prune-ratio claim of BENCH_dse.json
        assert!(dropped > 0, "conv_relu lattice has no dominated candidates?");
        assert!(is_canonical(&filtered), "filtering must preserve canonical order");
        // per-field minima are attained by the kept set, so the suffix
        // lower bounds (and the infeasibility verdict) are unchanged
        let fields: [fn(&Candidate) -> u64; 3] = [|c| c.cycles, |c| c.res.dsp, |c| c.res.bram()];
        for f in fields {
            assert_eq!(orig.iter().map(f).min(), filtered.iter().map(f).min());
        }
        // the fastest candidate always survives (it heads the order and
        // nothing precedes it to dominate it)
        assert_eq!(filtered[0], orig[0]);
        // every dropped candidate really is dominated by a kept one
        for c in &orig {
            let survives = filtered
                .iter()
                .any(|a| (a.unroll_par, a.unroll_red) == (c.unroll_par, c.unroll_red));
            if !survives {
                assert!(
                    filtered.iter().any(|a| a.cycles <= c.cycles && a.res.le(&c.res)),
                    "dropped candidate {}x{} has no dominator",
                    c.unroll_par,
                    c.unroll_red
                );
            }
        }
        // idempotent: a second pass finds nothing left to drop
        let mut again = filtered.clone();
        assert_eq!(dominance_filter(&mut again), 0);
        assert_eq!(again.len(), filtered.len());
    }

    #[test]
    fn more_unroll_more_resources_fewer_cycles() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        let scalar = cands.iter().find(|c| c.unroll_par == 1 && c.unroll_red == 1).unwrap();
        let full = cands.iter().find(|c| c.unroll_par == 128 && c.unroll_red == 128).unwrap();
        assert!(full.cycles < scalar.cycles);
        assert!(full.res.dsp > scalar.res.dsp);
        assert!(full.res.bram() >= scalar.res.bram());
    }
}
