//! Candidate enumeration over divisor lattices.

use crate::analysis::classify::KernelClass;
use crate::dataflow::design::Design;
use crate::dataflow::node::NodeTiming;
use crate::ir::types::DType;
use crate::resources::bram::bram_blocks;
use crate::resources::dsp::dsp_for_macs;

/// All positive divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// One unroll candidate for a node, with its pre-computed cost/resources.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub unroll_par: u64,
    pub unroll_red: u64,
    pub timing: NodeTiming,
    /// Standalone cycle estimate with this timing (ILP objective term).
    pub cycles: u64,
    /// DSPs this candidate consumes.
    pub dsp: u64,
    /// BRAM blocks attributable to this node's partitioned buffers.
    pub bram: u64,
}

/// Enumerate candidates for node `nid` of `d`, cheapest-cycles first.
///
/// * MAC nodes (conv / matmul): `u_par | out_features`, `u_red | red_trip`;
///   pipeline depth grows with the log of the adder tree.
/// * Pure-parallel nodes: fixed full-token-width ALU (no DSPs), II = 1 —
///   they are never the bottleneck and need no exploration.
pub fn candidates(d: &Design, nid: usize) -> Vec<Candidate> {
    let n = &d.nodes[nid];
    let op = &d.graph.ops[n.op_index];
    if n.geo.macs_per_out_token == 0 {
        let lanes = n.geo.out_token_len as u64;
        let timing = NodeTiming {
            mac_lanes: lanes,
            ii: 1,
            depth: 2,
            unroll_par: lanes,
            unroll_red: 1,
        };
        let mut node = n.clone();
        node.timing = timing;
        return vec![Candidate {
            unroll_par: lanes,
            unroll_red: 1,
            timing,
            cycles: node.standalone_cycles(),
            dsp: 0,
            bram: 0,
        }];
    }

    let par_trip = n.geo.out_token_len as u64;
    let red_trip = op.reduction_space().max(1);
    let elem_bits = d.graph.tensor(op.inputs[0]).ty.dtype.bits();
    // channel-dim bound for line-buffer partitioning (conv) — see
    // dataflow::build::refresh_buffers
    let chan_bound = *d.graph.tensor(op.inputs[0]).ty.shape.last().unwrap_or(&1) as u64;

    let mut out = Vec::new();
    for &up in &divisors(par_trip) {
        for &ur in &divisors(red_trip) {
            let lanes = up * ur;
            let depth = 4 + (64 - (lanes.max(1)).leading_zeros() as u64); // log2 adder tree
            let timing = NodeTiming {
                mac_lanes: lanes,
                ii: 1,
                depth,
                unroll_par: up,
                unroll_red: ur,
            };
            let mut node = n.clone();
            node.timing = timing;
            let cycles = node.standalone_cycles();
            let dsp = dsp_for_macs(lanes, DType::I8);
            // BRAM contribution: partitioned line buffers only
            let bram = match n.geo.class {
                KernelClass::SlidingWindow(_) => {
                    if let Some(lb) = n.geo.line_buffer {
                        let part = ur.clamp(1, chan_bound);
                        lb.rows as u64 * bram_blocks(lb.row_len as u64 * elem_bits, part)
                    } else {
                        0
                    }
                }
                KernelClass::RegularReduction => {
                    if let Some(lb) = n.geo.line_buffer {
                        let part = ur.clamp(1, lb.row_len as u64);
                        bram_blocks(lb.total_bits(), part)
                    } else {
                        0
                    }
                }
                KernelClass::PureParallel => 0,
            };
            out.push(Candidate { unroll_par: up, unroll_red: ur, timing, cycles, dsp, bram });
        }
    }
    out.sort_by_key(|c| (c.cycles, c.dsp, c.bram));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::util::prop::forall;

    #[test]
    fn divisor_lattices() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(8), vec![1, 2, 4, 8]);
        assert_eq!(divisors(72).len(), 12);
        forall("divisors divide", 100, |g| g.rng.range(1, 512), |&n| {
            divisors(n).iter().all(|&d| n % d == 0)
        });
    }

    #[test]
    fn conv_candidates_cover_lattice() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        // div(8)=4 × div(72)=12
        assert_eq!(cands.len(), 48);
        // every candidate satisfies the unroll-divides-trip constraint
        for c in &cands {
            assert_eq!(8 % c.unroll_par, 0);
            assert_eq!(72 % c.unroll_red, 0);
        }
        // cheapest-first ordering
        assert!(cands.windows(2).all(|w| w[0].cycles <= w[1].cycles));
        // full unroll exists and is fastest
        assert_eq!(cands[0].unroll_par, 8);
        assert_eq!(cands[0].unroll_red, 72);
        assert_eq!(cands[0].dsp, 288);
    }

    #[test]
    fn pure_parallel_single_candidate() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 1);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].dsp, 0);
    }

    #[test]
    fn more_unroll_more_resources_fewer_cycles() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let cands = candidates(&d, 0);
        let scalar = cands.iter().find(|c| c.unroll_par == 1 && c.unroll_red == 1).unwrap();
        let full = cands.iter().find(|c| c.unroll_par == 128 && c.unroll_red == 128).unwrap();
        assert!(full.cycles < scalar.cycles);
        assert!(full.dsp > scalar.dsp);
        assert!(full.bram >= scalar.bram);
    }
}
