//! The `Design` — the hardware-design representation shared by every
//! framework strategy, the resource estimator, the simulator and the code
//! generator.

use anyhow::Result;

use crate::ir::graph::ModelGraph;

use super::buffers::BufferAlloc;
use super::channel::{Channel, ChannelId, Endpoint};
use super::node::DfgNode;

/// Execution discipline of the generated design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignStyle {
    /// MING / StreamHLS: task-level DATAFLOW — all nodes run concurrently,
    /// connected by streams; latency is governed by the slowest node plus
    /// pipeline fill.
    Dataflow,
    /// Vanilla Vitis: ops execute one after another, each reading/writing
    /// full tensors in on-chip memory (no overlap between nodes).
    Sequential,
}

/// A complete hardware design for one model graph.
#[derive(Debug, Clone)]
pub struct Design {
    /// The source model (ops, tensors, weights).
    pub graph: ModelGraph,
    /// Human-readable provenance, e.g. "ming" / "vanilla" / "streamhls".
    pub framework: String,
    pub style: DesignStyle,
    /// Nodes in topological order (node `id` == index).
    pub nodes: Vec<DfgNode>,
    pub channels: Vec<Channel>,
    /// All on-chip arrays (line buffers, weights, intermediates…).
    pub buffers: Vec<BufferAlloc>,
    /// Target clock (MHz) — used only for reporting, cycle counts are the
    /// primary metric as in the paper.
    pub clock_mhz: u32,
}

impl Design {
    pub fn channel(&self, id: ChannelId) -> &Channel {
        &self.channels[id.0]
    }

    /// Channels feeding a node, in input order.
    pub fn node_inputs(&self, node: usize) -> Vec<&Channel> {
        self.nodes[node].in_channels.iter().map(|&c| self.channel(c)).collect()
    }

    /// The channels carrying the design's external input.
    pub fn input_channels(&self) -> Vec<&Channel> {
        self.channels.iter().filter(|c| c.src == Endpoint::GraphInput).collect()
    }

    /// The channel carrying the design's external output.
    pub fn output_channel(&self) -> Result<&Channel> {
        self.channels
            .iter()
            .find(|c| c.dst == Endpoint::GraphOutput)
            .ok_or_else(|| anyhow::anyhow!("design has no output channel"))
    }

    /// Sum of the standalone per-node cycle estimates — the paper ILP's
    /// objective value (a conservative, non-overlapped latency bound).
    pub fn sum_node_cycles(&self) -> u64 {
        self.nodes.iter().map(|n| n.standalone_cycles()).sum()
    }

    /// Critical-path estimate under DATAFLOW overlap: the slowest node's
    /// streaming interval dominates, plus every node's warm-up and depth
    /// along the chain. (The simulator measures this exactly.)
    pub fn overlapped_cycles_estimate(&self) -> u64 {
        match self.style {
            DesignStyle::Sequential => self.sum_node_cycles(),
            DesignStyle::Dataflow => {
                let bottleneck = self
                    .nodes
                    .iter()
                    .map(|n| {
                        let interval = n.compute_interval();
                        n.geo.out_tokens * interval
                    })
                    .max()
                    .unwrap_or(0);
                let fills: u64 =
                    self.nodes.iter().map(|n| n.geo.warmup_tokens + n.timing.depth).sum();
                bottleneck + fills
            }
        }
    }

    /// Total MACs in the workload (for MAC/cycle efficiency reporting).
    pub fn total_macs(&self) -> u64 {
        self.graph.total_macs()
    }
}

#[cfg(test)]
mod tests {
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn design_accessors() {
        let g = models::conv_relu(16, 4, 4);
        let d = build_streaming_design(&g).unwrap();
        assert_eq!(d.framework, "ming");
        assert!(!d.input_channels().is_empty());
        assert!(d.output_channel().is_ok());
        assert!(d.sum_node_cycles() > 0);
        assert!(d.overlapped_cycles_estimate() <= d.sum_node_cycles());
    }
}
