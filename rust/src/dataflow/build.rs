//! MING's streaming-architecture construction (paper §IV-B).
//!
//! One KPN node per `linalg.generic` op; FIFO channels for every
//! producer→consumer edge (fan-out = one channel per consumer, broadcast
//! writes); line buffers for sliding-window nodes; a single-line buffer
//! for regular reductions; nothing but streams for pure-parallel nodes.
//! Large intermediate tensors are **never** materialized.

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use crate::analysis::classify::KernelClass;
use crate::analysis::shapes::{activation_inputs, node_geometry};
use crate::ir::graph::{ModelGraph, TensorKind};
use crate::resources::model::{weight_partitions, weight_storage};

use super::buffers::{BufferAlloc, BufferRole, Storage};
use super::channel::{Channel, ChannelId, Endpoint};
use super::design::{Design, DesignStyle};
use super::node::{DfgNode, NodeTiming};

/// Default FIFO depth for ordinary producer→consumer streams (tokens).
/// Skip/diamond channels are re-sized by `dse::fifo`.
pub const DEFAULT_FIFO_DEPTH: usize = 4;

/// Build the MING streaming design for a validated model graph.
///
/// Timing starts at scalar defaults (`mac_lanes = 1`); run the DSE
/// (`dse::ilp::solve`) to set unroll factors, then
/// [`refresh_buffers`] to recompute partitioning and storage binding.
pub fn build_streaming_design(g: &ModelGraph) -> Result<Design> {
    g.validate().context("building streaming design")?;
    let order = g.toposort()?;

    // node id per op index
    let mut node_of_op: HashMap<usize, usize> = HashMap::new();
    for (nid, &oi) in order.iter().enumerate() {
        node_of_op.insert(oi, nid);
    }

    let mut nodes: Vec<DfgNode> = Vec::with_capacity(order.len());
    let mut channels: Vec<Channel> = Vec::new();

    // First pass: create nodes (geometry only).
    for (nid, &oi) in order.iter().enumerate() {
        let op = &g.ops[oi];
        let geo = node_geometry(g, op)?;
        nodes.push(DfgNode {
            id: nid,
            name: op.name.clone(),
            op_index: oi,
            geo,
            in_channels: Vec::new(),
            out_channels: Vec::new(),
            timing: NodeTiming::default(),
        });
    }

    // Second pass: wire channels for every activation-input edge.
    for nid in 0..nodes.len() {
        let oi = nodes[nid].op_index;
        let op = &g.ops[oi];
        let acts = activation_inputs(g, op);
        for (slot, &ai) in acts.iter().enumerate() {
            let src_tensor = op.inputs[ai];
            let t = g.tensor(src_tensor);
            let (src, src_node) = match t.kind {
                TensorKind::Input => (Endpoint::GraphInput, None),
                _ => {
                    let prod_op = g
                        .ops
                        .iter()
                        .position(|o| o.output == src_tensor)
                        .with_context(|| format!("no producer for {}", t.name))?;
                    let pn = node_of_op[&prod_op];
                    (Endpoint::Node(pn), Some(pn))
                }
            };
            let token_len = nodes[nid].geo.in_token_len[slot];
            let tokens_total = nodes[nid].geo.in_tokens[slot];
            let cid = ChannelId(channels.len());
            channels.push(Channel {
                id: cid,
                name: format!("{}_in{}", nodes[nid].name, slot),
                src,
                dst: Endpoint::Node(nid),
                token_len,
                lanes: token_len, // full width until DSE narrows it
                depth: DEFAULT_FIFO_DEPTH,
                tokens_total,
                elem_bits: t.ty.dtype.bits(),
                externally_buffered: false,
            });
            nodes[nid].in_channels.push(cid);
            if let Some(pn) = src_node {
                nodes[pn].out_channels.push(cid);
            }
        }
    }

    // Output channel: from the node producing the graph output tensor.
    let out_tensor = g.outputs()[0].id;
    let out_op = g
        .ops
        .iter()
        .position(|o| o.output == out_tensor)
        .context("output tensor has no producer")?;
    let out_node = node_of_op[&out_op];
    let (out_tokens, out_len) = {
        let n = &nodes[out_node];
        (n.geo.out_tokens, n.geo.out_token_len)
    };
    let cid = ChannelId(channels.len());
    channels.push(Channel {
        id: cid,
        name: "graph_out".into(),
        src: Endpoint::Node(out_node),
        dst: Endpoint::GraphOutput,
        token_len: out_len,
        lanes: out_len,
        depth: DEFAULT_FIFO_DEPTH,
        tokens_total: out_tokens,
        elem_bits: g.tensor(out_tensor).ty.dtype.bits(),
        externally_buffered: false,
    });
    nodes[out_node].out_channels.push(cid);

    // every node must reach somewhere
    for n in &nodes {
        ensure!(!n.out_channels.is_empty(), "node {} has no consumers", n.name);
    }

    let mut design = Design {
        graph: g.clone(),
        framework: "ming".into(),
        style: DesignStyle::Dataflow,
        nodes,
        channels,
        buffers: Vec::new(),
        clock_mhz: 300,
    };
    refresh_buffers(&mut design);
    Ok(design)
}

/// Build the streaming design for one grid cell of `g`'s feature maps
/// (the outer tile schedule of `crate::tiling` runs this one design per
/// cell, reusing line buffers and weight ROMs across cells). `h_local`
/// and `w_local` are the cell's input extents, halo included; strided
/// ops shrink the downstream extents per the window arithmetic of
/// [`crate::tiling::rewindow`].
pub fn build_cell_design(g: &ModelGraph, h_local: usize, w_local: usize) -> Result<Design> {
    let cell = crate::tiling::rewindow(g, h_local, w_local)?;
    build_streaming_design(&cell)
}

/// (Re)derive buffer allocations + partitioning + storage binding from the
/// current node timing. Called at build time and again after the DSE
/// assigns unroll factors (partition factor = unroll of the accessing
/// loop, per the paper's BRAM constraint).
pub fn refresh_buffers(d: &mut Design) {
    let mut buffers: Vec<BufferAlloc> = Vec::new();
    for n in &d.nodes {
        let op = &d.graph.ops[n.op_index];
        match n.geo.class {
            KernelClass::SlidingWindow(_) => {
                if let Some(lb) = n.geo.line_buffer {
                    // (K-1) independent row arrays, each partitioned by the
                    // channel-unroll so one window column loads per cycle.
                    let chans = *d.graph.tensor(op.inputs[0]).ty.shape.last().unwrap_or(&1) as u64;
                    let part = n.timing.unroll_red.clamp(1, chans);
                    for r in 0..lb.rows {
                        buffers.push(BufferAlloc {
                            name: format!("{}_line{}", n.name, r),
                            role: BufferRole::LineBuffer,
                            bits: lb.row_len as u64 * lb.elem_bits,
                            partitions: part,
                            storage: Storage::Bram, // BIND_STORAGE=ram_1p
                            node: Some(n.id),
                        });
                    }
                }
                if let Some(wv) = n.geo.window_values {
                    buffers.push(BufferAlloc {
                        name: format!("{}_window", n.name),
                        role: BufferRole::WindowBuffer,
                        bits: wv as u64 * 8,
                        partitions: wv as u64, // fully partitioned registers
                        storage: Storage::Ff,
                        node: Some(n.id),
                    });
                }
            }
            KernelClass::RegularReduction => {
                if let Some(lb) = n.geo.line_buffer {
                    let part = n.timing.unroll_red.clamp(1, lb.row_len as u64);
                    buffers.push(BufferAlloc {
                        name: format!("{}_line", n.name),
                        role: BufferRole::ReductionLine,
                        bits: lb.total_bits(),
                        partitions: part,
                        storage: Storage::Bram,
                        node: Some(n.id),
                    });
                }
            }
            KernelClass::PureParallel => {}
        }
        // Weight ROMs: resident constants. Storage binding and partition
        // factor come from the unified resource model's policy
        // (`resources::model::weight_storage`), the same computation the
        // DSE charges per candidate — allocation and pricing cannot
        // diverge.
        for &inp in &op.inputs {
            let t = d.graph.tensor(inp);
            if t.kind == TensorKind::Weight {
                let lanes = n.timing.mac_lanes.max(1);
                let bits = t.ty.bits();
                buffers.push(BufferAlloc {
                    name: format!("{}_{}", n.name, t.name),
                    role: BufferRole::Weights,
                    bits,
                    partitions: weight_partitions(t.ty.numel() as u64, lanes),
                    storage: weight_storage(bits, lanes),
                    node: Some(n.id),
                });
            }
        }
    }
    d.buffers = buffers;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn conv_relu_design_shape() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        assert_eq!(d.nodes.len(), 2);
        // channels: input->conv, conv->rr, rr->out
        assert_eq!(d.channels.len(), 3);
        assert_eq!(d.input_channels().len(), 1);
        // conv has a 2-row line buffer + window + weights
        let roles: Vec<_> = d.buffers.iter().map(|b| b.role).collect();
        assert_eq!(roles.iter().filter(|r| **r == BufferRole::LineBuffer).count(), 2);
        assert_eq!(roles.iter().filter(|r| **r == BufferRole::WindowBuffer).count(), 1);
        assert_eq!(roles.iter().filter(|r| **r == BufferRole::Weights).count(), 1);
        // and crucially: NO intermediate tensors
        assert!(!roles.contains(&BufferRole::IntermediateTensor));
    }

    #[test]
    fn residual_fanout_channels() {
        let g = models::residual(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        // graph input feeds conv0 and add0
        assert_eq!(d.input_channels().len(), 2);
        // every channel has exactly one consumer node or the graph output
        for c in &d.channels {
            match c.dst {
                Endpoint::Node(n) => assert!(n < d.nodes.len()),
                Endpoint::GraphOutput => {}
                Endpoint::GraphInput => panic!("channel into the input"),
            }
        }
    }

    #[test]
    fn channels_are_toposorted_edges() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        for c in &d.channels {
            if let (Endpoint::Node(s), Endpoint::Node(t)) = (c.src, c.dst) {
                assert!(s < t, "channel {} goes backwards", c.name);
            }
        }
    }

    #[test]
    fn refresh_buffers_scales_partitions_with_unroll() {
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        let before: u64 = d
            .buffers
            .iter()
            .filter(|b| b.role == BufferRole::LineBuffer)
            .map(|b| b.partitions)
            .sum();
        assert_eq!(before, 2, "scalar timing: 1 partition per row array");
        d.nodes[0].timing.unroll_red = 8;
        d.nodes[0].timing.mac_lanes = 64;
        refresh_buffers(&mut d);
        let after: u64 = d
            .buffers
            .iter()
            .filter(|b| b.role == BufferRole::LineBuffer)
            .map(|b| b.partitions)
            .sum();
        assert_eq!(after, 16, "(K-1) rows × channel unroll 8");
    }

    #[test]
    fn cell_design_shrinks_line_buffers_only() {
        let g = models::conv_relu(64, 8, 8);
        let full = build_streaming_design(&g).unwrap();
        let cell = build_cell_design(&g, 64, 18).unwrap();
        assert_eq!(cell.nodes.len(), full.nodes.len());
        let row_len = |d: &Design| {
            d.nodes[0].geo.line_buffer.unwrap().row_len
        };
        assert_eq!(row_len(&full), 64 * 8);
        assert_eq!(row_len(&cell), 18 * 8);
        // weights identical: cells reuse the resident ROMs
        let wbits = |d: &Design| -> u64 {
            d.buffers.iter().filter(|b| b.role == BufferRole::Weights).map(|b| b.bits).sum()
        };
        assert_eq!(wbits(&full), wbits(&cell));
    }

    #[test]
    fn cell_design_tracks_strided_downstream_widths() {
        // conv -> pool -> conv: the second conv's line buffer follows the
        // pooled (halved) local width, not the cell input width.
        let g = models::conv_pool_conv(64, 8);
        let cell = build_cell_design(&g, 64, 40).unwrap();
        let lb_of = |d: &Design, name: &str| {
            let nid = d.nodes.iter().position(|n| n.name == name).unwrap();
            d.nodes[nid].geo.line_buffer.unwrap().row_len
        };
        assert_eq!(lb_of(&cell, "conv0"), 40 * 8);
        assert_eq!(lb_of(&cell, "conv1"), 20 * 8);
    }

    #[test]
    fn linear_design_has_reduction_line() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        assert!(d.buffers.iter().any(|b| b.role == BufferRole::ReductionLine));
    }
}
