//! Design well-formedness and deadlock-freedom checks.
//!
//! The structural deadlock hazard in a pure streaming architecture is the
//! *diamond*: two paths from one producer reconverging at one consumer
//! with different latencies (the paper's residual-block case). The fast
//! path's FIFO must absorb at least the token-lag difference between the
//! two paths or both paths stall permanently. `check_diamond_depths`
//! verifies the declared depths against a conservative lag bound; the
//! simulator would otherwise detect the deadlock dynamically.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use super::channel::Endpoint;
use super::design::Design;

/// Structural checks: endpoint sanity, token accounting per channel,
/// single-producer/single-consumer, connectivity.
pub fn validate_design(d: &Design) -> Result<()> {
    ensure!(!d.nodes.is_empty(), "design has no nodes");
    for (i, n) in d.nodes.iter().enumerate() {
        ensure!(n.id == i, "node {} id mismatch", n.name);
        ensure!(
            n.in_channels.len() == n.geo.in_tokens.len(),
            "node {}: {} in-channels vs {} activation inputs",
            n.name,
            n.in_channels.len(),
            n.geo.in_tokens.len()
        );
        ensure!(!n.out_channels.is_empty(), "node {}: no out channels", n.name);
        // broadcast consistency: all out channels carry the same token count
        for &c in &n.out_channels {
            let ch = d.channel(c);
            ensure!(
                ch.tokens_total == n.geo.out_tokens,
                "node {}: out channel {} carries {} tokens, node produces {}",
                n.name,
                ch.name,
                ch.tokens_total,
                n.geo.out_tokens
            );
        }
        for (slot, &c) in n.in_channels.iter().enumerate() {
            let ch = d.channel(c);
            ensure!(ch.dst == Endpoint::Node(i), "channel {} dst mismatch", ch.name);
            ensure!(
                ch.tokens_total == n.geo.in_tokens[slot],
                "node {}: in channel {} token count mismatch",
                n.name,
                ch.name
            );
            ensure!(ch.lanes >= 1 && ch.lanes <= ch.token_len.max(1), "channel {} lanes", ch.name);
            ensure!(ch.depth >= 1, "channel {} has zero depth", ch.name);
        }
    }
    // each channel appears exactly once as input (or graph output)
    let mut seen = vec![0usize; d.channels.len()];
    for n in &d.nodes {
        for &c in &n.in_channels {
            seen[c.0] += 1;
        }
    }
    for c in &d.channels {
        match c.dst {
            Endpoint::Node(_) => ensure!(seen[c.id.0] == 1, "channel {} consumers != 1", c.name),
            Endpoint::GraphOutput => ensure!(seen[c.id.0] == 0, "output channel consumed"),
            Endpoint::GraphInput => bail!("channel {} terminates at the input", c.name),
        }
    }
    Ok(())
}

/// Conservative token-lag bound per node: how many input tokens the node
/// may consume before emitting its first output token (warm-up), plus
/// the reconvergence lag accumulated upstream.
fn first_output_lag(d: &Design, node: usize, memo: &mut HashMap<usize, u64>) -> u64 {
    if let Some(&v) = memo.get(&node) {
        return v;
    }
    let n = &d.nodes[node];
    let upstream = n
        .in_channels
        .iter()
        .map(|&c| match d.channel(c).src {
            Endpoint::Node(p) => first_output_lag(d, p, memo),
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    let v = upstream + n.geo.warmup_tokens;
    memo.insert(node, v);
    v
}

/// Check every reconvergent (diamond) input pair: the shallower path's
/// FIFO depth must cover the lag difference. Returns the list of
/// `(channel_name, required_depth)` violations.
pub fn check_diamond_depths(d: &Design) -> Vec<(String, u64)> {
    let mut memo = HashMap::new();
    let mut bad = Vec::new();
    for n in &d.nodes {
        if n.in_channels.len() < 2 {
            continue;
        }
        // lag of each input path
        let lags: Vec<u64> = n
            .in_channels
            .iter()
            .map(|&c| match d.channel(c).src {
                Endpoint::Node(p) => first_output_lag(d, p, &mut memo),
                _ => 0,
            })
            .collect();
        let max_lag = *lags.iter().max().unwrap();
        for (slot, &c) in n.in_channels.iter().enumerate() {
            let ch = d.channel(c);
            let need = max_lag - lags[slot];
            if need > 0 && (ch.depth as u64) < need {
                bad.push((ch.name.clone(), need));
            }
        }
    }
    bad
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn all_paper_designs_validate() {
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(16)).unwrap();
            let d = build_streaming_design(&g).unwrap();
            validate_design(&d).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn residual_skip_needs_deep_fifo() {
        // With default shallow FIFOs, the skip channel of the residual
        // diamond must be flagged as deadlock-prone.
        let g = models::residual(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let bad = check_diamond_depths(&d);
        assert!(
            bad.iter().any(|(name, need)| name.starts_with("add0_in") && *need > 4),
            "expected skip-FIFO violation, got {bad:?}"
        );
    }

    #[test]
    fn straight_pipelines_have_no_diamond_violations() {
        let g = models::cascade(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        assert!(check_diamond_depths(&d).is_empty());
    }

    #[test]
    fn tampered_design_fails_validation() {
        let g = models::conv_relu(16, 4, 4);
        let mut d = build_streaming_design(&g).unwrap();
        d.channels[0].tokens_total += 1;
        assert!(validate_design(&d).is_err());
    }
}
