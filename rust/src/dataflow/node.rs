//! Dataflow nodes (KPN processes) and their timing configuration.

use crate::analysis::shapes::NodeGeometry;

use super::channel::ChannelId;

/// Per-node timing/parallelism parameters. For MING these are the DSE
/// solution (unroll factors → MAC lanes, II); baselines set them to model
/// their framework's strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeTiming {
    /// MAC (or ALU) lanes operating in parallel each cycle — the product
    /// of the node's loop unroll factors.
    pub mac_lanes: u64,
    /// Initiation interval of the node's pipeline. 1 unless the design
    /// style has memory hazards (WAR ⇒ 2 in ScaleHLS/StreamHLS designs).
    pub ii: u64,
    /// Pipeline depth (latency from consuming a token to emitting the
    /// corresponding result), in cycles.
    pub depth: u64,
    /// Unroll factor along the output-feature (parallel) loop.
    pub unroll_par: u64,
    /// Unroll factor along the reduction loops.
    pub unroll_red: u64,
}

impl Default for NodeTiming {
    fn default() -> Self {
        Self { mac_lanes: 1, ii: 1, depth: 4, unroll_par: 1, unroll_red: 1 }
    }
}

impl NodeTiming {
    /// Cycles between consecutive output tokens, given the work one
    /// output token requires (`work` MACs or ALU ops).
    pub fn interval_for(&self, work: u64) -> u64 {
        work.div_ceil(self.mac_lanes).max(1) * self.ii
    }
}

/// One dataflow node: an op from the source graph plus its streaming
/// geometry, channel hookup, and timing parameters.
#[derive(Debug, Clone)]
pub struct DfgNode {
    /// Index into `Design::nodes` (== position).
    pub id: usize,
    /// Name (the op's name).
    pub name: String,
    /// Index of the originating op in `ModelGraph::ops`.
    pub op_index: usize,
    /// Streaming geometry from `analysis::shapes`.
    pub geo: NodeGeometry,
    /// Input channels, one per activation input, in op-input order.
    pub in_channels: Vec<ChannelId>,
    /// Output channels: one per consumer (broadcast on write).
    pub out_channels: Vec<ChannelId>,
    /// Timing/parallelism configuration.
    pub timing: NodeTiming,
}

impl DfgNode {
    /// Cycles between consecutive output tokens for this node's workload
    /// (compute-bound interval; the simulator additionally applies
    /// channel transfer and back-pressure effects).
    ///
    /// MAC nodes: `work` = MACs per output token, spread over MAC lanes.
    /// Pure-ALU nodes: each lane applies the whole payload to one element
    /// per cycle (relu/requant/add are single-cycle combinational), so
    /// `work` = elements per token — payload complexity costs fabric and
    /// pipeline depth, not initiation interval.
    pub fn compute_interval(&self) -> u64 {
        let work = if self.geo.macs_per_out_token > 0 {
            self.geo.macs_per_out_token
        } else {
            self.geo.out_token_len as u64
        };
        self.timing.interval_for(work.max(1))
    }

    /// Standalone latency estimate: warm-up plus interval times tokens.
    /// This is the per-node `Cycles(v)` term of the paper's ILP objective.
    pub fn standalone_cycles(&self) -> u64 {
        let transfer_in = self
            .geo
            .in_token_len
            .iter()
            .map(|&l| (l as u64).div_ceil(self.timing.unroll_red.min(l as u64).max(1)))
            .max()
            .unwrap_or(1);
        let interval = self.compute_interval().max(transfer_in);
        self.geo.warmup_tokens + self.geo.out_tokens * interval + self.timing.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_math() {
        let t = NodeTiming { mac_lanes: 64, ii: 1, ..Default::default() };
        assert_eq!(t.interval_for(576), 9);
        assert_eq!(t.interval_for(64), 1);
        assert_eq!(t.interval_for(1), 1);
        let t2 = NodeTiming { mac_lanes: 576, ii: 2, ..Default::default() };
        assert_eq!(t2.interval_for(576), 2, "II multiplies the interval");
    }

    #[test]
    fn default_timing_is_scalar() {
        let t = NodeTiming::default();
        assert_eq!(t.mac_lanes, 1);
        assert_eq!(t.ii, 1);
    }
}
