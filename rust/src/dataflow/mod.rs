//! Streaming dataflow architecture (paper §IV-B).
//!
//! A [`design::Design`] is the common hardware-design representation that
//! every framework strategy (MING and the baselines) lowers a
//! [`crate::ir::ModelGraph`] into, and that the resource estimator, the
//! cycle-level simulator, and the HLS code generator all consume. MING's
//! lowering ([`build::build_streaming_design`]) produces the paper's fully
//! streaming architecture: one KPN process per `linalg.generic` op, FIFO
//! channels for every producer→consumer edge, line buffers for
//! sliding-window nodes and single-line buffers for reductions — no
//! intermediate tensors, ever.

pub mod design;
pub mod node;
pub mod channel;
pub mod buffers;
pub mod build;
pub mod validate;

pub use build::build_streaming_design;
pub use channel::{Channel, ChannelId, Endpoint};
pub use design::{Design, DesignStyle};
pub use node::{DfgNode, NodeTiming};
