//! On-chip buffer allocations: line buffers, window buffers, weight ROMs,
//! and (for baseline designs) whole intermediate tensors.

/// Storage binding of a buffer (the BIND_STORAGE pragma target).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    /// Block RAM (RAM18K slices).
    Bram,
    /// Distributed LUT RAM.
    Lutram,
    /// Flip-flop registers (fully partitioned small arrays).
    Ff,
    /// Read-only BRAM (weight constants).
    Rom,
}

/// Why a buffer exists — drives resource attribution and reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferRole {
    /// Sliding-window line buffer ((K-1) row arrays).
    LineBuffer,
    /// Current compute window (K·K·C values).
    WindowBuffer,
    /// Reduction data line (one row).
    ReductionLine,
    /// Constant weights.
    Weights,
    /// A whole intermediate tensor (baseline designs only — MING never
    /// allocates these).
    IntermediateTensor,
    /// Reorder/double buffer (StreamHLS-style).
    ReorderBuffer,
    /// Deep FIFO backing store (skip connections bound to BRAM).
    FifoBacking,
}

/// One allocated on-chip array.
#[derive(Debug, Clone)]
pub struct BufferAlloc {
    pub name: String,
    pub role: BufferRole,
    /// Total payload bits (before partition rounding).
    pub bits: u64,
    /// ARRAY_PARTITION factor: number of independent slices. Each slice
    /// costs at least one physical RAM of its storage kind.
    pub partitions: u64,
    pub storage: Storage,
    /// Owning node (index into `Design::nodes`), if any.
    pub node: Option<usize>,
}

impl BufferAlloc {
    /// Bits per partition slice (rounded up).
    pub fn bits_per_slice(&self) -> u64 {
        self.bits.div_ceil(self.partitions.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_math() {
        let b = BufferAlloc {
            name: "lb".into(),
            role: BufferRole::LineBuffer,
            bits: 1000,
            partitions: 3,
            storage: Storage::Bram,
            node: Some(0),
        };
        assert_eq!(b.bits_per_slice(), 334);
    }

    #[test]
    fn zero_partitions_treated_as_one() {
        let b = BufferAlloc {
            name: "w".into(),
            role: BufferRole::Weights,
            bits: 64,
            partitions: 0,
            storage: Storage::Rom,
            node: None,
        };
        assert_eq!(b.bits_per_slice(), 64);
    }
}
