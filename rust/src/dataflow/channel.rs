//! FIFO channel declarations (the `dfg` dialect's KPN edges).

/// Identifier of a channel within a design.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChannelId(pub usize);

/// What a channel endpoint attaches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// A dataflow node (index into `Design::nodes`).
    Node(usize),
    /// The design's external input reader (host memory → stream).
    GraphInput,
    /// The design's external output writer (stream → host memory).
    GraphOutput,
}

/// One FIFO channel: single producer, single consumer, fixed token shape.
/// Fan-out is expressed as one channel per consumer with the producer
/// broadcasting (KPN-legal: every write goes to all out-channels).
#[derive(Debug, Clone)]
pub struct Channel {
    pub id: ChannelId,
    pub name: String,
    pub src: Endpoint,
    pub dst: Endpoint,
    /// Values per token (e.g. C for a pixel channel).
    pub token_len: usize,
    /// Values transferred per cycle (stream width, set by DSE; the HLS
    /// STREAM pragma's width). `lanes == token_len` ⇒ 1 token/cycle.
    pub lanes: usize,
    /// FIFO depth in tokens (the STREAM pragma depth; DSE-sized to avoid
    /// deadlock on diamonds).
    pub depth: usize,
    /// Tokens that flow through per graph execution.
    pub tokens_total: u64,
    /// Element bit width.
    pub elem_bits: u64,
    /// When true, the channel's storage is represented by explicit
    /// `BufferAlloc`s in the design (baseline strategies that pass whole
    /// tensors between nodes); the BRAM/fabric models then skip the FIFO
    /// itself to avoid double-counting.
    pub externally_buffered: bool,
}

impl Channel {
    /// Cycles to transfer one token at the configured width.
    pub fn cycles_per_token(&self) -> u64 {
        (self.token_len as u64).div_ceil(self.lanes as u64)
    }

    /// Total FIFO storage in bits.
    pub fn storage_bits(&self) -> u64 {
        self.depth as u64 * self.token_len as u64 * self.elem_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ch(token_len: usize, lanes: usize, depth: usize) -> Channel {
        Channel {
            id: ChannelId(0),
            name: "t".into(),
            src: Endpoint::GraphInput,
            dst: Endpoint::Node(0),
            token_len,
            lanes,
            depth,
            tokens_total: 100,
            elem_bits: 8,
            externally_buffered: false,
        }
    }

    #[test]
    fn cycles_per_token_rounds_up() {
        assert_eq!(ch(8, 8, 2).cycles_per_token(), 1);
        assert_eq!(ch(8, 4, 2).cycles_per_token(), 2);
        assert_eq!(ch(9, 4, 2).cycles_per_token(), 3);
        assert_eq!(ch(1, 1, 2).cycles_per_token(), 1);
    }

    #[test]
    fn storage_bits() {
        assert_eq!(ch(8, 8, 4).storage_bits(), 4 * 8 * 8);
    }
}
