//! StreamHLS-like strategy (paper §II/§V observations):
//!
//! * streaming dataflow between nodes, **but** intermediate tensors are
//!   still materialized — and reordered into an additional tensor per
//!   edge ("StreamHLS reorders the intermediate tensor into an additional
//!   newly created tensor") — so BRAM grows linearly with the input area
//!   (Fig. 3) and explodes at 224×224 (>6000 BRAM in Table II);
//! * its DSE optimizes under a **DSP-only** constraint: convolutions get
//!   innermost-loop unrolling; linear layers get unbounded reduction
//!   unrolling, which is exactly the Table II failure ("for kernels
//!   containing linear computations, the framework fails to produce
//!   feasible designs" — DSP 28330);
//! * WAR hazards persist ⇒ II=2.

use anyhow::Result;

use crate::analysis::classify::KernelClass;
use crate::dataflow::buffers::{BufferAlloc, BufferRole, Storage};
use crate::dataflow::build::build_streaming_design;
use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::{Design, DesignStyle};
use crate::dataflow::node::NodeTiming;
use crate::ir::graph::ModelGraph;
use crate::ir::graph::TensorKind;
use crate::resources::device::DeviceSpec;

use super::framework::{Framework, FrameworkKind};

/// WAR-hazard II of StreamHLS pipelines.
pub const STREAMHLS_II: u64 = 2;

pub struct StreamHls;

impl Framework for StreamHls {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::StreamHls
    }

    fn compile(&self, g: &ModelGraph, _device: &DeviceSpec) -> Result<Design> {
        let mut d = build_streaming_design(g)?;
        d.framework = self.kind().name().into();
        d.style = DesignStyle::Dataflow;

        for n in &mut d.nodes {
            let timing = match n.geo.class {
                KernelClass::SlidingWindow(_) => {
                    // innermost (channel) loop unrolled, WAR II=2
                    let c = n.geo.in_token_len[0] as u64;
                    NodeTiming {
                        mac_lanes: c,
                        ii: STREAMHLS_II,
                        depth: 8,
                        unroll_par: 1,
                        unroll_red: c,
                    }
                }
                KernelClass::RegularReduction => {
                    // DSP-unaware full reduction unroll (the Linear/FF
                    // failure mode): lanes = K·N.
                    let k = n.geo.in_token_len[0] as u64;
                    let nn = n.geo.out_token_len as u64;
                    NodeTiming {
                        mac_lanes: k * nn,
                        ii: STREAMHLS_II,
                        depth: 10,
                        unroll_par: nn,
                        unroll_red: k,
                    }
                }
                KernelClass::PureParallel => NodeTiming {
                    mac_lanes: n.geo.out_token_len as u64,
                    ii: STREAMHLS_II,
                    depth: 2,
                    unroll_par: n.geo.out_token_len as u64,
                    unroll_red: 1,
                },
            };
            n.timing = timing;
        }

        // Materialized intermediates: every node→node edge gets the full
        // tensor in BRAM *plus* the reorder copy, the copy partitioned by
        // the consumer's unroll (the "additional memory partitioning"
        // the paper observes). Channels behave as full-tensor buffers.
        let mut buffers = Vec::new();
        for t in &d.graph.tensors {
            if t.kind == TensorKind::Weight {
                buffers.push(BufferAlloc {
                    name: t.name.clone(),
                    role: BufferRole::Weights,
                    bits: t.ty.bits(),
                    partitions: 2,
                    storage: Storage::Rom,
                    node: None,
                });
            }
        }
        let chans: Vec<(usize, usize)> = d
            .channels
            .iter()
            .enumerate()
            .filter_map(|(i, c)| match (c.src, c.dst) {
                (Endpoint::Node(_), Endpoint::Node(dst)) => Some((i, dst)),
                _ => None,
            })
            .collect();
        for (ci, dst) in chans {
            let c = &d.channels[ci];
            let bits = c.tokens_total * c.token_len as u64 * c.elem_bits;
            buffers.push(BufferAlloc {
                name: format!("{}_tensor", c.name),
                role: BufferRole::IntermediateTensor,
                bits,
                partitions: 1,
                storage: Storage::Bram,
                node: None,
            });
            let part = d.nodes[dst].timing.unroll_red.max(1);
            buffers.push(BufferAlloc {
                name: format!("{}_reorder", c.name),
                role: BufferRole::ReorderBuffer,
                bits,
                partitions: part,
                storage: Storage::Bram,
                node: Some(dst),
            });
        }
        d.buffers = buffers;
        for c in &mut d.channels {
            c.depth = c.tokens_total.max(4) as usize; // tensor-backed edges
            c.externally_buffered = true; // tensors modeled as BufferAllocs
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;
    use crate::resources::estimate;
    use crate::sim::{simulate, SimMode};
    use crate::util::prng;

    #[test]
    fn streamhls_bram_scales_with_input_area() {
        // Fig. 3: near-linear BRAM growth with input size.
        let mut brams = Vec::new();
        for n in [32usize, 64, 128, 224] {
            let d =
                StreamHls.compile(&models::conv_relu(n, 8, 8), &DeviceSpec::kv260()).unwrap();
            brams.push(estimate(&d, &DeviceSpec::kv260()).bram18k);
        }
        assert!(brams.windows(2).all(|w| w[0] < w[1]), "monotone: {brams:?}");
        // 224 blows the 288-slice budget massively (paper: >2000)
        assert!(brams[3] > 1000, "expected BRAM explosion at 224: {}", brams[3]);
    }

    #[test]
    fn streamhls_linear_is_dsp_infeasible() {
        // Table II: Linear/FeedForward DSP explodes beyond any device.
        let d = StreamHls.compile(&models::linear(), &DeviceSpec::kv260()).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(r.dsp > 1248, "DSP must exceed KV260: {}", r.dsp);
        assert!(!r.fits());
    }

    #[test]
    fn streamhls_conv_faster_than_vanilla_slower_than_ming() {
        use crate::baselines::framework::{compile_with, FrameworkKind};
        let g = models::conv_relu(32, 8, 8);
        let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect();
        let mut cyc = std::collections::HashMap::new();
        for k in [FrameworkKind::Vanilla, FrameworkKind::StreamHls, FrameworkKind::Ming] {
            let d = compile_with(k, &g, &DeviceSpec::kv260()).unwrap();
            let rep = simulate(&d, &x, SimMode::of(d.style)).unwrap().expect_complete();
            cyc.insert(k, rep.cycles);
        }
        assert!(cyc[&FrameworkKind::StreamHls] < cyc[&FrameworkKind::Vanilla]);
        assert!(cyc[&FrameworkKind::Ming] < cyc[&FrameworkKind::StreamHls]);
    }
}
