//! ScaleHLS-like strategy (paper §V observations):
//!
//! * graph-level (DATAFLOW) pipelining is applied, but "apart from
//!   applying pipelining, no additional performance optimizations such as
//!   loop unrolling are employed";
//! * Write-After-Read dependencies prevent II=1 — nodes pipeline at II=2,
//!   which is why ScaleHLS lands *below* the Vanilla baseline (~0.65–0.8×
//!   in Table II);
//! * intermediate data is passed as function arguments and "automatically
//!   managed by the HLS tool … implemented as circuit using LUT, LUTRAM
//!   and FF" — minimal BRAM, but fabric consumption grows fastest with
//!   network depth (Table III), exhausting the board before BRAM does.

use anyhow::Result;

use crate::dataflow::buffers::{BufferAlloc, BufferRole, Storage};
use crate::dataflow::build::build_streaming_design;
use crate::dataflow::design::{Design, DesignStyle};
use crate::dataflow::node::NodeTiming;
use crate::ir::graph::{ModelGraph, TensorKind};
use crate::resources::device::DeviceSpec;

use super::framework::{Framework, FrameworkKind};

/// WAR-hazard initiation interval of ScaleHLS-generated pipelines.
pub const SCALEHLS_II: u64 = 2;

pub struct ScaleHls;

impl Framework for ScaleHls {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::ScaleHls
    }

    fn compile(&self, g: &ModelGraph, _device: &DeviceSpec) -> Result<Design> {
        let mut d = build_streaming_design(g)?;
        d.framework = self.kind().name().into();
        d.style = DesignStyle::Dataflow;
        for n in &mut d.nodes {
            n.timing = NodeTiming {
                mac_lanes: 1,
                ii: SCALEHLS_II,
                depth: 8,
                unroll_par: 1,
                unroll_red: 1,
            };
        }
        // Inter-node data passes through HLS-managed argument arrays:
        // effectively unbounded transfer capacity (full tensor), realized
        // in fabric. Model: channels get tensor-sized depth, buffers for
        // each intermediate land in LUTRAM.
        for c in &mut d.channels {
            c.depth = c.tokens_total.max(4) as usize;
            c.externally_buffered = true; // HLS-managed argument arrays
        }
        let mut buffers = Vec::new();
        for t in &d.graph.tensors {
            match t.kind {
                TensorKind::Weight => buffers.push(BufferAlloc {
                    name: t.name.clone(),
                    role: BufferRole::Weights,
                    bits: t.ty.bits(),
                    partitions: 2,
                    storage: Storage::Rom, // weights stay in BRAM ROMs
                    node: None,
                }),
                TensorKind::Intermediate => buffers.push(BufferAlloc {
                    name: t.name.clone(),
                    role: BufferRole::IntermediateTensor,
                    bits: t.ty.bits(),
                    partitions: 1,
                    storage: Storage::Lutram, // HLS-managed args => fabric
                    node: None,
                }),
                _ => {} // input/output stream through AXI
            }
        }
        d.buffers = buffers;
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::vanilla::Vanilla;
    use crate::ir::builder::models;
    use crate::resources::estimate;
    use crate::sim::{simulate, SimMode};
    use crate::util::prng;

    #[test]
    fn scalehls_uses_minimal_bram() {
        let g = models::conv_relu(224, 8, 8);
        let d = ScaleHls.compile(&g, &DeviceSpec::kv260()).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(r.bram18k < 30, "BRAM should be weights-only: {}", r.bram18k);
    }

    #[test]
    fn scalehls_fabric_grows_with_depth_faster_than_ming() {
        // Table III: LUT/LUTRAM/FF grow fastest with network depth.
        let one = estimate(
            &ScaleHls.compile(&models::conv_relu(32, 8, 8), &DeviceSpec::kv260()).unwrap(),
            &DeviceSpec::kv260(),
        );
        let two = estimate(
            &ScaleHls.compile(&models::cascade(32, 8, 8), &DeviceSpec::kv260()).unwrap(),
            &DeviceSpec::kv260(),
        );
        assert!(two.lutram > one.lutram);
        assert!(two.lut > one.lut);
    }

    #[test]
    fn scalehls_slower_than_vanilla() {
        // The paper's surprise: ScaleHLS ends up ~1.5x slower than the
        // baseline because WAR hazards force II=2.
        let g = models::conv_relu(32, 8, 8);
        let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect();
        let dv = Vanilla.compile(&g, &DeviceSpec::kv260()).unwrap();
        let ds = ScaleHls.compile(&g, &DeviceSpec::kv260()).unwrap();
        let rv = simulate(&dv, &x, SimMode::of(dv.style)).unwrap().expect_complete();
        let rs = simulate(&ds, &x, SimMode::of(ds.style)).unwrap().expect_complete();
        assert_eq!(rv.output, rs.output);
        assert!(
            rs.cycles > rv.cycles,
            "ScaleHLS ({}) should be slower than Vanilla ({})",
            rs.cycles,
            rv.cycles
        );
    }
}
