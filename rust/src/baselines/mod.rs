//! Re-implementations of the comparison frameworks' design *strategies*
//! (paper §V): Vanilla (Vitis auto-optimization), ScaleHLS-like, and
//! StreamHLS-like. Each lowers a model graph onto the same [`Design`]
//! representation so the shared resource estimator and simulator compare
//! strategies like-for-like — the substitution for running the actual
//! third-party binaries + Vitis (see DESIGN.md).
//!
//! Strategy summaries (derived from the paper's §II/§V observations):
//!
//! | framework  | node overlap | II | unroll | intermediates |
//! |------------|--------------|----|--------|----------------|
//! | Vanilla    | sequential   | 1  | none   | full tensors in BRAM |
//! | ScaleHLS   | dataflow     | 2 (WAR) | none | HLS-managed args → LUTRAM/FF |
//! | StreamHLS  | dataflow     | 2 (WAR) | innermost (convs); unbounded (linears) | materialized + reordered tensors in BRAM |
//! | MING       | dataflow     | 1  | ILP DSE | none (streams + line buffers) |
//!
//! [`Design`]: crate::dataflow::design::Design

pub mod framework;
pub mod vanilla;
pub mod scalehls;
pub mod streamhls;

pub use framework::{compile_with, Framework, FrameworkKind};
