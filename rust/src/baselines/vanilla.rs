//! Vanilla baseline: what Vitis HLS produces from naive loop-nest C++
//! with auto-pipelining only (the paper's Table II baseline).
//!
//! Characteristics observed in the paper:
//! * ops run **sequentially**, each materializing its output tensor in
//!   on-chip BRAM ("inefficient BRAM utilization for large-size input due
//!   to the allocation of memory for intermediate tensors", >40× BRAM
//!   growth from 32² to 224²);
//! * the innermost loop is pipelined at II=1 but nothing is unrolled
//!   ("absence of loop-level optimizations results in minimal DSP usage").

use anyhow::Result;

use crate::dataflow::buffers::{BufferAlloc, BufferRole, Storage};
use crate::dataflow::build::build_streaming_design;
use crate::dataflow::design::{Design, DesignStyle};
use crate::dataflow::node::NodeTiming;
use crate::ir::graph::{ModelGraph, TensorKind};
use crate::resources::device::DeviceSpec;

use super::framework::{Framework, FrameworkKind};

pub struct Vanilla;

impl Framework for Vanilla {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::Vanilla
    }

    fn compile(&self, g: &ModelGraph, _device: &DeviceSpec) -> Result<Design> {
        // Reuse the structural lowering (nodes + channels describe the
        // same computation), then rewrite style / timing / buffers.
        let mut d = build_streaming_design(g)?;
        d.framework = self.kind().name().into();
        d.style = DesignStyle::Sequential;
        for n in &mut d.nodes {
            // innermost pipeline II=1, no unrolling, modest depth
            n.timing = NodeTiming { mac_lanes: 1, ii: 1, depth: 8, unroll_par: 1, unroll_red: 1 };
        }

        // Buffers: every non-weight tensor lives whole in BRAM; weights
        // are ROMs. No line buffers, no partitioning.
        let mut buffers = Vec::new();
        for t in &d.graph.tensors {
            match t.kind {
                TensorKind::Weight => buffers.push(BufferAlloc {
                    name: t.name.clone(),
                    role: BufferRole::Weights,
                    bits: t.ty.bits(),
                    partitions: 1,
                    storage: Storage::Rom,
                    node: None,
                }),
                _ => buffers.push(BufferAlloc {
                    name: t.name.clone(),
                    role: BufferRole::IntermediateTensor,
                    bits: t.ty.bits(),
                    partitions: 1,
                    storage: Storage::Bram,
                    node: None,
                }),
            }
        }
        d.buffers = buffers;
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::estimate;
    use crate::sim::{simulate, SimMode};
    use crate::util::prng;

    fn input_for(g: &ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    #[test]
    fn vanilla_bram_scales_quadratically_with_input() {
        use crate::ir::builder::models;
        let d32 = Vanilla.compile(&models::conv_relu(32, 8, 8), &DeviceSpec::kv260()).unwrap();
        let d224 = Vanilla.compile(&models::conv_relu(224, 8, 8), &DeviceSpec::kv260()).unwrap();
        let r32 = estimate(&d32, &DeviceSpec::kv260());
        let r224 = estimate(&d224, &DeviceSpec::kv260());
        // paper: >40x BRAM growth scaling 32 -> 224 (49x area ratio)
        assert!(
            r224.bram18k > 30 * r32.bram18k,
            "BRAM must scale ~quadratically: {} vs {}",
            r224.bram18k,
            r32.bram18k
        );
        assert!(!r224.fits(), "vanilla conv at 224 must exceed the KV260");
    }

    #[test]
    fn vanilla_dsp_is_minimal() {
        use crate::ir::builder::models;
        let d = Vanilla.compile(&models::cascade(32, 8, 8), &DeviceSpec::kv260()).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(r.dsp <= 4, "no unrolling => minimal DSP, got {}", r.dsp);
    }

    #[test]
    fn vanilla_simulates_sequentially_and_correctly() {
        use crate::ir::builder::models;
        let g = models::conv_relu(16, 8, 8);
        let d = Vanilla.compile(&g, &DeviceSpec::kv260()).unwrap();
        let x = input_for(&g);
        let rep = simulate(&d, &x, SimMode::of(d.style)).unwrap().expect_complete();
        // ~work cycles: out_tokens × macs_per_token (=576) per conv
        let approx = 16 * 16 * 576;
        assert!(
            rep.cycles as f64 > approx as f64 * 0.8,
            "sequential vanilla too fast: {} vs {approx}",
            rep.cycles
        );
        // functional agreement with the streaming design
        let ming = build_streaming_design(&g).unwrap();
        let rep2 = simulate(&ming, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert_eq!(rep.output, rep2.output);
    }
}
