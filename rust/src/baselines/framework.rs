//! The `Framework` trait: graph + device → design.

use anyhow::Result;

use crate::dataflow::design::Design;
use crate::dse::ilp::{solve, DseConfig};
use crate::dataflow::build::build_streaming_design;
use crate::ir::graph::ModelGraph;
use crate::resources::device::DeviceSpec;

/// Identifies one of the four evaluated compilation strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    Vanilla,
    ScaleHls,
    StreamHls,
    Ming,
}

impl FrameworkKind {
    pub fn name(self) -> &'static str {
        match self {
            FrameworkKind::Vanilla => "vanilla",
            FrameworkKind::ScaleHls => "scalehls",
            FrameworkKind::StreamHls => "streamhls",
            FrameworkKind::Ming => "ming",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "vanilla" => Some(FrameworkKind::Vanilla),
            "scalehls" => Some(FrameworkKind::ScaleHls),
            "streamhls" => Some(FrameworkKind::StreamHls),
            "ming" => Some(FrameworkKind::Ming),
            _ => None,
        }
    }

    /// All four, in the paper's Table II column order.
    pub fn all() -> [FrameworkKind; 4] {
        [FrameworkKind::Vanilla, FrameworkKind::ScaleHls, FrameworkKind::StreamHls, FrameworkKind::Ming]
    }
}

/// A compilation strategy.
pub trait Framework {
    fn kind(&self) -> FrameworkKind;
    /// Lower `g` into a hardware design for `device`.
    fn compile(&self, g: &ModelGraph, device: &DeviceSpec) -> Result<Design>;
}

/// MING itself: streaming build + ILP DSE.
pub struct Ming;

impl Framework for Ming {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::Ming
    }

    fn compile(&self, g: &ModelGraph, device: &DeviceSpec) -> Result<Design> {
        let mut d = build_streaming_design(g)?;
        solve(&mut d, &DseConfig::new(device.clone()))?;
        Ok(d)
    }
}

/// Compile `g` with the named strategy.
pub fn compile_with(kind: FrameworkKind, g: &ModelGraph, device: &DeviceSpec) -> Result<Design> {
    match kind {
        FrameworkKind::Vanilla => super::vanilla::Vanilla.compile(g, device),
        FrameworkKind::ScaleHls => super::scalehls::ScaleHls.compile(g, device),
        FrameworkKind::StreamHls => super::streamhls::StreamHls.compile(g, device),
        FrameworkKind::Ming => Ming.compile(g, device),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn names_roundtrip() {
        for k in FrameworkKind::all() {
            assert_eq!(FrameworkKind::parse(k.name()), Some(k));
        }
        assert_eq!(FrameworkKind::parse("tvm"), None);
    }

    #[test]
    fn all_frameworks_compile_conv() {
        let g = models::conv_relu(16, 8, 8);
        for k in FrameworkKind::all() {
            let d = compile_with(k, &g, &DeviceSpec::kv260()).unwrap();
            assert_eq!(d.framework, k.name());
            assert_eq!(d.nodes.len(), g.ops.len());
        }
    }
}
