//! Affine expressions and maps — the exact information MING's Algorithm 1
//! and 2 inspect (paper Fig. 5).
//!
//! We support the canonical forms that appear in `linalg` indexing maps of
//! CNN kernels: single dimensions `d_i`, scaled dims `c * d_i`, constants,
//! and sums thereof (the sliding-window form `s*d_p + δ*d_r`).

use std::fmt;

/// An affine expression over loop dimensions `d0..dn`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AffineExpr {
    /// `d_i`
    Dim(usize),
    /// integer constant
    Const(i64),
    /// `lhs + rhs`
    Add(Box<AffineExpr>, Box<AffineExpr>),
    /// `expr * c` (c constant)
    Mul(Box<AffineExpr>, i64),
}

impl AffineExpr {
    pub fn dim(i: usize) -> Self {
        AffineExpr::Dim(i)
    }

    pub fn scaled(i: usize, c: i64) -> Self {
        if c == 1 {
            AffineExpr::Dim(i)
        } else {
            AffineExpr::Mul(Box::new(AffineExpr::Dim(i)), c)
        }
    }

    pub fn add(self, other: AffineExpr) -> Self {
        AffineExpr::Add(Box::new(self), Box::new(other))
    }

    /// Is this expression exactly a single bare dimension? Returns it.
    /// (`IS_SINGLE_DIM` in paper Algorithm 2.)
    pub fn single_dim(&self) -> Option<usize> {
        match self {
            AffineExpr::Dim(i) => Some(*i),
            _ => None,
        }
    }

    /// Decompose as a list of `(dim, coefficient)` terms plus a constant
    /// offset, iff the expression is a linear combination of distinct dims.
    /// This is the "try to rewrite E as A + B, each term (iterator·const)"
    /// step in paper Algorithm 1 (generalized to any number of terms).
    pub fn linear_terms(&self) -> Option<(Vec<(usize, i64)>, i64)> {
        let mut terms: Vec<(usize, i64)> = Vec::new();
        let mut konst = 0i64;
        if !collect(self, 1, &mut terms, &mut konst) {
            return None;
        }
        // merge duplicate dims
        terms.sort_by_key(|&(d, _)| d);
        let mut merged: Vec<(usize, i64)> = Vec::new();
        for (d, c) in terms {
            if let Some(last) = merged.last_mut() {
                if last.0 == d {
                    last.1 += c;
                    continue;
                }
            }
            merged.push((d, c));
        }
        merged.retain(|&(_, c)| c != 0);
        return Some((merged, konst));

        fn collect(
            e: &AffineExpr,
            scale: i64,
            terms: &mut Vec<(usize, i64)>,
            konst: &mut i64,
        ) -> bool {
            match e {
                AffineExpr::Dim(i) => {
                    terms.push((*i, scale));
                    true
                }
                AffineExpr::Const(c) => {
                    *konst += scale * c;
                    true
                }
                AffineExpr::Add(a, b) => {
                    collect(a, scale, terms, konst) && collect(b, scale, terms, konst)
                }
                AffineExpr::Mul(a, c) => collect(a, scale * c, terms, konst),
            }
        }
    }

    /// All dimensions referenced by this expression.
    pub fn dims(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.visit_dims(&mut |d| out.push(d));
        out.sort_unstable();
        out.dedup();
        out
    }

    fn visit_dims(&self, f: &mut impl FnMut(usize)) {
        match self {
            AffineExpr::Dim(i) => f(*i),
            AffineExpr::Const(_) => {}
            AffineExpr::Add(a, b) => {
                a.visit_dims(f);
                b.visit_dims(f);
            }
            AffineExpr::Mul(a, _) => a.visit_dims(f),
        }
    }

    /// Evaluate at a concrete index vector.
    pub fn eval(&self, idx: &[i64]) -> i64 {
        match self {
            AffineExpr::Dim(i) => idx[*i],
            AffineExpr::Const(c) => *c,
            AffineExpr::Add(a, b) => a.eval(idx) + b.eval(idx),
            AffineExpr::Mul(a, c) => a.eval(idx) * c,
        }
    }
}

impl fmt::Display for AffineExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AffineExpr::Dim(i) => write!(f, "d{i}"),
            AffineExpr::Const(c) => write!(f, "{c}"),
            AffineExpr::Add(a, b) => write!(f, "{a} + {b}"),
            AffineExpr::Mul(a, c) => match a.as_ref() {
                AffineExpr::Dim(i) => write!(f, "d{i} * {c}"),
                other => write!(f, "({other}) * {c}"),
            },
        }
    }
}

/// An affine map `(d0, ..., d{n-1}) -> (e0, ..., e{m-1})`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct AffineMap {
    pub num_dims: usize,
    pub results: Vec<AffineExpr>,
}

impl AffineMap {
    pub fn new(num_dims: usize, results: Vec<AffineExpr>) -> Self {
        for r in &results {
            for d in r.dims() {
                assert!(d < num_dims, "map result references d{d} >= num_dims {num_dims}");
            }
        }
        Self { num_dims, results }
    }

    /// The identity map over `n` dims: `(d0..dn) -> (d0..dn)`.
    pub fn identity(n: usize) -> Self {
        Self::new(n, (0..n).map(AffineExpr::Dim).collect())
    }

    /// Projection map selecting the given dims: `(d0..dn) -> (d_sel...)`.
    pub fn select(num_dims: usize, sel: &[usize]) -> Self {
        Self::new(num_dims, sel.iter().map(|&i| AffineExpr::Dim(i)).collect())
    }

    pub fn is_identity(&self) -> bool {
        self.results.len() == self.num_dims
            && self
                .results
                .iter()
                .enumerate()
                .all(|(i, e)| matches!(e, AffineExpr::Dim(d) if *d == i))
    }

    /// Evaluate the map at a concrete iteration point.
    pub fn eval(&self, idx: &[i64]) -> Vec<i64> {
        assert_eq!(idx.len(), self.num_dims);
        self.results.iter().map(|e| e.eval(idx)).collect()
    }
}

impl fmt::Display for AffineMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = (0..self.num_dims).map(|i| format!("d{i}")).collect();
        let res: Vec<String> = self.results.iter().map(|e| e.to_string()).collect();
        write!(f, "({}) -> ({})", dims.join(", "), res.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_dim_detection() {
        assert_eq!(AffineExpr::dim(3).single_dim(), Some(3));
        assert_eq!(AffineExpr::scaled(3, 2).single_dim(), None);
        assert_eq!(AffineExpr::Const(0).single_dim(), None);
    }

    #[test]
    fn linear_terms_of_sliding_window_expr() {
        // E = 2*d0 + 3*d4 (stride 2, dilation 3)
        let e = AffineExpr::scaled(0, 2).add(AffineExpr::scaled(4, 3));
        let (terms, k) = e.linear_terms().unwrap();
        assert_eq!(terms, vec![(0, 2), (4, 3)]);
        assert_eq!(k, 0);
    }

    #[test]
    fn linear_terms_merges_and_drops_zero() {
        // d1 + d1 + 0*d2 + 5
        let e = AffineExpr::dim(1)
            .add(AffineExpr::dim(1))
            .add(AffineExpr::scaled(2, 0))
            .add(AffineExpr::Const(5));
        let (terms, k) = e.linear_terms().unwrap();
        assert_eq!(terms, vec![(1, 2)]);
        assert_eq!(k, 5);
    }

    #[test]
    fn identity_map() {
        let m = AffineMap::identity(4);
        assert!(m.is_identity());
        assert_eq!(m.eval(&[1, 2, 3, 4]), vec![1, 2, 3, 4]);
        assert_eq!(m.to_string(), "(d0, d1, d2, d3) -> (d0, d1, d2, d3)");
    }

    #[test]
    fn conv_input_map_eval() {
        // (d0,d1,d2,d3,d4,d5) -> (d0+d3, d1+d4, d5): the paper's map1 shape
        let m = AffineMap::new(
            6,
            vec![
                AffineExpr::dim(0).add(AffineExpr::dim(3)),
                AffineExpr::dim(1).add(AffineExpr::dim(4)),
                AffineExpr::dim(5),
            ],
        );
        assert!(!m.is_identity());
        assert_eq!(m.eval(&[10, 20, 0, 1, 2, 3]), vec![11, 22, 3]);
    }

    #[test]
    #[should_panic(expected = "references d5")]
    fn map_rejects_out_of_range_dims() {
        AffineMap::new(3, vec![AffineExpr::dim(5)]);
    }

    #[test]
    fn select_map() {
        let m = AffineMap::select(6, &[2, 3, 4, 5]);
        assert_eq!(m.eval(&[0, 0, 7, 8, 9, 10]), vec![7, 8, 9, 10]);
    }
}
