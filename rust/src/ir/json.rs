//! Dependency-free JSON (de)serialization for model graphs — the stand-in
//! for the paper's ONNX/TensorFlow/PyTorch front-ends. A model file lists
//! layers; `import_model` lowers them through [`GraphBuilder`] into
//! `linalg.generic` form exactly like the builder API.
//!
//! ```json
//! {
//!   "name": "tiny",
//!   "input": {"shape": [32, 32, 8], "dtype": "i8"},
//!   "layers": [
//!     {"op": "conv2d", "filters": 8, "kernel": 3, "stride": 1, "pad": 1,
//!      "seed": 101, "activation": "relu"},
//!     {"op": "linear", "features": 128, "seed": 202}
//!   ]
//! }
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, ensure, Context, Result};

use super::builder::GraphBuilder;
use super::graph::{ModelGraph, TilingHint};
use super::types::DType;

/// A JSON value (numbers kept as f64; ints round-trip exactly to 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Ok(*n as i64),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self.as_obj()?.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing key {key:?}"),
        }
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a Json) -> &'a Json {
        self.as_obj().ok().and_then(|m| m.get(key)).unwrap_or(default)
    }

    /// Serialize to a compact string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        match self.b.get(self.i) {
            Some(c) => Ok(*c),
            None => bail!("unexpected end of input"),
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected char {:?} at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string"),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.b[start..])?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

/// Optional per-layer weight metadata emitted by the Python front-end
/// (`compile/model.py::json_model`): `weight_elems` (element count) and
/// `weight_bits` (bits per element). The importer derives the actual
/// weight tensor from the layer geometry + seed, so the metadata ships
/// no tensor data — it lets external tooling (and the unified resource
/// model's ROM accounting) price weight storage without materializing
/// weights, and is validated here against the derived shape so the two
/// descriptions cannot drift apart.
fn check_weight_meta(layer: &Json, li: usize, elems: u64, dtype: DType) -> Result<()> {
    if let Some(v) = layer.as_obj()?.get("weight_elems") {
        let got = v.as_usize()? as u64;
        ensure!(
            got == elems,
            "layer {li}: weight_elems {got} does not match the derived weight \
             shape ({elems} elements)"
        );
    }
    if let Some(v) = layer.as_obj()?.get("weight_bits") {
        let got = v.as_usize()? as u64;
        ensure!(
            got == dtype.bits(),
            "layer {li}: weight_bits {got} does not match dtype {} ({} bits)",
            dtype.name(),
            dtype.bits()
        );
    }
    Ok(())
}

/// Import a layered model description into a `ModelGraph`.
pub fn import_model(text: &str) -> Result<ModelGraph> {
    let doc = parse(text)?;
    let name = doc.get("name")?.as_str()?.to_string();
    let input = doc.get("input")?;
    let shape: Vec<usize> = input
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<_>>()?;
    let dtype = DType::parse(input.get_or("dtype", &Json::Str("i8".into())).as_str()?)
        .context("bad input dtype")?;

    // Optional tile-grid metadata for the tiling subsystem
    // (crate::tiling). Written by python/compile/aot.py --emit-model-json.
    // axis "width" is the legacy 1 x N strip form; "grid" additionally
    // carries a tile_height for 2-D rows x cols decompositions.
    let tiling = match doc.as_obj()?.get("tiling") {
        Some(t) => {
            let axis = match t.as_obj()?.get("axis") {
                Some(a) => {
                    ensure!(
                        matches!(a.as_str()?, "width" | "grid"),
                        "tiling axis must be \"width\" or \"grid\", got {:?}",
                        a
                    );
                    Some(a.as_str()?)
                }
                None => None,
            };
            let tile_height = match t.as_obj()?.get("tile_height") {
                Some(v) => Some(v.as_usize()?),
                None => None,
            };
            // the legacy "width" axis declares a 1 x N strip plan — a
            // tile_height would contradict it silently, so reject
            ensure!(
                !(axis == Some("width") && tile_height.is_some()),
                "tiling axis \"width\" cannot carry a tile_height — use \
                 axis \"grid\" for 2-D rows x cols hints"
            );
            Some(TilingHint {
                tile_width: match t.as_obj()?.get("tile_width") {
                    Some(v) => Some(v.as_usize()?),
                    None => None,
                },
                tile_height,
                max_tiles: match t.as_obj()?.get("max_tiles") {
                    Some(v) => Some(v.as_usize()?),
                    None => None,
                },
            })
        }
        None => None,
    };

    let relu_default = Json::Str("relu".into());
    let mut b = GraphBuilder::new(name);
    let mut cur = b.input("x", shape.clone(), dtype);
    let mut cur_shape = shape;
    for (li, layer) in doc.get("layers")?.as_arr()?.iter().enumerate() {
        let op = layer.get("op")?.as_str()?;
        let seed = layer.get_or("seed", &Json::Num(100.0 + li as f64)).as_i64()? as u64;
        match op {
            "conv2d" => {
                ensure!(cur_shape.len() == 3, "conv2d needs (H,W,C) input at layer {li}");
                let f = layer.get("filters")?.as_usize()?;
                let k = layer.get_or("kernel", &Json::Num(3.0)).as_usize()?;
                let stride = layer.get_or("stride", &Json::Num(1.0)).as_usize()?;
                let pad = layer.get_or("pad", &Json::Num((k / 2) as f64)).as_usize()?;
                let c = cur_shape[2];
                check_weight_meta(layer, li, (f * k * k * c) as u64, DType::I8)?;
                let w = b.det_weight(&format!("w{li}"), vec![f, k, k, c], seed);
                let acc = b.conv2d(&format!("conv{li}"), cur, w, stride, pad);
                let act = layer.get_or("activation", &relu_default).as_str()?;
                cur = match act {
                    "relu" => b.relu_requant(&format!("rr{li}"), acc),
                    "none" => b.requant(&format!("req{li}"), acc),
                    other => bail!("unknown activation {other:?}"),
                };
                let keff = k;
                cur_shape = vec![
                    (cur_shape[0] + 2 * pad - keff) / stride + 1,
                    (cur_shape[1] + 2 * pad - keff) / stride + 1,
                    f,
                ];
            }
            "maxpool2d" => {
                let k = layer.get_or("kernel", &Json::Num(2.0)).as_usize()?;
                let stride = layer.get_or("stride", &Json::Num(k as f64)).as_usize()?;
                cur = b.maxpool2d(&format!("pool{li}"), cur, k, stride);
                cur_shape = vec![
                    (cur_shape[0] - k) / stride + 1,
                    (cur_shape[1] - k) / stride + 1,
                    cur_shape[2],
                ];
            }
            "linear" => {
                ensure!(cur_shape.len() == 2, "linear needs (M,K) input at layer {li}");
                let n = layer.get("features")?.as_usize()?;
                check_weight_meta(layer, li, (cur_shape[1] * n) as u64, DType::I8)?;
                let w = b.det_weight(&format!("w{li}"), vec![cur_shape[1], n], seed);
                let acc = b.linear(&format!("mm{li}"), cur, w);
                let act = layer.get_or("activation", &relu_default).as_str()?;
                cur = match act {
                    "relu" => b.relu_requant(&format!("rr{li}"), acc),
                    "none" => b.requant(&format!("req{li}"), acc),
                    other => bail!("unknown activation {other:?}"),
                };
                cur_shape = vec![cur_shape[0], n];
            }
            other => bail!("unknown layer op {other:?} at layer {li}"),
        }
    }
    b.mark_output(cur);
    let mut g = b.finish();
    g.tiling = tiling;
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, "x\n", true, null], "b": {"c": -3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.render()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn as_f64_accepts_any_number() {
        assert_eq!(parse("2.5").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(parse("-3").unwrap().as_f64().unwrap(), -3.0);
        assert!(parse("\"x\"").unwrap().as_f64().is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn parse_utf8_and_escapes() {
        let v = parse(r#""Aé\t""#).unwrap();
        assert_eq!(v, Json::Str("Aé\t".into()));
    }

    #[test]
    fn import_two_layer_model() {
        let g = import_model(
            r#"{
              "name": "tiny",
              "input": {"shape": [16, 16, 4], "dtype": "i8"},
              "layers": [
                {"op": "conv2d", "filters": 8, "kernel": 3, "seed": 101},
                {"op": "conv2d", "filters": 4, "kernel": 3, "seed": 202}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(g.ops.len(), 4); // 2x (conv + relu_requant)
        assert_eq!(g.outputs()[0].ty.shape, vec![16, 16, 4]);
        g.validate().unwrap();
    }

    #[test]
    fn import_mlp() {
        let g = import_model(
            r#"{
              "name": "mlp",
              "input": {"shape": [64, 32]},
              "layers": [
                {"op": "linear", "features": 16},
                {"op": "linear", "features": 8, "activation": "none"}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(g.outputs()[0].ty.shape, vec![64, 8]);
    }

    #[test]
    fn import_carries_tiling_metadata() {
        let g = import_model(
            r#"{
              "name": "wide",
              "input": {"shape": [64, 64, 8], "dtype": "i8"},
              "tiling": {"axis": "width", "tile_width": 16, "max_tiles": 8},
              "layers": [
                {"op": "conv2d", "filters": 8, "kernel": 3, "seed": 101}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(
            g.tiling,
            Some(TilingHint { tile_width: Some(16), tile_height: None, max_tiles: Some(8) })
        );
        // no metadata -> no hint
        let g2 = import_model(
            r#"{"name":"x","input":{"shape":[16,16,4]},
                "layers":[{"op":"conv2d","filters":4}]}"#,
        )
        .unwrap();
        assert_eq!(g2.tiling, None);
        // unknown axes are rejected ("width" and "grid" only)
        let err = import_model(
            r#"{"name":"x","input":{"shape":[16,16,4]},
                "tiling": {"axis": "height"},
                "layers":[{"op":"conv2d","filters":4}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("width"));
    }

    #[test]
    fn import_carries_grid_tiling_metadata() {
        // the 2-D form: axis "grid" with a tile_height for rows x cols
        let g = import_model(
            r#"{
              "name": "tall",
              "input": {"shape": [64, 64, 8], "dtype": "i8"},
              "tiling": {"axis": "grid", "tile_width": 16, "tile_height": 32,
                         "max_tiles": 12},
              "layers": [
                {"op": "conv2d", "filters": 8, "kernel": 3, "seed": 101}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(
            g.tiling,
            Some(TilingHint {
                tile_width: Some(16),
                tile_height: Some(32),
                max_tiles: Some(12),
            })
        );
        // the legacy "width" axis contradicts a 2-D tile_height
        let err = import_model(
            r#"{"name":"x","input":{"shape":[16,16,4]},
                "tiling": {"axis": "width", "tile_height": 4},
                "layers":[{"op":"conv2d","filters":4}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("tile_height"), "{err}");
    }

    #[test]
    fn import_weight_metadata_roundtrip() {
        // weight_elems / weight_bits ride along without shipping weight
        // data; the importer validates them against the derived shapes.
        let src = r#"{
          "name": "meta",
          "input": {"shape": [16, 16, 4], "dtype": "i8"},
          "layers": [
            {"op": "conv2d", "filters": 8, "kernel": 3, "seed": 101,
             "weight_elems": 288, "weight_bits": 8}
          ]
        }"#;
        let g = import_model(src).unwrap();
        let w = &g.weights()[0];
        assert_eq!(w.ty.numel(), 288, "8 filters x 3x3x4");
        assert_eq!(w.ty.dtype.bits(), 8);
        // survives a parse -> render -> parse round trip bit-exactly
        let doc = parse(src).unwrap();
        let again = parse(&doc.render()).unwrap();
        assert_eq!(doc, again);
        import_model(&doc.render()).unwrap();
    }

    #[test]
    fn import_rejects_mismatched_weight_metadata() {
        for (key, val) in [("weight_elems", 999), ("weight_bits", 16)] {
            let src = format!(
                r#"{{"name":"x","input":{{"shape":[16,16,4]}},
                    "layers":[{{"op":"conv2d","filters":8,"{key}":{val}}}]}}"#
            );
            let err = import_model(&src).unwrap_err();
            assert!(err.to_string().contains(key), "{key}: {err}");
        }
        // mismatch on linear layers too
        let err = import_model(
            r#"{"name":"x","input":{"shape":[64,32]},
                "layers":[{"op":"linear","features":16,"weight_elems":1}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("weight_elems"), "{err}");
    }

    #[test]
    fn import_rejects_bad_layer() {
        let err = import_model(
            r#"{"name":"x","input":{"shape":[8,8,2]},
                "layers":[{"op":"transformer"}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown layer op"));
    }

    #[test]
    fn import_conv_then_pool() {
        let g = import_model(
            r#"{"name":"cp","input":{"shape":[16,16,4]},
                "layers":[{"op":"conv2d","filters":4},
                          {"op":"maxpool2d","kernel":2}]}"#,
        )
        .unwrap();
        assert_eq!(g.outputs()[0].ty.shape, vec![8, 8, 4]);
    }
}
