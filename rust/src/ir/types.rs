//! Element types and tensor types.

use std::fmt;

/// Element dtypes supported by the quantized-CNN pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    I8,
    I16,
    I32,
    F32,
}

impl DType {
    /// Bit width of one element (for BRAM packing and stream widths).
    pub fn bits(self) -> u64 {
        match self {
            DType::I8 => 8,
            DType::I16 => 16,
            DType::I32 | DType::F32 => 32,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::I8 => "i8",
            DType::I16 => "i16",
            DType::I32 => "i32",
            DType::F32 => "f32",
        }
    }

    /// The HLS C++ spelling (`ap_int`-free: plain stdint types).
    pub fn cpp(self) -> &'static str {
        match self {
            DType::I8 => "int8_t",
            DType::I16 => "int16_t",
            DType::I32 => "int32_t",
            DType::F32 => "float",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "i8" => Some(DType::I8),
            "i16" => Some(DType::I16),
            "i32" => Some(DType::I32),
            "f32" => Some(DType::F32),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A ranked tensor type: shape + element dtype.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorType {
    pub fn new(shape: Vec<usize>, dtype: DType) -> Self {
        Self { shape, dtype }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total size in bits (for resource estimation).
    pub fn bits(&self) -> u64 {
        self.numel() as u64 * self.dtype.bits()
    }
}

impl fmt::Display for TensorType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        write!(f, "tensor<{}x{}>", dims.join("x"), self.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bits_and_roundtrip() {
        for d in [DType::I8, DType::I16, DType::I32, DType::F32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::I8.bits(), 8);
        assert_eq!(DType::I32.bits(), 32);
        assert_eq!(DType::parse("i64"), None);
    }

    #[test]
    fn tensor_type_math() {
        let t = TensorType::new(vec![32, 32, 8], DType::I8);
        assert_eq!(t.numel(), 8192);
        assert_eq!(t.bits(), 65536);
        assert_eq!(t.to_string(), "tensor<32x32x8xi8>");
        assert_eq!(t.rank(), 3);
    }
}
