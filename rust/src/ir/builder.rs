//! Graph builder + the paper's five evaluation kernels.
//!
//! The builder plays the role of MING's front-end import path
//! (ONNX/TensorFlow/PyTorch → IREE → `linalg`): it constructs
//! `linalg.generic`-shaped ops with the exact indexing maps / iterator
//! types the paper's Fig. 5 shows for CNN workloads.

use super::affine::{AffineExpr, AffineMap};
use super::generic::{GenericOp, IterType, Payload};
use super::graph::{ModelGraph, TensorId, TensorKind};
use super::types::{DType, TensorType};
use crate::util::prng;

/// Requantization shift shared with `python/compile/kernels/ref.py`.
pub const REQUANT_SHIFT: u32 = 6;

/// Incremental graph construction with SSA tensors.
pub struct GraphBuilder {
    g: ModelGraph,
    n_ops: usize,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self { g: ModelGraph::new(name), n_ops: 0 }
    }

    pub fn input(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> TensorId {
        self.g.add_tensor(name, TensorType::new(shape, dtype), TensorKind::Input, None)
    }

    pub fn weight(&mut self, name: &str, shape: Vec<usize>, data: Vec<i8>) -> TensorId {
        self.g.add_tensor(name, TensorType::new(shape, dtype_i8()), TensorKind::Weight, Some(data))
    }

    /// Deterministic weight tensor from the PRNG shared with Python.
    pub fn det_weight(&mut self, name: &str, shape: Vec<usize>, seed: u64) -> TensorId {
        let n: usize = shape.iter().product();
        self.weight(name, shape, prng::det_tensor(seed, n))
    }

    fn intermediate(&mut self, name: String, shape: Vec<usize>, dtype: DType) -> TensorId {
        self.g.add_tensor(name, TensorType::new(shape, dtype), TensorKind::Intermediate, None)
    }

    fn push(&mut self, op: GenericOp) -> TensorId {
        let out = op.output;
        self.g.ops.push(op);
        self.n_ops += 1;
        out
    }

    /// 2-D convolution, NHWC(-without-N) input `(H, W, C)`, weights
    /// `(F, K, K, C)`, same- or valid-padding; output `(H_out, W_out, F)`
    /// int32 accumulators.
    ///
    /// Loop dims: `d0=h_out, d1=w_out, d2=f` (parallel),
    /// `d3=kh, d4=kw, d5=c` (reduction). Input map results are the
    /// sliding-window form `s·d_p + δ·d_r − pad` of paper Algorithm 1.
    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        w: TensorId,
        stride: usize,
        pad: usize,
    ) -> TensorId {
        self.conv2d_dilated(name, x, w, stride, pad, 1)
    }

    pub fn conv2d_dilated(
        &mut self,
        name: &str,
        x: TensorId,
        w: TensorId,
        stride: usize,
        pad: usize,
        dilation: usize,
    ) -> TensorId {
        let (h, wid, c) = {
            let t = &self.g.tensor(x).ty;
            assert_eq!(t.rank(), 3, "conv2d input must be (H,W,C)");
            (t.shape[0], t.shape[1], t.shape[2])
        };
        let (f, k) = {
            let t = &self.g.tensor(w).ty;
            assert_eq!(t.rank(), 4, "conv2d weight must be (F,K,K,C)");
            assert_eq!(t.shape[1], t.shape[2], "square kernels only");
            assert_eq!(t.shape[3], c, "channel mismatch");
            (t.shape[0], t.shape[1])
        };
        let keff = (k - 1) * dilation + 1;
        let h_out = (h + 2 * pad - keff) / stride + 1;
        let w_out = (wid + 2 * pad - keff) / stride + 1;
        let out = self.intermediate(format!("{name}_acc"), vec![h_out, w_out, f], DType::I32);

        let sw = |p: usize, r: usize| {
            let e = AffineExpr::scaled(p, stride as i64).add(AffineExpr::scaled(r, dilation as i64));
            if pad > 0 {
                e.add(AffineExpr::Const(-(pad as i64)))
            } else {
                e
            }
        };
        let x_map = AffineMap::new(6, vec![sw(0, 3), sw(1, 4), AffineExpr::dim(5)]);
        let w_map = AffineMap::select(6, &[2, 3, 4, 5]);
        let o_map = AffineMap::select(6, &[0, 1, 2]);
        self.push(GenericOp {
            name: name.into(),
            inputs: vec![x, w],
            output: out,
            indexing_maps: vec![x_map, w_map, o_map],
            iter_types: vec![
                IterType::Parallel,
                IterType::Parallel,
                IterType::Parallel,
                IterType::Reduction,
                IterType::Reduction,
                IterType::Reduction,
            ],
            dims: vec![h_out, w_out, f, k, k, c],
            payload: Payload::MulAcc,
            pad,
        })
    }

    /// Matrix multiply `x (M,K) @ w (K,N) -> (M,N)` int32 accumulators.
    /// Dims: `d0=m, d1=n` (parallel), `d2=k` (reduction) — the paper's
    /// regular-reduction kernel.
    pub fn linear(&mut self, name: &str, x: TensorId, w: TensorId) -> TensorId {
        let (m, k) = {
            let t = &self.g.tensor(x).ty;
            assert_eq!(t.rank(), 2);
            (t.shape[0], t.shape[1])
        };
        let n = {
            let t = &self.g.tensor(w).ty;
            assert_eq!(t.rank(), 2);
            assert_eq!(t.shape[0], k, "contraction mismatch");
            t.shape[1]
        };
        let out = self.intermediate(format!("{name}_acc"), vec![m, n], DType::I32);
        let x_map = AffineMap::select(3, &[0, 2]);
        let w_map = AffineMap::select(3, &[2, 1]);
        let o_map = AffineMap::select(3, &[0, 1]);
        self.push(GenericOp {
            name: name.into(),
            inputs: vec![x, w],
            output: out,
            indexing_maps: vec![x_map, w_map, o_map],
            iter_types: vec![IterType::Parallel, IterType::Parallel, IterType::Reduction],
            dims: vec![m, n, k],
            payload: Payload::MulAcc,
            pad: 0,
        })
    }

    fn elementwise(&mut self, name: &str, ins: Vec<TensorId>, payload: Payload, out_dtype: DType) -> TensorId {
        let shape = self.g.tensor(ins[0]).ty.shape.clone();
        let rank = shape.len();
        let out = self.intermediate(format!("{name}_out"), shape.clone(), out_dtype);
        let maps = vec![AffineMap::identity(rank); ins.len() + 1];
        self.push(GenericOp {
            name: name.into(),
            inputs: ins,
            output: out,
            indexing_maps: maps,
            iter_types: vec![IterType::Parallel; rank],
            dims: shape,
            payload,
            pad: 0,
        })
    }

    /// ReLU (keeps the input dtype).
    pub fn relu(&mut self, name: &str, x: TensorId) -> TensorId {
        let dt = self.g.tensor(x).ty.dtype;
        self.elementwise(name, vec![x], Payload::Relu, dt)
    }

    /// Requantize int32 accumulators to int8 (no ReLU).
    pub fn requant(&mut self, name: &str, x: TensorId) -> TensorId {
        self.elementwise(name, vec![x], Payload::Requant { shift: REQUANT_SHIFT }, DType::I8)
    }

    /// Fused ReLU + requantize: the paper's post-conv activation node.
    pub fn relu_requant(&mut self, name: &str, x: TensorId) -> TensorId {
        self.elementwise(name, vec![x], Payload::ReluRequant { shift: REQUANT_SHIFT }, DType::I8)
    }

    /// Saturating int8 addition (residual skip merge).
    pub fn add_sat(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        assert_eq!(self.g.tensor(a).ty.shape, self.g.tensor(b).ty.shape, "add shape mismatch");
        self.elementwise(name, vec![a, b], Payload::AddSat, DType::I8)
    }

    /// 2-D max-pooling `(H,W,C) -> (H/k, W/k, C)` — a sliding-window op
    /// with a *single-input* window (no weights); used by extension tests.
    pub fn maxpool2d(&mut self, name: &str, x: TensorId, k: usize, stride: usize) -> TensorId {
        let (h, w, c) = {
            let t = &self.g.tensor(x).ty;
            (t.shape[0], t.shape[1], t.shape[2])
        };
        let h_out = (h - k) / stride + 1;
        let w_out = (w - k) / stride + 1;
        let dt = self.g.tensor(x).ty.dtype;
        let out = self.intermediate(format!("{name}_out"), vec![h_out, w_out, c], dt);
        let sw = |p: usize, r: usize| AffineExpr::scaled(p, stride as i64).add(AffineExpr::dim(r));
        let x_map = AffineMap::new(5, vec![sw(0, 3), sw(1, 4), AffineExpr::dim(2)]);
        let o_map = AffineMap::select(5, &[0, 1, 2]);
        self.push(GenericOp {
            name: name.into(),
            inputs: vec![x],
            output: out,
            indexing_maps: vec![x_map, o_map],
            iter_types: vec![
                IterType::Parallel,
                IterType::Parallel,
                IterType::Parallel,
                IterType::Reduction,
                IterType::Reduction,
            ],
            dims: vec![h_out, w_out, c, k, k],
            payload: Payload::MaxReduce,
            pad: 0,
        })
    }

    /// Mark a tensor as a graph output.
    pub fn mark_output(&mut self, t: TensorId) {
        self.g.tensors[t.0].kind = TensorKind::Output;
    }

    pub fn finish(self) -> ModelGraph {
        self.g
    }
}

fn dtype_i8() -> DType {
    DType::I8
}

/// The five paper evaluation kernels (Table II) plus helpers.
pub mod models {
    use super::*;

    /// Conv channel geometry fixed across the paper's size sweep
    /// (see DESIGN.md; consistent with Table II's Vanilla cycle counts).
    pub const CONV_C: usize = 8;
    pub const CONV_F: usize = 8;
    pub const CONV_K: usize = 3;

    /// Linear geometry: batch-512 activations, 128 features.
    pub const LIN_M: usize = 512;
    pub const LIN_K: usize = 128;
    pub const LIN_N: usize = 128;

    fn conv_weight_shape(c: usize, f: usize) -> Vec<usize> {
        vec![f, CONV_K, CONV_K, c]
    }

    /// Conv+ReLU single layer at `n`×`n` input.
    pub fn conv_relu(n: usize, c: usize, f: usize) -> ModelGraph {
        let mut b = GraphBuilder::new(format!("conv_relu_{n}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let w = b.det_weight("w1", conv_weight_shape(c, f), prng::SEED_W1);
        let acc = b.conv2d("conv0", x, w, 1, 1);
        let y = b.relu_requant("rr0", acc);
        b.mark_output(y);
        b.finish()
    }

    /// Cascade Conv Block: two Conv+ReLU layers back to back.
    pub fn cascade(n: usize, c: usize, f: usize) -> ModelGraph {
        let mut b = GraphBuilder::new(format!("cascade_{n}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let w1 = b.det_weight("w1", conv_weight_shape(c, f), prng::SEED_W1);
        let w2 = b.det_weight("w2", conv_weight_shape(f, f), prng::SEED_W2);
        let a0 = b.conv2d("conv0", x, w1, 1, 1);
        let t = b.relu_requant("rr0", a0);
        let a1 = b.conv2d("conv1", t, w2, 1, 1);
        let y = b.relu_requant("rr1", a1);
        b.mark_output(y);
        b.finish()
    }

    /// Residual Block: `y = relu(x + requant(conv(relu(conv(x)))))` —
    /// the diamond dataflow whose skip FIFO the DSE must size.
    pub fn residual(n: usize, c: usize, f: usize) -> ModelGraph {
        assert_eq!(c, f, "residual needs C == F for the skip add");
        let mut b = GraphBuilder::new(format!("residual_{n}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let w1 = b.det_weight("w1", conv_weight_shape(c, f), prng::SEED_W1);
        let w2 = b.det_weight("w2", conv_weight_shape(f, f), prng::SEED_W2);
        let a0 = b.conv2d("conv0", x, w1, 1, 1);
        let t = b.relu_requant("rr0", a0);
        let a1 = b.conv2d("conv1", t, w2, 1, 1);
        let u = b.requant("req1", a1);
        let s = b.add_sat("add0", x, u);
        let y = b.relu("relu_out", s);
        b.mark_output(y);
        b.finish()
    }

    /// Linear 512x128 (one matmul + activation).
    pub fn linear() -> ModelGraph {
        let mut b = GraphBuilder::new("linear_0");
        let x = b.input("x", vec![LIN_M, LIN_K], DType::I8);
        let w = b.det_weight("w1", vec![LIN_K, LIN_N], prng::SEED_W1);
        let acc = b.linear("mm0", x, w);
        let y = b.relu_requant("rr0", acc);
        b.mark_output(y);
        b.finish()
    }

    /// Feed Forward: two cascaded Linear layers.
    pub fn feedforward() -> ModelGraph {
        let mut b = GraphBuilder::new("feedforward_0");
        let x = b.input("x", vec![LIN_M, LIN_K], DType::I8);
        let w1 = b.det_weight("w1", vec![LIN_K, LIN_N], prng::SEED_W1);
        let w2 = b.det_weight("w2", vec![LIN_N, LIN_N], prng::SEED_W2);
        let a0 = b.linear("mm0", x, w1);
        let t = b.relu_requant("rr0", a0);
        let a1 = b.linear("mm1", t, w2);
        let y = b.relu_requant("rr1", a1);
        b.mark_output(y);
        b.finish()
    }

    /// A VGG-style block: `layers` cascaded 3×3 same-padded Conv+ReLU
    /// stages at a constant channel count `c` on an `n`×`n` input — the
    /// oversized-workload generator for the halo-aware tiling subsystem.
    /// At e.g. 512×512×256×3 on the KV260 the minimal line buffers alone
    /// exceed the device BRAM, so only MING-with-tiling can place it.
    pub fn vgg_block(n: usize, c: usize, layers: usize) -> ModelGraph {
        assert!(layers >= 1, "vgg_block needs at least one layer");
        let mut b = GraphBuilder::new(format!("vgg{layers}_{n}x{c}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let mut cur = x;
        for li in 0..layers {
            let w = b.det_weight(&format!("w{li}"), vec![c, CONV_K, CONV_K, c], 1000 + li as u64);
            let acc = b.conv2d(&format!("conv{li}"), cur, w, 1, 1);
            cur = b.relu_requant(&format!("rr{li}"), acc);
        }
        b.mark_output(cur);
        b.finish()
    }

    /// Strided showcase for the tile-grid subsystem: a same-padded
    /// 3×3 conv, a 2×2 stride-2 max-pool, and another 3×3 conv, all at
    /// a constant channel count `c` on an `n`×`n` input. The pool halves
    /// the output lattice, so tiling it needs the stride-aware
    /// coordinate remapping of `tiling::halo` — the width-strip planner
    /// hard-rejected this chain. At e.g. 512×512×384 on the KV260 the
    /// minimal line buffers alone exceed the device BRAM, so only the
    /// grid fallback can place it.
    pub fn conv_pool_conv(n: usize, c: usize) -> ModelGraph {
        let mut b = GraphBuilder::new(format!("cpc_{n}x{c}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let w1 = b.det_weight("w1", vec![c, CONV_K, CONV_K, c], prng::SEED_W1);
        let w2 = b.det_weight("w2", vec![c, CONV_K, CONV_K, c], prng::SEED_W2);
        let a0 = b.conv2d("conv0", x, w1, 1, 1);
        let t0 = b.relu_requant("rr0", a0);
        let p0 = b.maxpool2d("pool0", t0, 2, 2);
        let a1 = b.conv2d("conv1", p0, w2, 1, 1);
        let y = b.relu_requant("rr1", a1);
        b.mark_output(y);
        b.finish()
    }

    /// A small but complete CNN beyond the paper's micro-kernels:
    /// conv(3x3,C->F) -> maxpool(2x2) -> conv(3x3,F->F) -> maxpool(2x2).
    /// Exercises stride-2 sliding windows and weight-less window nodes
    /// through the whole pipeline (extension workload, not in Table II).
    pub fn tiny_cnn(n: usize, c: usize, f: usize) -> ModelGraph {
        let mut b = GraphBuilder::new(format!("tiny_cnn_{n}"));
        let x = b.input("x", vec![n, n, c], DType::I8);
        let w1 = b.det_weight("w1", conv_weight_shape(c, f), prng::SEED_W1);
        let w2 = b.det_weight("w2", conv_weight_shape(f, f), prng::SEED_W2);
        let a0 = b.conv2d("conv0", x, w1, 1, 1);
        let t0 = b.relu_requant("rr0", a0);
        let p0 = b.maxpool2d("pool0", t0, 2, 2);
        let a1 = b.conv2d("conv1", p0, w2, 1, 1);
        let t1 = b.relu_requant("rr1", a1);
        let p1 = b.maxpool2d("pool1", t1, 2, 2);
        b.mark_output(p1);
        b.finish()
    }

    /// Paper kernel by name ("conv_relu" | "cascade" | "residual" |
    /// "linear" | "feedforward") at input size `n` (ignored for linear/ff).
    pub fn paper_kernel(name: &str, n: usize) -> anyhow::Result<ModelGraph> {
        Ok(match name {
            "conv_relu" => conv_relu(n, CONV_C, CONV_F),
            "cascade" => cascade(n, CONV_C, CONV_F),
            "residual" => residual(n, CONV_C, CONV_F),
            "linear" => linear(),
            "feedforward" => feedforward(),
            // oversized extension workloads (tiling showcases, not Table II)
            "vgg3" => vgg_block(n, 256, 3),
            "conv_pool" => conv_pool_conv(n, 384),
            other => anyhow::bail!("unknown paper kernel {other:?}"),
        })
    }

    /// All Table II workloads as `(kernel, size)` pairs.
    pub fn table2_workloads() -> Vec<(&'static str, usize)> {
        vec![
            ("conv_relu", 32),
            ("conv_relu", 224),
            ("cascade", 32),
            ("cascade", 224),
            ("residual", 32),
            ("residual", 224),
            ("linear", 0),
            ("feedforward", 0),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::models::*;
    use super::*;
    use crate::ir::generic::IterType;

    #[test]
    fn all_paper_kernels_validate() {
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(8)).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn conv_maps_have_sliding_window_form() {
        let g = conv_relu(16, 4, 4);
        let conv = g.op("conv0").unwrap();
        let x_map = &conv.indexing_maps[0];
        // first result: d0 + d3 - 1 (stride 1, dilation 1, pad 1)
        let (terms, k) = x_map.results[0].linear_terms().unwrap();
        assert_eq!(terms, vec![(0, 1), (3, 1)]);
        assert_eq!(k, -1);
        assert_eq!(conv.iter_types[0], IterType::Parallel);
        assert_eq!(conv.iter_types[3], IterType::Reduction);
    }

    #[test]
    fn strided_dilated_conv_geometry() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![16, 16, 2], DType::I8);
        let w = b.det_weight("w", vec![2, 3, 3, 2], 1);
        let acc = b.conv2d_dilated("c", x, w, 2, 0, 2);
        let g = {
            b.mark_output(acc);
            b.finish()
        };
        // keff = 5; h_out = (16-5)/2+1 = 6
        assert_eq!(g.tensor(acc).ty.shape, vec![6, 6, 2]);
        g.validate().unwrap();
        let (terms, _) = g.op("c").unwrap().indexing_maps[0].results[0].linear_terms().unwrap();
        assert_eq!(terms, vec![(0, 2), (3, 2)]); // stride 2, dilation 2
    }

    #[test]
    fn linear_is_regular_reduction_shape() {
        let g = linear();
        let mm = g.op("mm0").unwrap();
        assert_eq!(mm.dims, vec![LIN_M, LIN_N, LIN_K]);
        assert_eq!(mm.reduction_space(), LIN_K as u64);
        // every input map result is a single dim (no compound exprs)
        for m in mm.input_maps() {
            for e in &m.results {
                assert!(e.single_dim().is_some());
            }
        }
    }

    #[test]
    fn maxpool_shapes() {
        let mut b = GraphBuilder::new("mp");
        let x = b.input("x", vec![8, 8, 4], DType::I8);
        let y = b.maxpool2d("pool0", x, 2, 2);
        b.mark_output(y);
        let g = b.finish();
        assert_eq!(g.tensor(y).ty.shape, vec![4, 4, 4]);
        g.validate().unwrap();
    }

    #[test]
    fn feedforward_macs_double_linear() {
        assert_eq!(feedforward().total_macs(), 2 * linear().total_macs());
    }

    #[test]
    fn vgg_block_shapes_and_macs() {
        let g = vgg_block(64, 16, 3);
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 6); // 3x (conv + relu_requant)
        assert_eq!(g.outputs()[0].ty.shape, vec![64, 64, 16]);
        // 3 layers x N^2 x C_out x K^2 x C_in MACs
        assert_eq!(g.total_macs(), 3 * 64 * 64 * 16 * 9 * 16);
        assert_eq!(g.weights().len(), 3);
    }

    #[test]
    fn conv_pool_conv_shapes() {
        let g = conv_pool_conv(64, 8);
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 5); // conv, rr, pool, conv, rr
        assert_eq!(g.outputs()[0].ty.shape, vec![32, 32, 8]);
        assert_eq!(g.weights().len(), 2);
        // 64^2·8·9·8 + 32^2·8·9·8 MACs across the two convs
        assert_eq!(g.total_macs(), (64 * 64 + 32 * 32) * 8 * 9 * 8);
    }

    #[test]
    fn weights_match_python_prng() {
        let g = conv_relu(8, CONV_C, CONV_F);
        let w = g.weights()[0];
        let expect = prng::det_tensor(prng::SEED_W1, CONV_F * 9 * CONV_C);
        assert_eq!(w.data.as_ref().unwrap()[..16], expect[..16]);
    }
}
