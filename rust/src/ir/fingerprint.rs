//! Content-addressed fingerprints for DSE problems.
//!
//! A *design cache* ([`crate::coordinator::cache`]) can only reuse a
//! solved design if two compilations of "the same" workload produce the
//! same key — across processes, across sweep shards, and regardless of
//! the order in which the front-end happened to build the graph. This
//! module computes that key: a stable 64-bit structural hash over
//! `(ModelGraph, DeviceSpec)` with three properties:
//!
//! 1. **Build-order independence.** Ops are folded in a *canonical*
//!    topological order (ready ops sorted by their structural signature
//!    and the canonical ids of their operands), and tensors are
//!    renumbered in that emission order, so `vgg3@512` hashes
//!    identically whether it came from `ir::builder::models`, from a
//!    JSON import, or from a graph whose branches were inserted in a
//!    different order.
//! 2. **Name independence.** Tensor/op/graph names never enter the
//!    hash — they are provenance, not structure. Weight *contents* do
//!    enter it (two models that differ only in weights emit different
//!    HLS and must not share a cache entry).
//! 3. **Process stability.** The hash is plain FNV-1a over a fixed
//!    byte encoding — no `std::hash` randomization, no pointer values —
//!    so a fingerprint written to disk by one process is meaningful to
//!    every other.
//!
//! The device's resource capacities (and the graph's tiling hint) are
//! part of the problem, not the workload, so [`problem_fingerprint`]
//! folds them on top of [`graph_fingerprint`]: shrinking the BRAM
//! budget or changing a tile-width hint changes the key and correctly
//! misses the cache.

use std::collections::HashMap;

use super::generic::{GenericOp, IterType, Payload};
use super::graph::{ModelGraph, TensorInfo, TensorKind};
use super::AffineExpr;
use crate::resources::device::DeviceSpec;

/// Bumped whenever the encoding below changes, so stale on-disk cache
/// entries from an older scheme can never alias a new fingerprint.
pub const FINGERPRINT_VERSION: u64 = 1;

/// Incremental FNV-1a (64-bit): tiny, fast, and — unlike
/// `std::collections::hash_map::DefaultHasher` — specified, so values
/// are stable across processes, architectures and toolchain versions.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Length-prefixed, so `("ab", "c")` and `("a", "bc")` differ.
    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

fn expr_hash(h: &mut Fnv64, e: &AffineExpr) {
    match e {
        AffineExpr::Dim(i) => {
            h.write_u8(1);
            h.write_usize(*i);
        }
        AffineExpr::Const(c) => {
            h.write_u8(2);
            h.write_i64(*c);
        }
        AffineExpr::Add(a, b) => {
            h.write_u8(3);
            expr_hash(h, a);
            expr_hash(h, b);
        }
        AffineExpr::Mul(a, c) => {
            h.write_u8(4);
            expr_hash(h, a);
            h.write_i64(*c);
        }
    }
}

/// Structural signature of one tensor: type only, never the name.
fn tensor_sig(t: &TensorInfo) -> u64 {
    let mut h = Fnv64::new();
    h.write_usize(t.ty.shape.len());
    for &d in &t.ty.shape {
        h.write_usize(d);
    }
    h.write_str(t.ty.dtype.name());
    h.finish()
}

fn payload_hash(h: &mut Fnv64, p: Payload) {
    h.write_str(p.name());
    match p {
        Payload::Requant { shift } | Payload::ReluRequant { shift } => {
            h.write_u64(shift as u64)
        }
        _ => h.write_u64(0),
    }
}

/// Tensor-id-free signature of one op: payload, iteration space,
/// indexing maps, padding, and the types (plus weight *contents*) of
/// its operands. Two structurally identical ops in different graphs —
/// or the same graph built twice in different orders — share it.
fn op_signature(g: &ModelGraph, op: &GenericOp) -> u64 {
    let mut h = Fnv64::new();
    payload_hash(&mut h, op.payload);
    h.write_usize(op.pad);
    h.write_usize(op.dims.len());
    for &d in &op.dims {
        h.write_usize(d);
    }
    for it in &op.iter_types {
        h.write_u8(match it {
            IterType::Parallel => 0,
            IterType::Reduction => 1,
        });
    }
    for m in &op.indexing_maps {
        h.write_usize(m.num_dims);
        h.write_usize(m.results.len());
        for e in &m.results {
            expr_hash(&mut h, e);
        }
    }
    for &inp in &op.inputs {
        let t = g.tensor(inp);
        h.write_u8(match t.kind {
            TensorKind::Input => 0,
            TensorKind::Weight => 1,
            TensorKind::Intermediate => 2,
            TensorKind::Output => 3,
        });
        h.write_u64(tensor_sig(t));
        if t.kind == TensorKind::Weight {
            if let Some(data) = &t.data {
                h.write_usize(data.len());
                // i8 -> u8 cast is a bijection; the raw bytes are the data
                for &v in data {
                    h.write_u8(v as u8);
                }
            }
        }
    }
    h.finish()
}

/// Canonical structural fingerprint of a model graph (workload only —
/// see [`problem_fingerprint`] for the full DSE-problem key).
pub fn graph_fingerprint(g: &ModelGraph) -> u64 {
    let local: Vec<u64> = g.ops.iter().map(|op| op_signature(g, op)).collect();

    // Canonical tensor numbering: graph inputs first (ordered by type
    // signature — paper graphs are single-input, but stay well-defined),
    // then each op's output in canonical emission order.
    let mut inputs = g.inputs();
    inputs.sort_by_key(|t| tensor_sig(t));
    let mut canon: HashMap<usize, u64> = HashMap::new();
    for (i, t) in inputs.iter().enumerate() {
        canon.insert(t.id.0, i as u64);
    }
    let mut next = inputs.len() as u64;

    let mut h = Fnv64::new();
    h.write_u64(FINGERPRINT_VERSION);
    h.write_usize(inputs.len());
    for t in &inputs {
        h.write_u64(tensor_sig(t));
    }

    // Canonical topological emission: among ops whose activation inputs
    // all have canonical ids, always emit the one with the smallest
    // (signature, operand-ids) key. Identical graphs built in any order
    // make identical choices, so the fold below is order-independent.
    let n = g.ops.len();
    let mut emitted = vec![false; n];
    for _ in 0..n {
        let mut best: Option<(Vec<u64>, usize)> = None;
        for (i, op) in g.ops.iter().enumerate() {
            if emitted[i] {
                continue;
            }
            let ids: Option<Vec<u64>> = op
                .inputs
                .iter()
                .map(|tid| {
                    if g.tensor(*tid).kind == TensorKind::Weight {
                        // weight contents are already in the signature
                        Some(u64::MAX)
                    } else {
                        canon.get(&tid.0).copied()
                    }
                })
                .collect();
            let Some(ids) = ids else { continue };
            let mut key = Vec::with_capacity(1 + ids.len());
            key.push(local[i]);
            key.extend(ids);
            let better = match &best {
                None => true,
                Some((bk, _)) => key < *bk,
            };
            if better {
                best = Some((key, i));
            }
        }
        let Some((key, i)) = best else {
            // Defensive: a cyclic (invalid) graph — fold the leftovers
            // in index order rather than panicking; `validate()` rejects
            // such graphs before they reach any solver anyway.
            for (j, sig) in local.iter().enumerate() {
                if !emitted[j] {
                    h.write_u64(*sig);
                }
            }
            break;
        };
        emitted[i] = true;
        for v in &key {
            h.write_u64(*v);
        }
        let out_t = g.tensor(g.ops[i].output);
        canon.insert(out_t.id.0, next);
        h.write_u64(next);
        next += 1;
        h.write_u64(tensor_sig(out_t));
        h.write_u8(if out_t.kind == TensorKind::Output { 1 } else { 0 });
    }

    // The tiling hint steers the grid search, so it is part of the key.
    match &g.tiling {
        None => h.write_u8(0),
        Some(t) => {
            h.write_u8(1);
            for v in [t.tile_width, t.tile_height, t.max_tiles] {
                match v {
                    Some(x) => {
                        h.write_u8(1);
                        h.write_usize(x);
                    }
                    None => h.write_u8(0),
                }
            }
        }
    }
    h.finish()
}

/// Fingerprint of a full DSE problem: the workload *and* the resource
/// budgets it must fit. The device name is deliberately excluded — two
/// identically-sized devices pose the same problem — while every
/// capacity the solver or the fabric reports read is included, so
/// `--dsp-limit` / `--bram-limit` / `--max-bram-frac` variants key
/// separate entries.
pub fn problem_fingerprint(g: &ModelGraph, dev: &DeviceSpec) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(graph_fingerprint(g));
    fold_device_budgets(&mut h, dev);
    h.finish()
}

/// Fold every solver-visible device capacity into `h` — the budget half
/// of [`problem_fingerprint`], shared with the per-node front keys of
/// `dse::warmstart` so both key on exactly the same capacity fields
/// (and stay in lockstep when a capacity is added).
pub fn fold_device_budgets(h: &mut Fnv64, dev: &DeviceSpec) {
    for v in [dev.bram18k, dev.dsp, dev.lut, dev.lutram, dev.ff] {
        h.write_u64(v);
    }
}

/// Render a fingerprint the way cache files and logs spell it.
pub fn hex(fp: u64) -> String {
    format!("{fp:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{models, GraphBuilder};
    use crate::ir::graph::TilingHint;
    use crate::ir::types::DType;

    #[test]
    fn fnv_is_stable_and_prefix_safe() {
        let mut a = Fnv64::new();
        a.write_bytes(b"hello");
        // reference FNV-1a 64 of "hello"
        assert_eq!(a.finish(), 0xa430_d846_80aa_bd0b);
        let mut b = Fnv64::new();
        b.write_str("ab");
        b.write_str("c");
        let mut c = Fnv64::new();
        c.write_str("a");
        c.write_str("bc");
        assert_ne!(b.finish(), c.finish(), "length prefixes must disambiguate");
    }

    #[test]
    fn same_builder_same_fingerprint() {
        let a = graph_fingerprint(&models::conv_relu(32, 8, 8));
        let b = graph_fingerprint(&models::conv_relu(32, 8, 8));
        assert_eq!(a, b);
    }

    #[test]
    fn workload_changes_change_the_fingerprint() {
        let base = graph_fingerprint(&models::conv_relu(32, 8, 8));
        assert_ne!(base, graph_fingerprint(&models::conv_relu(64, 8, 8)), "size");
        assert_ne!(base, graph_fingerprint(&models::conv_relu(32, 4, 8)), "channels");
        assert_ne!(base, graph_fingerprint(&models::cascade(32, 8, 8)), "depth");
    }

    #[test]
    fn names_do_not_enter_the_fingerprint() {
        let mut a = models::conv_relu(32, 8, 8);
        let fp = graph_fingerprint(&a);
        a.name = "renamed_beyond_recognition".into();
        for t in &mut a.tensors {
            t.name = format!("t{}", t.id.0);
        }
        for (i, op) in a.ops.iter_mut().enumerate() {
            op.name = format!("op{i}");
        }
        assert_eq!(fp, graph_fingerprint(&a), "names are provenance, not structure");
    }

    #[test]
    fn weight_contents_enter_the_fingerprint() {
        // Same shapes, different seed => different ROM contents => keys
        // must differ (the cache returns full designs with baked weights).
        fn conv_with_seed(seed: u64) -> crate::ir::graph::ModelGraph {
            let mut b = GraphBuilder::new("seeded");
            let x = b.input("x", vec![16, 16, 4], DType::I8);
            let w = b.det_weight("w", vec![4, 3, 3, 4], seed);
            let acc = b.conv2d("conv0", x, w, 1, 1);
            let y = b.relu_requant("rr0", acc);
            b.mark_output(y);
            b.finish()
        }
        assert_ne!(
            graph_fingerprint(&conv_with_seed(1)),
            graph_fingerprint(&conv_with_seed(2))
        );
    }

    #[test]
    fn build_order_does_not_enter_the_fingerprint() {
        // A diamond whose two branches can be inserted in either order:
        //   x -> conv -> requant --\
        //   x ---------------------+-> add_sat -> relu
        // Branch-insertion order permutes op and tensor indices; the
        // canonical emission must erase that.
        fn diamond(branch_first: bool) -> crate::ir::graph::ModelGraph {
            let mut b = GraphBuilder::new("diamond");
            let x = b.input("x", vec![16, 16, 4], DType::I8);
            let w = b.det_weight("w", vec![4, 3, 3, 4], 7);
            let (conv, req);
            if branch_first {
                conv = b.conv2d("conv0", x, w, 1, 1);
                req = b.requant("req0", conv);
            } else {
                // same ops, created under different names/order pressure:
                // an unrelated tensor id is burned first so all ids shift
                let _decoy = b.det_weight("decoy", vec![1, 1, 1, 4], 9);
                conv = b.conv2d("c", x, w, 1, 1);
                req = b.requant("r", conv);
            }
            let s = b.add_sat("add0", x, req);
            let y = b.relu("out", s);
            b.mark_output(y);
            b.finish()
        }
        let a = diamond(true);
        let b = diamond(false);
        // the decoy weight is dead (no op consumes it) and must not count
        assert_eq!(graph_fingerprint(&a), graph_fingerprint(&b));

        // op *storage* order is erased too: reversing the op vector
        // (ModelGraph does not require sorted creation order) must not
        // move the fingerprint
        let mut c = diamond(true);
        c.ops.reverse();
        assert_eq!(graph_fingerprint(&a), graph_fingerprint(&c));
    }

    #[test]
    fn tiling_hint_enters_the_fingerprint() {
        let mut g = models::conv_relu(32, 8, 8);
        let base = graph_fingerprint(&g);
        g.tiling = Some(TilingHint {
            tile_width: Some(8),
            tile_height: None,
            max_tiles: None,
        });
        let hinted = graph_fingerprint(&g);
        assert_ne!(base, hinted);
        g.tiling = Some(TilingHint {
            tile_width: Some(16),
            tile_height: None,
            max_tiles: None,
        });
        assert_ne!(hinted, graph_fingerprint(&g));
    }

    #[test]
    fn device_and_limits_key_the_problem() {
        let g = models::conv_relu(32, 8, 8);
        let kv = DeviceSpec::kv260();
        let base = problem_fingerprint(&g, &kv);
        assert_eq!(base, problem_fingerprint(&g, &DeviceSpec::kv260()));
        assert_ne!(base, problem_fingerprint(&g, &DeviceSpec::zcu104()));
        assert_ne!(base, problem_fingerprint(&g, &kv.with_dsp_limit(250)));
        assert_ne!(base, problem_fingerprint(&g, &kv.with_bram_limit(64)));
        // a renamed but identically-sized device is the same problem
        let mut twin = DeviceSpec::kv260();
        twin.name = "kv260-rebadged".into();
        assert_eq!(base, problem_fingerprint(&g, &twin));
    }

    #[test]
    fn hex_renders_16_digits() {
        assert_eq!(hex(0xab), "00000000000000ab");
        assert_eq!(hex(u64::MAX).len(), 16);
    }
}
