//! `ModelGraph` — a DAG of generic ops over SSA tensors.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use super::generic::GenericOp;
use super::types::TensorType;

/// Identifier of a tensor value within a graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

/// Role of a tensor in the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// External input (fed from host memory at run time).
    Input,
    /// Constant weights, baked into the design (BRAM/ROM on the FPGA).
    Weight,
    /// Produced by one op, consumed by other op(s).
    Intermediate,
    /// Graph output (streamed back to host memory).
    Output,
}

/// A tensor value: type, role, and (for weights) constant data.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    pub ty: TensorType,
    pub kind: TensorKind,
    /// Constant contents for `Weight` tensors (flat, row-major int8).
    pub data: Option<Vec<i8>>,
}

/// Front-end tiling metadata, carried from the JSON model schema's
/// optional `"tiling"` object into the tile-grid subsystem
/// (`crate::tiling`). Hints are advisory: the tiling planner tries them
/// first and falls back to its own grid search when they do not fit.
/// Core extents are in **final-output** coordinates (halo excluded);
/// strided/pooled chains scale them back to input windows via the
/// grid's coordinate remapping.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TilingHint {
    /// Requested core cell width in output columns.
    pub tile_width: Option<usize>,
    /// Requested core cell height in output rows (1-row × N-col strips
    /// when absent — the legacy width-strip behaviour).
    pub tile_height: Option<usize>,
    /// Upper bound on the number of grid cells the fallback search may
    /// try.
    pub max_tiles: Option<usize>,
}

/// A model: tensors + ops in (not necessarily sorted) creation order.
#[derive(Debug, Clone, Default)]
pub struct ModelGraph {
    pub name: String,
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<GenericOp>,
    /// Optional front-end tiling metadata (see [`TilingHint`]).
    pub tiling: Option<TilingHint>,
}

impl ModelGraph {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), tensors: Vec::new(), ops: Vec::new(), tiling: None }
    }

    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.0]
    }

    pub fn add_tensor(
        &mut self,
        name: impl Into<String>,
        ty: TensorType,
        kind: TensorKind,
        data: Option<Vec<i8>>,
    ) -> TensorId {
        let id = TensorId(self.tensors.len());
        if let Some(d) = &data {
            assert_eq!(d.len(), ty.numel(), "constant data length mismatch");
        }
        self.tensors.push(TensorInfo { id, name: name.into(), ty, kind, data });
        id
    }

    /// The op producing `t`, if any.
    pub fn producer(&self, t: TensorId) -> Option<&GenericOp> {
        self.ops.iter().find(|op| op.output == t)
    }

    /// Ops consuming `t` as a (non-weight) input.
    pub fn consumers(&self, t: TensorId) -> Vec<&GenericOp> {
        self.ops.iter().filter(|op| op.inputs.contains(&t)).collect()
    }

    pub fn inputs(&self) -> Vec<&TensorInfo> {
        self.tensors.iter().filter(|t| t.kind == TensorKind::Input).collect()
    }

    pub fn outputs(&self) -> Vec<&TensorInfo> {
        self.tensors.iter().filter(|t| t.kind == TensorKind::Output).collect()
    }

    pub fn weights(&self) -> Vec<&TensorInfo> {
        self.tensors.iter().filter(|t| t.kind == TensorKind::Weight).collect()
    }

    /// Ops in topological (dataflow) order.
    pub fn toposort(&self) -> Result<Vec<usize>> {
        // producer index per tensor
        let mut prod: HashMap<TensorId, usize> = HashMap::new();
        for (i, op) in self.ops.iter().enumerate() {
            ensure!(
                prod.insert(op.output, i).is_none(),
                "tensor {:?} has two producers",
                op.output
            );
        }
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, op) in self.ops.iter().enumerate() {
            for inp in &op.inputs {
                if let Some(&p) = prod.get(inp) {
                    succ[p].push(i);
                    indeg[i] += 1;
                }
            }
        }
        let mut q: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut out = Vec::with_capacity(n);
        while let Some(i) = q.pop() {
            out.push(i);
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push(s);
                }
            }
        }
        ensure!(out.len() == n, "graph {} has a cycle", self.name);
        // stable order: sort ready sets by original index for determinism
        // (Kahn above pops LIFO; re-run with deterministic tie-break)
        let pos: HashMap<usize, usize> = out.iter().enumerate().map(|(k, &v)| (v, k)).collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|i| pos[i]);
        Ok(out)
    }

    /// Whole-graph validation: op structure, operand existence, type/shape
    /// agreement between indexing maps and tensor shapes.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.ops.is_empty(), "graph {} has no ops", self.name);
        for op in &self.ops {
            op.validate().with_context(|| format!("validating op {}", op.name))?;
            for (i, &inp) in op.inputs.iter().enumerate() {
                ensure!(inp.0 < self.tensors.len(), "op {}: input {i} out of range", op.name);
                let t = self.tensor(inp);
                let m = &op.indexing_maps[i];
                ensure!(
                    m.results.len() == t.ty.rank(),
                    "op {}: input {i} map arity {} != tensor rank {} ({})",
                    op.name,
                    m.results.len(),
                    t.ty.rank(),
                    t.name
                );
            }
            ensure!(op.output.0 < self.tensors.len(), "op {}: output out of range", op.name);
            let out_t = self.tensor(op.output);
            ensure!(
                op.output_map().results.len() == out_t.ty.rank(),
                "op {}: output map arity {} != tensor rank {}",
                op.name,
                op.output_map().results.len(),
                out_t.ty.rank()
            );
            ensure!(
                out_t.kind != TensorKind::Input && out_t.kind != TensorKind::Weight,
                "op {} writes to input/weight tensor {}",
                op.name,
                out_t.name
            );
            // Access-bounds check: every map result must stay within the
            // operand shape at the iteration-space corners (affine => the
            // extrema are at corners; `pad` relaxes the first input).
            for (i, &inp) in op.inputs.iter().enumerate() {
                let t = self.tensor(inp);
                let pad = if i == 0 { op.pad as i64 } else { 0 };
                let m = &op.indexing_maps[i];
                let lo: Vec<i64> = vec![0; op.dims.len()];
                let hi: Vec<i64> = op.dims.iter().map(|&d| d as i64 - 1).collect();
                for (ax, e) in m.results.iter().enumerate() {
                    let (vlo, vhi) = (e.eval(&lo).min(e.eval(&hi)), e.eval(&lo).max(e.eval(&hi)));
                    ensure!(
                        vlo >= -pad && vhi < t.ty.shape[ax] as i64 + pad,
                        "op {}: input {i} axis {ax} accesses [{vlo},{vhi}] outside 0..{} (pad {pad})",
                        op.name,
                        t.ty.shape[ax]
                    );
                }
            }
        }
        // all weight tensors must have data; all intermediates a producer
        for t in &self.tensors {
            match t.kind {
                TensorKind::Weight => {
                    ensure!(t.data.is_some(), "weight {} has no data", t.name)
                }
                TensorKind::Intermediate | TensorKind::Output => {
                    ensure!(
                        self.producer(t.id).is_some(),
                        "tensor {} ({:?}) has no producer",
                        t.name,
                        t.kind
                    );
                }
                TensorKind::Input => {}
            }
        }
        self.toposort()?;
        // exactly one external input and one output (paper kernels are SISO
        // at the top level; residual skip reuses the same input tensor)
        ensure!(!self.inputs().is_empty(), "graph {} has no input", self.name);
        ensure!(!self.outputs().is_empty(), "graph {} has no output", self.name);
        Ok(())
    }

    /// Total MAC count of the whole graph (workload size metric).
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|op| op.iter_space() * op.payload.macs_per_iter()).sum()
    }

    /// Find an op by name.
    pub fn op(&self, name: &str) -> Result<&GenericOp> {
        match self.ops.iter().find(|o| o.name == name) {
            Some(o) => Ok(o),
            None => bail!("no op named {name} in graph {}", self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn conv_relu_graph_validates() {
        let g = models::conv_relu(32, 8, 8);
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 2); // conv, relu+requant
        assert_eq!(g.inputs().len(), 1);
        assert_eq!(g.outputs().len(), 1);
    }

    #[test]
    fn toposort_orders_producers_first() {
        let g = models::cascade(32, 8, 8);
        let order = g.toposort().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; order.len()];
            for (k, &i) in order.iter().enumerate() {
                p[i] = k;
            }
            p
        };
        for (i, op) in g.ops.iter().enumerate() {
            for inp in &op.inputs {
                if let Some(prod) = g.ops.iter().position(|o| o.output == *inp) {
                    assert!(pos[prod] < pos[i], "op {} before its producer", op.name);
                }
            }
        }
    }

    #[test]
    fn residual_is_a_dag_with_fanout() {
        let g = models::residual(32, 8, 8);
        g.validate().unwrap();
        let input = g.inputs()[0].id;
        assert!(g.consumers(input).len() >= 2, "residual input must fan out");
    }

    #[test]
    fn total_macs_conv() {
        let g = models::conv_relu(32, 8, 8);
        // conv: 32*32*8 outputs * 3*3*8 reduction = 589824 MACs
        assert_eq!(g.total_macs(), 32 * 32 * 8 * 9 * 8);
    }

    #[test]
    fn double_producer_rejected() {
        let mut g = models::conv_relu(8, 4, 4);
        let dup = g.ops[0].clone();
        g.ops.push(dup);
        assert!(g.toposort().is_err() || g.validate().is_err());
    }

    #[test]
    fn op_lookup() {
        let g = models::conv_relu(8, 4, 4);
        assert!(g.op("conv0").is_ok());
        assert!(g.op("nonexistent").is_err());
    }
}
