//! `GenericOp` — the Rust mirror of `linalg.generic`.

use std::fmt;

use super::affine::AffineMap;
use super::graph::TensorId;

/// Iterator type of a loop dimension (paper Fig. 5 `iterator_types`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IterType {
    Parallel,
    Reduction,
}

impl IterType {
    pub fn name(self) -> &'static str {
        match self {
            IterType::Parallel => "parallel",
            IterType::Reduction => "reduction",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "parallel" => Some(IterType::Parallel),
            "reduction" => Some(IterType::Reduction),
            _ => None,
        }
    }
}

/// Structured computation payload of a generic op (the `linalg` region
/// body). MING only needs payloads rich enough for quantized CNNs; each
/// variant defines bit-exact integer semantics mirrored by the Python
/// oracle (`ref.py`) and executed by `sim::process`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Payload {
    /// `out += in0 * in1` over the reduction dims (conv, matmul).
    /// Accumulates in i32 from i8 operands.
    MulAcc,
    /// `out = max(in0, 0)` (ReLU on i32 accumulators or i8 data).
    Relu,
    /// `out = clamp(in0 >> shift, -128, 127)` (requantize i32 -> i8).
    Requant { shift: u32 },
    /// Fused `relu` then `requant` — produced by op fusion.
    ReluRequant { shift: u32 },
    /// `out = sat_i8(in0 + in1)` (residual addition).
    AddSat,
    /// `out = max(out, in0)` over reduction dims (maxpool).
    MaxReduce,
    /// `out = in0` (reshape-free copy; identity streaming node).
    Copy,
}

impl Payload {
    /// MAC (multiply-accumulate) operations per innermost iteration —
    /// the quantity the DSP model scales by unroll factors.
    pub fn macs_per_iter(self) -> u64 {
        match self {
            Payload::MulAcc => 1,
            _ => 0,
        }
    }

    /// Non-MAC ALU ops per iteration (adds, compares, shifts) — these map
    /// to LUT fabric, not DSPs, in the integer-arithmetic resource model.
    pub fn alu_per_iter(self) -> u64 {
        match self {
            Payload::MulAcc => 0,
            Payload::Relu => 1,
            Payload::Requant { .. } => 2,
            Payload::ReluRequant { .. } => 3,
            Payload::AddSat => 2,
            Payload::MaxReduce => 1,
            Payload::Copy => 0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Payload::MulAcc => "mulacc",
            Payload::Relu => "relu",
            Payload::Requant { .. } => "requant",
            Payload::ReluRequant { .. } => "relu_requant",
            Payload::AddSat => "add_sat",
            Payload::MaxReduce => "max_reduce",
            Payload::Copy => "copy",
        }
    }
}

/// One `linalg.generic`-equivalent operation.
///
/// Indexing maps are ordered inputs-then-output: `indexing_maps[i]` is the
/// map for `inputs[i]`, and `indexing_maps.last()` is the output map.
#[derive(Debug, Clone)]
pub struct GenericOp {
    /// Unique op name within its graph (also the dataflow node name).
    pub name: String,
    /// Input tensor operands (activations first, then constants/weights).
    pub inputs: Vec<TensorId>,
    /// Single output tensor.
    pub output: TensorId,
    /// One map per input plus one for the output (last).
    pub indexing_maps: Vec<AffineMap>,
    /// Iterator type per loop dimension.
    pub iter_types: Vec<IterType>,
    /// Loop trip counts per dimension (`dims[i]` = trip of `d_i`).
    pub dims: Vec<usize>,
    /// The computation body.
    pub payload: Payload,
    /// Border padding applied to the first input when gathering windows
    /// (same-padding conv). 0 for non-windowed ops.
    pub pad: usize,
}

impl GenericOp {
    /// The output indexing map.
    pub fn output_map(&self) -> &AffineMap {
        self.indexing_maps.last().expect("op has no maps")
    }

    /// Indexing maps of the inputs only.
    pub fn input_maps(&self) -> &[AffineMap] {
        &self.indexing_maps[..self.indexing_maps.len() - 1]
    }

    /// Trip count product over all dims (total iteration space).
    pub fn iter_space(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }

    /// Trip count product over reduction dims only.
    pub fn reduction_space(&self) -> u64 {
        self.dims
            .iter()
            .zip(&self.iter_types)
            .filter(|(_, t)| **t == IterType::Reduction)
            .map(|(&d, _)| d as u64)
            .product()
    }

    /// Trip count product over parallel dims only.
    pub fn parallel_space(&self) -> u64 {
        self.dims
            .iter()
            .zip(&self.iter_types)
            .filter(|(_, t)| **t == IterType::Parallel)
            .map(|(&d, _)| d as u64)
            .product()
    }

    pub fn has_reduction(&self) -> bool {
        self.iter_types.contains(&IterType::Reduction)
    }

    /// Structural well-formedness: map count, dim arities, trip counts.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.indexing_maps.len() == self.inputs.len() + 1,
            "op {}: {} maps for {} inputs (+1 output expected)",
            self.name,
            self.indexing_maps.len(),
            self.inputs.len()
        );
        anyhow::ensure!(
            self.iter_types.len() == self.dims.len(),
            "op {}: {} iter_types vs {} dims",
            self.name,
            self.iter_types.len(),
            self.dims.len()
        );
        anyhow::ensure!(!self.dims.is_empty(), "op {}: empty iteration space", self.name);
        for (i, m) in self.indexing_maps.iter().enumerate() {
            anyhow::ensure!(
                m.num_dims == self.dims.len(),
                "op {}: map {i} has {} dims, op has {}",
                self.name,
                m.num_dims,
                self.dims.len()
            );
        }
        for (i, &d) in self.dims.iter().enumerate() {
            anyhow::ensure!(d > 0, "op {}: dim d{i} has trip count 0", self.name);
        }
        // Output map of a well-formed linalg op uses only parallel dims.
        for e in &self.output_map().results {
            for d in e.dims() {
                anyhow::ensure!(
                    self.iter_types[d] == IterType::Parallel,
                    "op {}: output map references reduction dim d{d}",
                    self.name
                );
            }
        }
        Ok(())
    }
}

impl fmt::Display for GenericOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let its: Vec<&str> = self.iter_types.iter().map(|t| t.name()).collect();
        writeln!(f, "linalg.generic \"{}\" {{", self.name)?;
        writeln!(f, "  iterator_types = [{}]", its.join(", "))?;
        writeln!(
            f,
            "  dims = [{}]",
            self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        )?;
        for (i, m) in self.indexing_maps.iter().enumerate() {
            let tag = if i + 1 == self.indexing_maps.len() { "out" } else { "in " };
            writeln!(f, "  map[{tag}] = {m}")?;
        }
        writeln!(f, "  payload = {}", self.payload.name())?;
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::affine::{AffineExpr, AffineMap};

    fn relu_op() -> GenericOp {
        GenericOp {
            name: "relu0".into(),
            inputs: vec![TensorId(0)],
            output: TensorId(1),
            indexing_maps: vec![AffineMap::identity(3), AffineMap::identity(3)],
            iter_types: vec![IterType::Parallel; 3],
            dims: vec![8, 8, 4],
            payload: Payload::Relu,
            pad: 0,
        }
    }

    #[test]
    fn relu_validates_and_spaces() {
        let op = relu_op();
        op.validate().unwrap();
        assert_eq!(op.iter_space(), 256);
        assert_eq!(op.parallel_space(), 256);
        assert_eq!(op.reduction_space(), 1);
        assert!(!op.has_reduction());
    }

    #[test]
    fn bad_map_count_rejected() {
        let mut op = relu_op();
        op.indexing_maps.pop();
        assert!(op.validate().is_err());
    }

    #[test]
    fn output_map_must_be_parallel() {
        let mut op = relu_op();
        op.iter_types[2] = IterType::Reduction;
        // output identity map now references a reduction dim
        assert!(op.validate().is_err());
    }

    #[test]
    fn zero_trip_rejected() {
        let mut op = relu_op();
        op.dims[1] = 0;
        assert!(op.validate().is_err());
    }

    #[test]
    fn payload_cost_model() {
        assert_eq!(Payload::MulAcc.macs_per_iter(), 1);
        assert_eq!(Payload::Relu.macs_per_iter(), 0);
        assert!(Payload::ReluRequant { shift: 6 }.alu_per_iter() > 0);
    }

    #[test]
    fn display_is_readable() {
        let s = relu_op().to_string();
        assert!(s.contains("iterator_types = [parallel, parallel, parallel]"));
        assert!(s.contains("payload = relu"));
    }

    #[test]
    fn mixed_iters_spaces() {
        let mut op = relu_op();
        op.iter_types = vec![IterType::Parallel, IterType::Parallel, IterType::Reduction];
        op.indexing_maps = vec![
            AffineMap::identity(3),
            AffineMap::new(3, vec![AffineExpr::dim(0), AffineExpr::dim(1)]),
        ];
        op.payload = Payload::MaxReduce;
        op.validate().unwrap();
        assert_eq!(op.parallel_space(), 64);
        assert_eq!(op.reduction_space(), 4);
    }
}
