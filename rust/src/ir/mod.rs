//! `linalg.generic`-style IR.
//!
//! MING's analyses (paper §IV-A) operate on exactly three pieces of
//! structural information per op: the **affine indexing maps** of each
//! operand, the **iterator types** (parallel / reduction) of each loop
//! dimension, and the loop **trip counts**. This module represents those
//! faithfully — one [`generic::GenericOp`] corresponds to one
//! `linalg.generic` in the paper's MLIR input (produced there by IREE).
//!
//! A [`graph::ModelGraph`] is an SSA-ish DAG of generic ops over tensors;
//! [`builder`] provides the CNN op constructors (conv2d, relu, linear,
//! add, maxpool) and the five paper evaluation kernels; [`json`] is a
//! dependency-free (de)serializer so models can be loaded from files —
//! the stand-in for the paper's ONNX/TensorFlow/PyTorch front-ends.

pub mod types;
pub mod affine;
pub mod generic;
pub mod builder;
pub mod graph;
pub mod json;
pub mod fingerprint;

pub use affine::{AffineExpr, AffineMap};
pub use generic::{GenericOp, IterType, Payload};
pub use graph::{ModelGraph, TensorId, TensorInfo, TensorKind};
pub use types::{DType, TensorType};
