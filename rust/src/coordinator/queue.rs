//! A small fixed-size worker pool over `std::thread` + `mpsc` (tokio is
//! not vendored in this environment; the compile service's workload is
//! CPU-bound, so OS threads are the right tool anyway).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::thread;
use std::time::Instant;

/// Fixed-size worker pool executing `FnOnce` jobs; results come back in
/// completion order through an mpsc channel.
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run all jobs, returning `(index, result)` pairs sorted by index.
    /// Panics in jobs are isolated per-thread and surfaced as `Err`
    /// strings.
    pub fn run_all<J, R>(&self, jobs: Vec<J>) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        self.run_all_streaming(jobs, |_, _| {})
    }

    /// Like [`Self::run_all`], additionally invoking `on_done` on the
    /// coordinator thread as each job finishes, in completion order.
    /// The sweep spool streams records to disk through this hook, so a
    /// crash mid-sweep loses at most the jobs still in flight — not the
    /// whole run.
    pub fn run_all_streaming<J, R>(
        &self,
        jobs: Vec<J>,
        on_done: impl FnMut(usize, &Result<R, String>),
    ) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        self.run_all_scoped(jobs, on_done)
    }

    /// The scoped core shared by every entry point: jobs (and their
    /// results) may **borrow** from the caller's stack — the pool runs
    /// them on `std::thread::scope` threads, so `simulate_tiled` can
    /// fan cell closures referencing the cell design and the input
    /// tensor straight out without cloning either. Results come back
    /// `(index, result)`-sorted; `on_done` fires in completion order on
    /// the coordinator thread.
    pub fn run_all_scoped<'env, J, R>(
        &self,
        jobs: Vec<J>,
        mut on_done: impl FnMut(usize, &Result<R, String>),
    ) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'env,
        R: Send + 'env,
    {
        let njobs = jobs.len();
        let queue: Mutex<Vec<(usize, J)>> =
            Mutex::new(jobs.into_iter().enumerate().rev().collect());
        let (tx, rx) = mpsc::channel::<(usize, Result<R, String>)>();
        let mut results: Vec<(usize, Result<R, String>)> = Vec::with_capacity(njobs);
        // Busy-vs-idle attribution: each worker clocks the time it
        // spends inside jobs; idle is the remainder of workers × wall.
        let busy_us = AtomicU64::new(0);
        let n_workers = self.workers.min(njobs.max(1));
        let wall = Instant::now();
        thread::scope(|s| {
            for widx in 0..n_workers {
                let tx = tx.clone();
                let queue = &queue;
                let busy_us = &busy_us;
                s.spawn(move || {
                    crate::obs::trace::global().set_thread_label(&format!("worker-{widx}"));
                    loop {
                        let next = queue.lock().unwrap().pop();
                        let Some((idx, job)) = next else { break };
                        let t = Instant::now();
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                            .map_err(|e| panic_msg(&*e));
                        busy_us.fetch_add(t.elapsed().as_micros() as u64, Ordering::Relaxed);
                        if tx.send((idx, out)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            for (idx, out) in rx.iter() {
                on_done(idx, &out);
                results.push((idx, out));
            }
        });
        let wall_us = wall.elapsed().as_micros() as u64;
        let busy = busy_us.load(Ordering::Relaxed);
        let m = crate::obs::metrics::global();
        m.add("pool.busy_us", busy);
        m.add("pool.idle_us", (n_workers as u64 * wall_us).saturating_sub(busy));
        results.sort_by_key(|(i, _)| *i);
        results
    }
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("job panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("job panicked: {s}")
    } else {
        "job panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs_and_orders_results() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..32).map(|i| Box::new(move || i * i) as _).collect();
        let results = pool.run_all(jobs);
        assert_eq!(results.len(), 32);
        for (i, r) in results {
            assert_eq!(*r.as_ref().unwrap(), i * i);
        }
    }

    #[test]
    fn panics_are_isolated() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let results = pool.run_all(jobs);
        assert_eq!(*results[0].1.as_ref().unwrap(), 1);
        assert!(results[1].1.as_ref().unwrap_err().contains("boom"));
        assert_eq!(*results[2].1.as_ref().unwrap(), 3);
    }

    #[test]
    fn streaming_callback_sees_every_completion_once() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..16).map(|i| Box::new(move || i + 1) as _).collect();
        let mut seen = Vec::new();
        let results = pool.run_all_streaming(jobs, |i, r| {
            seen.push((i, *r.as_ref().unwrap()));
        });
        assert_eq!(results.len(), 16);
        assert_eq!(seen.len(), 16, "one callback per job");
        seen.sort_unstable();
        assert_eq!(seen, (0usize..16).map(|i| (i, i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_jobs_may_borrow_the_callers_stack() {
        // The contract simulate_tiled relies on: closures borrowing a
        // local slice run fine on pool threads (no 'static, no clones).
        let pool = WorkerPool::new(3);
        let data: Vec<usize> = (0..64).collect();
        let jobs: Vec<_> =
            data.chunks(8).map(|ch| move || ch.iter().sum::<usize>()).collect();
        let results = pool.run_all_scoped(jobs, |_, _| {});
        let total: usize = results.iter().map(|(_, r)| *r.as_ref().unwrap()).sum();
        assert_eq!(total, 64 * 63 / 2);
    }

    #[test]
    fn pool_flushes_busy_and_idle_time() {
        // Deltas are >= because other tests share the global registry.
        let m = crate::obs::metrics::global();
        let busy0 = m.get("pool.busy_us");
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    i
                }) as _
            })
            .collect();
        pool.run_all(jobs);
        // 4 jobs × 5ms of in-job time, minus timer slack
        assert!(m.get("pool.busy_us") - busy0 >= 15_000);
    }

    #[test]
    fn single_worker_degenerates_to_serial() {
        let pool = WorkerPool::new(1);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..5).map(|i| Box::new(move || i) as _).collect();
        let results = pool.run_all(jobs);
        assert_eq!(results.iter().map(|(_, r)| *r.as_ref().unwrap()).sum::<usize>(), 10);
    }
}
