//! Compile jobs and their results.

use anyhow::Result;

use crate::baselines::framework::{compile_with, FrameworkKind};
use crate::ir::builder::models;
use crate::resources::device::DeviceSpec;
use crate::resources::estimate;
use crate::resources::report::UtilizationReport;
use crate::sim::{simulate, SimMode, SimReport};
use crate::util::prng;

/// One unit of work for the compile service: lower `kernel`@`size` with
/// `framework` for `device`, estimate resources, simulate.
#[derive(Debug, Clone)]
pub struct CompileJob {
    pub kernel: String,
    pub size: usize,
    pub framework: FrameworkKind,
    pub device: DeviceSpec,
    /// Skip the (functional) simulation — estimation only.
    pub estimate_only: bool,
}

/// Everything a job produces.
pub struct JobResult {
    pub job: CompileJob,
    pub util: UtilizationReport,
    /// `None` when `estimate_only` or when compilation itself failed
    /// fatally (recorded in `error`).
    pub sim: Option<SimReport>,
    pub cycles: u64,
    /// MACs in the workload (speedup normalization).
    pub macs: u64,
    pub error: Option<String>,
}

impl CompileJob {
    pub fn id(&self) -> String {
        format!("{}_{}@{}", self.kernel, self.size, self.framework.name())
    }

    /// Execute the job (called from worker threads).
    pub fn run(&self) -> Result<JobResult> {
        let g = models::paper_kernel(&self.kernel, self.size)?;
        let design = compile_with(self.framework, &g, &self.device)?;
        let util = estimate(&design, &self.device);
        let macs = design.total_macs();
        if self.estimate_only {
            let cycles = design.overlapped_cycles_estimate();
            return Ok(JobResult { job: self.clone(), util, sim: None, cycles, macs, error: None });
        }
        let input: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect();
        let rep = simulate(&design, &input, SimMode::of(design.style))?;
        let (cycles, error) = match &rep.deadlock {
            Some(blocked) => (0, Some(format!("deadlock: {}", blocked.join("; ")))),
            None => (rep.cycles, None),
        };
        Ok(JobResult { job: self.clone(), util, sim: Some(rep), cycles, macs, error })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_runs_end_to_end() {
        let job = CompileJob {
            kernel: "conv_relu".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let r = job.run().unwrap();
        assert!(r.cycles > 0);
        assert!(r.util.fits());
        assert!(r.error.is_none());
        assert_eq!(r.job.id(), "conv_relu_32@ming");
    }

    #[test]
    fn estimate_only_skips_sim() {
        let job = CompileJob {
            kernel: "linear".into(),
            size: 0,
            framework: FrameworkKind::Vanilla,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let r = job.run().unwrap();
        assert!(r.sim.is_none());
        assert!(r.cycles > 0, "estimate path still yields cycles");
    }

    #[test]
    fn unknown_kernel_errors() {
        let job = CompileJob {
            kernel: "transformer".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        assert!(job.run().is_err());
    }
}
