//! Compile jobs and their results.

use anyhow::Result;

use crate::baselines::framework::{compile_with, FrameworkKind};
use crate::dse::ilp::{solve_with_tiling_fallback, Compiled, DseConfig};
use crate::ir::builder::models;
use crate::ir::graph::ModelGraph;
use crate::resources::device::DeviceSpec;
use crate::resources::estimate;
use crate::resources::report::UtilizationReport;
use crate::sim::{simulate, SimMode, SimReport};
use crate::tiling::{simulate_tiled, TiledCompilation};
use crate::util::prng;

/// One unit of work for the compile service: lower `kernel`@`size` with
/// `framework` for `device`, estimate resources, simulate.
#[derive(Debug, Clone)]
pub struct CompileJob {
    pub kernel: String,
    pub size: usize,
    pub framework: FrameworkKind,
    pub device: DeviceSpec,
    /// Skip the (functional) simulation — estimation only.
    pub estimate_only: bool,
}

/// Everything a job produces.
pub struct JobResult {
    pub job: CompileJob,
    pub util: UtilizationReport,
    /// `None` when `estimate_only`, when the design was grid-tiled (the
    /// tiled runner stitches its own report), or when compilation itself
    /// failed fatally (recorded in `error`).
    pub sim: Option<SimReport>,
    pub cycles: u64,
    /// MACs in the workload (speedup normalization).
    pub macs: u64,
    /// Number of grid cells the design was tiled into (1 = untiled).
    pub tiles: usize,
    pub error: Option<String>,
}

impl CompileJob {
    pub fn id(&self) -> String {
        format!("{}_{}@{}", self.kernel, self.size, self.framework.name())
    }

    fn det_input(g: &ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    /// Execute the job (called from worker threads).
    pub fn run(&self) -> Result<JobResult> {
        let g = models::paper_kernel(&self.kernel, self.size)?;
        // MING gets the tile-grid feasibility fallback; the baseline
        // strategies have no tiling story (the paper's infeasible cells).
        let design = match self.framework {
            FrameworkKind::Ming => {
                let cfg = DseConfig::new(self.device.clone());
                match solve_with_tiling_fallback(&g, &cfg)? {
                    Compiled::Flat(d, _) => *d,
                    Compiled::Tiled(tc) => return self.finish_tiled(&g, *tc),
                }
            }
            fw => compile_with(fw, &g, &self.device)?,
        };
        let util = estimate(&design, &self.device);
        let macs = design.total_macs();
        if self.estimate_only {
            let cycles = design.overlapped_cycles_estimate();
            return Ok(JobResult {
                job: self.clone(),
                util,
                sim: None,
                cycles,
                macs,
                tiles: 1,
                error: None,
            });
        }
        let input = Self::det_input(&g);
        let rep = simulate(&design, &input, SimMode::of(design.style))?;
        let (cycles, error) = match &rep.deadlock {
            Some(blocked) => (0, Some(format!("deadlock: {}", blocked.join("; ")))),
            None => (rep.cycles, None),
        };
        Ok(JobResult { job: self.clone(), util, sim: Some(rep), cycles, macs, tiles: 1, error })
    }

    /// Finish a job whose workload only fits the device grid-tiled.
    fn finish_tiled(&self, g: &ModelGraph, tc: TiledCompilation) -> Result<JobResult> {
        let util = estimate(&tc.cell, &self.device);
        let macs = g.total_macs();
        let tiles = tc.grid.n_cells();
        if self.estimate_only {
            return Ok(JobResult {
                job: self.clone(),
                util,
                sim: None,
                cycles: tc.estimated_cycles(),
                macs,
                tiles,
                error: None,
            });
        }
        let input = Self::det_input(g);
        // A deadlocking strip is a job *result* (rendered as × in the
        // tables), not a job failure — same contract as the flat path.
        let (cycles, error) = match simulate_tiled(&tc, &input) {
            Ok(rep) => (rep.cycles, None),
            Err(e) => (0, Some(format!("{e:#}"))),
        };
        Ok(JobResult { job: self.clone(), util, sim: None, cycles, macs, tiles, error })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_runs_end_to_end() {
        let job = CompileJob {
            kernel: "conv_relu".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let r = job.run().unwrap();
        assert!(r.cycles > 0);
        assert!(r.util.fits());
        assert!(r.error.is_none());
        assert_eq!(r.tiles, 1);
        assert_eq!(r.job.id(), "conv_relu_32@ming");
    }

    #[test]
    fn estimate_only_skips_sim() {
        let job = CompileJob {
            kernel: "linear".into(),
            size: 0,
            framework: FrameworkKind::Vanilla,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let r = job.run().unwrap();
        assert!(r.sim.is_none());
        assert!(r.cycles > 0, "estimate path still yields cycles");
    }

    #[test]
    fn unknown_kernel_errors() {
        let job = CompileJob {
            kernel: "transformer".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        assert!(job.run().is_err());
    }

    #[test]
    fn ming_job_tiles_oversized_workload() {
        // Estimate-only sweep cell for the oversized VGG block: the
        // untiled DSE has no feasible point on the stock KV260; the job
        // must come back grid-tiled with a BRAM-fitting cell.
        let job = CompileJob {
            kernel: "vgg3".into(),
            size: 512,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let r = job.run().unwrap();
        assert!(r.tiles >= 2, "expected a tiled result, got {} tiles", r.tiles);
        assert!(r.util.bram18k <= r.util.device.bram18k);
        assert!(r.cycles > 0);
        assert!(r.error.is_none());
    }

    #[test]
    fn baseline_job_fails_on_oversized_workload() {
        // The same workload through a baseline strategy must keep the
        // paper's behaviour: no tiling story for the comparison points.
        let job = CompileJob {
            kernel: "vgg3".into(),
            size: 512,
            framework: FrameworkKind::StreamHls,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        // baselines either error or report an over-budget design
        if let Ok(r) = job.run() {
            assert!(!r.util.fits());
        }
    }
}
