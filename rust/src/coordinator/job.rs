//! Compile jobs and their results, as an explicit staged pipeline:
//!
//! ```text
//!   lower  ──▶  solve  ──▶  estimate  ──▶  simulate
//!  (graph)    (design,      (utilization,   (cycle-exact run,
//!             cache-aware)   cycle model)    skipped if estimate-only)
//! ```
//!
//! The stages are public so callers can stop anywhere (the CLI's
//! `compile` is lower+solve+estimate; sweeps run all four), and so the
//! solve stage can consult the coordinator's content-addressed design
//! cache ([`super::cache`]): a job whose `(graph, device)` problem was
//! already solved — this run, a previous run, or another shard's
//! process — reuses the design with zero ILP solves.

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::framework::{compile_with, FrameworkKind};
use crate::dataflow::build::build_streaming_design;
use crate::dataflow::design::Design;
use crate::dse::ilp::{solve_with_tiling_fallback, Compiled, DseConfig};
use crate::ir::builder::models;
use crate::ir::graph::ModelGraph;
use crate::resources::device::DeviceSpec;
use crate::resources::estimate;
use crate::resources::report::UtilizationReport;
use crate::sim::{simulate, SimMode, SimReport};
use crate::tiling::{simulate_tiled, simulate_tiled_parallel, TiledCompilation};
use crate::util::prng;

use super::cache::DesignCache;
use super::sched;

/// One unit of work for the compile service: lower `kernel`@`size` with
/// `framework` for `device`, estimate resources, simulate.
#[derive(Debug, Clone)]
pub struct CompileJob {
    pub kernel: String,
    pub size: usize,
    pub framework: FrameworkKind,
    pub device: DeviceSpec,
    /// Skip the (functional) simulation — estimation only.
    pub estimate_only: bool,
}

/// Wall-clock microseconds per pipeline stage of one job run —
/// measured unconditionally (two clock reads per stage), carried into
/// spool records and the `--profile` table.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageTimes {
    pub lower_us: u64,
    pub solve_us: u64,
    pub estimate_us: u64,
    /// 0 for estimate-only jobs.
    pub simulate_us: u64,
    /// Whole-job wall time; ≥ the stage sum (the slack is inter-stage
    /// glue, asserted small by `stage_times_sum_to_job_wall_time`).
    pub total_us: u64,
}

impl StageTimes {
    /// Sum of the four per-stage times.
    pub fn staged_sum(&self) -> u64 {
        self.lower_us + self.solve_us + self.estimate_us + self.simulate_us
    }
}

/// Everything a job produces.
pub struct JobResult {
    pub job: CompileJob,
    pub util: UtilizationReport,
    /// `None` when `estimate_only` or when compilation itself failed
    /// fatally (recorded in `error`). Grid-tiled simulations stitch
    /// their per-cell runs into one report, so flat and tiled cells
    /// have output parity here.
    pub sim: Option<SimReport>,
    pub cycles: u64,
    /// MACs in the workload (speedup normalization).
    pub macs: u64,
    /// Number of grid cells the design was tiled into (1 = untiled).
    pub tiles: usize,
    /// Per-stage wall times for this run.
    pub stages: StageTimes,
    pub error: Option<String>,
}

/// Output of the solve stage: the design an estimate/simulate stage
/// consumes. Mirrors [`Compiled`] but also covers baseline strategies
/// (which have no tiling story and always come back flat).
pub enum SolvedDesign {
    Flat(Box<Design>),
    Tiled(Box<TiledCompilation>),
}

impl SolvedDesign {
    /// Grid cells (1 = untiled).
    pub fn tiles(&self) -> usize {
        match self {
            SolvedDesign::Flat(_) => 1,
            SolvedDesign::Tiled(tc) => tc.grid.n_cells(),
        }
    }
}

impl CompileJob {
    pub fn id(&self) -> String {
        format!("{}_{}@{}", self.kernel, self.size, self.framework.name())
    }

    fn det_input(g: &ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    /// Stage 1 — lower the workload to a model graph.
    pub fn lower(&self) -> Result<ModelGraph> {
        models::paper_kernel(&self.kernel, self.size)
    }

    /// Predicted relative cost of this job, for makespan-aware (LPT)
    /// sweep ordering — a *ranking* signal built from what the pipeline
    /// already knows, never consulted for results:
    ///
    /// - simulation scales with the model's MAC count (0 when
    ///   estimate-only);
    /// - a MING solve is the assignment-lattice volume (exact per-node
    ///   candidate counts, the same enumeration the solver performs) —
    ///   or 0 when the design cache already holds the problem's
    ///   fingerprint, probed with the stat-neutral
    ///   [`DesignCache::peek`];
    /// - an untiled-infeasible workload additionally pays the tile-grid
    ///   search (many cell solves); `macs / 8` stands in for that —
    ///   crude, but oversized workloads dominate the MAC scale by
    ///   orders of magnitude, which is all a longest-first order needs;
    /// - baseline frameworks are fixed strategies: no search, cost is
    ///   simulation only.
    ///
    /// Jobs that fail to lower rank 0 — they fail instantly at run time
    /// too.
    pub fn predicted_cost(&self, cache: Option<&DesignCache>) -> u64 {
        let Ok(g) = self.lower() else { return 0 };
        let macs = g.total_macs();
        let sim = if self.estimate_only { 0 } else { macs };
        let solve = match self.framework {
            FrameworkKind::Ming => {
                let fp = crate::ir::fingerprint::problem_fingerprint(&g, &self.device);
                if cache.is_some_and(|c| c.peek(fp)) {
                    0
                } else {
                    let volume = build_streaming_design(&g)
                        .map(|d| {
                            let model = crate::resources::model::ResourceModel::new(&d);
                            (0..d.nodes.len()).fold(1u64, |v, i| {
                                let n = crate::dse::space::candidates_with(&model, &d, i).len();
                                v.saturating_mul(n.max(1) as u64)
                            })
                        })
                        .unwrap_or(0);
                    volume.saturating_add(macs / 8)
                }
            }
            _ => 0,
        };
        sim.saturating_add(solve)
    }

    /// Stage 2 — solve. MING gets the tile-grid feasibility fallback
    /// (and, when `cache` is present, content-addressed design reuse;
    /// when `warm` is present, cross-problem front memoization and
    /// incumbent seeding — both provably solution-invariant); the
    /// baseline strategies have no tiling story (the paper's infeasible
    /// cells) and never consult either — their "solve" is a fixed
    /// strategy, not a search worth memoizing.
    pub fn solve(
        &self,
        g: &ModelGraph,
        cache: Option<&Arc<DesignCache>>,
        warm: Option<&Arc<crate::dse::WarmStart>>,
    ) -> Result<SolvedDesign> {
        match self.framework {
            FrameworkKind::Ming => {
                // Nested parallelism is safe now that every site submits
                // into the one work-stealing scheduler: a sweep job's DSE
                // subtrees land on its worker's own deque, and idle
                // sweep workers steal them — a straggler recruits the
                // cores its finished siblings freed instead of pinning
                // itself to one. `current_workers()` sizes the fan-out
                // to the owning scheduler (1 ⇒ exact serial paths).
                let mut cfg = DseConfig::new(self.device.clone())
                    .with_workers(sched::current_workers());
                if let Some(c) = cache {
                    cfg = cfg.with_cache(Arc::clone(c));
                }
                if let Some(w) = warm {
                    cfg = cfg.with_warm_start(Arc::clone(w));
                }
                match solve_with_tiling_fallback(g, &cfg)? {
                    Compiled::Flat(d, _) => Ok(SolvedDesign::Flat(d)),
                    Compiled::Tiled(tc) => Ok(SolvedDesign::Tiled(tc)),
                }
            }
            fw => Ok(SolvedDesign::Flat(Box::new(compile_with(fw, g, &self.device)?))),
        }
    }

    /// Stage 3 — estimate: utilization report plus the cycle-model
    /// latency (overlapped for flat designs, gather-overlapped tiled
    /// estimate for grids).
    pub fn estimate(&self, solved: &SolvedDesign) -> (UtilizationReport, u64) {
        match solved {
            SolvedDesign::Flat(d) => (estimate(d, &self.device), d.overlapped_cycles_estimate()),
            SolvedDesign::Tiled(tc) => (estimate(&tc.cell, &self.device), tc.estimated_cycles()),
        }
    }

    /// Stage 4 — simulate (cycle-exact, bit-exact). A deadlocking
    /// design is a job *result* (rendered as × in the tables), not a
    /// job failure, on both the flat and the tiled path.
    pub fn simulate(
        &self,
        g: &ModelGraph,
        solved: &SolvedDesign,
    ) -> Result<(Option<SimReport>, u64, Option<String>)> {
        let input = Self::det_input(g);
        match solved {
            SolvedDesign::Flat(d) => {
                let rep = simulate(d, &input, SimMode::of(d.style))?;
                let (cycles, error) = match &rep.deadlock {
                    Some(blocked) => (0, Some(format!("deadlock: {}", blocked.join("; ")))),
                    None => (rep.cycles, None),
                };
                Ok((Some(rep), cycles, error))
            }
            SolvedDesign::Tiled(tc) => {
                // Cell fan-out submits into the current scheduler (the
                // report is bit-identical to the serial stitch); with
                // one worker this takes the exact serial path inline.
                let run = if sched::current_workers() > 1 && tc.grid.n_cells() > 1 {
                    simulate_tiled_parallel(tc, &input, &sched::current_or_global())
                } else {
                    simulate_tiled(tc, &input)
                };
                match run {
                    Ok(rep) => {
                        let cycles = rep.cycles;
                        Ok((Some(rep.into_sim_report()), cycles, None))
                    }
                    Err(e) => Ok((None, 0, Some(format!("{e:#}")))),
                }
            }
        }
    }

    /// Execute all stages (called from worker threads). Each stage is
    /// wall-clocked into [`StageTimes`] and wrapped in a `stage` span;
    /// the whole job gets a `job` span labelled with [`Self::id`].
    pub fn run_with(&self, cache: Option<&Arc<DesignCache>>) -> Result<JobResult> {
        self.run_warm(cache, None)
    }

    /// [`Self::run_with`] plus shared warm-start state — the sweep
    /// entry point ([`super::service::CompileService`] hands every
    /// shard-mate the same [`crate::dse::WarmStart`] so node fronts and
    /// incumbent seeds carry across the jobs of a sweep).
    pub fn run_warm(
        &self,
        cache: Option<&Arc<DesignCache>>,
        warm: Option<&Arc<crate::dse::WarmStart>>,
    ) -> Result<JobResult> {
        let _job_span = crate::obs::span_with("job", || self.id());
        let job_start = std::time::Instant::now();
        let mut stages = StageTimes::default();

        let g = {
            let _sp = crate::obs::span("stage", "lower");
            let t = std::time::Instant::now();
            let g = self.lower();
            stages.lower_us = t.elapsed().as_micros() as u64;
            g?
        };
        let solved = {
            let _sp = crate::obs::span("stage", "solve");
            let t = std::time::Instant::now();
            let s = self.solve(&g, cache, warm);
            stages.solve_us = t.elapsed().as_micros() as u64;
            s?
        };
        let (util, est_cycles) = {
            let _sp = crate::obs::span("stage", "estimate");
            let t = std::time::Instant::now();
            let e = self.estimate(&solved);
            stages.estimate_us = t.elapsed().as_micros() as u64;
            e
        };
        let macs = g.total_macs();
        let tiles = solved.tiles();
        if self.estimate_only {
            stages.total_us = job_start.elapsed().as_micros() as u64;
            return Ok(JobResult {
                job: self.clone(),
                util,
                sim: None,
                cycles: est_cycles,
                macs,
                tiles,
                stages,
                error: None,
            });
        }
        let (sim, cycles, error) = {
            let _sp = crate::obs::span("stage", "simulate");
            let t = std::time::Instant::now();
            let s = self.simulate(&g, &solved);
            stages.simulate_us = t.elapsed().as_micros() as u64;
            s?
        };
        stages.total_us = job_start.elapsed().as_micros() as u64;
        Ok(JobResult { job: self.clone(), util, sim, cycles, macs, tiles, stages, error })
    }

    /// Execute the job without a design cache.
    pub fn run(&self) -> Result<JobResult> {
        self.run_with(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_runs_end_to_end() {
        let job = CompileJob {
            kernel: "conv_relu".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let r = job.run().unwrap();
        assert!(r.cycles > 0);
        assert!(r.util.fits());
        assert!(r.error.is_none());
        assert_eq!(r.tiles, 1);
        assert_eq!(r.job.id(), "conv_relu_32@ming");
    }

    #[test]
    fn estimate_only_skips_sim() {
        let job = CompileJob {
            kernel: "linear".into(),
            size: 0,
            framework: FrameworkKind::Vanilla,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let r = job.run().unwrap();
        assert!(r.sim.is_none());
        assert!(r.cycles > 0, "estimate path still yields cycles");
    }

    #[test]
    fn unknown_kernel_errors() {
        let job = CompileJob {
            kernel: "transformer".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        assert!(job.run().is_err());
    }

    #[test]
    fn ming_job_tiles_oversized_workload() {
        // Estimate-only sweep cell for the oversized VGG block: the
        // untiled DSE has no feasible point on the stock KV260; the job
        // must come back grid-tiled with a BRAM-fitting cell.
        let job = CompileJob {
            kernel: "vgg3".into(),
            size: 512,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let r = job.run().unwrap();
        assert!(r.tiles >= 2, "expected a tiled result, got {} tiles", r.tiles);
        assert!(r.util.bram18k <= r.util.device.bram18k);
        assert!(r.cycles > 0);
        assert!(r.error.is_none());
    }

    #[test]
    fn baseline_job_fails_on_oversized_workload() {
        // The same workload through a baseline strategy must keep the
        // paper's behaviour: no tiling story for the comparison points.
        let job = CompileJob {
            kernel: "vgg3".into(),
            size: 512,
            framework: FrameworkKind::StreamHls,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        // baselines either error or report an over-budget design
        if let Ok(r) = job.run() {
            assert!(!r.util.fits());
        }
    }

    #[test]
    fn tiled_simulated_job_carries_a_stitched_sim_report() {
        // Regression: tiled non-estimate jobs used to drop their
        // SimReport (`sim` was always None on the tiled path), breaking
        // sweep output parity between flat and tiled cells.
        // conv_relu@400: the untiled line buffers alone need 2 blocks per
        // row (400·8·8 bits > 18K) × 2 rows = 4 at any unroll — infeasible
        // under a 3-block budget — while a half-width cell (1 block per
        // row + the weight ROM) fits.
        let job = CompileJob {
            kernel: "conv_relu".into(),
            size: 400,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260().with_bram_limit(3),
            estimate_only: false,
        };
        let r = job.run().unwrap();
        assert!(r.tiles >= 2, "workload must tile under a 3-block budget");
        let sim = r.sim.expect("tiled sim report must be carried through");
        assert_eq!(sim.cycles, r.cycles);
        assert!(sim.total_firings > 0);
        // the stitched output covers the full feature map
        let g = job.lower().unwrap();
        assert_eq!(sim.output.len(), g.outputs()[0].ty.numel());
        assert!(r.error.is_none());
    }

    #[test]
    fn stage_times_sum_to_job_wall_time() {
        // Profile-consistency: the four stage clocks tile the job's
        // wall clock — their sum never exceeds the total, and the
        // inter-stage glue (clone + field moves) is bounded generously.
        let job = CompileJob {
            kernel: "conv_relu".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let r = job.run().unwrap();
        let st = r.stages;
        assert!(st.total_us > 0, "job wall time must be measured");
        assert!(st.simulate_us > 0, "non-estimate-only job simulates");
        assert!(
            st.staged_sum() <= st.total_us,
            "stage sum {} exceeds total {}",
            st.staged_sum(),
            st.total_us
        );
        let glue = st.total_us - st.staged_sum();
        assert!(glue < 250_000, "inter-stage glue suspiciously large: {glue}us");

        // estimate-only jobs report zero simulate time
        let eo = CompileJob { estimate_only: true, ..job };
        let r = eo.run().unwrap();
        assert_eq!(r.stages.simulate_us, 0);
        assert!(r.stages.staged_sum() <= r.stages.total_us);
    }

    #[test]
    fn staged_run_matches_composed_stages() {
        // The staged API and run_with() agree end to end.
        let job = CompileJob {
            kernel: "cascade".into(),
            size: 32,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let g = job.lower().unwrap();
        let solved = job.solve(&g, None, None).unwrap();
        let (util, _est) = job.estimate(&solved);
        let (sim, cycles, error) = job.simulate(&g, &solved).unwrap();
        let r = job.run().unwrap();
        assert_eq!(r.util.bram18k, util.bram18k);
        assert_eq!(r.cycles, cycles);
        assert_eq!(r.error, error);
        assert_eq!(r.sim.unwrap().output, sim.unwrap().output);
    }
}
