//! Sweep spooling: durable, mergeable JSONL shard outputs.
//!
//! A sharded sweep (`--shard i/n --spool <dir>`) appends one JSON line
//! per finished job to `<dir>/shard-<i>-of-<n>.jsonl`. Each line is
//! self-describing — the sweep's identity hash and report kind, the
//! job's global sequence number, the total job count of the sweep, the
//! job id, and either the reduced Table-II [`Cell`] or the failure
//! message — so shard files can be:
//!
//! * **merged**: `ming merge-sweep --spool <dir>` reads every
//!   `*.jsonl` in the directory, orders records by global sequence
//!   number, and renders the exact rows an unsharded sweep would have
//!   printed (row-identity is covered by tests and the CI smoke job);
//! * **resumed**: a re-run shard reads its own spool first and skips
//!   every *successfully completed* sequence number, so a crashed sweep
//!   continues where it stopped instead of starting over. Failed jobs
//!   are retried on resume (a transient panic should not poison the
//!   table forever); [`merge`] dedupes per sequence number preferring
//!   the successful record.
//!
//! Torn trailing lines (a crash mid-write) parse as errors and are
//! skipped with a count, never aborting a resume or a merge.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::baselines::framework::FrameworkKind;
use crate::ir::json::{parse, Json};

use super::job::{JobResult, StageTimes};
use super::report::{self, Cell};
use super::service::Shard;

/// On-disk schema version of a spool line.
const SPOOL_VERSION: u64 = 1;

/// One spooled job outcome.
#[derive(Debug, Clone)]
pub struct SpoolRecord {
    /// Sweep identity ([`crate::coordinator::CompileService::sweep_id`])
    /// — resume and merge refuse records from a different sweep.
    pub sweep: u64,
    /// Report kind the sweep was run for (`table2` / `table3`), so
    /// `merge-sweep` picks the right renderer without the user having
    /// to remember it.
    pub report: String,
    /// Global job index in the sweep's deterministic job list.
    pub seq: usize,
    /// Total jobs in the sweep (for completeness checks at merge time).
    pub total: usize,
    /// Human-readable job id (`kernel_size@framework`).
    pub id: String,
    /// `Ok(cell)` for a finished job, `Err(msg)` for a failed one.
    pub outcome: Result<Cell, String>,
}

/// Spool file path of one shard.
pub fn shard_file(dir: &Path, shard: Shard) -> PathBuf {
    dir.join(format!("shard-{}-of-{}.jsonl", shard.index, shard.count))
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn cell_to_json(c: &Cell) -> Json {
    let mut m = BTreeMap::new();
    m.insert("kernel".into(), Json::Str(c.kernel.clone()));
    m.insert("size".into(), num(c.size as u64));
    m.insert("framework".into(), Json::Str(c.framework.name().into()));
    m.insert("mcycles".into(), Json::Num(c.mcycles));
    m.insert("bram".into(), num(c.bram));
    m.insert("bram_rom".into(), num(c.bram_rom));
    m.insert("bram_fifo".into(), num(c.bram_fifo));
    m.insert("dsp".into(), num(c.dsp));
    m.insert("lut_pct".into(), Json::Num(c.lut_pct));
    m.insert("lutram_pct".into(), Json::Num(c.lutram_pct));
    m.insert("ff_pct".into(), Json::Num(c.ff_pct));
    m.insert("fits".into(), Json::Bool(c.fits));
    m.insert("tiles".into(), num(c.tiles as u64));
    // per-stage compile wall times (µs values fit f64 exactly)
    let mut st = BTreeMap::new();
    st.insert("lower_us".into(), num(c.stages.lower_us));
    st.insert("solve_us".into(), num(c.stages.solve_us));
    st.insert("estimate_us".into(), num(c.stages.estimate_us));
    st.insert("simulate_us".into(), num(c.stages.simulate_us));
    st.insert("total_us".into(), num(c.stages.total_us));
    m.insert("stages".into(), Json::Obj(st));
    m.insert(
        "error".into(),
        match &c.error {
            Some(e) => Json::Str(e.clone()),
            None => Json::Null,
        },
    );
    Json::Obj(m)
}

fn cell_from_json(v: &Json) -> Result<Cell> {
    let fw_name = v.get("framework")?.as_str()?;
    let framework = FrameworkKind::parse(fw_name)
        .with_context(|| format!("unknown framework {fw_name:?} in spool record"))?;
    let f = |key: &str| -> Result<f64> {
        match v.get(key)? {
            Json::Num(n) => Ok(*n),
            other => bail!("field {key:?} must be a number, got {other:?}"),
        }
    };
    Ok(Cell {
        kernel: v.get("kernel")?.as_str()?.to_string(),
        size: v.get("size")?.as_usize()?,
        framework,
        mcycles: f("mcycles")?,
        bram: v.get("bram")?.as_usize()? as u64,
        bram_rom: v.get("bram_rom")?.as_usize()? as u64,
        bram_fifo: v.get("bram_fifo")?.as_usize()? as u64,
        dsp: v.get("dsp")?.as_usize()? as u64,
        lut_pct: f("lut_pct")?,
        lutram_pct: f("lutram_pct")?,
        ff_pct: f("ff_pct")?,
        fits: match v.get("fits")? {
            Json::Bool(b) => *b,
            other => bail!("field \"fits\" must be a bool, got {other:?}"),
        },
        tiles: v.get("tiles")?.as_usize()?,
        // absent in pre-timing spool lines → zeroed (still version 1;
        // profiling fields are additive, never load-bearing for tables)
        stages: match v.as_obj()?.get("stages") {
            Some(s) => {
                let u = |key: &str| -> Result<u64> { Ok(s.get(key)?.as_usize()? as u64) };
                StageTimes {
                    lower_us: u("lower_us")?,
                    solve_us: u("solve_us")?,
                    estimate_us: u("estimate_us")?,
                    simulate_us: u("simulate_us")?,
                    total_us: u("total_us")?,
                }
            }
            None => StageTimes::default(),
        },
        error: match v.get("error")? {
            Json::Null => None,
            Json::Str(s) => Some(s.clone()),
            other => bail!("field \"error\" must be a string or null, got {other:?}"),
        },
    })
}

/// Serialize one job outcome as a single JSONL line (no trailing `\n`).
/// The sweep id is rendered as a 16-hex string — `Json::Num` is an f64
/// and cannot hold all u64 fingerprints losslessly.
pub fn record_line(
    sweep: u64,
    report: &str,
    seq: usize,
    total: usize,
    id: &str,
    outcome: &Result<JobResult, String>,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("v".into(), num(SPOOL_VERSION));
    m.insert("sweep".into(), Json::Str(crate::ir::fingerprint::hex(sweep)));
    m.insert("report".into(), Json::Str(report.to_string()));
    m.insert("seq".into(), num(seq as u64));
    m.insert("total".into(), num(total as u64));
    m.insert("id".into(), Json::Str(id.to_string()));
    match outcome {
        Ok(jr) => {
            m.insert("cell".into(), cell_to_json(&report::cell(jr)));
        }
        Err(msg) => {
            m.insert("failed".into(), Json::Str(msg.clone()));
        }
    }
    Json::Obj(m).render()
}

/// Parse one spool line.
pub fn parse_line(line: &str) -> Result<SpoolRecord> {
    let doc = parse(line)?;
    ensure!(
        doc.get("v")?.as_usize()? as u64 == SPOOL_VERSION,
        "unknown spool record version"
    );
    let sweep = u64::from_str_radix(doc.get("sweep")?.as_str()?, 16)
        .context("bad sweep id in spool record")?;
    let report = doc.get("report")?.as_str()?.to_string();
    let seq = doc.get("seq")?.as_usize()?;
    let total = doc.get("total")?.as_usize()?;
    let id = doc.get("id")?.as_str()?.to_string();
    let outcome = match doc.as_obj()?.get("failed") {
        Some(msg) => Err(msg.as_str()?.to_string()),
        None => Ok(cell_from_json(doc.get("cell")?)?),
    };
    Ok(SpoolRecord { sweep, report, seq, total, id, outcome })
}

/// Read one spool file. A missing file is an empty spool (fresh shard);
/// unparseable lines (torn writes) are skipped and counted. Returns
/// `(records, skipped_lines)`.
pub fn read_spool_file(path: &Path) -> Result<(Vec<SpoolRecord>, usize)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((Vec::new(), 0)),
        Err(e) => {
            return Err(e).with_context(|| format!("reading spool {}", path.display()))
        }
    };
    let mut records = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(line) {
            Ok(r) => records.push(r),
            Err(_) => skipped += 1,
        }
    }
    Ok((records, skipped))
}

/// Read every `*.jsonl` spool in a directory (any shard layout), in
/// deterministic (sorted-path) order.
pub fn read_spool_dir(dir: &Path) -> Result<(Vec<SpoolRecord>, usize)> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading spool dir {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
        .collect();
    paths.sort();
    ensure!(!paths.is_empty(), "no *.jsonl spool files in {}", dir.display());
    let mut records = Vec::new();
    let mut skipped = 0;
    for p in paths {
        let (mut r, s) = read_spool_file(&p)?;
        records.append(&mut r);
        skipped += s;
    }
    Ok((records, skipped))
}

/// The stitched view of a spooled sweep.
#[derive(Debug, Default)]
pub struct MergedSweep {
    /// Successful cells in global job order — exactly the rows the
    /// unsharded sweep would have rendered.
    pub cells: Vec<Cell>,
    /// Failed jobs as `(seq, id, message)`, in global job order.
    pub failures: Vec<(usize, String, String)>,
    /// Sequence numbers no shard reported (incomplete sweep).
    pub missing: Vec<usize>,
    /// Report kind recorded by the sweep (`None` only for an empty
    /// record set; uniform otherwise — mixed sweeps are rejected).
    pub report: Option<String>,
}

/// Merge spool records: dedupe by sequence number, order globally, and
/// report gaps against the recorded sweep size. Refuses to stitch
/// records from different sweeps (a spool dir reused across commands,
/// devices or configs would otherwise silently mix rows).
pub fn merge(records: Vec<SpoolRecord>) -> Result<MergedSweep> {
    let mut sweeps: Vec<u64> = records.iter().map(|r| r.sweep).collect();
    sweeps.sort_unstable();
    sweeps.dedup();
    ensure!(
        sweeps.len() <= 1,
        "spool holds records from {} different sweeps ({}) — use one spool \
         dir per sweep",
        sweeps.len(),
        sweeps
            .iter()
            .map(|s| crate::ir::fingerprint::hex(*s))
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut by_seq: BTreeMap<usize, SpoolRecord> = BTreeMap::new();
    let mut total = 0usize;
    let mut report = None;
    for r in records {
        total = total.max(r.total);
        report.get_or_insert_with(|| r.report.clone());
        // dedupe preferring success: a resume retries failed jobs, so a
        // seq can carry an old failure record and a newer success — the
        // success is the row the unsharded sweep would have printed
        let keep_existing =
            matches!(by_seq.get(&r.seq), Some(prev) if prev.outcome.is_ok() || r.outcome.is_err());
        if !keep_existing {
            by_seq.insert(r.seq, r);
        }
    }
    let mut out = MergedSweep { report, ..Default::default() };
    for (seq, r) in &by_seq {
        match &r.outcome {
            Ok(cell) => out.cells.push(cell.clone()),
            Err(msg) => out.failures.push((*seq, r.id.clone(), msg.clone())),
        }
    }
    out.missing = (0..total).filter(|s| !by_seq.contains_key(s)).collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::CompileJob;
    use crate::resources::device::DeviceSpec;

    fn sample_result() -> Result<JobResult, String> {
        CompileJob {
            kernel: "linear".into(),
            size: 0,
            framework: FrameworkKind::Ming,
            device: DeviceSpec::kv260(),
            estimate_only: true,
        }
        .run()
        .map_err(|e| format!("{e:#}"))
    }

    const SWEEP: u64 = 0xdead_beef_cafe_f00d;

    #[test]
    fn record_roundtrips_cells_exactly() {
        let r = sample_result();
        let line = record_line(SWEEP, "table2", 3, 8, "linear_0@ming", &r);
        assert!(!line.contains('\n'), "one record per line");
        let rec = parse_line(&line).unwrap();
        assert_eq!(rec.sweep, SWEEP, "u64 sweep ids round-trip via hex");
        assert_eq!((rec.seq, rec.total), (3, 8));
        assert_eq!(rec.id, "linear_0@ming");
        let cell = rec.outcome.unwrap();
        let orig = report::cell(r.as_ref().unwrap());
        // f64 fields round-trip exactly (Rust prints shortest-roundtrip)
        assert_eq!(cell.mcycles, orig.mcycles);
        assert_eq!(cell.bram, orig.bram);
        assert_eq!(cell.dsp, orig.dsp);
        assert_eq!(cell.framework, orig.framework);
        assert_eq!(cell.fits, orig.fits);
        assert_eq!(cell.error, orig.error);
        // per-stage timings round-trip and were actually measured
        assert_eq!(cell.stages, orig.stages);
        assert!(orig.stages.total_us > 0);
        assert!(orig.stages.staged_sum() <= orig.stages.total_us);
        // and the rendered table rows are byte-identical
        assert_eq!(
            report::render_table2(&[cell]),
            report::render_table2(&[orig])
        );
    }

    #[test]
    fn pre_timing_spool_lines_still_parse() {
        // Lines written before the stage-timing fields existed have no
        // "stages" object — they parse with zeroed timings, so a resume
        // over an old spool keeps working.
        let r = sample_result();
        let line = record_line(SWEEP, "table2", 0, 1, "linear_0@ming", &r);
        let mut doc = parse(&line).unwrap();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(cm)) = m.get_mut("cell") {
                cm.remove("stages");
            }
        }
        let rec = parse_line(&doc.render()).unwrap();
        assert_eq!(rec.outcome.unwrap().stages, StageTimes::default());
    }

    #[test]
    fn failed_jobs_spool_and_merge_as_failures() {
        let err = Err("unknown kernel".into());
        let line = record_line(SWEEP, "table2", 5, 8, "transformer_32@ming", &err);
        let rec = parse_line(&line).unwrap();
        assert_eq!(rec.outcome.as_ref().unwrap_err(), "unknown kernel");
        let merged = merge(vec![rec]).unwrap();
        assert!(merged.cells.is_empty());
        assert_eq!(merged.failures.len(), 1);
        assert_eq!(merged.failures[0].0, 5);
    }

    #[test]
    fn merge_orders_dedupes_and_finds_gaps() {
        let r = sample_result();
        let mk = |seq: usize| {
            parse_line(&record_line(SWEEP, "table2", seq, 4, "linear_0@ming", &r)).unwrap()
        };
        // out of order, one duplicate, seq 2 missing
        let merged = merge(vec![mk(3), mk(0), mk(1), mk(1)]).unwrap();
        assert_eq!(merged.cells.len(), 3);
        assert_eq!(merged.missing, vec![2]);
        assert!(merged.failures.is_empty());
    }

    #[test]
    fn merge_prefers_success_over_a_retried_failure() {
        // seq 0 failed once (transient panic), then a resume retried it
        // successfully: the merged table must carry the success, in
        // either record order.
        let ok = sample_result();
        let failed: Result<JobResult, String> = Err("job panicked: transient".into());
        let mk = |outcome: &Result<JobResult, String>| {
            parse_line(&record_line(SWEEP, "table2", 0, 1, "linear_0@ming", outcome)).unwrap()
        };
        for records in [vec![mk(&failed), mk(&ok)], vec![mk(&ok), mk(&failed)]] {
            let merged = merge(records).unwrap();
            assert_eq!(merged.cells.len(), 1);
            assert!(merged.failures.is_empty());
            assert!(merged.missing.is_empty());
        }
    }

    #[test]
    fn merge_refuses_mixed_sweeps() {
        // Two sweeps sharing a spool dir (e.g. table2 then table3, or a
        // device change) must not silently stitch into one table.
        let r = sample_result();
        let a = parse_line(&record_line(1, "table2", 0, 2, "linear_0@ming", &r)).unwrap();
        let b = parse_line(&record_line(2, "table3", 1, 2, "linear_0@ming", &r)).unwrap();
        let err = merge(vec![a, b]).unwrap_err();
        assert!(format!("{err:#}").contains("different sweeps"), "{err:#}");
    }

    #[test]
    fn torn_lines_are_skipped_not_fatal() {
        let dir = std::env::temp_dir()
            .join(format!("ming-spool-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = sample_result();
        let good = record_line(SWEEP, "table2", 0, 2, "linear_0@ming", &r);
        let torn = &good[..good.len() / 2];
        let path = dir.join("shard-0-of-1.jsonl");
        std::fs::write(&path, format!("{good}\n{torn}")).unwrap();
        let (records, skipped) = read_spool_file(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(skipped, 1);
        // a missing file is an empty spool, not an error
        let (none, s) = read_spool_file(&dir.join("absent.jsonl")).unwrap();
        assert!(none.is_empty());
        assert_eq!(s, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_file_names_are_stable() {
        let s = Shard { index: 1, count: 4 };
        assert_eq!(
            shard_file(Path::new("/tmp/spool"), s),
            PathBuf::from("/tmp/spool/shard-1-of-4.jsonl")
        );
    }
}
