//! The L3 coordination layer: a staged, cache-backed compile service.
//!
//! [`job`] runs one compile as explicit stages (lower → solve →
//! estimate → simulate); [`service`] sweeps kernel × framework × size
//! job lists over the process-wide work-stealing scheduler ([`sched`]),
//! with deterministic round-robin sharding across processes and
//! makespan-aware (LPT) job ordering; [`cache`] memoizes solved
//! designs content-addressed by `(graph, device, config)` fingerprint,
//! in memory and as JSON on disk; [`spool`] persists shard results as
//! mergeable, resumable JSONL; [`report`] formats the paper's Tables
//! II–IV and Fig. 3 from sweep cells (stitched back together by the
//! `merge-sweep` CLI subcommand for sharded runs).

pub mod cache;
pub mod job;
pub mod report;
pub mod sched;
pub mod service;
pub mod spool;

pub use cache::{CacheStats, CachedDesign, DesignCache, DiskStats};
pub use job::{CompileJob, JobResult, StageTimes};
pub use sched::{SchedHandle, Scheduler};
pub use service::{CompileService, JobOrder, Shard, SweepConfig};
