//! The L3 coordination layer: a multi-threaded compile service that runs
//! kernel × framework × size sweeps (compile → estimate → simulate →
//! optionally golden-verify) over a worker pool, plus the report
//! formatters that regenerate the paper's Tables II–IV and Fig. 3.

pub mod job;
pub mod queue;
pub mod service;
pub mod report;

pub use job::{CompileJob, JobResult};
pub use queue::WorkerPool;
pub use service::{CompileService, SweepConfig};
