//! The process-wide work-stealing task scheduler.
//!
//! One persistent pool of OS threads (tokio/rayon are not vendored in
//! this environment; the workload is CPU-bound, so plain threads are
//! the right tool) replaces the per-site pool spin-ups that used to
//! live in the sweep service, the parallel branch-and-bound, the
//! speculative tile-grid search, and the tiled simulator. Every
//! parallel site now submits **task groups** into the same cores:
//!
//! - each worker owns a deque, pushes nested tasks to its back and pops
//!   from the back (LIFO — depth-first, cache-warm);
//! - an idle worker pops the global injector queue (top-level
//!   submissions, FIFO — sweep jobs run in submission order), then
//!   steals **half** a victim's deque from its front (FIFO end — the
//!   oldest, coarsest tasks migrate, the fine-grained tail stays local);
//! - a worker whose task waits on a nested group *helps*: it executes
//!   tasks from its own deque, then steals, until the group resolves —
//!   nested `run_all_scoped` calls therefore never deadlock and never
//!   oversubscribe, no matter how deep they nest.
//!
//! This is what lets an idle worker at a sweep tail steal a straggler
//! job's DSE subtrees or sim-cell chunks instead of watching one core
//! grind. Determinism is the callers' contract, not the scheduler's:
//! every parallel site reduces its results in task-index order (strict
//! shared+1 incumbent, minimum-index grid commit, row-major stitch), so
//! the scheduler only changes *when* work runs, never *what* wins.
//!
//! Accounting: every core-second lands in exactly one lane.
//! `sched.busy_us` is per-task **self time** (a task that helps a
//! nested group while waiting does not double-count its children),
//! `sched.idle_us` is time actively searching for work, parked time is
//! charged to nobody, `sched.steals` counts migrated tasks and
//! `sched.tasks` executed ones.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::thread;
use std::time::Instant;

use crate::obs::metrics::Metric;

/// One unit of queued work. The closure is lifetime-erased (see the
/// SAFETY argument in [`SchedHandle::run_all_scoped`]); the group latch
/// is what makes the erasure sound.
struct Task {
    run: Box<dyn FnOnce() + Send>,
    group: Arc<Group>,
    /// Worker index this task was stolen from, for trace annotation.
    stolen_from: Option<usize>,
}

/// Completion latch of one `run_all_scoped` call.
struct Group {
    remaining: AtomicUsize,
}

impl Group {
    fn done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }
}

struct Shared {
    /// Configured width. `<= 1` means the scheduler runs everything
    /// inline (the exact serial paths) and owns no threads.
    width: usize,
    /// Top-level submissions (calls from non-worker threads), FIFO.
    injector: Mutex<VecDeque<Task>>,
    /// Per-worker deques: own pops from the back (LIFO), steals drain
    /// from the front (FIFO).
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently sitting in the injector or a deque. Parking
    /// re-checks it under `sleep`, so pushes never get lost.
    pending: AtomicUsize,
    sleep: Mutex<()>,
    cv: Condvar,
    shutdown: AtomicBool,
    m_busy: Metric,
    m_idle: Metric,
    m_steals: Metric,
    m_tasks: Metric,
}

thread_local! {
    /// Set on scheduler worker threads: which scheduler and which slot.
    static CURRENT: RefCell<Option<(Weak<Shared>, usize)>> = const { RefCell::new(None) };
    /// Per-thread stack of child-task wall times, one frame per nested
    /// task execution — the self-time accounting that keeps
    /// `sched.busy_us` from double-counting help-while-wait work.
    static EXEC_FRAMES: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// `current_workers` override (0 = none). Benches use it to emulate
    /// the old nested `workers=1` pin.
    static WORKER_CAP: Cell<usize> = const { Cell::new(0) };
    /// Whether this thread's trace lane has been labelled while tracing
    /// was enabled (labels are dropped by the sink while it is off, so
    /// workers retry until one sticks).
    static LANE_LABELED: Cell<bool> = const { Cell::new(false) };
}

/// A cheap, cloneable handle to a scheduler — what parallel sites hold
/// and what [`current_or_global`] resolves to.
#[derive(Clone)]
pub struct SchedHandle {
    shared: Arc<Shared>,
}

/// An owned scheduler: the global one (never dropped) or a private
/// instance for tests and benches. Dropping joins the worker threads.
/// Derefs to [`SchedHandle`], which carries all the submission methods.
pub struct Scheduler {
    h: SchedHandle,
    threads: Vec<thread::JoinHandle<()>>,
}

impl std::ops::Deref for Scheduler {
    type Target = SchedHandle;

    fn deref(&self) -> &SchedHandle {
        &self.h
    }
}

impl Scheduler {
    /// A scheduler with `workers` threads. `workers <= 1` spawns no
    /// threads at all: every submission runs inline on the caller — the
    /// exact serial code paths.
    pub fn new(workers: usize) -> Self {
        let m = crate::obs::metrics::global();
        let shared = Arc::new(Shared {
            width: workers,
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            sleep: Mutex::new(()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            m_busy: m.handle("sched.busy_us"),
            m_idle: m.handle("sched.idle_us"),
            m_steals: m.handle("sched.steals"),
            m_tasks: m.handle("sched.tasks"),
        });
        let threads = if workers >= 2 {
            (0..workers)
                .map(|widx| {
                    let shared = Arc::clone(&shared);
                    thread::Builder::new()
                        .name(format!("sched-{widx}"))
                        .spawn(move || worker_loop(shared, widx))
                        .expect("spawning scheduler worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        Scheduler { h: SchedHandle { shared }, threads }
    }

    /// A cloneable handle to this scheduler.
    pub fn handle(&self) -> SchedHandle {
        self.h.clone()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // No group can still be in flight: run_all_scoped borrows the
        // scheduler for its whole duration, so by the time Drop runs
        // every submitted task has completed.
        self.h.shared.shutdown.store(true, Ordering::Release);
        drop(self.h.shared.sleep.lock().unwrap());
        self.h.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl SchedHandle {
    /// Effective parallelism: 1 means every submission runs inline.
    pub fn workers(&self) -> usize {
        self.shared.width.max(1)
    }

    /// Run all jobs, returning `(index, result)` pairs sorted by index.
    /// Panics in jobs are isolated per-task and surfaced as `Err`
    /// strings.
    pub fn run_all<J, R>(&self, jobs: Vec<J>) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        self.run_all_scoped(jobs, |_, _| {})
    }

    /// Like [`Self::run_all`], additionally invoking `on_done` on the
    /// calling thread as each job finishes, in completion order. The
    /// sweep spool streams records to disk through this hook, so a
    /// crash mid-sweep loses at most the jobs still in flight — not the
    /// whole run.
    pub fn run_all_streaming<J, R>(
        &self,
        jobs: Vec<J>,
        on_done: impl FnMut(usize, &Result<R, String>),
    ) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        self.run_all_scoped(jobs, on_done)
    }

    /// The scoped core shared by every entry point: jobs (and their
    /// results) may **borrow** from the caller's stack, so
    /// `simulate_tiled` can fan cell closures referencing the cell
    /// design and the input tensor straight out without cloning either.
    /// Results come back `(index, result)`-sorted; `on_done` fires in
    /// completion order on the calling thread.
    ///
    /// Called from a worker of this scheduler, the jobs become a
    /// **nested group** on that worker's own deque and the worker helps
    /// execute until the group resolves — nested parallel sites (DSE
    /// subtrees inside a sweep job, cell solves inside a grid search)
    /// share the same cores instead of spinning a pool inside a pool.
    pub fn run_all_scoped<'env, J, R>(
        &self,
        jobs: Vec<J>,
        mut on_done: impl FnMut(usize, &Result<R, String>),
    ) -> Vec<(usize, Result<R, String>)>
    where
        J: FnOnce() -> R + Send + 'env,
        R: Send + 'env,
    {
        let njobs = jobs.len();
        let sh = &self.shared;
        let mut results: Vec<(usize, Result<R, String>)> = Vec::with_capacity(njobs);
        if self.workers() <= 1 || njobs <= 1 {
            // The exact serial path: index order, inline on the caller,
            // panic isolation and busy accounting intact.
            for (i, job) in jobs.into_iter().enumerate() {
                let out = exec_accounted(sh, || run_caught(job));
                on_done(i, &out);
                results.push((i, out));
            }
            return results;
        }

        let (tx, rx) = mpsc::channel::<(usize, Result<R, String>)>();
        let group = Arc::new(Group { remaining: AtomicUsize::new(njobs) });
        let mut tasks = Vec::with_capacity(njobs);
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let f: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let out = run_caught(job);
                let _ = tx.send((i, out));
            });
            // SAFETY: the closure borrows `'env` state (the job and its
            // Sender of `R`). It is sound to erase that lifetime because
            // this frame provably outlives every task: the `GroupWait`
            // guard below blocks — helping or parked — until the group
            // latch reaches zero, on the normal path *and* on unwind, and
            // the latch is decremented only after a task's closure has
            // returned. No task can run, or exist, past this function.
            let f: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(f)
            };
            tasks.push(Task { run: f, group: Arc::clone(&group), stolen_from: None });
        }
        drop(tx);
        let here = current_worker_of(sh);
        // Declared after `rx` so its Drop (which waits for the group)
        // runs before the receiver drops — tasks never send into a
        // closed channel.
        let wait = GroupWait { shared: sh, group: &group, worker: here };
        sh.submit(tasks, here);
        match here {
            // Top-level call: block on the channel; workers do the work.
            None => {
                for (idx, out) in rx.iter() {
                    on_done(idx, &out);
                    results.push((idx, out));
                }
            }
            // Nested call on a worker: help execute until the group is
            // done — our own deque first (it holds this group's tasks),
            // then steal them back from whoever took them.
            Some(widx) => loop {
                match rx.try_recv() {
                    Ok((idx, out)) => {
                        on_done(idx, &out);
                        results.push((idx, out));
                    }
                    Err(TryRecvError::Disconnected) => break,
                    Err(TryRecvError::Empty) => {
                        if !sh.help_once(widx) {
                            sh.park_while(|| {
                                !group.done() && sh.pending.load(Ordering::Acquire) == 0
                            });
                        }
                    }
                }
            },
        }
        drop(wait);
        results.sort_by_key(|(i, _)| *i);
        results
    }
}

/// Blocks until the group resolves — **also on unwind**, which is what
/// makes the lifetime erasure in `run_all_scoped` sound even if
/// `on_done` panics mid-collection.
struct GroupWait<'a> {
    shared: &'a Shared,
    group: &'a Arc<Group>,
    worker: Option<usize>,
}

impl Drop for GroupWait<'_> {
    fn drop(&mut self) {
        let (sh, group) = (self.shared, self.group);
        match self.worker {
            Some(widx) => {
                while !group.done() {
                    if !sh.help_once(widx) {
                        sh.park_while(|| {
                            !group.done() && sh.pending.load(Ordering::Acquire) == 0
                        });
                    }
                }
            }
            None => sh.park_while(|| !group.done()),
        }
    }
}

impl Shared {
    /// Queue a group's tasks: onto the submitting worker's own deque
    /// (nested groups — back-pushed in reverse so its LIFO pops run
    /// them in index order) or the global injector (top-level, FIFO).
    fn submit(&self, tasks: Vec<Task>, here: Option<usize>) {
        let k = tasks.len();
        match here {
            Some(widx) => {
                let mut dq = self.deques[widx].lock().unwrap();
                for t in tasks.into_iter().rev() {
                    dq.push_back(t);
                }
            }
            None => {
                let mut inj = self.injector.lock().unwrap();
                inj.extend(tasks);
            }
        }
        self.pending.fetch_add(k, Ordering::Release);
        // Lock-then-notify: a parker that saw pending == 0 is already
        // waiting by the time we take the lock, so the notify reaches it.
        drop(self.sleep.lock().unwrap());
        self.cv.notify_all();
    }

    /// Find one task for worker `widx`: own deque (LIFO), injector
    /// (FIFO), then steal half a victim's deque from the front. Returns
    /// with `pending` already decremented for the returned task; a
    /// stolen batch's surplus lands on our deque, still pending.
    fn find_task(&self, widx: usize) -> Option<Task> {
        if let Some(t) = self.deques[widx].lock().unwrap().pop_back() {
            self.pending.fetch_sub(1, Ordering::Release);
            return Some(t);
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            self.pending.fetch_sub(1, Ordering::Release);
            return Some(t);
        }
        self.try_steal(widx)
    }

    /// Steal-half: drain the front (oldest, coarsest) half of the first
    /// non-empty victim deque, run the first migrated task, keep the
    /// rest on our own deque for LIFO consumption (and wake siblings —
    /// the surplus is stealable again).
    fn try_steal(&self, widx: usize) -> Option<Task> {
        for off in 1..self.deques.len() {
            let victim = (widx + off) % self.deques.len();
            let mut batch: VecDeque<Task> = {
                let mut dq = self.deques[victim].lock().unwrap();
                let n = dq.len();
                if n == 0 {
                    continue;
                }
                dq.drain(..n.div_ceil(2)).collect()
            };
            for t in batch.iter_mut() {
                t.stolen_from = Some(victim);
            }
            self.m_steals.add(batch.len() as u64);
            let first = batch.pop_front().expect("batch is non-empty");
            self.pending.fetch_sub(1, Ordering::Release);
            if !batch.is_empty() {
                self.deques[widx].lock().unwrap().extend(batch);
                drop(self.sleep.lock().unwrap());
                self.cv.notify_all();
            }
            return Some(first);
        }
        None
    }

    /// Execute one available task if any (the help-while-wait step).
    fn help_once(&self, widx: usize) -> bool {
        match self.find_task(widx) {
            Some(task) => {
                self.exec(task);
                true
            }
            None => false,
        }
    }

    /// Park on the scheduler condvar while `keep_parked` holds. Both
    /// wake sources — task pushes and group completions — bump their
    /// state *before* taking `sleep` and notifying, and this re-checks
    /// under the lock, so wakeups are never lost.
    fn park_while(&self, keep_parked: impl Fn() -> bool) {
        let mut g = self.sleep.lock().unwrap();
        while keep_parked() {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Run one task with trace + metrics envelope, then resolve its
    /// group. Stolen tasks get a `steal` span carrying the victim lane
    /// (`stolen_from`) so straggler recruitment is visible in Perfetto.
    fn exec(&self, task: Task) {
        maybe_label_lane();
        let sink = crate::obs::trace::global();
        let _steal_span = task.stolen_from.map(|victim| {
            sink.span_with_arg("sched", "steal", "stolen_from", || format!("worker-{victim}"))
        });
        let run = task.run;
        exec_accounted(self, move || run());
        if task.group.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            drop(self.sleep.lock().unwrap());
            self.cv.notify_all();
        }
    }
}

/// Execute `f` charging `sched.busy_us` with its **self time**: wall
/// time minus the wall time of any tasks executed inside it (nested
/// groups helping while they wait). Each core-second is attributed to
/// exactly one task — the fix for the old per-pool flush, which counted
/// nested parallel work once in the inner pool and again in the outer.
fn exec_accounted<R>(sh: &Shared, f: impl FnOnce() -> R) -> R {
    sh.m_tasks.incr();
    EXEC_FRAMES.with(|fr| fr.borrow_mut().push(0));
    let t0 = Instant::now();
    let out = f();
    let wall = t0.elapsed().as_micros() as u64;
    let child = EXEC_FRAMES.with(|fr| {
        let mut fr = fr.borrow_mut();
        let child = fr.pop().unwrap_or(0);
        if let Some(parent) = fr.last_mut() {
            *parent += wall;
        }
        child
    });
    sh.m_busy.add(wall.saturating_sub(child));
    out
}

/// Worker main loop: search (clocked as `sched.idle_us`), execute, park
/// (charged to nobody — the core is genuinely free).
fn worker_loop(shared: Arc<Shared>, widx: usize) {
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::downgrade(&shared), widx)));
    maybe_label_lane();
    loop {
        let t = Instant::now();
        let found = shared.find_task(widx);
        shared.m_idle.add(t.elapsed().as_micros() as u64);
        match found {
            Some(task) => shared.exec(task),
            None => {
                if shared.shutdown.load(Ordering::Acquire) {
                    break;
                }
                shared.park_while(|| {
                    shared.pending.load(Ordering::Acquire) == 0
                        && !shared.shutdown.load(Ordering::Acquire)
                });
            }
        }
    }
}

/// Label this worker's trace lane `worker-N` once tracing is on. The
/// sink drops labels while tracing is disabled, and the pool is
/// persistent (workers usually outlive a `--trace-out` arm/disarm
/// cycle), so workers retry at each task until a label sticks.
fn maybe_label_lane() {
    if LANE_LABELED.get() {
        return;
    }
    let sink = crate::obs::trace::global();
    if !sink.is_tracing() {
        return;
    }
    if let Some((_, widx)) = CURRENT.with(|c| c.borrow().clone()) {
        sink.set_thread_label(&format!("worker-{widx}"));
        LANE_LABELED.set(true);
    }
}

/// If the calling thread is a worker of the scheduler behind `sh`,
/// its worker index.
fn current_worker_of(sh: &Arc<Shared>) -> Option<usize> {
    CURRENT.with(|c| {
        c.borrow().as_ref().and_then(|(weak, widx)| {
            weak.upgrade().filter(|cur| Arc::ptr_eq(cur, sh)).map(|_| *widx)
        })
    })
}

pub(crate) fn run_caught<R>(job: impl FnOnce() -> R) -> Result<R, String> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).map_err(|e| panic_msg(&*e))
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("job panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("job panicked: {s}")
    } else {
        "job panicked".to_string()
    }
}

/// Default width: one thread per core, leaving one for the coordinator.
pub fn default_size() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

static GLOBAL_WIDTH: AtomicUsize = AtomicUsize::new(0); // 0 = default_size()
static GLOBAL: OnceLock<Scheduler> = OnceLock::new();

/// Set the global scheduler's width (the CLI's `--workers`). Must run
/// before the first [`global`] use; afterwards the width is fixed —
/// returns whether the request took effect (or already matched).
pub fn configure(workers: usize) -> bool {
    if let Some(s) = GLOBAL.get() {
        return s.workers() == workers.max(1);
    }
    GLOBAL_WIDTH.store(workers, Ordering::SeqCst);
    true
}

/// The process-wide scheduler every production parallel site submits
/// into. Created on first use with the [`configure`]d width (default:
/// [`default_size`]); its threads live for the process.
pub fn global() -> &'static Scheduler {
    GLOBAL.get_or_init(|| {
        let w = GLOBAL_WIDTH.load(Ordering::SeqCst);
        Scheduler::new(if w == 0 { default_size() } else { w })
    })
}

/// The scheduler owning the calling thread (a nested parallel site on a
/// worker — possibly of a private test scheduler), else the global one.
pub fn current_or_global() -> SchedHandle {
    let here = CURRENT.with(|c| c.borrow().as_ref().and_then(|(weak, _)| weak.upgrade()));
    match here {
        Some(shared) => SchedHandle { shared },
        None => global().handle(),
    }
}

/// The parallelism available to the calling context: the
/// [`with_worker_cap`] override if set, the width of the scheduler
/// owning this worker thread, or the global width (configured or
/// default — without instantiating the pool). Nested parallel sites
/// size their dispatch decisions (`workers > 1`?) off this, so
/// `--workers 1` takes the exact serial paths all the way down.
pub fn current_workers() -> usize {
    let cap = WORKER_CAP.get();
    if cap > 0 {
        return cap;
    }
    let here = CURRENT.with(|c| c.borrow().as_ref().and_then(|(weak, _)| weak.upgrade()));
    if let Some(shared) = here {
        return shared.width.max(1);
    }
    if let Some(s) = GLOBAL.get() {
        return s.workers();
    }
    match GLOBAL_WIDTH.load(Ordering::SeqCst) {
        0 => default_size(),
        w => w.max(1),
    }
}

/// Run `f` with [`current_workers`] pinned to `n` on this thread —
/// restored on exit *and* on unwind. `benches/sched_perf.rs` uses the
/// cap to reproduce the old "nested sites solve serially" behaviour as
/// its comparison baseline.
pub fn with_worker_cap<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            WORKER_CAP.set(self.0);
        }
    }
    let _restore = Restore(WORKER_CAP.replace(n));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs_and_orders_results() {
        let sched = Scheduler::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..32).map(|i| Box::new(move || i * i) as _).collect();
        let results = sched.run_all(jobs);
        assert_eq!(results.len(), 32);
        for (i, r) in results {
            assert_eq!(*r.as_ref().unwrap(), i * i);
        }
    }

    #[test]
    fn panics_are_isolated() {
        let sched = Scheduler::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let results = sched.run_all(jobs);
        assert_eq!(*results[0].1.as_ref().unwrap(), 1);
        assert!(results[1].1.as_ref().unwrap_err().contains("boom"));
        assert_eq!(*results[2].1.as_ref().unwrap(), 3);
    }

    #[test]
    fn streaming_callback_sees_every_completion_once() {
        let sched = Scheduler::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..16).map(|i| Box::new(move || i + 1) as _).collect();
        let mut seen = Vec::new();
        let results = sched.run_all_streaming(jobs, |i, r| {
            seen.push((i, *r.as_ref().unwrap()));
        });
        assert_eq!(results.len(), 16);
        assert_eq!(seen.len(), 16, "one callback per job");
        seen.sort_unstable();
        assert_eq!(seen, (0usize..16).map(|i| (i, i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_jobs_may_borrow_the_callers_stack() {
        // The contract simulate_tiled relies on: closures borrowing a
        // local slice run fine on scheduler threads (no 'static, no
        // clones).
        let sched = Scheduler::new(3);
        let data: Vec<usize> = (0..64).collect();
        let jobs: Vec<_> =
            data.chunks(8).map(|ch| move || ch.iter().sum::<usize>()).collect();
        let results = sched.run_all_scoped(jobs, |_, _| {});
        let total: usize = results.iter().map(|(_, r)| *r.as_ref().unwrap()).sum();
        assert_eq!(total, 64 * 63 / 2);
    }

    #[test]
    fn nested_groups_run_on_the_same_pool_and_may_borrow() {
        // A task spawns a sub-group of borrowing closures; the group
        // runs on the same workers (help-while-wait, no deadlock at any
        // width, including width < fan-out).
        for width in [2usize, 3, 8] {
            let sched = Scheduler::new(width);
            let h = sched.handle();
            let jobs: Vec<_> = (0..4usize)
                .map(|outer| {
                    let h = h.clone();
                    move || {
                        let data: Vec<usize> = (0..32).map(|i| i + outer).collect();
                        let sub: Vec<_> = data
                            .chunks(4)
                            .map(|ch| move || ch.iter().sum::<usize>())
                            .collect();
                        let nested = h.run_all_scoped(sub, |_, _| {});
                        nested.into_iter().map(|(_, r)| r.unwrap()).sum::<usize>()
                    }
                })
                .collect();
            let results = sched.run_all_scoped(jobs, |_, _| {});
            for (outer, r) in results {
                let want: usize = (0..32).map(|i| i + outer).sum();
                assert_eq!(*r.as_ref().unwrap(), want, "width {width}, outer {outer}");
            }
        }
    }

    #[test]
    fn forced_straggler_is_rescued_by_stealing() {
        // One job fans a wide nested group while its siblings finish
        // instantly: idle workers must steal the straggler's subtasks
        // off its deque (`sched.steals` counts migrated tasks).
        let m = crate::obs::metrics::global();
        let steals0 = m.get("sched.steals");
        let sched = Scheduler::new(4);
        let h = sched.handle();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..4usize)
            .map(|j| {
                let h = h.clone();
                Box::new(move || {
                    if j != 0 {
                        return j;
                    }
                    // the straggler: 32 nested tasks of ~2ms each
                    let sub: Vec<_> = (0..32usize)
                        .map(|i| {
                            move || {
                                std::thread::sleep(std::time::Duration::from_millis(2));
                                i
                            }
                        })
                        .collect();
                    h.run_all_scoped(sub, |_, _| {})
                        .into_iter()
                        .map(|(_, r)| r.unwrap())
                        .sum::<usize>()
                }) as _
            })
            .collect();
        let results = sched.run_all(jobs);
        assert_eq!(*results[0].1.as_ref().unwrap(), (0..32).sum::<usize>());
        assert!(
            m.get("sched.steals") > steals0,
            "idle workers must steal the straggler's nested tasks"
        );
    }

    #[test]
    fn busy_time_is_attributed_once() {
        // 4 jobs × 5ms of in-task time: busy must cover it. With nested
        // help-while-wait the self-time accounting must not double-count
        // — bounded loosely here (other tests share the registry).
        let m = crate::obs::metrics::global();
        let busy0 = m.get("sched.busy_us");
        let tasks0 = m.get("sched.tasks");
        let sched = Scheduler::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    i
                }) as _
            })
            .collect();
        pool_wall(|| {
            sched.run_all(jobs);
        });
        assert!(m.get("sched.busy_us") - busy0 >= 15_000);
        assert!(m.get("sched.tasks") - tasks0 >= 4);
    }

    // Tiny wrapper so the busy-time test reads as "work happened here".
    fn pool_wall(f: impl FnOnce()) {
        f();
    }

    #[test]
    fn nested_self_time_does_not_double_count() {
        // One outer task whose only work is a nested group: outer self
        // time is ~0, nested tasks carry the wall time. Total busy must
        // be ~1× the slept time, not ~2×.
        let m = crate::obs::metrics::global();
        let busy0 = m.get("sched.busy_us");
        let sched = Scheduler::new(2);
        let h = sched.handle();
        let jobs: Vec<Box<dyn FnOnce() -> u64 + Send>> = vec![Box::new(move || {
            let sub: Vec<_> = (0..4usize)
                .map(|_| {
                    move || {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                        1u64
                    }
                })
                .collect();
            h.run_all_scoped(sub, |_, _| {}).into_iter().map(|(_, r)| r.unwrap()).sum()
        })];
        let t0 = Instant::now();
        sched.run_all(jobs);
        let wall = t0.elapsed().as_micros() as u64;
        let busy = m.get("sched.busy_us") - busy0;
        // 4 × 10ms of sleep across 2 workers: busy ≈ 40ms. Double
        // counting would report ≈ 40ms (nested) + 40ms (outer wall).
        // Bound: busy <= workers × wall with slack for registry sharing.
        assert!(busy >= 40_000, "nested work must be charged: {busy}us");
        assert!(
            busy <= 2 * wall + 20_000,
            "busy {busy}us exceeds 2x wall {wall}us — double-counted"
        );
    }

    #[test]
    fn single_worker_degenerates_to_serial() {
        let sched = Scheduler::new(1);
        assert_eq!(sched.workers(), 1);
        let mut order = Vec::new();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..5).map(|i| Box::new(move || i) as _).collect();
        let results = sched.run_all_streaming(jobs, |i, _| order.push(i));
        assert_eq!(order, vec![0, 1, 2, 3, 4], "serial path runs in index order");
        assert_eq!(results.iter().map(|(_, r)| *r.as_ref().unwrap()).sum::<usize>(), 10);
    }

    #[test]
    fn worker_cap_overrides_current_workers() {
        let outside = current_workers();
        assert!(outside >= 1);
        with_worker_cap(1, || {
            assert_eq!(current_workers(), 1);
            with_worker_cap(3, || assert_eq!(current_workers(), 3));
            assert_eq!(current_workers(), 1);
        });
        assert_eq!(current_workers(), outside);
    }

    #[test]
    fn current_workers_sees_the_owning_scheduler() {
        let sched = Scheduler::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            vec![Box::new(current_workers), Box::new(current_workers)];
        for (_, r) in sched.run_all(jobs) {
            assert_eq!(*r.as_ref().unwrap(), 3, "worker threads report their own width");
        }
    }
}
