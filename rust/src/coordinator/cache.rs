//! The design cache: content-addressed reuse of solved designs.
//!
//! Every DSE problem — a `(ModelGraph, DeviceSpec, DseConfig)` triple —
//! is keyed by its [`crate::ir::fingerprint::problem_fingerprint`]. A
//! cache entry stores the *solution*, not the design: the per-node
//! [`NodeTiming`] assignment (plus the winning grid shape for tiled
//! outcomes). Rebuilding from a hit is deterministic and cheap — apply
//! the timings, re-derive buffers, size FIFOs — and reproduces the
//! solved design byte-for-byte (the determinism property tests in
//! `tests/scale_out.rs` compare `Debug` renderings and emitted HLS), so
//! storing timings instead of megabytes of design is both smaller and
//! safer: a cache can never hand back storage the resource model would
//! not re-derive.
//!
//! Two tiers:
//! * **in-memory** — a mutexed map, shared by all worker threads of one
//!   process (one sweep solves each distinct problem once);
//! * **JSON-on-disk** (`--design-cache <dir>`) — one file per entry
//!   named by the hex fingerprint, written atomically (tmp + rename),
//!   so shards on different processes/machines share solutions and a
//!   re-run sweep performs **zero** ILP solves.
//!
//! Failure policy: a corrupt/truncated/stale cache file, a timing that
//! is not on the node's unroll lattice, or a rebuilt design that busts
//! the device budget all *degrade to a miss* (counted in
//! [`CacheStats::corrupt`]) and the solver runs normally — the cache
//! can slow a run down, never wrong it.
//!
//! Layering note: this module lives in the coordinator (it is sweep
//! infrastructure) but is consulted from `dse::ilp` and
//! `tiling::schedule` — a deliberate upward module reference, mirroring
//! the pre-existing `dse ↔ tiling` mutual dependency. If a future
//! refactor wants strict layering, the solver-facing half
//! ([`solve_cached`] / [`apply_cached_timings`] / [`rebuild_compiled`])
//! can split into a `dse::cache` with this module re-exporting it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use crate::dataflow::build::{build_cell_design, build_streaming_design, refresh_buffers};
use crate::dataflow::design::Design;
use crate::dataflow::node::NodeTiming;
use crate::dse::fifo::size_fifos;
use crate::dse::ilp::{solve, Compiled, DseConfig, DseSolution};
use crate::dse::space::{unroll_timings, Candidate};
use crate::ir::fingerprint::{hex, problem_fingerprint};
use crate::ir::graph::ModelGraph;
use crate::ir::json::Json;
use crate::resources::model::ResourceModel;
use crate::tiling::{TileGrid, TiledCompilation};

/// On-disk schema version; entries with another version are misses.
const CACHE_VERSION: u64 = 1;

/// One cached solution, keyed by a problem fingerprint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CachedDesign {
    /// The untiled streaming design was feasible: per-node timings in
    /// node (= topological) order.
    Flat { timings: Vec<NodeTiming> },
    /// The workload only fit grid-tiled: the winning grid shape plus
    /// the cell design's per-node timings.
    Tiled { rows: usize, cols: usize, timings: Vec<NodeTiming> },
    /// **Negative entry**: the flat DSE for this fingerprint has no
    /// feasible point at the fingerprinted device budget. Cached so
    /// `compile_tiled` cell solves and fallback callers stop re-proving
    /// infeasibility with a full branch-and-bound run; the original
    /// solver error is preserved verbatim. The fingerprint covers the
    /// device budgets, so a budget change is automatically a miss.
    Infeasible { msg: String },
}

/// Counters accumulated over a cache's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found a usable entry.
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Entries written (memory and, when configured, disk).
    pub stores: u64,
    /// Entries that existed but could not be used (parse error, lattice
    /// mismatch, budget violation) — each also ran the solver.
    pub corrupt: u64,
    /// Real ILP solves performed through the cached entry points. A
    /// fully warm cache keeps this at zero across an entire sweep.
    pub solves: u64,
    /// Disk entries removed by [`DesignCache::gc`] (mtime-LRU sweep).
    pub evicted: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// What the disk tier of a cache currently holds — computed by
/// [`DesignCache::disk_stats`] for `ming cache-stats`. Zero-valued for
/// in-memory caches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskStats {
    /// Parseable `.json` entry files.
    pub entries: usize,
    /// Bytes across all entry files (readable or not).
    pub bytes: u64,
    /// Entries holding a negative [`CachedDesign::Infeasible`] verdict.
    pub infeasible: usize,
    /// Entry files that failed to read or parse (would degrade to a
    /// miss at lookup time).
    pub unreadable: usize,
}

/// Thread-safe design cache (wrap in `Arc` to share across workers).
pub struct DesignCache {
    dir: Option<PathBuf>,
    mem: Mutex<HashMap<u64, CachedDesign>>,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    corrupt: AtomicU64,
    solves: AtomicU64,
    evicted: AtomicU64,
    /// Snapshot of the last [`Self::flush_metrics`] — the delta base, so
    /// the flush can mirror counter *changes* into the global registry
    /// without double-counting (see that method).
    flushed: Mutex<CacheStats>,
}

impl std::fmt::Debug for DesignCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DesignCache")
            .field("dir", &self.dir)
            .field("stats", &self.stats())
            .finish()
    }
}

impl DesignCache {
    /// Process-local cache (no persistence).
    pub fn in_memory() -> Self {
        Self {
            dir: None,
            mem: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            flushed: Mutex::new(CacheStats::default()),
        }
    }

    /// Disk-backed cache rooted at `dir` (created if absent). Entries
    /// are shared with every other process pointed at the same dir.
    pub fn at_dir(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating design cache dir {}", dir.display()))?;
        let mut c = Self::in_memory();
        c.dir = Some(dir);
        Ok(c)
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    fn entry_path(&self, fp: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("{}.json", hex(fp))))
    }

    /// Look up a fingerprint: memory first, then disk. Counts a hit or
    /// a miss; unreadable disk entries additionally count as corrupt.
    /// Counters reach the global `cache.*` metrics through the unified
    /// [`Self::flush_metrics`], not inline — every command path (and
    /// the `Drop` backstop) syncs the registry the same way.
    pub fn lookup(&self, fp: u64) -> Option<CachedDesign> {
        if let Some(e) = self.mem.lock().unwrap().get(&fp).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(e);
        }
        if let Some(path) = self.entry_path(fp) {
            match std::fs::read_to_string(&path) {
                Ok(text) => match entry_from_json(&text) {
                    Ok(e) => {
                        self.mem.lock().unwrap().insert(fp, e.clone());
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Some(e);
                    }
                    Err(_) => {
                        // corrupt on disk: degrade to a miss
                        self.corrupt.fetch_add(1, Ordering::Relaxed);
                    }
                },
                // absent: a plain miss; any *other* IO error (permissions,
                // disk fault) is a health signal operators need to see
                Err(e) => {
                    if e.kind() != std::io::ErrorKind::NotFound {
                        self.corrupt.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Whether `fp` would hit, **without** counting a hit or a miss (and
    /// without promoting a disk entry into memory). The sweep service's
    /// makespan predictor peeks every job's fingerprint up front to
    /// order work — those probes must not perturb the `cache.*` stats
    /// the real lookups report.
    pub fn peek(&self, fp: u64) -> bool {
        if self.mem.lock().unwrap().contains_key(&fp) {
            return true;
        }
        self.entry_path(fp).is_some_and(|p| p.exists())
    }

    /// Insert an entry (memory + disk when configured). Disk writes are
    /// atomic — a concurrent reader sees the old file or the new one,
    /// never a torn line — and write failures are ignored: persistence
    /// is an optimization, not a correctness requirement. The tmp name
    /// carries a process-wide counter on top of the pid so concurrent
    /// worker threads inserting the same fingerprint (recurring cell
    /// geometries do collide by design) never share a tmp file.
    pub fn insert(&self, fp: u64, entry: CachedDesign) {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        if let Some(path) = self.entry_path(fp) {
            let tmp = path.with_extension(format!(
                "tmp.{}.{}",
                std::process::id(),
                TMP_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let text = entry_to_json(&entry).render();
            if std::fs::write(&tmp, text).is_ok() {
                let _ = std::fs::rename(&tmp, &path);
            }
        }
        self.mem.lock().unwrap().insert(fp, entry);
        self.stores.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an entry that a [`Self::lookup`] returned (counting a
    /// hit) but that could not be applied: demotes that hit to a miss,
    /// so hit-rate metrics reflect only entries that actually served a
    /// design. Callers must invoke this at most once per failed lookup.
    pub fn note_corrupt(&self) {
        self.corrupt.fetch_add(1, Ordering::Relaxed);
        self.hits.fetch_sub(1, Ordering::Relaxed);
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one real ILP solve behind a cached entry point.
    pub fn count_solve(&self) {
        self.solves.fetch_add(1, Ordering::Relaxed);
    }

    /// Read the lifetime counters, syncing the global `cache.*` metrics
    /// on the way out (every `stats()`/`summary()` caller — which is
    /// every cache-enabled command path — keeps `--profile` current
    /// without per-operation registry traffic; see
    /// [`Self::flush_metrics`]).
    pub fn stats(&self) -> CacheStats {
        self.flush_metrics()
    }

    /// Mirror counter *changes since the last flush* into the global
    /// metrics registry (`cache.hits` … `cache.evicted`) and return the
    /// current totals.
    ///
    /// This replaces the old per-operation inline `incr` calls, which
    /// covered `compile`/`import` (whose summaries forced a sync) but
    /// left any path that dropped the cache without printing — `simulate`
    /// most visibly, plus every error path — with a registry permanently
    /// behind the cache's own counters. Now one delta-sync runs from
    /// `stats()` and from `Drop`, so the registry converges to the true
    /// totals on every command, however it exits. Deltas can be negative
    /// ([`Self::note_corrupt`] demotes an already-counted hit), hence
    /// the signed add/sub below.
    pub fn flush_metrics(&self) -> CacheStats {
        // lock first, load second: concurrent flushes each sync a
        // non-overlapping, non-decreasing slice of the counters
        let mut last = self.flushed.lock().unwrap();
        let cur = CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            solves: self.solves.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        };
        let m = crate::obs::metrics::global();
        let sync = |name: &str, cur: u64, prev: u64| {
            if cur > prev {
                m.add(name, cur - prev);
            } else if prev > cur {
                m.sub(name, prev - cur);
            }
        };
        sync("cache.hits", cur.hits, last.hits);
        sync("cache.misses", cur.misses, last.misses);
        sync("cache.stores", cur.stores, last.stores);
        sync("cache.corrupt", cur.corrupt, last.corrupt);
        sync("cache.ilp_solves", cur.solves, last.solves);
        sync("cache.evicted", cur.evicted, last.evicted);
        *last = cur;
        cur
    }

    /// One-line summary for sweep footers.
    pub fn summary(&self) -> String {
        let s = self.stats();
        format!(
            "design cache: {} hits / {} misses ({:.0}% hit rate), {} stores, \
             {} ilp solves, {} corrupt entries, {} evicted",
            s.hits,
            s.misses,
            100.0 * s.hit_rate(),
            s.stores,
            s.solves,
            s.corrupt,
            s.evicted
        )
    }

    /// mtime-LRU garbage collection of the disk tier: keep the
    /// `max_entries` most-recently-used entry files, remove the rest.
    /// Atomic renames give every served entry a fresh mtime only when
    /// (re)written, so "least recently written" approximates LRU well
    /// enough for long-lived sweep caches; readers racing a removal
    /// simply take a miss and re-solve. Returns `(kept, evicted)`.
    /// No-op for in-memory caches.
    pub fn gc(&self, max_entries: usize) -> Result<(usize, usize)> {
        let Some(dir) = &self.dir else {
            return Ok((0, 0));
        };
        let mut entries: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
        for e in std::fs::read_dir(dir)
            .with_context(|| format!("reading design cache dir {}", dir.display()))?
        {
            let e = e?;
            let path = e.path();
            if path.extension().and_then(|x| x.to_str()) != Some("json") {
                continue; // tmp files and strangers are not entries
            }
            let mtime = e
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            entries.push((mtime, path));
        }
        // newest first; ties broken by path for determinism
        entries.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        let mut evicted = 0usize;
        for (_, path) in entries.iter().skip(max_entries) {
            if std::fs::remove_file(path).is_ok() {
                evicted += 1;
            }
        }
        self.evicted.fetch_add(evicted as u64, Ordering::Relaxed);
        let kept = entries.len().min(max_entries);
        if evicted > 0 {
            // Best-effort history line for `ming cache-stats`. The file
            // is not `.json`, so the entry scan above ignores it and it
            // can never be GC'd as an entry itself.
            let secs = std::time::SystemTime::now()
                .duration_since(std::time::SystemTime::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0);
            let line = format!("{secs} evicted {evicted} kept {kept}\n");
            use std::io::Write;
            if let Ok(mut f) =
                std::fs::File::options().create(true).append(true).open(dir.join(EVICTION_LOG))
            {
                let _ = f.write_all(line.as_bytes());
            }
        }
        Ok((kept, evicted))
    }

    /// Scan the disk tier for `ming cache-stats`: entry count, on-disk
    /// bytes, negative-verdict count and unreadable files. Reads every
    /// entry file once; no cache state is touched (no hit/miss/corrupt
    /// counting — this is inspection, not lookup).
    pub fn disk_stats(&self) -> Result<DiskStats> {
        let Some(dir) = &self.dir else {
            return Ok(DiskStats::default());
        };
        let mut ds = DiskStats::default();
        for e in std::fs::read_dir(dir)
            .with_context(|| format!("reading design cache dir {}", dir.display()))?
        {
            let e = e?;
            let path = e.path();
            if path.extension().and_then(|x| x.to_str()) != Some("json") {
                continue;
            }
            ds.bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
            match std::fs::read_to_string(&path).map_err(anyhow::Error::from).and_then(|t| {
                entry_from_json(&t)
            }) {
                Ok(CachedDesign::Infeasible { .. }) => {
                    ds.entries += 1;
                    ds.infeasible += 1;
                }
                Ok(_) => ds.entries += 1,
                Err(_) => ds.unreadable += 1,
            }
        }
        Ok(ds)
    }

    /// The GC history lines [`Self::gc`] appended (`"<unix-secs> evicted
    /// <n> kept <k>"`, oldest first). Empty for in-memory caches or
    /// when no eviction ever happened.
    pub fn eviction_history(&self) -> Vec<String> {
        let Some(dir) = &self.dir else {
            return Vec::new();
        };
        std::fs::read_to_string(dir.join(EVICTION_LOG))
            .map(|t| t.lines().map(str::to_string).collect())
            .unwrap_or_default()
    }
}

/// GC history file inside a disk cache dir (non-`.json` so the entry
/// scans skip it).
const EVICTION_LOG: &str = "evictions.log";

impl Drop for DesignCache {
    /// Final metrics sync: commands that never read `stats()` — errors,
    /// early exits, cache-enabled paths without a summary line — still
    /// leave the global `cache.*` registry equal to the cache's own
    /// lifetime counters, so `--profile` deltas are trustworthy
    /// everywhere. (The CLI drops its cache `Arc` when the command
    /// scope ends, before the profile table renders.)
    fn drop(&mut self) {
        self.flush_metrics();
    }
}

// ---- JSON encoding ------------------------------------------------------

fn timing_to_json(t: &NodeTiming) -> Json {
    Json::Arr(vec![
        Json::Num(t.mac_lanes as f64),
        Json::Num(t.ii as f64),
        Json::Num(t.depth as f64),
        Json::Num(t.unroll_par as f64),
        Json::Num(t.unroll_red as f64),
    ])
}

fn timing_from_json(v: &Json) -> Result<NodeTiming> {
    let a = v.as_arr()?;
    ensure!(a.len() == 5, "timing must have 5 fields, got {}", a.len());
    let f = |i: usize| -> Result<u64> { Ok(a[i].as_usize()? as u64) };
    Ok(NodeTiming {
        mac_lanes: f(0)?,
        ii: f(1)?,
        depth: f(2)?,
        unroll_par: f(3)?,
        unroll_red: f(4)?,
    })
}

/// Serialize an entry to its on-disk JSON document.
pub fn entry_to_json(e: &CachedDesign) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("version".into(), Json::Num(CACHE_VERSION as f64));
    let timings = |ts: &[NodeTiming]| Json::Arr(ts.iter().map(timing_to_json).collect());
    match e {
        CachedDesign::Flat { timings: ts } => {
            m.insert("kind".into(), Json::Str("flat".into()));
            m.insert("timings".into(), timings(ts));
        }
        CachedDesign::Tiled { rows, cols, timings: ts } => {
            m.insert("kind".into(), Json::Str("tiled".into()));
            m.insert("rows".into(), Json::Num(*rows as f64));
            m.insert("cols".into(), Json::Num(*cols as f64));
            m.insert("timings".into(), timings(ts));
        }
        CachedDesign::Infeasible { msg } => {
            m.insert("kind".into(), Json::Str("infeasible".into()));
            m.insert("msg".into(), Json::Str(msg.clone()));
        }
    }
    Json::Obj(m)
}

/// Parse an on-disk entry; any deviation from the schema is an error
/// (which the cache treats as a miss).
pub fn entry_from_json(text: &str) -> Result<CachedDesign> {
    let doc = crate::ir::json::parse(text)?;
    ensure!(
        doc.get("version")?.as_usize()? as u64 == CACHE_VERSION,
        "cache entry has an unknown version"
    );
    let kind = doc.get("kind")?.as_str()?.to_string();
    if kind == "infeasible" {
        return Ok(CachedDesign::Infeasible { msg: doc.get("msg")?.as_str()?.to_string() });
    }
    let timings: Vec<NodeTiming> = doc
        .get("timings")?
        .as_arr()?
        .iter()
        .map(timing_from_json)
        .collect::<Result<_>>()?;
    ensure!(!timings.is_empty(), "cache entry has no timings");
    match kind.as_str() {
        "flat" => Ok(CachedDesign::Flat { timings }),
        "tiled" => Ok(CachedDesign::Tiled {
            rows: doc.get("rows")?.as_usize()?,
            cols: doc.get("cols")?.as_usize()?,
            timings,
        }),
        other => bail!("unknown cache entry kind {other:?}"),
    }
}

// ---- applying cached solutions ------------------------------------------

/// Apply a cached per-node timing assignment to a freshly built design,
/// reproducing exactly what `dse::ilp::solve` would have left behind:
/// timings set, buffers re-derived, FIFOs sized. Validates the timings
/// against each node's unroll lattice and the result against the device
/// budget, so a stale or foreign entry fails here (and the caller
/// degrades to a real solve) instead of mis-compiling.
pub fn apply_cached_timings(
    design: &mut Design,
    timings: &[NodeTiming],
    cfg: &DseConfig,
) -> Result<DseSolution> {
    ensure!(
        timings.len() == design.nodes.len(),
        "cached entry has {} timings for {} nodes",
        timings.len(),
        design.nodes.len()
    );
    // Reconstruct the solution's per-node candidates (and validate each
    // timing actually lies on the node's divisor lattice) before any
    // mutation, while the pristine design can still price them.
    let (chosen, objective) = {
        let model = ResourceModel::new(design);
        let mut chosen = Vec::with_capacity(timings.len());
        let mut objective = 0u64;
        for (nid, t) in timings.iter().enumerate() {
            ensure!(
                unroll_timings(design, nid).iter().any(|u| u == t),
                "cached timing for node {} is not on its unroll lattice",
                design.nodes[nid].name
            );
            let mut node = design.nodes[nid].clone();
            node.timing = *t;
            let cycles = node.standalone_cycles();
            objective += cycles;
            chosen.push(Candidate {
                unroll_par: t.unroll_par,
                unroll_red: t.unroll_red,
                timing: *t,
                cycles,
                res: model.node_vec(nid, t),
            });
        }
        (chosen, objective)
    };
    for (node, t) in design.nodes.iter_mut().zip(timings) {
        node.timing = *t;
    }
    refresh_buffers(design);
    size_fifos(design);
    let resources = ResourceModel::as_built(design);
    ensure!(
        resources.dsp <= cfg.device.dsp && resources.bram() <= cfg.device.bram18k,
        "cached design needs {} DSP / {} BRAM but device {} allows {} / {}",
        resources.dsp,
        resources.bram(),
        cfg.device.name,
        cfg.device.dsp,
        cfg.device.bram18k
    );
    Ok(DseSolution {
        chosen,
        objective,
        dsp_used: resources.dsp,
        bram_used: resources.bram(),
        resources,
        nodes_explored: 0,
    })
}

/// Rebuild a full [`Compiled`] outcome from a cached entry for graph
/// `g`: the cheap deterministic tail of the pipeline (build + apply),
/// with **zero** ILP solves and zero grid-lattice search.
pub fn rebuild_compiled(
    g: &ModelGraph,
    cfg: &DseConfig,
    entry: &CachedDesign,
) -> Result<Compiled> {
    match entry {
        CachedDesign::Flat { timings } => {
            let mut design = build_streaming_design(g)?;
            let sol = apply_cached_timings(&mut design, timings, cfg)?;
            Ok(Compiled::Flat(Box::new(design), sol))
        }
        CachedDesign::Tiled { rows, cols, timings } => {
            let grid = TileGrid::build(g, *rows, *cols)?;
            let mut cell = build_cell_design(g, grid.h.local_in, grid.w.local_in)?;
            let out = &cell.graph.outputs()[0].ty.shape;
            ensure!(
                out[0] == grid.h.local_out && out[1] == grid.w.local_out,
                "cached grid {}x{} no longer matches the cell graph",
                rows,
                cols
            );
            let solution = apply_cached_timings(&mut cell, timings, cfg)?;
            Ok(Compiled::Tiled(Box::new(TiledCompilation {
                graph: g.clone(),
                grid,
                cell,
                solution,
            })))
        }
        // a negative entry describes *no* design — the fallback handles
        // it before calling here; anyone else treats it as unusable
        CachedDesign::Infeasible { msg } => {
            bail!("cached verdict: flat DSE infeasible ({msg})")
        }
    }
}

/// The cache entry describing an already-compiled outcome.
pub fn compiled_entry(c: &Compiled) -> CachedDesign {
    match c {
        Compiled::Flat(d, _) => {
            CachedDesign::Flat { timings: d.nodes.iter().map(|n| n.timing).collect() }
        }
        Compiled::Tiled(tc) => CachedDesign::Tiled {
            rows: tc.grid.rows(),
            cols: tc.grid.cols(),
            timings: tc.cell.nodes.iter().map(|n| n.timing).collect(),
        },
    }
}

/// Solve one design's DSE through the config's cache: a hit applies the
/// cached timings (no ILP run), a miss runs the real solver and stores
/// the solution under the design's graph fingerprint. With no cache
/// configured this is exactly [`crate::dse::ilp::solve`].
///
/// **Negative caching**: an infeasible solve stores a
/// [`CachedDesign::Infeasible`] verdict under the same fingerprint, and
/// a later lookup returns the original error without re-running the
/// branch-and-bound proof. The grid-lattice search probes many cell
/// geometries that *don't* fit before finding one that does — on a
/// warm cache those dead ends now cost a map lookup each.
///
/// This is the entry point the tile-grid search uses per candidate
/// cell: identical cell geometries — which recur across grid candidates
/// of one search *and* across workloads sharing a chain shape — are
/// solved once ever, feasible or not.
pub fn solve_cached(design: &mut Design, cfg: &DseConfig) -> Result<DseSolution> {
    let Some(cache) = &cfg.cache else {
        return solve(design, cfg);
    };
    let fp = problem_fingerprint(&design.graph, &cfg.device);
    // A Tiled whole-outcome entry can share this fingerprint (a cell
    // graph identical to a whole workload compiled via the fallback).
    // It cannot satisfy a flat solve, but it must not be *clobbered*
    // by the negative verdict below either — overwriting it would make
    // the next fallback compile of that workload redo its whole grid
    // search.
    let mut preserve_entry = false;
    if let Some(entry) = cache.lookup(fp) {
        match &entry {
            CachedDesign::Flat { timings } => {
                match apply_cached_timings(design, timings, cfg) {
                    Ok(sol) => return Ok(sol),
                    Err(_) => cache.note_corrupt(),
                }
            }
            CachedDesign::Infeasible { msg } => {
                bail!("infeasible (cached verdict): {msg}")
            }
            CachedDesign::Tiled { .. } => {
                cache.note_corrupt();
                preserve_entry = true;
            }
        }
    }
    cache.count_solve();
    match solve(design, cfg) {
        Ok(sol) => {
            cache.insert(
                fp,
                CachedDesign::Flat {
                    timings: design.nodes.iter().map(|n| n.timing).collect(),
                },
            );
            Ok(sol)
        }
        Err(e) => {
            if !preserve_entry {
                cache.insert(fp, CachedDesign::Infeasible { msg: format!("{e:#}") });
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use std::sync::Arc;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("ming-cache-test-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn entry_json_roundtrip() {
        let flat = CachedDesign::Flat {
            timings: vec![
                NodeTiming { mac_lanes: 576, ii: 1, depth: 14, unroll_par: 8, unroll_red: 72 },
                NodeTiming::default(),
            ],
        };
        let tiled = CachedDesign::Tiled {
            rows: 2,
            cols: 4,
            timings: vec![NodeTiming::default()],
        };
        let infeasible = CachedDesign::Infeasible {
            msg: "infeasible: minimal design needs 9 DSP, device allows 0".into(),
        };
        for e in [flat, tiled, infeasible] {
            let text = entry_to_json(&e).render();
            assert_eq!(entry_from_json(&text).unwrap(), e);
        }
    }

    #[test]
    fn corrupt_entries_parse_to_errors_not_panics() {
        for text in [
            "",
            "{",
            "null",
            r#"{"version":1}"#,
            r#"{"version":99,"kind":"flat","timings":[[1,1,4,1,1]]}"#,
            r#"{"version":1,"kind":"flat","timings":[]}"#,
            r#"{"version":1,"kind":"warped","timings":[[1,1,4,1,1]]}"#,
            r#"{"version":1,"kind":"flat","timings":[[1,1,4,1]]}"#,
            r#"{"version":1,"kind":"tiled","timings":[[1,1,4,1,1]]}"#,
            r#"{"version":1,"kind":"infeasible"}"#,
        ] {
            assert!(entry_from_json(text).is_err(), "{text:?} must not parse");
        }
    }

    #[test]
    fn hit_miss_and_store_counters() {
        let c = DesignCache::in_memory();
        assert!(c.lookup(42).is_none());
        c.insert(42, CachedDesign::Flat { timings: vec![NodeTiming::default()] });
        assert!(c.lookup(42).is_some());
        assert!(c.lookup(43).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.stores), (1, 2, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn note_corrupt_demotes_the_hit_to_a_miss() {
        let c = DesignCache::in_memory();
        c.insert(1, CachedDesign::Flat { timings: vec![NodeTiming::default()] });
        assert!(c.lookup(1).is_some()); // counted as a hit...
        c.note_corrupt(); // ...until it turns out to be unusable
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.corrupt), (0, 1, 1));
        assert_eq!(s.hit_rate(), 0.0, "unusable entries serve nothing");
    }

    #[test]
    fn disk_cache_roundtrips_across_instances() {
        let dir = tmp_dir("roundtrip");
        let timing = NodeTiming { mac_lanes: 8, ii: 1, depth: 7, unroll_par: 8, unroll_red: 1 };
        let entry = CachedDesign::Tiled { rows: 1, cols: 4, timings: vec![timing] };
        {
            let c = DesignCache::at_dir(&dir).unwrap();
            c.insert(7, entry.clone());
        }
        // a *fresh* instance (empty memory tier) must find it on disk
        let c2 = DesignCache::at_dir(&dir).unwrap();
        assert_eq!(c2.lookup(7), Some(entry));
        assert_eq!(c2.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_disk_entry_degrades_to_miss() {
        let dir = tmp_dir("corrupt");
        let c = DesignCache::at_dir(&dir).unwrap();
        std::fs::write(dir.join(format!("{}.json", hex(9))), "{definitely not json").unwrap();
        assert!(c.lookup(9).is_none(), "corrupt file must read as a miss");
        let s = c.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.corrupt, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn solve_cached_hits_reproduce_the_solution() {
        let g = models::conv_relu(32, 8, 8);
        let cache = Arc::new(DesignCache::in_memory());
        let cfg = DseConfig::new(DeviceSpec::kv260()).with_cache(cache.clone());

        let mut fresh = build_streaming_design(&g).unwrap();
        let sol1 = solve_cached(&mut fresh, &cfg).unwrap();
        assert_eq!(cache.stats().solves, 1);

        let mut cached = build_streaming_design(&g).unwrap();
        let sol2 = solve_cached(&mut cached, &cfg).unwrap();
        assert_eq!(cache.stats().solves, 1, "second solve must be a pure hit");
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(sol1.objective, sol2.objective);
        assert_eq!(sol1.resources, sol2.resources);
        assert_eq!(sol2.nodes_explored, 0, "a hit explores nothing");
        // byte-identical designs, the determinism property
        assert_eq!(format!("{fresh:?}"), format!("{cached:?}"));
    }

    #[test]
    fn infeasible_solves_are_negative_cached() {
        // A DSP-starved conv has no feasible flat point. The first
        // solve_cached pays the branch-and-bound proof and stores the
        // verdict; the second returns the same error as a pure hit.
        let g = models::conv_relu(32, 8, 8);
        let cache = Arc::new(DesignCache::in_memory());
        let cfg = DseConfig::new(DeviceSpec::kv260().with_dsp_limit(0)).with_cache(cache.clone());

        let mut d1 = build_streaming_design(&g).unwrap();
        let e1 = solve_cached(&mut d1, &cfg).unwrap_err();
        assert_eq!(cache.stats().solves, 1);
        assert_eq!(cache.stats().stores, 1, "verdict must be stored");

        let mut d2 = build_streaming_design(&g).unwrap();
        let e2 = solve_cached(&mut d2, &cfg).unwrap_err();
        let s = cache.stats();
        assert_eq!(s.solves, 1, "cached verdict must skip the solver");
        assert_eq!(s.hits, 1);
        assert!(format!("{e2:#}").contains("cached verdict"), "{e2:#}");
        // the original reason is preserved
        assert!(format!("{e2:#}").contains(&format!("{e1:#}")), "{e1:#} vs {e2:#}");

        // a feasible budget is a different fingerprint: unaffected
        let ok_cfg = DseConfig::new(DeviceSpec::kv260()).with_cache(cache.clone());
        let mut d3 = build_streaming_design(&g).unwrap();
        solve_cached(&mut d3, &ok_cfg).unwrap();
    }

    #[test]
    fn gc_keeps_newest_entries_and_counts_evictions() {
        let dir = tmp_dir("gc");
        let c = DesignCache::at_dir(&dir).unwrap();
        let entry = CachedDesign::Flat { timings: vec![NodeTiming::default()] };
        for fp in 0..6u64 {
            c.insert(fp, entry.clone());
            // distinct mtimes so LRU order is deterministic
            let t = std::time::SystemTime::now() - std::time::Duration::from_secs(600 - fp);
            let f = std::fs::File::options()
                .append(true)
                .open(dir.join(format!("{}.json", hex(fp))))
                .unwrap();
            f.set_modified(t).unwrap();
        }
        // a tmp straggler must not count as an entry
        std::fs::write(dir.join("stray.tmp.1.2"), "x").unwrap();
        let (kept, evicted) = c.gc(2).unwrap();
        assert_eq!((kept, evicted), (2, 4));
        assert_eq!(c.stats().evicted, 4);
        // the two newest fingerprints survive on disk
        let fresh = DesignCache::at_dir(&dir).unwrap();
        assert!(fresh.lookup(5).is_some());
        assert!(fresh.lookup(4).is_some());
        assert!(fresh.lookup(0).is_none(), "oldest entry must be gone");
        // the sweep is recorded in the history log (and the log itself
        // is invisible to the entry scan)
        let hist = c.eviction_history();
        assert_eq!(hist.len(), 1);
        assert!(hist[0].contains("evicted 4 kept 2"), "{hist:?}");
        // idempotent: nothing more to evict, no new history line
        assert_eq!(c.gc(2).unwrap(), (2, 0));
        assert_eq!(c.eviction_history().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_metrics_delta_syncs_the_registry_once() {
        let m = crate::obs::metrics::global();
        let (h0, s0) = (m.get("cache.hits"), m.get("cache.stores"));
        let c = DesignCache::in_memory();
        c.insert(11, CachedDesign::Flat { timings: vec![NodeTiming::default()] });
        assert!(c.lookup(11).is_some());
        assert!(c.lookup(12).is_none());
        let st = c.stats(); // flushes
        assert_eq!((st.hits, st.misses, st.stores), (1, 1, 1));
        // monotone `>=`: the registry is global and other tests run
        // concurrently — we can only pin our own contribution's floor
        assert!(m.get("cache.hits") >= h0 + 1);
        assert!(m.get("cache.stores") >= s0 + 1);
        // a second flush with no new activity adds nothing from *this*
        // cache: its internal delta base caught up
        assert_eq!(*c.flushed.lock().unwrap(), st);
        let again = c.stats();
        assert_eq!(again, st, "totals are stable across flushes");
    }

    #[test]
    fn dropping_a_cache_flushes_unsynced_counters() {
        // The regression S-fix: `simulate` (and every error path) drops
        // the cache without printing a summary, so only the Drop flush
        // gets its counters into the registry.
        let m = crate::obs::metrics::global();
        let s0 = m.get("cache.stores");
        {
            let c = DesignCache::in_memory();
            c.insert(21, CachedDesign::Flat { timings: vec![NodeTiming::default()] });
            // no stats()/summary() call — Drop must sync
        }
        assert!(m.get("cache.stores") >= s0 + 1, "Drop flush missing");
    }

    #[test]
    fn disk_stats_census_entries_bytes_and_verdicts() {
        let dir = tmp_dir("disk-stats");
        let c = DesignCache::at_dir(&dir).unwrap();
        assert_eq!(c.disk_stats().unwrap(), DiskStats::default(), "fresh dir is empty");
        c.insert(1, CachedDesign::Flat { timings: vec![NodeTiming::default()] });
        c.insert(2, CachedDesign::Infeasible { msg: "no feasible point".into() });
        std::fs::write(dir.join(format!("{}.json", hex(3))), "{torn").unwrap();
        std::fs::write(dir.join("stray.tmp.1.2"), "x").unwrap(); // not an entry
        let ds = c.disk_stats().unwrap();
        assert_eq!(ds.entries, 2);
        assert_eq!(ds.infeasible, 1);
        assert_eq!(ds.unreadable, 1);
        assert!(ds.bytes > 0);
        // inspection leaves lookup counters untouched
        assert_eq!(c.stats().misses, 0);
        assert_eq!(c.stats().corrupt, 0);
        // in-memory caches report an empty census
        assert_eq!(DesignCache::in_memory().disk_stats().unwrap(), DiskStats::default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lattice_validation_rejects_foreign_timings() {
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        let cfg = DseConfig::new(DeviceSpec::kv260());
        // unroll 5 divides neither the 8-wide parallel trip nor 72
        let bogus = vec![
            NodeTiming { mac_lanes: 5, ii: 1, depth: 6, unroll_par: 5, unroll_red: 1 },
            NodeTiming::default(),
        ];
        assert!(apply_cached_timings(&mut d, &bogus, &cfg).is_err());
        // wrong arity is rejected before anything is applied
        assert!(apply_cached_timings(&mut d, &[NodeTiming::default()], &cfg).is_err());
    }

    #[test]
    fn budget_validation_rejects_oversized_cached_designs() {
        // A full-unroll timing is on the lattice but cannot fit a
        // 1-DSP device: the cached apply must refuse, so a cache
        // populated against a big device never leaks designs onto a
        // small one (their fingerprints differ anyway — this is the
        // defense-in-depth layer).
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        let full = solve(&mut d.clone(), &DseConfig::new(DeviceSpec::kv260())).unwrap();
        let timings: Vec<NodeTiming> = full.chosen.iter().map(|c| c.timing).collect();
        let tiny = DseConfig::new(DeviceSpec::kv260().with_dsp_limit(1));
        assert!(apply_cached_timings(&mut d, &timings, &tiny).is_err());
    }
}
