//! The compile service: batch sweeps over kernels × frameworks × sizes,
//! shardable across processes and backed by the content-addressed
//! design cache.
//!
//! The job list of a sweep is **deterministic** (workloads × frameworks
//! in declaration order), so a global sequence number identifies a job
//! across processes. Sharding partitions that list round-robin
//! (`seq % count == index`): every shard sees an interleaved slice of
//! the sweep, the shards are disjoint, and their union is exactly the
//! unsharded job list — which is what lets `merge-sweep` stitch shard
//! spools back into row-identical reports.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::baselines::framework::FrameworkKind;
use crate::ir::builder::models;
use crate::resources::device::DeviceSpec;

use super::cache::DesignCache;
use super::job::{CompileJob, JobResult};
use super::sched::{self, SchedHandle};

/// Sweep specification.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// `(kernel, size)` workloads; defaults to the paper's Table II set.
    pub workloads: Vec<(String, usize)>,
    pub frameworks: Vec<FrameworkKind>,
    pub device: DeviceSpec,
    pub estimate_only: bool,
}

impl SweepConfig {
    pub fn table2(device: DeviceSpec) -> Self {
        Self {
            workloads: models::table2_workloads()
                .into_iter()
                .map(|(k, s)| (k.to_string(), s))
                .collect(),
            frameworks: FrameworkKind::all().to_vec(),
            device,
            estimate_only: false,
        }
    }
}

/// One shard of a sweep: this process owns every job whose global
/// sequence number is `index` modulo `count`. `Shard::full()` (0/1) is
/// the unsharded sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub count: usize,
}

impl Shard {
    /// The whole sweep in one process.
    pub fn full() -> Self {
        Shard { index: 0, count: 1 }
    }

    /// Parse the CLI form `i/n` (e.g. `0/2`).
    pub fn parse(s: &str) -> Result<Self> {
        let Some((i, n)) = s.split_once('/') else {
            bail!("--shard must be i/n (e.g. 0/2), got {s:?}");
        };
        let (index, count): (usize, usize) = (i.trim().parse()?, n.trim().parse()?);
        ensure!(count >= 1, "shard count must be >= 1");
        ensure!(index < count, "shard index {index} out of range for {count} shards");
        Ok(Shard { index, count })
    }

    /// Does this shard own global job `seq`?
    pub fn owns(&self, seq: usize) -> bool {
        seq % self.count == self.index
    }

    pub fn is_full(&self) -> bool {
        self.count == 1
    }
}

impl std::fmt::Display for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// How a shard's jobs are ordered for submission. Either way, results
/// are restored to global sequence order before reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobOrder {
    /// Makespan-aware longest-first (LPT): predicted cost descending
    /// ([`CompileJob::predicted_cost`] — lattice volume, cache-hit
    /// prediction, MAC count), ties broken by the locality key. Starting
    /// the expensive jobs first keeps the sweep tail short: the cheap
    /// jobs pack around the stragglers, and whatever imbalance remains
    /// is absorbed by work-stealing of the stragglers' nested tasks.
    #[default]
    Lpt,
    /// Locality order (kernel, size, framework) — the pre-LPT behaviour,
    /// kept as the measurable baseline for `benches/sched_perf.rs`.
    Submission,
}

/// Runs sweeps over the process-wide work-stealing scheduler
/// ([`super::sched`]) and collects results.
pub struct CompileService {
    workers: usize,
    /// Explicit scheduler for tests/benches; `None` = the global one.
    sched: Option<SchedHandle>,
    order: JobOrder,
    /// Per-job [`sched::with_worker_cap`] pin, emulating the old
    /// "nested sites solve serially" behaviour (bench baseline only).
    nested_cap: Option<usize>,
    cache: Option<Arc<DesignCache>>,
    /// Warm-start state shared by every MING job this service runs
    /// (node-front memoization + incumbent seeding, `dse::warmstart`).
    /// Always on: it is provably solution-invariant, purely in-memory,
    /// and a sweep is exactly the workload it pays off on.
    warm: Arc<crate::dse::WarmStart>,
}

impl Default for CompileService {
    fn default() -> Self {
        Self::new(sched::default_size())
    }
}

impl CompileService {
    /// A service fanning up to `workers` jobs at a time into the global
    /// scheduler. `1` runs jobs serially inline, with nested parallelism
    /// capped to 1 as well — the exact serial paths end to end.
    pub fn new(workers: usize) -> Self {
        Self {
            workers: workers.max(1),
            sched: None,
            order: JobOrder::default(),
            nested_cap: None,
            cache: None,
            warm: Arc::new(crate::dse::WarmStart::new()),
        }
    }

    /// Attach a design cache shared by every job of every sweep this
    /// service runs (and, when disk-backed, by other processes too).
    pub fn with_cache(mut self, cache: Arc<DesignCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Submit into an explicit scheduler instead of the global one
    /// (tests and benches; the width actually used is the scheduler's).
    pub fn with_scheduler(mut self, sched: SchedHandle) -> Self {
        self.workers = sched.workers();
        self.sched = Some(sched);
        self
    }

    /// Override the job submission order (default [`JobOrder::Lpt`]).
    pub fn with_job_order(mut self, order: JobOrder) -> Self {
        self.order = order;
        self
    }

    /// Pin every job's *nested* parallelism ([`sched::current_workers`]
    /// as seen inside the job) to `n`. `benches/sched_perf.rs` uses
    /// `1` to reproduce the old chunked/pinned sweep as its baseline.
    pub fn with_nested_worker_cap(mut self, n: usize) -> Self {
        self.nested_cap = Some(n.max(1));
        self
    }

    pub fn cache(&self) -> Option<&Arc<DesignCache>> {
        self.cache.as_ref()
    }

    /// The service's shared warm-start state (one per service lifetime,
    /// spanning every sweep it runs).
    pub fn warm(&self) -> &Arc<crate::dse::WarmStart> {
        &self.warm
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The scheduler this service submits into.
    fn sched(&self) -> SchedHandle {
        self.sched.clone().unwrap_or_else(|| sched::global().handle())
    }

    /// Stable identity of a sweep: the device's capacities and name,
    /// the estimate flag, and the deterministic job list. Spool records
    /// carry it so resume and `merge-sweep` refuse to mix records from
    /// different sweeps that happen to share a spool directory (same
    /// shard filename, overlapping sequence numbers).
    pub fn sweep_id(cfg: &SweepConfig) -> u64 {
        use crate::ir::fingerprint::Fnv64;
        let mut h = Fnv64::new();
        h.write_u8(cfg.estimate_only as u8);
        let d = &cfg.device;
        for v in [d.bram18k, d.dsp, d.lut, d.lutram, d.ff] {
            h.write_u64(v);
        }
        h.write_str(&d.name);
        for j in Self::jobs(cfg) {
            h.write_str(&j.id());
        }
        h.finish()
    }

    /// The deterministic global job list of a sweep. Sequence numbers
    /// (= indices into this list) are stable across processes, which is
    /// the contract sharding and spool resume depend on.
    pub fn jobs(cfg: &SweepConfig) -> Vec<CompileJob> {
        let mut jobs = Vec::with_capacity(cfg.workloads.len() * cfg.frameworks.len());
        for (kernel, size) in &cfg.workloads {
            for &fw in &cfg.frameworks {
                jobs.push(CompileJob {
                    kernel: kernel.clone(),
                    size: *size,
                    framework: fw,
                    device: cfg.device.clone(),
                    estimate_only: cfg.estimate_only,
                });
            }
        }
        jobs
    }

    /// Execute every (workload × framework) job; failed jobs yield a
    /// `JobResult`-free error string, successful ones a full result.
    pub fn run_sweep(&self, cfg: &SweepConfig) -> Vec<Result<JobResult, String>> {
        self.run_shard(cfg, Shard::full(), &BTreeSet::new())
            .into_iter()
            .map(|(_, r)| r)
            .collect()
    }

    /// Execute one shard of a sweep, skipping the global sequence
    /// numbers in `done` (jobs already present in a spool). Results are
    /// tagged with their global sequence numbers, in global order.
    pub fn run_shard(
        &self,
        cfg: &SweepConfig,
        shard: Shard,
        done: &BTreeSet<usize>,
    ) -> Vec<(usize, Result<JobResult, String>)> {
        self.run_shard_streaming(cfg, shard, done, |_, _| {})
    }

    /// Like [`Self::run_shard`], invoking `on_done(seq, outcome)` as
    /// each job finishes (completion order, coordinator thread) — the
    /// spool appends records through this hook so a crash loses at most
    /// the jobs in flight, keeping sweeps genuinely resumable.
    pub fn run_shard_streaming(
        &self,
        cfg: &SweepConfig,
        shard: Shard,
        done: &BTreeSet<usize>,
        mut on_done: impl FnMut(usize, &Result<JobResult, String>),
    ) -> Vec<(usize, Result<JobResult, String>)> {
        // Trace envelope for the whole shard; per-job spans open inside
        // `run_with` on the worker threads.
        let _sp = crate::obs::span_with("sweep", || format!("shard {shard}"));
        let mut mine: Vec<(usize, CompileJob)> = Self::jobs(cfg)
            .into_iter()
            .enumerate()
            .filter(|(seq, _)| shard.owns(*seq) && !done.contains(seq))
            .collect();
        // Submission order is invisible in every rendered artifact —
        // results are re-sorted to global sequence order below, spool
        // records carry explicit seqs, and each job's outcome is
        // order-independent (the warm tier is solution-invariant) — so
        // ordering reorders wall-clock only. Both sorts are stable:
        // equal keys keep sweep order.
        //
        // The locality key groups structurally-adjacent problems (same
        // kernel, then neighboring sizes) so warm-start front hits and
        // incumbent seeds land while the neighbor's entry is hot. LPT
        // (the default) additionally puts predicted-expensive jobs
        // first: a straggler started last runs alone past the sweep
        // tail, started first it overlaps everything else — and the
        // locality key still breaks cost ties, keeping the warmth.
        let locality = |a: &CompileJob, b: &CompileJob| {
            (&a.kernel, a.size, a.framework.name()).cmp(&(&b.kernel, b.size, b.framework.name()))
        };
        match self.order {
            JobOrder::Submission => mine.sort_by(|(_, a), (_, b)| locality(a, b)),
            JobOrder::Lpt => {
                let cache = self.cache.as_deref();
                let mut costed: Vec<(u64, usize, CompileJob)> = mine
                    .into_iter()
                    .map(|(seq, j)| (j.predicted_cost(cache), seq, j))
                    .collect();
                costed.sort_by(|(ca, _, a), (cb, _, b)| {
                    cb.cmp(ca).then_with(|| locality(a, b))
                });
                mine = costed.into_iter().map(|(_, seq, j)| (seq, j)).collect();
            }
        }
        let seqs: Vec<usize> = mine.iter().map(|(s, _)| *s).collect();
        // A 1-worker service caps nested parallelism too: the exact
        // serial code paths end to end, whatever the global scheduler's
        // width. Benches pin other values to reproduce old behaviours.
        let cap = match self.nested_cap {
            Some(n) => Some(n),
            None if self.workers <= 1 => Some(1),
            None => None,
        };
        let closures: Vec<Box<dyn FnOnce() -> Result<JobResult, String> + Send>> = mine
            .into_iter()
            .map(|(_, j)| {
                let cache = self.cache.clone();
                let warm = Arc::clone(&self.warm);
                Box::new(move || {
                    let run = || {
                        j.run_warm(cache.as_ref(), Some(&warm))
                            .map_err(|e| format!("{}: {e:#}", j.id()))
                    };
                    match cap {
                        Some(n) => sched::with_worker_cap(n, run),
                        None => run(),
                    }
                }) as _
            })
            .collect();
        let mut out: Vec<(usize, Result<JobResult, String>)> = if self.workers <= 1 {
            // Serial inline on the coordinator thread (panic isolation
            // intact), never touching — or instantiating — the pool.
            closures
                .into_iter()
                .enumerate()
                .map(|(i, job)| {
                    let r = match sched::run_caught(job) {
                        Ok(inner) => inner,
                        Err(panic) => Err(panic),
                    };
                    on_done(seqs[i], &r);
                    (seqs[i], r)
                })
                .collect()
        } else {
            self.sched()
                .run_all_streaming(closures, |i, r| match r {
                    Ok(inner) => on_done(seqs[i], inner),
                    Err(panic) => on_done(seqs[i], &Err(panic.clone())),
                })
                .into_iter()
                .map(|(i, r)| {
                    let outcome = match r {
                        Ok(inner) => inner,
                        Err(panic) => Err(panic),
                    };
                    (seqs[i], outcome)
                })
                .collect()
        };
        // Restore the documented contract: results in global seq order,
        // regardless of the submission order above.
        out.sort_by_key(|(seq, _)| *seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_runs_all_cells() {
        let cfg = SweepConfig {
            workloads: vec![("conv_relu".into(), 16), ("linear".into(), 0)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let svc = CompileService::new(2);
        let results = svc.run_sweep(&cfg);
        assert_eq!(results.len(), 4);
        for r in &results {
            let r = r.as_ref().unwrap();
            assert!(r.cycles > 0, "{}", r.job.id());
        }
    }

    #[test]
    fn sweep_tiles_oversized_workloads_automatically() {
        // An oversized VGG block rides through the sweep machinery: the
        // MING cell comes back grid-tiled (tiles > 1) instead of erroring
        // out the way the untiled DSE would.
        let cfg = SweepConfig {
            workloads: vec![("vgg3".into(), 512)],
            frameworks: vec![FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let results = CompileService::new(1).run_sweep(&cfg);
        assert_eq!(results.len(), 1);
        let r = results[0].as_ref().unwrap();
        assert!(r.tiles >= 2, "expected a tiled cell, got {} tiles", r.tiles);
        assert!(r.util.bram18k <= r.util.device.bram18k);
    }

    #[test]
    fn ming_beats_vanilla_in_sweep() {
        let cfg = SweepConfig {
            workloads: vec![("conv_relu".into(), 32)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let results = CompileService::new(2).run_sweep(&cfg);
        let cycles: Vec<u64> = results.iter().map(|r| r.as_ref().unwrap().cycles).collect();
        assert!(cycles[1] * 50 < cycles[0], "ming {} vs vanilla {}", cycles[1], cycles[0]);
    }

    #[test]
    fn shard_parse_and_ownership() {
        let s = Shard::parse("1/3").unwrap();
        assert_eq!((s.index, s.count), (1, 3));
        assert!(!s.is_full());
        assert!(s.owns(1) && s.owns(4));
        assert!(!s.owns(0) && !s.owns(2));
        assert_eq!(s.to_string(), "1/3");
        assert!(Shard::parse("3/3").is_err(), "index out of range");
        assert!(Shard::parse("0/0").is_err(), "zero shards");
        assert!(Shard::parse("nope").is_err());
        assert!(Shard::full().owns(17), "the full shard owns everything");
    }

    #[test]
    fn shards_partition_the_job_list() {
        let cfg = SweepConfig {
            workloads: vec![("conv_relu".into(), 16), ("linear".into(), 0)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let svc = CompileService::new(2);
        let all: Vec<usize> =
            (0..CompileService::jobs(&cfg).len()).collect();
        let mut seen = Vec::new();
        for index in 0..2 {
            let part = svc.run_shard(&cfg, Shard { index, count: 2 }, &BTreeSet::new());
            for (seq, r) in part {
                assert!(r.is_ok(), "seq {seq}");
                seen.push(seq);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, all, "shards must partition the sweep exactly");
    }

    #[test]
    fn sweep_id_distinguishes_sweeps() {
        let base = SweepConfig {
            workloads: vec![("conv_relu".into(), 16)],
            frameworks: vec![FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let id = CompileService::sweep_id(&base);
        assert_eq!(id, CompileService::sweep_id(&base.clone()), "stable");
        let mut other = base.clone();
        other.estimate_only = false;
        assert_ne!(id, CompileService::sweep_id(&other), "estimate flag");
        let mut other = base.clone();
        other.device = DeviceSpec::zcu104();
        assert_ne!(id, CompileService::sweep_id(&other), "device");
        let mut other = base.clone();
        other.workloads.push(("linear".into(), 0));
        assert_ne!(id, CompileService::sweep_id(&other), "job list");
    }

    #[test]
    fn results_come_back_in_global_seq_order_despite_locality_sort() {
        // Workloads deliberately out of kernel order: the locality sort
        // submits conv_relu first and residual last, yet the returned
        // vector must be in global sequence order — the contract report
        // rendering, sharding, and merge-sweep are built on.
        let cfg = SweepConfig {
            workloads: vec![
                ("residual".into(), 16),
                ("linear".into(), 0),
                ("conv_relu".into(), 16),
            ],
            frameworks: vec![FrameworkKind::Ming, FrameworkKind::Vanilla],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let svc = CompileService::new(1);
        let results = svc.run_shard(&cfg, Shard::full(), &BTreeSet::new());
        let seqs: Vec<usize> = results.iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, (0..6).collect::<Vec<_>>(), "global seq order restored");
        for (seq, r) in &results {
            let r = r.as_ref().unwrap_or_else(|e| panic!("seq {seq}: {e}"));
            // the locality sort must not reorder the (seq -> job) map
            assert_eq!(r.job.id(), CompileService::jobs(&cfg)[*seq].id());
        }
    }

    #[test]
    fn run_shard_skips_done_jobs() {
        let cfg = SweepConfig {
            workloads: vec![("linear".into(), 0)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let svc = CompileService::new(1);
        let done: BTreeSet<usize> = [0usize].into_iter().collect();
        let rest = svc.run_shard(&cfg, Shard::full(), &done);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, 1, "seq 0 was already spooled and must be skipped");
    }
}
