//! The compile service: batch sweeps over kernels × frameworks × sizes.

use anyhow::Result;

use crate::baselines::framework::FrameworkKind;
use crate::ir::builder::models;
use crate::resources::device::DeviceSpec;

use super::job::{CompileJob, JobResult};
use super::queue::WorkerPool;

/// Sweep specification.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// `(kernel, size)` workloads; defaults to the paper's Table II set.
    pub workloads: Vec<(String, usize)>,
    pub frameworks: Vec<FrameworkKind>,
    pub device: DeviceSpec,
    pub estimate_only: bool,
}

impl SweepConfig {
    pub fn table2(device: DeviceSpec) -> Self {
        Self {
            workloads: models::table2_workloads()
                .into_iter()
                .map(|(k, s)| (k.to_string(), s))
                .collect(),
            frameworks: FrameworkKind::all().to_vec(),
            device,
            estimate_only: false,
        }
    }
}

/// Runs sweeps over a worker pool and collects results.
pub struct CompileService {
    pool: WorkerPool,
}

impl Default for CompileService {
    fn default() -> Self {
        Self::new(WorkerPool::default_size())
    }
}

impl CompileService {
    pub fn new(pool: WorkerPool) -> Self {
        Self { pool }
    }

    /// Execute every (workload × framework) job; failed jobs yield a
    /// `JobResult`-free error string, successful ones a full result.
    pub fn run_sweep(&self, cfg: &SweepConfig) -> Vec<Result<JobResult, String>> {
        let mut jobs: Vec<CompileJob> = Vec::new();
        for (kernel, size) in &cfg.workloads {
            for &fw in &cfg.frameworks {
                jobs.push(CompileJob {
                    kernel: kernel.clone(),
                    size: *size,
                    framework: fw,
                    device: cfg.device.clone(),
                    estimate_only: cfg.estimate_only,
                });
            }
        }
        let closures: Vec<Box<dyn FnOnce() -> Result<JobResult, String> + Send>> = jobs
            .into_iter()
            .map(|j| {
                Box::new(move || j.run().map_err(|e| format!("{}: {e:#}", j.id()))) as _
            })
            .collect();
        self.pool
            .run_all(closures)
            .into_iter()
            .map(|(_, r)| match r {
                Ok(inner) => inner,
                Err(panic) => Err(panic),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_runs_all_cells() {
        let cfg = SweepConfig {
            workloads: vec![("conv_relu".into(), 16), ("linear".into(), 0)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let svc = CompileService::new(WorkerPool::new(2));
        let results = svc.run_sweep(&cfg);
        assert_eq!(results.len(), 4);
        for r in &results {
            let r = r.as_ref().unwrap();
            assert!(r.cycles > 0, "{}", r.job.id());
        }
    }

    #[test]
    fn sweep_tiles_oversized_workloads_automatically() {
        // An oversized VGG block rides through the sweep machinery: the
        // MING cell comes back grid-tiled (tiles > 1) instead of erroring
        // out the way the untiled DSE would.
        let cfg = SweepConfig {
            workloads: vec![("vgg3".into(), 512)],
            frameworks: vec![FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: true,
        };
        let results = CompileService::new(WorkerPool::new(1)).run_sweep(&cfg);
        assert_eq!(results.len(), 1);
        let r = results[0].as_ref().unwrap();
        assert!(r.tiles >= 2, "expected a tiled cell, got {} tiles", r.tiles);
        assert!(r.util.bram18k <= r.util.device.bram18k);
    }

    #[test]
    fn ming_beats_vanilla_in_sweep() {
        let cfg = SweepConfig {
            workloads: vec![("conv_relu".into(), 32)],
            frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
            device: DeviceSpec::kv260(),
            estimate_only: false,
        };
        let results = CompileService::new(WorkerPool::new(2)).run_sweep(&cfg);
        let cycles: Vec<u64> = results.iter().map(|r| r.as_ref().unwrap().cycles).collect();
        assert!(cycles[1] * 50 < cycles[0], "ming {} vs vanilla {}", cycles[1], cycles[0]);
    }
}
