//! Paper-table formatting: Tables II / III / IV and the Fig. 3 series,
//! computed from sweep results.

use std::collections::HashMap;

use crate::baselines::framework::FrameworkKind;
use crate::util::tables::{fnum, TextTable};

use super::job::{JobResult, StageTimes};

/// One Table-II cell, reduced from a `JobResult`.
#[derive(Debug, Clone)]
pub struct Cell {
    pub kernel: String,
    pub size: usize,
    pub framework: FrameworkKind,
    pub mcycles: f64,
    pub bram: u64,
    /// Weight-ROM share of `bram` (unified resource model breakdown).
    pub bram_rom: u64,
    /// FIFO-backing share of `bram`.
    pub bram_fifo: u64,
    pub dsp: u64,
    pub lut_pct: f64,
    pub lutram_pct: f64,
    pub ff_pct: f64,
    pub fits: bool,
    /// Grid cells the design was tiled into (1 = untiled).
    pub tiles: usize,
    /// Per-stage compile wall times (spooled for profiling; never
    /// rendered in the paper tables — those must stay byte-stable
    /// across sharded/unsharded runs).
    pub stages: StageTimes,
    pub error: Option<String>,
}

pub fn cell(r: &JobResult) -> Cell {
    Cell {
        kernel: r.job.kernel.clone(),
        size: r.job.size,
        framework: r.job.framework,
        mcycles: r.cycles as f64 / 1e6,
        bram: r.util.bram18k,
        bram_rom: r.util.bram_weights,
        bram_fifo: r.util.bram_fifos,
        dsp: r.util.dsp,
        lut_pct: r.util.lut_pct(),
        lutram_pct: r.util.lutram_pct(),
        ff_pct: r.util.ff_pct(),
        fits: r.util.fits(),
        tiles: r.tiles,
        stages: r.stages,
        error: r.error.clone(),
    }
}

/// Framework column label, marking grid-tiled designs.
fn fw_label(c: &Cell) -> String {
    if c.tiles > 1 {
        format!("{} (T={})", c.framework.name(), c.tiles)
    } else {
        c.framework.name().to_string()
    }
}

fn workload_key(c: &Cell) -> (String, usize) {
    (c.kernel.clone(), c.size)
}

/// Speedup of `c` relative to the Vanilla cell of the same workload.
pub fn speedup(cells: &[Cell], c: &Cell) -> Option<f64> {
    let base = cells.iter().find(|b| {
        workload_key(b) == workload_key(c) && b.framework == FrameworkKind::Vanilla
    })?;
    if c.mcycles <= 0.0 || base.mcycles <= 0.0 {
        return None;
    }
    Some(base.mcycles / c.mcycles)
}

/// DSP efficiency: `E_DSP = Speedup / (DSP_compare / DSP_baseline)`.
pub fn e_dsp(cells: &[Cell], c: &Cell) -> Option<f64> {
    let base = cells.iter().find(|b| {
        workload_key(b) == workload_key(c) && b.framework == FrameworkKind::Vanilla
    })?;
    let sp = speedup(cells, c)?;
    if c.dsp == 0 || base.dsp == 0 {
        return None;
    }
    Some(sp / (c.dsp as f64 / base.dsp as f64))
}

fn wl_name(kernel: &str, size: usize) -> String {
    if size == 0 {
        kernel.to_string()
    } else {
        format!("{kernel} {size}x{size}")
    }
}

/// Render Table II: per workload × framework — MCycles, BRAM (with the
/// unified model's weight-ROM / FIFO shares), DSP, LUT/FF fabric
/// estimates (`resources::fabric`, report-only: the ILP does not
/// constrain fabric), speedup, E_DSP, feasibility.
pub fn render_table2(cells: &[Cell]) -> String {
    let mut t = TextTable::new(vec![
        "kernel", "framework", "MCycles", "BRAM", "ROM", "FIFO", "DSP", "LUT%", "FF%",
        "Speedup", "E_DSP", "fits",
    ]);
    for c in cells {
        let sp = speedup(cells, c);
        let ed = e_dsp(cells, c);
        t.row(vec![
            wl_name(&c.kernel, c.size),
            fw_label(c),
            if c.error.is_some() { "×".into() } else { fnum(c.mcycles, 4) },
            c.bram.to_string(),
            c.bram_rom.to_string(),
            c.bram_fifo.to_string(),
            c.dsp.to_string(),
            fnum(c.lut_pct, 1),
            fnum(c.ff_pct, 1),
            sp.map(|v| fnum(v, 2)).unwrap_or_else(|| "—".into()),
            ed.map(|v| fnum(v, 2)).unwrap_or_else(|| "—".into()),
            if c.fits { "yes".into() } else { "EXCEEDS".to_string() },
        ]);
    }
    t.render()
}

/// Render Table III: post-PnR fabric percentages for 32×32 kernels.
pub fn render_table3(cells: &[Cell]) -> String {
    let mut t = TextTable::new(vec!["kernel", "framework", "LUT%", "LUTRAM%", "FF%"]);
    for c in cells {
        if c.framework == FrameworkKind::Vanilla {
            continue; // paper compares ScaleHLS / StreamHLS / MING
        }
        t.row(vec![
            wl_name(&c.kernel, c.size),
            fw_label(c),
            fnum(c.lut_pct, 2),
            fnum(c.lutram_pct, 2),
            fnum(c.ff_pct, 2),
        ]);
    }
    t.render()
}

/// Render Table IV: the DSP-constraint sweep on Conv+ReLU 32×32.
/// `rows` = (dsp_cap, cell, vanilla_mcycles).
pub fn render_table4(rows: &[(u64, Cell, f64)]) -> String {
    let mut t = TextTable::new(vec!["DSP constraint", "Speedup", "DSP", "E_DSP"]);
    for (cap, c, base_mc) in rows {
        let sp = base_mc / c.mcycles;
        // E_DSP vs the unconstrained Vanilla baseline DSP (1 by our model)
        let ed = sp / c.dsp.max(1) as f64;
        t.row(vec![
            cap.to_string(),
            fnum(sp, 2),
            c.dsp.to_string(),
            fnum(ed, 3),
        ]);
    }
    t.render()
}

/// Fig. 3 series: input size → BRAM for a single framework.
pub fn render_fig3(series: &HashMap<&'static str, Vec<(usize, u64)>>) -> String {
    let mut t = TextTable::new(vec!["input", "framework", "BRAM18K"]);
    let mut keys: Vec<_> = series.keys().collect();
    keys.sort();
    for fw in keys {
        for (n, bram) in &series[*fw] {
            t.row(vec![format!("{n}x{n}"), fw.to_string(), bram.to_string()]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(kernel: &str, fw: FrameworkKind, mcycles: f64, dsp: u64) -> Cell {
        Cell {
            kernel: kernel.into(),
            size: 32,
            framework: fw,
            mcycles,
            bram: 10,
            bram_rom: 2,
            bram_fifo: 1,
            dsp,
            lut_pct: 1.0,
            lutram_pct: 1.0,
            ff_pct: 1.0,
            fits: true,
            tiles: 1,
            stages: StageTimes::default(),
            error: None,
        }
    }

    #[test]
    fn tiled_cells_are_labelled() {
        let mut c = mk("vgg3", FrameworkKind::Ming, 10.0, 1000);
        assert_eq!(fw_label(&c), "ming");
        c.tiles = 4;
        assert_eq!(fw_label(&c), "ming (T=4)");
        let s = render_table2(&[c]);
        assert!(s.contains("ming (T=4)"));
    }

    #[test]
    fn speedup_and_edsp() {
        let cells = vec![
            mk("conv_relu", FrameworkKind::Vanilla, 0.53, 5),
            mk("conv_relu", FrameworkKind::Ming, 0.00106, 250),
        ];
        let sp = speedup(&cells, &cells[1]).unwrap();
        assert!((sp - 500.0).abs() < 1.0);
        let ed = e_dsp(&cells, &cells[1]).unwrap();
        assert!((ed - 10.0).abs() < 0.1, "{ed}");
    }

    #[test]
    fn table2_renders_rows() {
        let cells = vec![
            mk("conv_relu", FrameworkKind::Vanilla, 0.5, 5),
            mk("conv_relu", FrameworkKind::Ming, 0.001, 288),
        ];
        let s = render_table2(&cells);
        assert!(s.contains("conv_relu 32x32"));
        assert!(s.contains("ming"));
        assert!(s.contains("Speedup"));
    }

    #[test]
    fn table2_includes_resource_breakdown_columns() {
        let cells = vec![mk("conv_relu", FrameworkKind::Ming, 0.001, 288)];
        let s = render_table2(&cells);
        assert!(s.contains("ROM") && s.contains("FIFO"), "{s}");
        // fabric-estimate columns (report-only; from resources::fabric)
        assert!(s.contains("LUT%") && s.contains("FF%"), "{s}");
    }

    #[test]
    fn table3_skips_vanilla() {
        let cells = vec![
            mk("conv_relu", FrameworkKind::Vanilla, 0.5, 5),
            mk("conv_relu", FrameworkKind::ScaleHls, 0.7, 10),
        ];
        let s = render_table3(&cells);
        assert!(!s.contains("vanilla"));
        assert!(s.contains("scalehls"));
    }
}
