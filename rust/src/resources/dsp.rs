//! DSP estimation with integer arithmetic (the paper's claimed improvement
//! over StreamHLS's model).
//!
//! On UltraScale+ a DSP48E2 performs a 27×18-bit multiply-accumulate:
//! * two **int8** MACs pack into one DSP (the well-known INT8 packing),
//! * one int16 MAC per DSP,
//! * int32 multiplies need 3 DSPs (27×18 decomposition).
//!
//! A node issuing `mac_lanes` int8 MACs per cycle therefore needs
//! `ceil(mac_lanes / 2)` DSPs. Non-MAC ALU ops (adds, compares, shifts)
//! go to LUT fabric — that is precisely what "supports integer
//! arithmetic" buys: float designs would burn DSPs on every add.

use crate::dataflow::design::Design;
use crate::dataflow::node::DfgNode;
use crate::ir::types::DType;

/// DSPs required for `lanes` concurrent MACs at the given element dtype.
pub fn dsp_for_macs(lanes: u64, dtype: DType) -> u64 {
    if lanes == 0 {
        return 0;
    }
    match dtype {
        DType::I8 => lanes.div_ceil(2),
        DType::I16 => lanes,
        DType::I32 => 3 * lanes,
        DType::F32 => 5 * lanes, // fadd+fmul DSP cost, for completeness
    }
}

/// DSPs of one node: MAC lanes only; pure-ALU nodes cost none.
pub fn node_dsp(n: &DfgNode) -> u64 {
    if n.geo.macs_per_out_token == 0 {
        return 0;
    }
    dsp_for_macs(n.timing.mac_lanes, DType::I8)
}

/// Total design DSP usage.
pub fn design_dsp(d: &Design) -> u64 {
    d.nodes.iter().map(node_dsp).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::util::prop::forall;

    #[test]
    fn int8_packing() {
        assert_eq!(dsp_for_macs(576, DType::I8), 288);
        assert_eq!(dsp_for_macs(1, DType::I8), 1);
        assert_eq!(dsp_for_macs(0, DType::I8), 0);
    }

    #[test]
    fn wider_types_cost_more() {
        forall("dtype ordering", 50, |g| g.rng.range(1, 1000), |&lanes| {
            dsp_for_macs(lanes, DType::I8) <= dsp_for_macs(lanes, DType::I16)
                && dsp_for_macs(lanes, DType::I16) <= dsp_for_macs(lanes, DType::I32)
        });
    }

    #[test]
    fn relu_nodes_use_no_dsp() {
        let g = models::conv_relu(16, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        d.nodes[1].timing.mac_lanes = 8; // even when parallelized
        assert_eq!(node_dsp(&d.nodes[1]), 0);
        assert!(node_dsp(&d.nodes[0]) > 0);
    }

    #[test]
    fn design_dsp_sums_nodes() {
        let g = models::cascade(16, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        for n in &mut d.nodes {
            n.timing.mac_lanes = 64;
        }
        // two conv nodes at 64 lanes → 2 × 32; relu nodes free
        assert_eq!(design_dsp(&d), 64);
    }
}
