//! The unified resource model: **one** computation of the full
//! per-design / per-candidate resource vector, shared by every consumer.
//!
//! MING's core claim is that generated designs *respect edge resource
//! constraints* — which only holds if the solver prices exactly what the
//! generated design allocates. Historically the DSE counted line-buffer
//! BRAM only: weight ROMs were baked into codegen without being charged,
//! and FIFO backing was approximated by a flat reserve. This module
//! closes that estimate-vs-implementation gap (the failure mode the
//! toolflow surveys attribute to estimate/implementation divergence):
//!
//! * [`ResourceVec`] — the full vector: line-buffer BRAM, weight-ROM
//!   BRAM, FIFO BRAM, other (baseline-only) BRAM, and DSP.
//! * [`ResourceModel::node_vec`] — the vector one node contributes under
//!   a candidate [`NodeTiming`], *including* the FIFO blocks of its
//!   output channels at the depths `dse::fifo::size_fifos` will assign
//!   for that timing. Contributions are separable per node (each
//!   channel's depth depends only on its producer's pipeline depth plus
//!   a timing-independent diamond floor), so the branch-and-bound can
//!   price FIFO deltas exactly and incrementally per partial assignment.
//! * [`ResourceModel::as_built`] — the same vector read back from a
//!   finished design's concrete allocations (buffers + channels).
//!
//! **Invariant** (enforced by tests and a debug assertion in
//! `dse::ilp::solve`): for every solved design, the summed candidate
//! vectors equal the as-built vector, i.e.
//! `solution.bram_used == resources::bram::design_bram(design)`.
//!
//! Consumers: `dse::space` (candidate enumeration), `dse::ilp` (ILP
//! constraint + reported usage), `tiling::cost` (strip lower bounds),
//! `tiling::schedule` (budget math), `resources::report` /
//! `coordinator::report` (utilization breakdown columns), and
//! `codegen` (BIND_STORAGE / ARRAY_PARTITION pragmas derived from the
//! same storage decisions via `dataflow::build::refresh_buffers`).

use std::ops::{Add, AddAssign};

use crate::analysis::classify::KernelClass;
use crate::dataflow::buffers::{BufferRole, Storage};
use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::Design;
use crate::dataflow::node::NodeTiming;
use crate::dse::fifo::{diamond_mins, planned_depth};
use crate::ir::graph::TensorKind;
use crate::ir::types::DType;

use super::bram::{bram_blocks, buffer_bram, channel_bram, channel_bram_at_depth};
use super::dsp::{design_dsp, dsp_for_macs};

/// Weight ROM slices smaller than this many bits are placed in LUTRAM by
/// the tool (register-tiny BRAM slices would waste whole RAM18Ks).
pub const WEIGHT_LUTRAM_SLICE_BITS: u64 = 1024;
/// At or beyond this many MAC lanes the weight array is partitioned so
/// finely that Vitis places it in distributed LUTRAM regardless of size.
pub const WEIGHT_LUTRAM_LANES: u64 = 32;

/// Storage binding of a weight ROM accessed by `lanes` parallel MACs —
/// the single policy shared by `dataflow::build::refresh_buffers` (and
/// therefore by codegen's BIND_STORAGE pragmas) and the DSE's pricing.
pub fn weight_storage(bits: u64, lanes: u64) -> Storage {
    if bits / lanes.max(1) < WEIGHT_LUTRAM_SLICE_BITS || lanes >= WEIGHT_LUTRAM_LANES {
        Storage::Lutram
    } else {
        Storage::Rom
    }
}

/// ARRAY_PARTITION factor of a weight ROM: one slice per MAC lane,
/// capped at the element count.
pub fn weight_partitions(numel: u64, lanes: u64) -> u64 {
    lanes.max(1).min(numel.max(1))
}

/// RAM18K blocks of one weight tensor (`bits` total, `numel` elements)
/// read by `lanes` parallel MACs. Zero when the ROM lands in LUTRAM.
pub fn weight_rom_bram(bits: u64, numel: u64, lanes: u64) -> u64 {
    match weight_storage(bits, lanes) {
        Storage::Rom => bram_blocks(bits, weight_partitions(numel, lanes)),
        _ => 0,
    }
}

/// The full resource vector of a design (or one node's contribution).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResourceVec {
    /// Line-buffer / reduction-line BRAM blocks.
    pub line_bram: u64,
    /// Weight-ROM BRAM blocks (0 for LUTRAM-bound ROMs).
    pub weight_bram: u64,
    /// FIFO backing BRAM blocks (channels + explicit FifoBacking arrays).
    pub fifo_bram: u64,
    /// BRAM of baseline-only structures (whole intermediate tensors,
    /// reorder buffers). Always 0 for MING streaming designs.
    pub other_bram: u64,
    /// DSP48 blocks.
    pub dsp: u64,
}

impl ResourceVec {
    /// Total BRAM18K blocks — the number the device constraint sees.
    pub fn bram(&self) -> u64 {
        self.line_bram + self.weight_bram + self.fifo_bram + self.other_bram
    }

    /// Component-wise `<=` (used by the monotonicity properties).
    pub fn le(&self, o: &ResourceVec) -> bool {
        self.line_bram <= o.line_bram
            && self.weight_bram <= o.weight_bram
            && self.fifo_bram <= o.fifo_bram
            && self.other_bram <= o.other_bram
            && self.dsp <= o.dsp
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            line_bram: self.line_bram + o.line_bram,
            weight_bram: self.weight_bram + o.weight_bram,
            fifo_bram: self.fifo_bram + o.fifo_bram,
            other_bram: self.other_bram + o.other_bram,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

/// Prices candidate timings against one design's streaming structure.
pub struct ResourceModel<'a> {
    d: &'a Design,
    /// Timing-independent diamond depth floors per channel.
    diamond_min: Vec<usize>,
}

impl<'a> ResourceModel<'a> {
    pub fn new(d: &'a Design) -> Self {
        Self { diamond_min: diamond_mins(d), d }
    }

    /// Timing-independent diamond depth floor of channel `cid` — the
    /// floor [`Self::node_fifo_bram`] prices into every candidate.
    /// Exposed so `dse::warmstart`'s per-node front fingerprint can
    /// cover exactly the inputs candidate pricing reads.
    pub fn diamond_floor(&self, cid: usize) -> usize {
        self.diamond_min[cid]
    }

    /// Line-buffer / reduction-line BRAM of node `nid` under `timing`,
    /// optionally rescaled to a `(full_width, strip_width)` pair for the
    /// tiling subsystem's per-strip accounting.
    fn storage_bram(&self, nid: usize, timing: &NodeTiming, rescale: Option<(usize, usize)>) -> u64 {
        let n = &self.d.nodes[nid];
        let op = &self.d.graph.ops[n.op_index];
        match n.geo.class {
            KernelClass::SlidingWindow(_) => match n.geo.line_buffer {
                Some(lb) => {
                    let lb = match rescale {
                        Some((old_w, new_w)) => lb.at_width(old_w, new_w),
                        None => lb,
                    };
                    let chans =
                        *self.d.graph.tensor(op.inputs[0]).ty.shape.last().unwrap_or(&1) as u64;
                    let part = timing.unroll_red.clamp(1, chans);
                    lb.rows as u64 * bram_blocks(lb.row_len as u64 * lb.elem_bits, part)
                }
                None => 0,
            },
            KernelClass::RegularReduction => match n.geo.line_buffer {
                Some(lb) => {
                    let part = timing.unroll_red.clamp(1, lb.row_len as u64);
                    bram_blocks(lb.total_bits(), part)
                }
                None => 0,
            },
            KernelClass::PureParallel => 0,
        }
    }

    /// Weight-ROM BRAM of node `nid` when its MACs run `lanes` wide.
    fn node_weight_bram(&self, nid: usize, timing: &NodeTiming) -> u64 {
        let n = &self.d.nodes[nid];
        let op = &self.d.graph.ops[n.op_index];
        op.inputs
            .iter()
            .map(|&inp| {
                let t = self.d.graph.tensor(inp);
                if t.kind == TensorKind::Weight {
                    weight_rom_bram(t.ty.bits(), t.ty.numel() as u64, timing.mac_lanes.max(1))
                } else {
                    0
                }
            })
            .sum()
    }

    /// FIFO BRAM of node `nid`'s output channels at the depths
    /// `size_fifos` will assign for `timing`. With `diamond` false the
    /// timing-independent diamond floors are dropped — an admissible
    /// relaxation for strip lower bounds, where lags shrink with width.
    fn node_fifo_bram(&self, nid: usize, timing: &NodeTiming, diamond: bool) -> u64 {
        self.d.nodes[nid]
            .out_channels
            .iter()
            .map(|&cid| {
                let floor = if diamond { self.diamond_min[cid.0] } else { 0 };
                let c = self.d.channel(cid);
                channel_bram_at_depth(c, planned_depth(Some(timing.depth), floor))
            })
            .sum()
    }

    /// The full vector node `nid` contributes under `timing`: line
    /// buffers, weight ROMs, output-FIFO backing, and DSPs.
    pub fn node_vec(&self, nid: usize, timing: &NodeTiming) -> ResourceVec {
        ResourceVec {
            line_bram: self.storage_bram(nid, timing, None),
            weight_bram: self.node_weight_bram(nid, timing),
            fifo_bram: self.node_fifo_bram(nid, timing, true),
            other_bram: 0,
            dsp: self.node_dsp(nid, timing),
        }
    }

    /// Lower-bound vector for running node `nid` on a width-`w_local`
    /// strip of a `full_w`-wide feature map: line buffers rescale with
    /// the strip width, weight ROMs and FIFO base depths do not, and the
    /// diamond floors (which shrink with width) are dropped. Admissible:
    /// never exceeds the node's contribution in the rebuilt strip design
    /// under the same timing.
    pub fn node_vec_at_width(
        &self,
        nid: usize,
        timing: &NodeTiming,
        full_w: usize,
        w_local: usize,
    ) -> ResourceVec {
        ResourceVec {
            line_bram: self.storage_bram(nid, timing, Some((full_w, w_local))),
            weight_bram: self.node_weight_bram(nid, timing),
            fifo_bram: self.node_fifo_bram(nid, timing, false),
            other_bram: 0,
            dsp: self.node_dsp(nid, timing),
        }
    }

    fn node_dsp(&self, nid: usize, timing: &NodeTiming) -> u64 {
        if self.d.nodes[nid].geo.macs_per_out_token == 0 {
            0
        } else {
            dsp_for_macs(timing.mac_lanes, DType::I8)
        }
    }

    /// FIFO BRAM of channels fed by the graph input — candidate-
    /// independent, charged once up front by the solver.
    pub fn input_fifo_bram(&self) -> u64 {
        self.d
            .channels
            .iter()
            .filter(|c| !matches!(c.src, Endpoint::Node(_)))
            .map(|c| channel_bram_at_depth(c, planned_depth(None, self.diamond_min[c.id.0])))
            .sum()
    }

    /// Like [`Self::input_fifo_bram`] but without the diamond floors —
    /// the admissible variant for strip lower bounds.
    pub fn input_fifo_floor(&self) -> u64 {
        self.d
            .channels
            .iter()
            .filter(|c| !matches!(c.src, Endpoint::Node(_)))
            .map(|c| channel_bram_at_depth(c, planned_depth(None, 0)))
            .sum()
    }

    /// The predicted full-design vector under the nodes' *current*
    /// timings. After `refresh_buffers` + `size_fifos` this equals
    /// [`ResourceModel::as_built`] exactly (see the invariant tests).
    pub fn design_vec(&self) -> ResourceVec {
        let mut v = ResourceVec { fifo_bram: self.input_fifo_bram(), ..Default::default() };
        for (nid, n) in self.d.nodes.iter().enumerate() {
            v += self.node_vec(nid, &n.timing);
        }
        v
    }

    /// The as-built vector of any design (MING or baseline), read from
    /// its concrete buffer allocations and channel depths. The total
    /// equals [`super::bram::design_bram`] / [`design_dsp`] by
    /// construction.
    pub fn as_built(d: &Design) -> ResourceVec {
        let mut v = ResourceVec::default();
        for b in &d.buffers {
            let blocks = buffer_bram(b);
            match b.role {
                BufferRole::LineBuffer
                | BufferRole::ReductionLine
                | BufferRole::WindowBuffer => v.line_bram += blocks,
                BufferRole::Weights => v.weight_bram += blocks,
                BufferRole::FifoBacking => v.fifo_bram += blocks,
                BufferRole::IntermediateTensor | BufferRole::ReorderBuffer => {
                    v.other_bram += blocks
                }
            }
        }
        for c in &d.channels {
            v.fifo_bram += channel_bram(c);
        }
        v.dsp = design_dsp(d);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::{build_streaming_design, refresh_buffers};
    use crate::dse::fifo::size_fifos;
    use crate::ir::builder::models;
    use crate::resources::bram::design_bram;
    use crate::util::prop::forall;

    /// Predicted-vs-as-built equality on a design in its current state.
    fn assert_model_exact(d: &Design) {
        let predicted = ResourceModel::new(d).design_vec();
        let built = ResourceModel::as_built(d);
        assert_eq!(predicted, built, "model must price exactly what is allocated");
        assert_eq!(predicted.bram(), design_bram(d));
    }

    #[test]
    fn scalar_designs_price_exactly() {
        for (name, size) in
            [("conv_relu", 32), ("cascade", 32), ("residual", 32), ("linear", 0), ("feedforward", 0)]
        {
            let g = models::paper_kernel(name, size).unwrap();
            let mut d = build_streaming_design(&g).unwrap();
            size_fifos(&mut d);
            assert_model_exact(&d);
        }
    }

    #[test]
    fn unrolled_design_prices_exactly() {
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        d.nodes[0].timing.unroll_red = 8;
        d.nodes[0].timing.mac_lanes = 576;
        d.nodes[0].timing.depth = 14;
        refresh_buffers(&mut d);
        size_fifos(&mut d);
        assert_model_exact(&d);
    }

    #[test]
    fn pooling_line_buffers_are_priced() {
        // Zero-MAC sliding nodes (maxpool) have line buffers too — the
        // old candidate accounting missed them entirely.
        let g = models::tiny_cnn(32, 4, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        assert_model_exact(&d);
        let m = ResourceModel::new(&d);
        let pool = d
            .nodes
            .iter()
            .position(|n| n.geo.macs_per_out_token == 0 && n.geo.line_buffer.is_some())
            .expect("tiny_cnn has pooling nodes");
        assert!(m.node_vec(pool, &d.nodes[pool].timing).line_bram > 0);
    }

    #[test]
    fn weight_storage_policy_thresholds() {
        // big ROM, scalar access: BRAM; tiny or wide-unrolled: LUTRAM
        assert_eq!(weight_storage(131_072, 1), Storage::Rom);
        assert_eq!(weight_storage(131_072, 32), Storage::Lutram);
        assert_eq!(weight_storage(512, 1), Storage::Lutram);
        assert_eq!(weight_rom_bram(131_072, 16_384, 1), 8);
        assert_eq!(weight_rom_bram(131_072, 16_384, 32), 0);
    }

    #[test]
    fn weight_rom_bram_monotone_in_bits() {
        // Adding weight bits never decreases the modeled blocks (at any
        // fixed lane count) — the ROM-accounting monotonicity guarantee.
        forall(
            "weight rom monotone",
            300,
            |g| {
                let lanes = 1 + g.rng.below(64);
                let e1 = 1 + g.rng.below(1 << 16);
                let e2 = e1 + g.rng.below(1 << 16);
                (lanes, e1, e2)
            },
            |&(lanes, e1, e2)| {
                weight_rom_bram(8 * e1, e1, lanes) <= weight_rom_bram(8 * e2, e2, lanes)
            },
        );
    }

    #[test]
    fn node_vec_monotone_in_weight_bits() {
        // Same guarantee at the vector level: two linear layers that
        // differ only in weight-tensor size — the bigger one never
        // models a smaller vector, at any lane count (including across
        // the ROM→LUTRAM storage flip).
        let build = |features: usize| {
            let mut b = crate::ir::builder::GraphBuilder::new(format!("mono{features}"));
            let x = b.input("x", vec![16, 128], DType::I8);
            let w = b.det_weight("w", vec![128, features], 1);
            let acc = b.linear("mm0", x, w);
            let y = b.relu_requant("rr0", acc);
            b.mark_output(y);
            build_streaming_design(&b.finish()).unwrap()
        };
        let (small, big) = (build(8), build(64));
        let (ms, mb) = (ResourceModel::new(&small), ResourceModel::new(&big));
        for lanes in [1u64, 2, 8, 16] {
            let timing =
                crate::dataflow::node::NodeTiming { mac_lanes: lanes, ..Default::default() };
            let (vs, vb) = (ms.node_vec(0, &timing), mb.node_vec(0, &timing));
            assert!(vs.le(&vb), "lanes {lanes}: {vs:?} must be <= {vb:?}");
        }
    }

    #[test]
    fn input_fifo_constant_covers_diamond_skip() {
        // residual @224: the skip FIFO hangs off the graph input and is
        // deep enough to need BRAM — the solver's constant term must see
        // it even though no candidate owns that channel.
        let g = models::residual(224, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        size_fifos(&mut d);
        let m = ResourceModel::new(&d);
        assert!(m.input_fifo_bram() > 0, "deep skip FIFO must be charged");
        assert!(m.input_fifo_floor() <= m.input_fifo_bram());
        assert_model_exact(&d);
    }

    #[test]
    fn as_built_totals_match_legacy_estimators() {
        use crate::baselines::framework::{compile_with, FrameworkKind};
        use crate::resources::device::DeviceSpec;
        let g = models::conv_relu(32, 8, 8);
        for fw in FrameworkKind::all() {
            let d = compile_with(fw, &g, &DeviceSpec::kv260()).unwrap();
            let v = ResourceModel::as_built(&d);
            assert_eq!(v.bram(), design_bram(&d), "{}", fw.name());
            assert_eq!(v.dsp, design_dsp(&d), "{}", fw.name());
        }
    }
}
