//! FPGA device database.

/// Resource capacities of a target FPGA.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceSpec {
    pub name: String,
    /// BRAM18K slices (two per BRAM36 tile).
    pub bram18k: u64,
    pub dsp: u64,
    pub lut: u64,
    /// LUTs usable as distributed RAM (subset of `lut`).
    pub lutram: u64,
    pub ff: u64,
}

impl DeviceSpec {
    /// AMD Kria KV260 (Zynq UltraScale+ K26 SOM) — the paper's evaluation
    /// board: 288 BRAM18K, 1248 DSP (paper §V).
    pub fn kv260() -> Self {
        Self {
            name: "kv260".into(),
            bram18k: 288,
            dsp: 1248,
            lut: 117_120,
            lutram: 57_600,
            ff: 234_240,
        }
    }

    /// ZCU104 (ZU7EV) — a mid-range edge board for sweeps.
    pub fn zcu104() -> Self {
        Self {
            name: "zcu104".into(),
            bram18k: 624,
            dsp: 1728,
            lut: 230_400,
            lutram: 101_760,
            ff: 460_800,
        }
    }

    /// Alveo U250 — a cloud-grade card ("tens of thousands of BRAMs,
    /// millions of LUTs" in the paper's discussion).
    pub fn u250() -> Self {
        Self {
            name: "u250".into(),
            bram18k: 5376,
            dsp: 12_288,
            lut: 1_728_000,
            lutram: 791_040,
            ff: 3_456_000,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "kv260" => Some(Self::kv260()),
            "zcu104" => Some(Self::zcu104()),
            "u250" => Some(Self::u250()),
            _ => None,
        }
    }

    /// A copy with a reduced DSP budget (the paper's Table IV sweep).
    pub fn with_dsp_limit(&self, dsp: u64) -> Self {
        Self { dsp, name: format!("{}@dsp{}", self.name, dsp), ..self.clone() }
    }

    /// A copy with a reduced BRAM budget.
    pub fn with_bram_limit(&self, bram18k: u64) -> Self {
        Self { bram18k, name: format!("{}@bram{}", self.name, bram18k), ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv260_matches_paper() {
        let d = DeviceSpec::kv260();
        assert_eq!(d.bram18k, 288);
        assert_eq!(d.dsp, 1248);
    }

    #[test]
    fn lookup_and_limits() {
        assert!(DeviceSpec::by_name("kv260").is_some());
        assert!(DeviceSpec::by_name("nope").is_none());
        let d = DeviceSpec::kv260().with_dsp_limit(50);
        assert_eq!(d.dsp, 50);
        assert_eq!(d.bram18k, 288);
        let b = DeviceSpec::kv260().with_bram_limit(64);
        assert_eq!(b.bram18k, 64);
    }

    #[test]
    fn device_ordering_edge_to_cloud() {
        assert!(DeviceSpec::kv260().bram18k < DeviceSpec::zcu104().bram18k);
        assert!(DeviceSpec::zcu104().bram18k < DeviceSpec::u250().bram18k);
    }
}
