//! BRAM18K packing model (the paper's BRAM constraint: "RAM18K blocks,
//! each capable of storing up to 18,432 bits").
//!
//! An array of `bits` partitioned into `p` slices costs
//! `p * ceil(bits / p / 18432)` RAM18K blocks — every slice occupies at
//! least one physical block, which is exactly why high ARRAY_PARTITION
//! factors inflate BRAM usage (paper §V-B's StreamHLS observation) and
//! why MING's (K-1)×C-partitioned line buffers cost a constant
//! `(K-1)·unroll_c` blocks regardless of input size.

use crate::dataflow::buffers::{BufferAlloc, Storage};
use crate::dataflow::channel::Channel;
use crate::dataflow::design::Design;

/// Usable bits per RAM18K slice.
pub const RAM18K_BITS: u64 = 18_432;

/// Per-lane FIFOs at or below this depth (elements per physical lane)
/// are implemented as shift registers (SRL) in LUT fabric; deeper ones
/// get BRAM backing. Mirrors Vitis' stream implementation heuristic.
pub const FIFO_SRL_MAX_DEPTH: u64 = 128;

/// RAM18K blocks for one array of `bits` split into `partitions` slices.
pub fn bram_blocks(bits: u64, partitions: u64) -> u64 {
    let p = partitions.max(1);
    p * bits.div_ceil(p).div_ceil(RAM18K_BITS)
}

/// RAM18K cost of one buffer allocation (0 for non-BRAM storage).
pub fn buffer_bram(b: &BufferAlloc) -> u64 {
    match b.storage {
        Storage::Bram | Storage::Rom => bram_blocks(b.bits, b.partitions),
        Storage::Lutram | Storage::Ff => 0,
    }
}

/// RAM18K cost of a FIFO channel at a hypothetical `depth` (the unified
/// resource model prices candidate depths before they are committed).
pub fn channel_bram_at_depth(c: &Channel, depth: usize) -> u64 {
    if c.externally_buffered {
        return 0; // storage accounted by explicit BufferAllocs
    }
    // a `lanes`-wide stream is `lanes` physical FIFOs, each holding
    // depth × token_len / lanes elements
    let lanes = c.lanes.max(1) as u64;
    let per_lane = depth as u64 * c.token_len as u64 / lanes;
    if per_lane <= FIFO_SRL_MAX_DEPTH {
        0
    } else {
        lanes * (per_lane * c.elem_bits).div_ceil(RAM18K_BITS)
    }
}

/// RAM18K cost of a FIFO channel: shallow FIFOs are SRLs (0 BRAM),
/// deep ones are packed into BRAM at their element width.
pub fn channel_bram(c: &Channel) -> u64 {
    channel_bram_at_depth(c, c.depth)
}

/// Total design BRAM: buffers + deep FIFOs.
pub fn design_bram(d: &Design) -> u64 {
    let bufs: u64 = d.buffers.iter().map(buffer_bram).sum();
    let fifos: u64 = d.channels.iter().map(channel_bram).sum();
    bufs + fifos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::buffers::BufferRole;
    use crate::dataflow::build::{build_streaming_design, refresh_buffers};
    use crate::ir::builder::models;
    use crate::util::prop::forall;

    #[test]
    fn packing_basics() {
        assert_eq!(bram_blocks(1, 1), 1, "any non-empty array needs one block");
        assert_eq!(bram_blocks(18_432, 1), 1);
        assert_eq!(bram_blocks(18_433, 1), 2);
        assert_eq!(bram_blocks(1000, 16), 16, "each slice costs at least 1");
    }

    #[test]
    fn partition_cost_lower_bounds() {
        // Partitioning can REDUCE total blocks when slices drop under 18Kb
        // boundaries (rounding), but never below either lower bound:
        // every partition costs >= 1 block, and total storage >= bits.
        forall(
            "partition lower bounds",
            200,
            |g| (g.rng.range(1, 1 << 24), g.rng.range(1, 128)),
            |&(bits, p)| {
                let blocks = bram_blocks(bits, p);
                blocks >= p && blocks * RAM18K_BITS >= bits
            },
        );
    }

    #[test]
    fn ming_conv_line_buffer_bram_constant_in_input_size() {
        // The headline Fig-3 contrast: MING BRAM must not scale with N.
        let mut got = Vec::new();
        for n in [32usize, 64, 128, 224] {
            let g = models::conv_relu(n, 8, 8);
            let mut d = build_streaming_design(&g).unwrap();
            d.nodes[0].timing.unroll_red = 8;
            d.nodes[0].timing.mac_lanes = 576;
            refresh_buffers(&mut d);
            let lb: u64 = d
                .buffers
                .iter()
                .filter(|b| b.role == BufferRole::LineBuffer)
                .map(buffer_bram)
                .sum();
            got.push(lb);
        }
        assert!(got.windows(2).all(|w| w[0] == w[1]), "line-buffer BRAM varies: {got:?}");
        assert_eq!(got[0], 16, "(K-1)=2 rows × 8 channel partitions");
    }

    #[test]
    fn shallow_fifos_cost_no_bram() {
        let g = models::cascade(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        for c in &d.channels {
            assert_eq!(channel_bram(c), 0, "default-depth FIFO {} should be SRL", c.name);
        }
    }

    #[test]
    fn deep_fifo_costs_bram() {
        let g = models::residual(224, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        // size the skip FIFO for the diamond (as the DSE would)
        let skip = d
            .channels
            .iter()
            .position(|c| c.name == "add0_in0")
            .expect("skip channel");
        d.channels[skip].depth = 2 * 224; // two rows of lag
        let blocks = channel_bram(&d.channels[skip]);
        assert!(blocks > 0, "deep skip FIFO must use BRAM");
    }
}
