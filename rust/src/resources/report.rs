//! Aggregated utilization report + device-constraint checking.

use std::fmt;

use crate::dataflow::design::Design;

use super::device::DeviceSpec;
use super::fabric::{design_fabric, Fabric};
use super::model::ResourceModel;

/// Estimated utilization of one design on one device.
#[derive(Debug, Clone)]
pub struct UtilizationReport {
    /// Total BRAM18K blocks — the sum of the breakdown below.
    pub bram18k: u64,
    /// Line-buffer / reduction-line blocks.
    pub bram_line: u64,
    /// Weight-ROM blocks (0 when ROMs land in LUTRAM).
    pub bram_weights: u64,
    /// FIFO backing blocks (deep streams + explicit backing arrays).
    pub bram_fifos: u64,
    /// Baseline-only structures (whole tensors, reorder buffers).
    pub bram_other: u64,
    pub dsp: u64,
    pub lut: u64,
    pub lutram: u64,
    pub ff: u64,
    pub device: DeviceSpec,
}

impl UtilizationReport {
    pub fn fits(&self) -> bool {
        self.violations().is_empty()
    }

    /// Human-readable list of exceeded resources.
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        let mut chk = |name: &str, used: u64, avail: u64| {
            if used > avail {
                v.push(format!("{name}: {used} > {avail}"));
            }
        };
        chk("BRAM18K", self.bram18k, self.device.bram18k);
        chk("DSP", self.dsp, self.device.dsp);
        chk("LUT", self.lut, self.device.lut);
        chk("LUTRAM", self.lutram, self.device.lutram);
        chk("FF", self.ff, self.device.ff);
        v
    }

    pub fn pct(&self, used: u64, avail: u64) -> f64 {
        100.0 * used as f64 / avail as f64
    }

    pub fn lut_pct(&self) -> f64 {
        self.pct(self.lut, self.device.lut)
    }

    pub fn lutram_pct(&self) -> f64 {
        self.pct(self.lutram, self.device.lutram)
    }

    pub fn ff_pct(&self) -> f64 {
        self.pct(self.ff, self.device.ff)
    }
}

impl fmt::Display for UtilizationReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "BRAM {}/{} (line {} · rom {} · fifo {}{})  DSP {}/{}  \
             LUT {} ({:.1}%)  LUTRAM {} ({:.1}%)  FF {} ({:.1}%){}",
            self.bram18k,
            self.device.bram18k,
            self.bram_line,
            self.bram_weights,
            self.bram_fifos,
            if self.bram_other > 0 {
                format!(" · other {}", self.bram_other)
            } else {
                String::new()
            },
            self.dsp,
            self.device.dsp,
            self.lut,
            self.lut_pct(),
            self.lutram,
            self.lutram_pct(),
            self.ff,
            self.ff_pct(),
            if self.fits() { "" } else { "  [EXCEEDS DEVICE]" }
        )
    }
}

/// Estimate a design's utilization on a device. BRAM and DSP come from
/// the unified resource model's as-built vector, so the report's totals
/// are the same numbers the DSE charged and codegen allocates.
pub fn estimate(d: &Design, device: &DeviceSpec) -> UtilizationReport {
    let Fabric { lut, lutram, ff } = design_fabric(d);
    let v = ResourceModel::as_built(d);
    UtilizationReport {
        bram18k: v.bram(),
        bram_line: v.line_bram,
        bram_weights: v.weight_bram,
        bram_fifos: v.fifo_bram,
        bram_other: v.other_bram,
        dsp: v.dsp,
        lut,
        lutram,
        ff,
        device: device.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::{build_streaming_design, refresh_buffers};
    use crate::ir::builder::models;

    #[test]
    fn scalar_design_fits_easily() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(r.fits(), "{r}");
        assert!(r.bram18k > 0, "line buffers must show up");
    }

    #[test]
    fn violations_detected() {
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        d.nodes[0].timing.mac_lanes = 1 << 14; // absurd unroll
        refresh_buffers(&mut d);
        let r = estimate(&d, &DeviceSpec::kv260());
        assert!(!r.fits());
        assert!(r.violations().iter().any(|v| v.starts_with("DSP")));
    }

    #[test]
    fn breakdown_sums_to_total() {
        let g = models::conv_relu(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        assert_eq!(
            r.bram18k,
            r.bram_line + r.bram_weights + r.bram_fifos + r.bram_other
        );
        assert!(r.bram_line > 0, "line buffers must show up");
        assert!(r.bram_weights > 0, "the scalar conv keeps its ROM in BRAM");
        assert_eq!(r.bram_other, 0, "MING designs have no whole-tensor buffers");
    }

    #[test]
    fn display_contains_key_fields() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let r = estimate(&d, &DeviceSpec::kv260());
        let s = r.to_string();
        assert!(s.contains("BRAM") && s.contains("DSP"));
        // the fabric estimate (resources::fabric) is reported in absolute
        // LUT/FF counts alongside the device percentages
        assert!(s.contains(&format!("LUT {} (", r.lut)), "{s}");
        assert!(s.contains(&format!("FF {} (", r.ff)), "{s}");
    }
}
