//! Hardware resource models (the paper's contribution #3: a resource
//! utilization estimator supporting integer arithmetic, claimed more
//! accurate than the state of the art).
//!
//! Numbers are estimated analytically from the design structure exactly
//! the way MING's compile-time model must (no HDL in the loop):
//! [`bram`] packs arrays into RAM18K slices respecting ARRAY_PARTITION,
//! [`dsp`] counts DSP48E2 blocks per integer MAC lane (two int8 MACs per
//! DSP via INT8 packing), [`fabric`] regresses LUT/LUTRAM/FF from node
//! structure, [`model`] is the unified per-candidate/per-design resource
//! vector (line-buffer + weight-ROM + FIFO BRAM, DSP) shared by the DSE,
//! the tiling subsystem, reports and codegen, and [`report`] aggregates
//! + checks device constraints.

pub mod device;
pub mod bram;
pub mod dsp;
pub mod fabric;
pub mod model;
pub mod report;

pub use device::DeviceSpec;
pub use model::{ResourceModel, ResourceVec};
pub use report::{estimate, UtilizationReport};
