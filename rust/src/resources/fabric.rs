//! LUT / LUTRAM / FF fabric estimation.
//!
//! HLS reports notoriously overestimate fabric (paper §V-A observes this
//! and re-measures after place&route); what matters for the Table III
//! reproduction is the *relative* consumption of the three framework
//! strategies. The per-structure constants below are first-order post-PnR
//! figures for UltraScale+ integer datapaths:
//!
//! * a pipelined int8 MAC lane (beyond its DSP) leaves ~12 LUT / ~20 FF of
//!   operand muxing and accumulation registers;
//! * a saturating int ALU lane (relu/add/requant) is ~35 LUT / ~24 FF;
//! * node control (FSM, counters, handshakes) ~250 LUT / ~350 FF;
//! * distributed RAM stores 64 bits per LUT (RAM64X1D);
//! * SRL-based shallow FIFOs store 32 bits per LUT plus ~45 LUT control;
//! * fully-partitioned register arrays land 1 FF per bit.

use crate::dataflow::buffers::{BufferAlloc, BufferRole, Storage};
use crate::dataflow::channel::Channel;
use crate::dataflow::design::Design;
use crate::resources::bram::FIFO_SRL_MAX_DEPTH;

pub const LUT_PER_MAC_LANE: u64 = 12;
pub const FF_PER_MAC_LANE: u64 = 20;
pub const LUT_PER_ALU_LANE: u64 = 35;
pub const FF_PER_ALU_LANE: u64 = 24;
pub const LUT_NODE_BASE: u64 = 250;
pub const FF_NODE_BASE: u64 = 350;
pub const LUTRAM_BITS_PER_LUT: u64 = 64;
pub const SRL_BITS_PER_LUT: u64 = 32;
pub const LUT_FIFO_CTRL: u64 = 45;

// HLS-managed argument arrays (ScaleHLS strategy): the tool realizes the
// whole intermediate tensor as fabric circuitry — datapath muxing LUTs and
// pipeline FFs proportional to the array size. Constants calibrated to the
// paper's Table III (ScaleHLS Conv+ReLU 32x32: 11.8% LUT / 4% LUTRAM /
// 8.4% FF on the KV260).
pub const ARG_ARRAY_LUT_PER_BITS: u64 = 20;
pub const ARG_ARRAY_FF_PER_BITS: u64 = 12;

// StreamHLS reorder infrastructure: the "additional newly created tensor"
// per edge comes with stream-splitting, reorder address generation and
// width-conversion datapaths whose cost tracks the tensor size. Calibrated
// to Table III (StreamHLS Conv+ReLU 32x32: 20.3% LUT / 7% LUTRAM /
// 14.6% FF).
pub const REORDER_LUT_PER_BITS: u64 = 12;
pub const REORDER_LUTRAM_PER_BITS: u64 = 64;
pub const REORDER_FF_PER_BITS: u64 = 8;

/// Fabric usage triple.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Fabric {
    pub lut: u64,
    pub lutram: u64,
    pub ff: u64,
}

impl Fabric {
    pub fn add(&mut self, o: Fabric) {
        self.lut += o.lut;
        self.lutram += o.lutram;
        self.ff += o.ff;
    }
}

/// Fabric cost of one buffer allocation.
pub fn buffer_fabric(b: &BufferAlloc) -> Fabric {
    if b.role == BufferRole::ReorderBuffer {
        // reorder engine + stream splitting (see REORDER_* docs); the BRAM
        // storage itself is counted by the BRAM model.
        return Fabric {
            lut: b.bits / REORDER_LUT_PER_BITS,
            lutram: b.bits / REORDER_LUTRAM_PER_BITS,
            ff: b.bits / REORDER_FF_PER_BITS,
        };
    }
    match b.storage {
        Storage::Bram | Storage::Rom => Fabric::default(),
        Storage::Lutram => {
            // LUTRAM LUTs are also LUTs; partition control adds muxing.
            let lutram = b.bits.div_ceil(LUTRAM_BITS_PER_LUT).max(b.partitions);
            let mut f = Fabric { lut: lutram + 4 * b.partitions, lutram, ff: 2 * b.partitions };
            if b.role == BufferRole::IntermediateTensor {
                // HLS-managed argument array (see ARG_ARRAY_* docs)
                f.lut += b.bits / ARG_ARRAY_LUT_PER_BITS;
                f.ff += b.bits / ARG_ARRAY_FF_PER_BITS;
            }
            f
        }
        Storage::Ff => Fabric { lut: b.partitions * 2, lutram: 0, ff: b.bits },
    }
}

/// Fabric cost of one FIFO channel (SRL shallow FIFOs only; deep FIFOs
/// are BRAM-backed and cost control logic only).
pub fn channel_fabric(c: &Channel) -> Fabric {
    if c.externally_buffered {
        return Fabric { lut: LUT_FIFO_CTRL, lutram: 0, ff: 16 };
    }
    let lanes = c.lanes.max(1) as u64;
    let per_lane = c.depth as u64 * c.token_len as u64 / lanes;
    if per_lane <= FIFO_SRL_MAX_DEPTH {
        let bits = per_lane * lanes * c.elem_bits;
        let srl = bits.div_ceil(SRL_BITS_PER_LUT);
        Fabric { lut: srl + LUT_FIFO_CTRL, lutram: srl, ff: 8 * c.lanes as u64 }
    } else {
        Fabric { lut: LUT_FIFO_CTRL + 40, lutram: 0, ff: 16 }
    }
}

/// Fabric of the whole design: node datapaths + buffers + channels.
pub fn design_fabric(d: &Design) -> Fabric {
    let mut f = Fabric::default();
    for n in &d.nodes {
        let lanes = n.timing.mac_lanes.max(1);
        if n.geo.macs_per_out_token > 0 {
            f.add(Fabric {
                lut: LUT_NODE_BASE + lanes * LUT_PER_MAC_LANE,
                lutram: 0,
                ff: FF_NODE_BASE + lanes * FF_PER_MAC_LANE,
            });
        } else {
            f.add(Fabric {
                lut: LUT_NODE_BASE + lanes * LUT_PER_ALU_LANE,
                lutram: 0,
                ff: FF_NODE_BASE + lanes * FF_PER_ALU_LANE,
            });
        }
    }
    for b in &d.buffers {
        f.add(buffer_fabric(b));
    }
    for c in &d.channels {
        f.add(channel_fabric(c));
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::buffers::BufferRole;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    fn alloc(bits: u64, partitions: u64, storage: Storage) -> BufferAlloc {
        BufferAlloc {
            name: "t".into(),
            role: BufferRole::Weights,
            bits,
            partitions,
            storage,
            node: None,
        }
    }

    #[test]
    fn bram_buffers_cost_no_fabric() {
        assert_eq!(buffer_fabric(&alloc(10_000, 4, Storage::Bram)), Fabric::default());
    }

    #[test]
    fn lutram_packs_64_bits_per_lut() {
        let f = buffer_fabric(&alloc(6400, 1, Storage::Lutram));
        assert_eq!(f.lutram, 100);
        assert!(f.lut >= 100);
    }

    #[test]
    fn ff_storage_is_bit_per_ff() {
        let f = buffer_fabric(&alloc(576, 576, Storage::Ff));
        assert_eq!(f.ff, 576);
        assert_eq!(f.lutram, 0);
    }

    #[test]
    fn design_fabric_scales_with_lanes() {
        let g = models::conv_relu(32, 8, 8);
        let mut d1 = build_streaming_design(&g).unwrap();
        let f1 = design_fabric(&d1);
        d1.nodes[0].timing.mac_lanes = 576;
        let f2 = design_fabric(&d1);
        assert!(f2.lut > f1.lut && f2.ff > f1.ff);
    }

    #[test]
    fn ming_conv_fabric_in_kv260_ballpark() {
        // Table III: MING Conv+ReLU ≈ 9% LUT, 1.7% LUTRAM, 5.2% FF of
        // the KV260. Assert we land within a factor-2 band.
        let g = models::conv_relu(32, 8, 8);
        let mut d = build_streaming_design(&g).unwrap();
        d.nodes[0].timing.mac_lanes = 576;
        d.nodes[0].timing.unroll_red = 72;
        d.nodes[0].timing.unroll_par = 8;
        d.nodes[1].timing.mac_lanes = 8;
        crate::dataflow::build::refresh_buffers(&mut d);
        let f = design_fabric(&d);
        let lut_pct = 100.0 * f.lut as f64 / 117_120.0;
        let ff_pct = 100.0 * f.ff as f64 / 234_240.0;
        assert!((3.0..20.0).contains(&lut_pct), "LUT% {lut_pct}");
        assert!((2.0..12.0).contains(&ff_pct), "FF% {ff_pct}");
    }
}
