//! Paper Algorithm 1 — sliding-window detection.
//!
//! A kernel is sliding-window iff some input indexing-map result is a
//! linear combination `s·i_p + δ·i_r` of exactly one *parallel* iterator
//! (coefficient `s` = stride) and one *reduction* iterator (coefficient
//! `δ` = dilation), both positive. Regular-reduction access patterns never
//! match this invariant. Runs in `O(Σ|E|)` over all map results.

use crate::ir::generic::{GenericOp, IterType};

/// Result of a positive sliding-window detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlidingWindow {
    pub stride: i64,
    pub dilation: i64,
    /// The parallel (spatial) iterator of the matched expression.
    pub parallel_dim: usize,
    /// The reduction (window) iterator of the matched expression.
    pub reduction_dim: usize,
}

/// Algorithm 1. Returns `Some(SlidingWindow)` with extracted stride and
/// dilation iff `op` exhibits sliding-window semantics.
pub fn detect_sliding_window(op: &GenericOp) -> Option<SlidingWindow> {
    // line 1: all-parallel ops can't slide
    if !op.has_reduction() {
        return None;
    }
    // lines 2-11: scan every result expression of every *input* map
    for map in op.input_maps() {
        for expr in &map.results {
            // line 4: rewrite E as a sum of (iterator · const) terms
            let Some((terms, _konst)) = expr.linear_terms() else {
                continue;
            };
            // exactly two dim terms, one parallel one reduction (lines 5-6)
            if terms.len() != 2 {
                continue;
            }
            let (d_a, c_a) = terms[0];
            let (d_b, c_b) = terms[1];
            let (p, r, s, delta) = match (op.iter_types[d_a], op.iter_types[d_b]) {
                (IterType::Parallel, IterType::Reduction) => (d_a, d_b, c_a, c_b),
                (IterType::Reduction, IterType::Parallel) => (d_b, d_a, c_b, c_a),
                _ => continue,
            };
            // nonzero positive coefficients (s, δ) required
            if s > 0 && delta > 0 {
                // line 7-8: stride = parallel coeff, dilation = reduction coeff
                return Some(SlidingWindow {
                    stride: s,
                    dilation: delta,
                    parallel_dim: p,
                    reduction_dim: r,
                });
            }
        }
    }
    // line 12
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{models, GraphBuilder};
    use crate::ir::types::DType;

    #[test]
    fn conv_is_sliding_window() {
        let g = models::conv_relu(16, 4, 4);
        let sw = detect_sliding_window(g.op("conv0").unwrap()).unwrap();
        assert_eq!(sw.stride, 1);
        assert_eq!(sw.dilation, 1);
        assert_eq!(sw.parallel_dim, 0);
        assert_eq!(sw.reduction_dim, 3);
    }

    #[test]
    fn strided_dilated_conv_extracts_parameters() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![32, 32, 2], DType::I8);
        let w = b.det_weight("w", vec![2, 3, 3, 2], 1);
        let acc = b.conv2d_dilated("c", x, w, 2, 0, 3);
        b.mark_output(acc);
        let g = b.finish();
        let sw = detect_sliding_window(g.op("c").unwrap()).unwrap();
        assert_eq!(sw.stride, 2);
        assert_eq!(sw.dilation, 3);
    }

    #[test]
    fn matmul_is_not_sliding_window() {
        let g = models::linear();
        assert_eq!(detect_sliding_window(g.op("mm0").unwrap()), None);
    }

    #[test]
    fn elementwise_is_not_sliding_window() {
        let g = models::conv_relu(16, 4, 4);
        assert_eq!(detect_sliding_window(g.op("rr0").unwrap()), None);
    }

    #[test]
    fn maxpool_is_sliding_window_without_weights() {
        let mut b = GraphBuilder::new("mp");
        let x = b.input("x", vec![8, 8, 2], DType::I8);
        let y = b.maxpool2d("pool", x, 2, 2);
        b.mark_output(y);
        let g = b.finish();
        let sw = detect_sliding_window(g.op("pool").unwrap()).unwrap();
        assert_eq!(sw.stride, 2);
        assert_eq!(sw.dilation, 1);
    }
}
