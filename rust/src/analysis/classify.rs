//! Kernel-class assignment (paper §IV-A): every `linalg.generic` op is
//! *pure parallel*, *regular reduction*, or *sliding window*; each class
//! gets its own dataflow/buffering strategy in `dataflow::build`.

use crate::ir::generic::GenericOp;

use super::sliding::{detect_sliding_window, SlidingWindow};

/// The three kernel categories of MING.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// All iterators parallel; consume-compute-produce per element.
    PureParallel,
    /// Has reduction dims but no sliding access; buffers one data line.
    RegularReduction,
    /// Sliding-window access; line buffer + window buffer.
    SlidingWindow(SlidingWindow),
}

impl KernelClass {
    pub fn name(&self) -> &'static str {
        match self {
            KernelClass::PureParallel => "pure-parallel",
            KernelClass::RegularReduction => "regular-reduction",
            KernelClass::SlidingWindow(_) => "sliding-window",
        }
    }
}

/// Classify one generic op.
pub fn classify(op: &GenericOp) -> KernelClass {
    if let Some(sw) = detect_sliding_window(op) {
        return KernelClass::SlidingWindow(sw);
    }
    if op.has_reduction() {
        KernelClass::RegularReduction
    } else {
        KernelClass::PureParallel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn paper_kernel_classes() {
        let g = models::conv_relu(16, 4, 4);
        assert!(matches!(classify(g.op("conv0").unwrap()), KernelClass::SlidingWindow(_)));
        assert_eq!(classify(g.op("rr0").unwrap()), KernelClass::PureParallel);

        let g = models::linear();
        assert_eq!(classify(g.op("mm0").unwrap()), KernelClass::RegularReduction);

        let g = models::residual(16, 4, 4);
        assert_eq!(classify(g.op("add0").unwrap()), KernelClass::PureParallel);
        assert_eq!(classify(g.op("relu_out").unwrap()), KernelClass::PureParallel);
    }

    #[test]
    fn class_names() {
        assert_eq!(KernelClass::PureParallel.name(), "pure-parallel");
        assert_eq!(KernelClass::RegularReduction.name(), "regular-reduction");
    }

    #[test]
    fn every_table2_op_is_classified_consistently() {
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(8)).unwrap();
            for op in &g.ops {
                let c = classify(op);
                match c {
                    KernelClass::SlidingWindow(sw) => {
                        assert!(sw.stride > 0 && sw.dilation > 0);
                        assert!(op.has_reduction());
                    }
                    KernelClass::RegularReduction => assert!(op.has_reduction()),
                    KernelClass::PureParallel => assert!(!op.has_reduction()),
                }
            }
        }
    }
}
