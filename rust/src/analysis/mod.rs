//! Kernel analysis (paper §IV-A): sliding-window detection (Algorithm 1),
//! iterator classification into P/R/O/W sets (Algorithm 2), kernel-class
//! assignment, and derived geometry (stream widths, line-buffer shapes).

pub mod sliding;
pub mod iters;
pub mod classify;
pub mod shapes;

pub use classify::{classify, KernelClass};
pub use iters::{classify_iterators, IterSets};
pub use sliding::{detect_sliding_window, SlidingWindow};
