//! Derived per-op geometry: token shapes for the streaming contract,
//! line-buffer / window-buffer sizes, and per-token work. This is the
//! "stream and buffer creation" information of paper §IV-B, computed from
//! the Algorithm 1/2 results plus tensor shapes.
//!
//! **Streaming contract.** Tensors flow through FIFOs in row-major order,
//! one *token* per innermost position group:
//!   * `(H, W, C)` feature maps: `H·W` tokens of `C` values (one pixel);
//!   * `(M, K)` activation matrices: `M` tokens of `K` values (one row).
//! Weights never stream — they are resident constants inside their node.

use anyhow::{ensure, Result};

use crate::ir::generic::{GenericOp, Payload};
use crate::ir::graph::{ModelGraph, TensorKind};

use super::classify::{classify, KernelClass};

/// Line buffer geometry (sliding-window and regular-reduction nodes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LineBufferShape {
    /// Number of buffered lines ((K-1) for a K-window; 1 for reductions).
    pub rows: usize,
    /// Values per line (W·C for conv; K for linear).
    pub row_len: usize,
    /// Element bit width.
    pub elem_bits: u64,
}

impl LineBufferShape {
    pub fn total_bits(&self) -> u64 {
        self.rows as u64 * self.row_len as u64 * self.elem_bits
    }

    /// The geometry this line buffer takes when its node's input map is
    /// `new_w` columns wide instead of `old_w` (halo columns included in
    /// `new_w`). Row storage is `rows × W·C`, so only `row_len` rescales
    /// — the basis of the tile-grid subsystem's per-cell BRAM accounting
    /// (`crate::tiling::cost::cell_bram_lower_bound`). For strided
    /// chains the caller passes each node's *own* local input width
    /// (from `crate::tiling::local_extents`): downstream of a stride-s
    /// op the cell width shrinks by `s`, and so does the line buffer.
    /// Height never enters: row count is `K−1` regardless of how many
    /// rows a grid cell spans, which is why BRAM-driven grid searches
    /// prefer width-major splits.
    pub fn at_width(&self, old_w: usize, new_w: usize) -> LineBufferShape {
        let per_col = self.row_len / old_w.max(1);
        LineBufferShape { rows: self.rows, row_len: per_col * new_w, elem_bits: self.elem_bits }
    }
}

/// Everything the dataflow builder / DSE / simulator need to know about
/// one op's streaming shape.
#[derive(Debug, Clone)]
pub struct NodeGeometry {
    /// Kernel class from Algorithm 1 + 2.
    pub class: KernelClass,
    /// Values per token for each *activation* input (weights excluded).
    pub in_token_len: Vec<usize>,
    /// Tokens per activation input for one graph execution.
    pub in_tokens: Vec<u64>,
    /// Values per output token.
    pub out_token_len: usize,
    /// Output tokens for one graph execution.
    pub out_tokens: u64,
    /// Line buffer, if the class requires one.
    pub line_buffer: Option<LineBufferShape>,
    /// Window buffer (K × K × C values), sliding-window class only.
    pub window_values: Option<usize>,
    /// MAC operations needed to produce one output token.
    pub macs_per_out_token: u64,
    /// Non-MAC ALU ops per output token.
    pub alu_per_out_token: u64,
    /// Tokens that must be consumed before the first output token can be
    /// produced (line-buffer warm-up; 0 for pure-parallel).
    pub warmup_tokens: u64,
}

/// Indices of `op.inputs` that are activations (non-weight operands).
pub fn activation_inputs(g: &ModelGraph, op: &GenericOp) -> Vec<usize> {
    op.inputs
        .iter()
        .enumerate()
        .filter(|(_, &t)| g.tensor(t).kind != TensorKind::Weight)
        .map(|(i, _)| i)
        .collect()
}

/// Token shape of a tensor: (token_count, values_per_token).
pub fn tensor_tokens(shape: &[usize]) -> (u64, usize) {
    match shape.len() {
        0 => (1, 1),
        1 => (1, shape[0]),
        _ => {
            let lead: u64 = shape[..shape.len() - 1].iter().map(|&d| d as u64).product();
            (lead, shape[shape.len() - 1])
        }
    }
}

/// Compute the full streaming geometry of one op within its graph.
pub fn node_geometry(g: &ModelGraph, op: &GenericOp) -> Result<NodeGeometry> {
    let class = classify(op);
    let act_idx = activation_inputs(g, op);
    ensure!(!act_idx.is_empty(), "op {} has no activation inputs", op.name);

    let mut in_token_len = Vec::new();
    let mut in_tokens = Vec::new();
    for &i in &act_idx {
        let t = g.tensor(op.inputs[i]);
        let (n, len) = tensor_tokens(&t.ty.shape);
        in_tokens.push(n);
        in_token_len.push(len);
    }
    let out_t = g.tensor(op.output);
    let (out_tokens, out_token_len) = tensor_tokens(&out_t.ty.shape);

    let elem_bits = g.tensor(op.inputs[act_idx[0]]).ty.dtype.bits();
    let macs_total = op.iter_space() * op.payload.macs_per_iter();
    let alu_total = op.iter_space() * op.payload.alu_per_iter().max(
        // reduction payloads like MaxReduce do one compare per iter
        if op.payload == Payload::MaxReduce { 1 } else { 0 },
    );

    let (line_buffer, window_values, warmup) = match class {
        KernelClass::SlidingWindow(sw) => {
            // Window extent along the sliding (reduction) dims: product of
            // trips of reduction dims that participate in compound exprs.
            let in_shape = &g.tensor(op.inputs[act_idx[0]]).ty.shape;
            let k = op.dims[sw.reduction_dim];
            // line width = input row length × channels (all trailing axes)
            let row_vals: usize = in_shape[1..].iter().product();
            let lb = LineBufferShape { rows: k.saturating_sub(1), row_len: row_vals, elem_bits };
            // window buffer: product of all reduction-dim trips (K·K·C for
            // conv, K·K for pooling)
            let winvals: usize = op.reduction_space() as usize;
            // First output row needs (K-1-pad) full input rows + K-pad pixels.
            let w_in = in_shape.get(1).copied().unwrap_or(1) as u64;
            let rows_needed = (k.saturating_sub(1 + op.pad)) as u64;
            (Some(lb), Some(winvals), rows_needed * w_in + 1)
        }
        KernelClass::RegularReduction => {
            // buffer one data line (the row being reduced)
            let len = in_token_len[0];
            let lb = LineBufferShape { rows: 1, row_len: len, elem_bits };
            (Some(lb), None, 1)
        }
        KernelClass::PureParallel => (None, None, 0),
    };

    Ok(NodeGeometry {
        class,
        in_token_len,
        in_tokens,
        out_token_len,
        out_tokens,
        line_buffer,
        window_values,
        macs_per_out_token: macs_total / out_tokens.max(1),
        alu_per_out_token: alu_total / out_tokens.max(1),
        warmup_tokens: warmup,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    #[test]
    fn conv_geometry_paper_line_buffer() {
        // Paper §IV-B: N×N input, K×K kernel -> line buffer (K-1)×N (×C).
        let g = models::conv_relu(32, 8, 8);
        let geo = node_geometry(&g, g.op("conv0").unwrap()).unwrap();
        let lb = geo.line_buffer.unwrap();
        assert_eq!(lb.rows, 2);
        assert_eq!(lb.row_len, 32 * 8);
        assert_eq!(lb.total_bits(), 2 * 32 * 8 * 8);
        assert_eq!(geo.window_values, Some(3 * 3 * 8));
        assert_eq!(geo.in_tokens, vec![32 * 32]);
        assert_eq!(geo.in_token_len, vec![8]);
        assert_eq!(geo.out_tokens, 32 * 32);
        assert_eq!(geo.out_token_len, 8);
        // F·K·K·C MACs per output pixel
        assert_eq!(geo.macs_per_out_token, 8 * 9 * 8);
        assert!(geo.warmup_tokens >= 32); // ≥ one row minus padding
    }

    #[test]
    fn linear_geometry_one_line() {
        let g = models::linear();
        let geo = node_geometry(&g, g.op("mm0").unwrap()).unwrap();
        let lb = geo.line_buffer.unwrap();
        assert_eq!(lb.rows, 1);
        assert_eq!(lb.row_len, 128);
        assert_eq!(geo.in_tokens, vec![512]);
        assert_eq!(geo.out_tokens, 512);
        assert_eq!(geo.out_token_len, 128);
        assert_eq!(geo.macs_per_out_token, 128 * 128);
        assert!(geo.window_values.is_none());
    }

    #[test]
    fn pure_parallel_geometry_no_buffers() {
        let g = models::conv_relu(32, 8, 8);
        let geo = node_geometry(&g, g.op("rr0").unwrap()).unwrap();
        assert!(geo.line_buffer.is_none());
        assert_eq!(geo.warmup_tokens, 0);
        assert_eq!(geo.macs_per_out_token, 0);
        assert!(geo.alu_per_out_token > 0);
    }

    #[test]
    fn add_has_two_activation_inputs() {
        let g = models::residual(16, 8, 8);
        let add = g.op("add0").unwrap();
        let geo = node_geometry(&g, add).unwrap();
        assert_eq!(geo.in_tokens.len(), 2);
        assert_eq!(geo.in_tokens[0], geo.in_tokens[1]);
    }

    #[test]
    fn conv_weights_not_streamed() {
        let g = models::conv_relu(16, 8, 8);
        let conv = g.op("conv0").unwrap();
        assert_eq!(activation_inputs(&g, conv), vec![0]);
    }

    #[test]
    fn tensor_token_shapes() {
        assert_eq!(tensor_tokens(&[32, 32, 8]), (1024, 8));
        assert_eq!(tensor_tokens(&[512, 128]), (512, 128));
        assert_eq!(tensor_tokens(&[128]), (1, 128));
    }

    #[test]
    fn line_buffer_at_width_rescales_rows_only() {
        let g = models::conv_relu(32, 8, 8);
        let lb = node_geometry(&g, g.op("conv0").unwrap()).unwrap().line_buffer.unwrap();
        let strip = lb.at_width(32, 18);
        assert_eq!(strip.rows, lb.rows);
        assert_eq!(strip.row_len, 18 * 8);
        assert_eq!(strip.elem_bits, lb.elem_bits);
        // identity at the same width
        assert_eq!(lb.at_width(32, 32), lb);
    }

    #[test]
    fn line_buffer_grows_linearly_with_input_size() {
        // The MING headline: line buffer bits scale with N, not N².
        let g32 = models::conv_relu(32, 8, 8);
        let g224 = models::conv_relu(224, 8, 8);
        let lb32 = node_geometry(&g32, g32.op("conv0").unwrap()).unwrap().line_buffer.unwrap();
        let lb224 =
            node_geometry(&g224, g224.op("conv0").unwrap()).unwrap().line_buffer.unwrap();
        assert_eq!(lb224.total_bits() / lb32.total_bits(), 224 / 32);
    }
}
