//! Paper Algorithm 2 — iterator classification for stream and line-buffer
//! construction.
//!
//! Walks every input indexing map: single-dim results land in `P`
//! (parallel) or `R` (reduction); compound results (the sliding
//! expressions) land in `O` (original input dims). Output-map parallel
//! dims not already in `P` form `W` (window / spatial walk dims).

use std::collections::BTreeSet;

use crate::ir::generic::{GenericOp, IterType};

/// The four dimension sets of paper Algorithm 2.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IterSets {
    /// Parallel dims: independent lanes shared by inputs and output —
    /// define the initial shape (width) of the *output* streams.
    pub p: BTreeSet<usize>,
    /// Reduction dims: accumulation axes — define the *input* stream shape.
    pub r: BTreeSet<usize>,
    /// Original input dims: compound (sliding) accesses that must be
    /// preserved to size line buffers.
    pub o: BTreeSet<usize>,
    /// Window dims: output-map parallel dims not in P — the spatial extent
    /// the window walks; compute-window data comes from the line buffer.
    pub w: BTreeSet<usize>,
}

/// Algorithm 2.
pub fn classify_iterators(op: &GenericOp) -> IterSets {
    let mut s = IterSets::default();
    // lines 2-12: input maps
    for map in op.input_maps() {
        for expr in &map.results {
            if let Some(d) = expr.single_dim() {
                match op.iter_types[d] {
                    IterType::Parallel => {
                        s.p.insert(d);
                    }
                    IterType::Reduction => {
                        s.r.insert(d);
                    }
                }
            } else {
                // compound expression: record every referenced dim as an
                // original-input dim (the sliding access)
                for d in expr.dims() {
                    s.o.insert(d);
                }
            }
        }
    }
    // lines 13-16: output map
    for expr in &op.output_map().results {
        if let Some(d) = expr.single_dim() {
            if op.iter_types[d] == IterType::Parallel && !s.p.contains(&d) {
                s.w.insert(d);
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::models;

    fn set(v: &[usize]) -> BTreeSet<usize> {
        v.iter().copied().collect()
    }

    #[test]
    fn conv_sets_match_paper_semantics() {
        // conv dims: d0=h, d1=w, d2=f (P), d3=kh, d4=kw, d5=c (R)
        // x map: (d0+d3-1, d1+d4-1, d5)  w map: (d2,d3,d4,d5)  out: (d0,d1,d2)
        let g = models::conv_relu(16, 4, 4);
        let s = classify_iterators(g.op("conv0").unwrap());
        assert_eq!(s.p, set(&[2]), "P = {{f}} from the weight map");
        assert_eq!(s.r, set(&[3, 4, 5]), "R = {{kh, kw, c}}");
        assert_eq!(s.o, set(&[0, 1, 3, 4]), "O = sliding dims");
        assert_eq!(s.w, set(&[0, 1]), "W = output spatial walk dims");
    }

    #[test]
    fn matmul_sets() {
        // dims: d0=m, d1=n (P), d2=k (R); x:(d0,d2) w:(d2,d1) out:(d0,d1)
        let g = models::linear();
        let s = classify_iterators(g.op("mm0").unwrap());
        assert_eq!(s.p, set(&[0, 1]));
        assert_eq!(s.r, set(&[2]));
        assert!(s.o.is_empty());
        assert!(s.w.is_empty(), "no window walk for regular reduction");
    }

    #[test]
    fn elementwise_sets() {
        let g = models::conv_relu(16, 4, 4);
        let s = classify_iterators(g.op("rr0").unwrap());
        assert_eq!(s.p, set(&[0, 1, 2]), "identity map: all dims in P");
        assert!(s.r.is_empty() && s.o.is_empty() && s.w.is_empty());
    }

    #[test]
    fn sets_are_disjoint_where_required() {
        // P and W are disjoint by construction (line 14 guards E ∉ P).
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(8)).unwrap();
            for op in &g.ops {
                let s = classify_iterators(op);
                assert!(s.p.is_disjoint(&s.w), "{}: P ∩ W ≠ ∅", op.name);
                assert!(s.p.is_disjoint(&s.r), "{}: P ∩ R ≠ ∅", op.name);
            }
        }
    }
}
