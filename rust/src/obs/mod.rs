//! Pipeline-wide observability: span tracing, a unified metrics
//! registry, and profile rendering — zero external dependencies.
//!
//! Three pieces, threaded through every pipeline layer:
//!
//! * [`trace`] — nested timed spans (`lower → solve → estimate →
//!   simulate`, per grid-cell solve, per tiled sim cell) with
//!   per-thread lanes, exported as Chrome trace-event JSON
//!   (`--trace-out trace.json`, loadable in Perfetto).
//! * [`metrics`] — a global registry of named atomic counters/gauges
//!   unifying the previously scattered stats (cache, ILP, grid search,
//!   simulator, worker pool).
//! * [`render_profile`] — the `--profile` phase-time + counter table,
//!   built from a snapshot delta.
//!
//! Everything is off by default and asserted cheap-when-disabled: a
//! span against a disabled sink is two atomic loads, and hot loops
//! (per-firing simulator paths) only flush local counters into the
//! registry at run boundaries.

pub mod metrics;
pub mod trace;

pub use metrics::{Metric, Registry, Snapshot};
pub use trace::{SpanGuard, TraceSink};

use crate::util::tables::{fnum, TextTable};

/// Open a span on the global sink (static name; aggregates profile time
/// under `time.<cat>.<name>`).
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard<'static> {
    trace::global().span(cat, name)
}

/// Open a span on the global sink with a lazily-built name (aggregates
/// profile time under `time.<cat>`; the closure only runs when tracing
/// is enabled).
pub fn span_with<F: FnOnce() -> String>(cat: &'static str, name: F) -> SpanGuard<'static> {
    trace::global().span_with(cat, name)
}

/// Render the `--profile` table from a metrics snapshot (usually a
/// [`Snapshot::delta`] covering one command). Phase times (`time.*`
/// keys, microseconds) print first as milliseconds; counters follow.
pub fn render_profile(snap: &Snapshot) -> String {
    let mut t = TextTable::new(vec!["metric", "value"]);
    for (name, v) in snap.iter() {
        if let Some(phase) = name.strip_prefix("time.") {
            t.row(vec![format!("time {phase}"), format!("{} ms", fnum(v as f64 / 1000.0, 2))]);
        }
    }
    for (name, v) in snap.iter() {
        if !name.starts_with("time.") {
            t.row(vec![name.to_string(), v.to_string()]);
        }
    }
    if t.is_empty() {
        return "profile: no activity recorded\n".to_string();
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_table_orders_times_before_counters() {
        let r = Registry::new();
        let before = r.snapshot();
        r.add("cache.hits", 12);
        r.add("time.stage.solve", 2500);
        let d = r.snapshot().delta(&before);
        let out = render_profile(&d);
        let time_at = out.find("time stage.solve").unwrap();
        let ctr_at = out.find("cache.hits").unwrap();
        assert!(time_at < ctr_at, "phase times render before counters:\n{out}");
        assert!(out.contains("2.5 ms"), "{out}");
    }

    #[test]
    fn empty_profile_has_a_placeholder() {
        assert!(render_profile(&Snapshot::default()).contains("no activity"));
    }
}
