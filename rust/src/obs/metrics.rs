//! Unified metrics registry: named atomic counters and max-gauges.
//!
//! One global registry absorbs the pipeline's previously scattered stats
//! (cache hits/misses, ILP nodes explored/pruned, grid candidates
//! tried/rejected, sim firings/token-ops/arena high-water, worker-pool
//! busy/idle time). Hot loops keep their local counters and flush totals
//! here at run boundaries — the registry itself is only touched at coarse
//! points, so a `Mutex<BTreeMap>` name lookup per update is cheap. Sites
//! that update more often can grab a [`Metric`] handle once and bump the
//! shared atomic directly.
//!
//! Naming convention: `subsystem.stat` (`cache.hits`, `dse.pruned`,
//! `sim.firings`, `sched.busy_us`); span-derived phase times land under
//! `time.*` in microseconds (see [`crate::obs::trace`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A registry of named `u64` metrics. Counters accumulate with [`add`];
/// high-water gauges accumulate with [`gauge_max`].
///
/// [`add`]: Registry::add
/// [`gauge_max`]: Registry::gauge_max
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn cell(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(c) = m.get(name) {
            return Arc::clone(c);
        }
        let c = Arc::new(AtomicU64::new(0));
        m.insert(name.to_string(), Arc::clone(&c));
        c
    }

    /// A shared handle for hot call sites: one name lookup, then direct
    /// atomic updates.
    pub fn handle(&self, name: &str) -> Metric {
        Metric(self.cell(name))
    }

    /// Add `v` to the named counter (creating it at zero first).
    pub fn add(&self, name: &str, v: u64) {
        self.cell(name).fetch_add(v, Ordering::Relaxed);
    }

    /// Increment the named counter by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Subtract `v` from the named counter — only for callers undoing
    /// their own earlier `add` (e.g. demoting a cache hit to a miss).
    pub fn sub(&self, name: &str, v: u64) {
        self.cell(name).fetch_sub(v, Ordering::Relaxed);
    }

    /// Raise the named gauge to `v` if `v` is larger (high-water mark).
    pub fn gauge_max(&self, name: &str, v: u64) {
        self.cell(name).fetch_max(v, Ordering::Relaxed);
    }

    /// Current value of a metric (0 if never touched).
    pub fn get(&self, name: &str) -> u64 {
        let m = self.metrics.lock().unwrap();
        m.get(name).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// A point-in-time copy of every metric, name-ordered.
    pub fn snapshot(&self) -> Snapshot {
        let m = self.metrics.lock().unwrap();
        Snapshot {
            values: m.iter().map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed))).collect(),
        }
    }
}

/// A shared counter/gauge handle (see [`Registry::handle`]).
#[derive(Clone)]
pub struct Metric(Arc<AtomicU64>);

impl Metric {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An immutable, ordered view of registry values; subtracting two
/// snapshots attributes activity to the work between them.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Snapshot {
    values: BTreeMap<String, u64>,
}

impl Snapshot {
    pub fn get(&self, name: &str) -> u64 {
        self.values.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.values.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Per-name saturating difference `self - earlier`, dropping zeros.
    /// (Saturating: gauges snapshotted mid-update never underflow.)
    pub fn delta(&self, earlier: &Snapshot) -> Snapshot {
        let values = self
            .values
            .iter()
            .map(|(k, v)| (k.clone(), v.saturating_sub(earlier.get(k))))
            .filter(|(_, d)| *d > 0)
            .collect();
        Snapshot { values }
    }
}

/// The process-wide registry every pipeline layer reports into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let r = Registry::new();
        r.incr("a.hits");
        r.add("a.hits", 4);
        r.gauge_max("a.hw", 10);
        r.gauge_max("a.hw", 3);
        assert_eq!(r.get("a.hits"), 5);
        assert_eq!(r.get("a.hw"), 10);
        assert_eq!(r.get("never.touched"), 0);
    }

    #[test]
    fn handles_share_the_same_cell() {
        let r = Registry::new();
        let h = r.handle("x");
        h.add(7);
        r.incr("x");
        assert_eq!(h.get(), 8);
        assert_eq!(r.get("x"), 8);
    }

    #[test]
    fn snapshot_delta_drops_zeros_and_orders_names() {
        let r = Registry::new();
        r.add("b.two", 2);
        r.add("a.one", 1);
        let before = r.snapshot();
        r.add("b.two", 3);
        r.add("c.new", 9);
        let after = r.snapshot();
        let d = after.delta(&before);
        let got: Vec<(String, u64)> = d.iter().map(|(k, v)| (k.to_string(), v)).collect();
        assert_eq!(got, vec![("b.two".to_string(), 3), ("c.new".to_string(), 9)]);
        assert_eq!(d.get("a.one"), 0);
    }

    #[test]
    fn concurrent_updates_are_lossless() {
        let r = std::sync::Arc::new(Registry::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let r = std::sync::Arc::clone(&r);
                s.spawn(move || {
                    let h = r.handle("t.count");
                    for _ in 0..1000 {
                        h.incr();
                    }
                });
            }
        });
        assert_eq!(r.get("t.count"), 4000);
    }
}
