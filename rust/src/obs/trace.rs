//! Span tracing with Chrome trace-event export.
//!
//! A [`TraceSink`] records nested timed phases as begin/end event pairs
//! with per-thread lanes; [`TraceSink::write_chrome_trace`] emits the
//! Chrome trace-event JSON array that `chrome://tracing` and Perfetto
//! load directly, so a sharded sweep renders as one lane per worker
//! thread with per-stage spans (`lower → solve → estimate → simulate`)
//! nested under each job.
//!
//! Cost model: a span against a sink with both tracing and profiling
//! disabled is two relaxed atomic loads — no clock read, no allocation,
//! no lock (asserted by `disabled_sink_spans_record_nothing`). With
//! profiling enabled (and tracing off), spans skip event recording and
//! only accumulate `time.<cat>[.<name>]` microsecond counters into the
//! global metrics registry — that feeds the `--profile` table without
//! paying for trace storage.

use std::borrow::Cow;
use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::thread::ThreadId;
use std::time::Instant;

use super::metrics;

/// One begin or end trace event. `ts_us` is microseconds since the
/// sink's origin; `tid` is the sink-assigned lane for the recording
/// thread (dense, in order of first appearance).
#[derive(Debug, Clone)]
struct Event {
    name: Cow<'static, str>,
    cat: &'static str,
    begin: bool,
    ts_us: u64,
    tid: u64,
    /// Optional single `"args":{key:value}` annotation, emitted on the
    /// begin event (Perfetto shows it in the span's detail pane — e.g.
    /// the scheduler tags stolen tasks with their victim lane).
    arg: Option<(&'static str, String)>,
}

#[derive(Default)]
struct Inner {
    events: Vec<Event>,
    lanes: HashMap<ThreadId, u64>,
    lane_names: HashMap<u64, String>,
}

impl Inner {
    fn lane(&mut self, id: ThreadId) -> u64 {
        let next = self.lanes.len() as u64;
        *self.lanes.entry(id).or_insert(next)
    }
}

/// Collects span events; instantiable for tests, with one process-wide
/// instance behind [`global`].
pub struct TraceSink {
    tracing: AtomicBool,
    profiling: AtomicBool,
    origin: Instant,
    inner: Mutex<Inner>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    pub fn new() -> Self {
        TraceSink {
            tracing: AtomicBool::new(false),
            profiling: AtomicBool::new(false),
            origin: Instant::now(),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Turn event recording on/off (the `--trace-out` switch).
    pub fn set_tracing(&self, on: bool) {
        self.tracing.store(on, Ordering::Relaxed);
    }

    /// Turn phase-time accumulation on/off (the `--profile` switch).
    pub fn set_profiling(&self, on: bool) {
        self.profiling.store(on, Ordering::Relaxed);
    }

    pub fn is_tracing(&self) -> bool {
        self.tracing.load(Ordering::Relaxed)
    }

    pub fn is_profiling(&self) -> bool {
        self.profiling.load(Ordering::Relaxed)
    }

    /// Name the calling thread's lane in the exported trace (e.g.
    /// `worker-3`). No-op while tracing is disabled.
    pub fn set_thread_label(&self, label: &str) {
        if !self.is_tracing() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let lane = inner.lane(std::thread::current().id());
        inner.lane_names.insert(lane, label.to_string());
    }

    /// Open a span with a static name. Dropping the guard closes it.
    /// Profile time aggregates under `time.<cat>.<name>`.
    pub fn span(&self, cat: &'static str, name: &'static str) -> SpanGuard<'_> {
        self.span_impl(cat, || Cow::Borrowed(name), true)
    }

    /// Open a span whose name is built lazily — the closure only runs
    /// (and allocates) when tracing is enabled. Profile time aggregates
    /// under `time.<cat>` (dynamic names would explode cardinality).
    pub fn span_with<F>(&self, cat: &'static str, name: F) -> SpanGuard<'_>
    where
        F: FnOnce() -> String,
    {
        self.span_impl(cat, || Cow::Owned(name()), false)
    }

    /// Open a span with a static name plus one `"args":{key:value}`
    /// annotation on the begin event. The value closure only runs (and
    /// allocates) when tracing is enabled; profile time aggregates
    /// under `time.<cat>.<name>` like [`Self::span`].
    pub fn span_with_arg<F>(
        &self,
        cat: &'static str,
        name: &'static str,
        key: &'static str,
        value: F,
    ) -> SpanGuard<'_>
    where
        F: FnOnce() -> String,
    {
        let tracing = self.is_tracing();
        let profiling = self.is_profiling();
        if !tracing && !profiling {
            return SpanGuard { sink: self, state: None };
        }
        if tracing {
            self.push(Cow::Borrowed(name), cat, true, Some((key, value())));
        }
        SpanGuard {
            sink: self,
            state: Some(SpanState {
                name: Cow::Borrowed(name),
                cat,
                static_name: true,
                tracing,
                profiling,
                start: Instant::now(),
            }),
        }
    }

    fn span_impl<F>(&self, cat: &'static str, name: F, static_name: bool) -> SpanGuard<'_>
    where
        F: FnOnce() -> Cow<'static, str>,
    {
        let tracing = self.is_tracing();
        let profiling = self.is_profiling();
        if !tracing && !profiling {
            return SpanGuard { sink: self, state: None };
        }
        let name = if tracing || static_name { name() } else { Cow::Borrowed("") };
        if tracing {
            self.push(name.clone(), cat, true, None);
        }
        SpanGuard {
            sink: self,
            state: Some(SpanState {
                name,
                cat,
                static_name,
                tracing,
                profiling,
                start: Instant::now(),
            }),
        }
    }

    fn push(
        &self,
        name: Cow<'static, str>,
        cat: &'static str,
        begin: bool,
        arg: Option<(&'static str, String)>,
    ) {
        let mut inner = self.inner.lock().unwrap();
        // Timestamp under the lock: the recorded order is globally
        // chronological, and per-lane B/E pairs nest by construction.
        let ts_us = self.origin.elapsed().as_micros() as u64;
        let tid = inner.lane(std::thread::current().id());
        inner.events.push(Event { name, cat, begin, ts_us, tid, arg });
    }

    /// Number of recorded events (tests; 0 while disabled).
    pub fn event_count(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    /// Render the Chrome trace-event JSON array (metadata events first,
    /// then B/E pairs in recorded order).
    pub fn to_chrome_json(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let pid = std::process::id();
        let mut out = String::from("[\n");
        let mut first = true;
        let mut emit = |line: String, out: &mut String| {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(&line);
        };
        emit(
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"name\":\"ming\"}}}}"
            ),
            &mut out,
        );
        let mut lanes: Vec<(&u64, &String)> = inner.lane_names.iter().collect();
        lanes.sort();
        for (tid, label) in lanes {
            emit(
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    escape(label)
                ),
                &mut out,
            );
        }
        for ev in &inner.events {
            let ph = if ev.begin { 'B' } else { 'E' };
            let args = match &ev.arg {
                Some((k, v)) => {
                    format!(",\"args\":{{\"{}\":\"{}\"}}", escape(k), escape(v))
                }
                None => String::new(),
            };
            emit(
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{ph}\",\"ts\":{},\
                     \"pid\":{pid},\"tid\":{}{args}}}",
                    escape(&ev.name),
                    escape(ev.cat),
                    ev.ts_us,
                    ev.tid
                ),
                &mut out,
            );
        }
        out.push_str("\n]\n");
        out
    }

    /// Write the Chrome trace JSON to `path` (Perfetto-loadable).
    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json())
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

struct SpanState {
    name: Cow<'static, str>,
    cat: &'static str,
    static_name: bool,
    tracing: bool,
    profiling: bool,
    start: Instant,
}

/// RAII span: records the end event (and/or accumulates profile time)
/// when dropped. Inert — no clock, no lock — when the sink was fully
/// disabled at open time.
pub struct SpanGuard<'a> {
    sink: &'a TraceSink,
    state: Option<SpanState>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(st) = self.state.take() else { return };
        if st.tracing {
            self.sink.push(st.name.clone(), st.cat, false, None);
        }
        if st.profiling {
            let us = st.start.elapsed().as_micros() as u64;
            if st.static_name {
                metrics::global().add(&format!("time.{}.{}", st.cat, st.name), us);
            } else {
                metrics::global().add(&format!("time.{}", st.cat), us);
            }
        }
    }
}

/// The process-wide sink the CLI arms via `--trace-out` / `--profile`.
pub fn global() -> &'static TraceSink {
    static GLOBAL: OnceLock<TraceSink> = OnceLock::new();
    GLOBAL.get_or_init(TraceSink::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::json::{parse, Json};

    #[test]
    fn disabled_sink_spans_record_nothing() {
        let sink = TraceSink::new();
        for _ in 0..10_000 {
            let _a = sink.span("stage", "solve");
            let _b = sink.span_with("job", || unreachable!("lazy name must not run"));
        }
        assert_eq!(sink.event_count(), 0);
    }

    #[test]
    fn spans_nest_and_pair_per_lane() {
        let sink = TraceSink::new();
        sink.set_tracing(true);
        {
            let _outer = sink.span("job", "j0");
            let _inner = sink.span("stage", "solve");
        }
        {
            let _late = sink.span_with("stage", || "estimate".to_string());
        }
        let json = sink.to_chrome_json();
        let doc = parse(&json).expect("trace must be valid JSON");
        let events = doc.as_arr().unwrap();
        // Per-lane: timestamps monotonic, B/E matched and well-nested.
        let mut stacks: HashMap<i64, Vec<String>> = HashMap::new();
        let mut last_ts: HashMap<i64, i64> = HashMap::new();
        let mut pairs = 0usize;
        for ev in events {
            let ph = ev.get("ph").unwrap().as_str().unwrap();
            if ph == "M" {
                continue;
            }
            let tid = ev.get("tid").unwrap().as_i64().unwrap();
            let ts = ev.get("ts").unwrap().as_i64().unwrap();
            let name = ev.get("name").unwrap().as_str().unwrap().to_string();
            assert!(ts >= last_ts.get(&tid).copied().unwrap_or(0), "ts regressed");
            last_ts.insert(tid, ts);
            let stack = stacks.entry(tid).or_default();
            match ph {
                "B" => stack.push(name),
                "E" => {
                    assert_eq!(stack.pop().as_deref(), Some(name.as_str()), "unmatched E");
                    pairs += 1;
                }
                other => panic!("unexpected ph {other}"),
            }
        }
        assert!(stacks.values().all(Vec::is_empty), "unclosed spans");
        assert_eq!(pairs, 3);
    }

    #[test]
    fn span_args_appear_on_begin_events_only() {
        let sink = TraceSink::new();
        sink.set_tracing(true);
        drop(sink.span_with_arg("sched", "steal", "stolen_from", || "worker-2".to_string()));
        let doc = parse(&sink.to_chrome_json()).unwrap();
        let events = doc.as_arr().unwrap();
        let begin = events
            .iter()
            .find(|ev| ev.get("ph").map(|p| p == &Json::Str("B".into())).unwrap_or(false))
            .expect("begin event");
        assert_eq!(
            begin.get("args").and_then(|a| a.get("stolen_from")).ok(),
            Some(&Json::Str("worker-2".into()))
        );
        let end = events
            .iter()
            .find(|ev| ev.get("ph").map(|p| p == &Json::Str("E".into())).unwrap_or(false))
            .expect("end event");
        assert!(end.get("args").is_err(), "args belong on the begin event");
    }

    #[test]
    fn span_arg_value_is_lazy_when_disabled() {
        let sink = TraceSink::new();
        drop(sink.span_with_arg("sched", "steal", "stolen_from", || {
            unreachable!("arg value must not be built while tracing is off")
        }));
        assert_eq!(sink.event_count(), 0);
    }

    #[test]
    fn thread_labels_become_metadata_events() {
        let sink = TraceSink::new();
        sink.set_tracing(true);
        sink.set_thread_label("worker-0");
        let _s = sink.span("stage", "lower");
        drop(_s);
        let doc = parse(&sink.to_chrome_json()).unwrap();
        let has_label = doc.as_arr().unwrap().iter().any(|ev| {
            ev.get("name").map(|n| n == &Json::Str("thread_name".into())).unwrap_or(false)
                && ev
                    .get("args")
                    .and_then(|a| a.get("name"))
                    .map(|n| n == &Json::Str("worker-0".into()))
                    .unwrap_or(false)
        });
        assert!(has_label, "thread_name metadata missing");
    }

    #[test]
    fn profiling_without_tracing_accumulates_time_only() {
        let sink = TraceSink::new();
        sink.set_profiling(true);
        let before = metrics::global().get("time.teststage.lower");
        {
            let _s = sink.span("teststage", "lower");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(sink.event_count(), 0, "profiling alone must not record events");
        // >= : other tests may run concurrently against the global registry.
        assert!(metrics::global().get("time.teststage.lower") >= before + 1000);
    }

    #[test]
    fn multithreaded_spans_get_distinct_lanes() {
        let sink = TraceSink::new();
        sink.set_tracing(true);
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let _sp = sink.span("stage", "simulate");
                });
            }
        });
        let doc = parse(&sink.to_chrome_json()).unwrap();
        let tids: std::collections::BTreeSet<i64> = doc
            .as_arr()
            .unwrap()
            .iter()
            .filter(|ev| ev.get("ph").unwrap().as_str().unwrap() != "M")
            .map(|ev| ev.get("tid").unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(tids.len(), 2, "each thread gets its own lane");
    }
}
