//! Runtime FIFO: a flat ring buffer of timestamped token *handles* with
//! occupancy accounting.
//!
//! Payloads live in the shared [`crate::sim::arena::TokenArena`]; a FIFO
//! entry is just an 12-byte `(push_cycle, TokenId)` pair, so push/pop
//! move no data and allocate nothing once the ring has grown to the
//! channel's high-water mark.

use super::arena::TokenId;

/// Runtime state of one channel.
#[derive(Debug)]
pub struct SimFifo {
    /// Capacity in tokens (∞ for Sequential-style full-tensor buffers).
    pub capacity: usize,
    /// Ring storage: `(push_cycle, token)` entries; `head` indexes the
    /// front, `len` entries are live.
    ring: Vec<(u64, TokenId)>,
    head: usize,
    len: usize,
    /// Total tokens ever pushed.
    pub pushed: u64,
    /// Total tokens ever popped.
    pub popped: u64,
    /// Pop cycles of the most recent `capacity + 1` tokens, indexed by
    /// absolute token number modulo the ring size — producers consult
    /// this for back-pressure (a push of token `i` must wait until token
    /// `i - capacity` was popped). Allocated lazily on the first pop of
    /// a bounded FIFO.
    pop_ring: Vec<u64>,
    /// High-water mark of occupancy (for FIFO sizing diagnostics).
    pub max_occupancy: usize,
    /// Power-of-two occupancy histogram (`hist[b]` counts pushes that
    /// left `len` in bucket `b`, see [`occupancy_bucket`]). Empty unless
    /// back-pressure profiling was enabled — the disabled cost on the
    /// push path is one `is_empty` branch.
    hist: Vec<u64>,
}

/// Number of histogram buckets: bucket `b` covers occupancies
/// `2^(b-1) < n ≤ 2^b` (bucket 0 is occupancy ≤ 1), with the last
/// bucket absorbing everything deeper.
pub const HIST_BUCKETS: usize = 16;

/// Bucket index for an observed occupancy.
pub fn occupancy_bucket(occupancy: usize) -> usize {
    if occupancy <= 1 {
        return 0;
    }
    let b = (usize::BITS - (occupancy - 1).leading_zeros()) as usize;
    b.min(HIST_BUCKETS - 1)
}

/// Human-readable occupancy range label for bucket `b` (e.g. `"2-4"`).
pub fn bucket_label(b: usize) -> String {
    if b == 0 {
        return "<=1".to_string();
    }
    let hi = 1u64 << b;
    if b == HIST_BUCKETS - 1 {
        format!(">{}", hi / 2)
    } else {
        format!("{}-{}", hi / 2 + 1, hi)
    }
}

impl SimFifo {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            ring: Vec::new(),
            head: 0,
            len: 0,
            pushed: 0,
            popped: 0,
            pop_ring: Vec::new(),
            max_occupancy: 0,
            hist: Vec::new(),
        }
    }

    /// Allocate the occupancy histogram; every subsequent push records
    /// its post-push occupancy bucket.
    pub fn enable_profile(&mut self) {
        if self.hist.is_empty() {
            self.hist = vec![0; HIST_BUCKETS];
        }
    }

    /// The occupancy histogram, if profiling was enabled and any push
    /// happened.
    pub fn occupancy_histogram(&self) -> Option<&[u64]> {
        if self.hist.is_empty() || self.hist.iter().all(|c| *c == 0) {
            None
        } else {
            Some(&self.hist)
        }
    }

    pub fn unbounded() -> Self {
        Self::new(usize::MAX)
    }

    /// Empty the queue (dropping any handles — the caller resets the
    /// arena alongside) but keep the ring capacity for the next run.
    pub fn reset(&mut self) {
        self.head = 0;
        self.len = 0;
        self.pushed = 0;
        self.popped = 0;
        self.max_occupancy = 0;
        self.hist.iter_mut().for_each(|c| *c = 0);
        // pop_ring entries are validated by index arithmetic; stale
        // values from a previous run are never read.
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is there space for one more token (structurally)?
    pub fn has_space(&self) -> bool {
        self.len < self.capacity
    }

    /// Earliest cycle at which the next push may happen given
    /// back-pressure: the pop time of token `pushed - capacity`.
    /// `None` while the FIFO is structurally full (consumer hasn't popped
    /// yet — the producer must re-try after the consumer runs).
    pub fn next_push_ready(&self) -> Option<u64> {
        if self.capacity == usize::MAX || self.pushed < self.capacity as u64 {
            return Some(0);
        }
        if !self.has_space() {
            return None;
        }
        // Token index that freed our slot. It was popped at most
        // `capacity` pops ago, so its entry is still in the ring.
        let need = self.pushed - self.capacity as u64;
        debug_assert!(need < self.popped);
        Some(self.pop_ring[(need % self.pop_ring.len() as u64) as usize])
    }

    pub fn push(&mut self, cycle: u64, tok: TokenId) {
        debug_assert!(self.has_space(), "push into full FIFO");
        if self.len == self.ring.len() {
            self.grow();
        }
        let tail = (self.head + self.len) % self.ring.len();
        self.ring[tail] = (cycle, tok);
        self.len += 1;
        self.pushed += 1;
        self.max_occupancy = self.max_occupancy.max(self.len);
        if !self.hist.is_empty() {
            self.hist[occupancy_bucket(self.len)] += 1;
        }
    }

    /// Double the ring, un-wrapping the live entries into the new tail.
    fn grow(&mut self) {
        let old = self.ring.len();
        let new = (old * 2).max(8);
        self.ring.resize(new, (0, TokenId::default()));
        // live entries occupy head..head+len (wrapping over `old`); the
        // wrapped prefix moves to the freshly added region, restoring
        // contiguity head..head+len in the doubled ring
        let wrapped = (self.head + self.len).saturating_sub(old);
        if wrapped > 0 {
            let (lo, hi) = self.ring.split_at_mut(old);
            hi[..wrapped].copy_from_slice(&lo[..wrapped]);
        }
    }

    /// Push for the fast-forward replay path: enqueue the handle and
    /// count it, but skip timestamping, the capacity assert, occupancy
    /// high-water and the histogram. The replay's transient occupancy is
    /// an artifact of its batched (whole-period) schedule, not of the
    /// simulated machine; the skipped periods' timing and statistics are
    /// applied analytically by [`Self::apply_fast_forward`] afterwards.
    pub fn replay_push(&mut self, tok: TokenId) {
        if self.len == self.ring.len() {
            self.grow();
        }
        let tail = (self.head + self.len) % self.ring.len();
        self.ring[tail] = (0, tok);
        self.len += 1;
        self.pushed += 1;
    }

    /// Pop for the replay path: dequeue and count, with no pop-time
    /// recording ([`Self::apply_fast_forward`] rebuilds the pop window).
    pub fn replay_pop(&mut self) -> TokenId {
        assert!(self.len > 0, "replay pop from empty FIFO");
        let (_, tok) = self.ring[self.head];
        self.head = (self.head + 1) % self.ring.len();
        self.len -= 1;
        self.popped += 1;
        tok
    }

    /// Arrival times of every queued token, front to back (steady-state
    /// snapshot helper).
    pub fn queued_arrivals(&self) -> Vec<u64> {
        (0..self.len).map(|k| self.arrival(k).unwrap()).collect()
    }

    /// Pop times of the most recent `min(popped, capacity + 1)` pops,
    /// oldest first (snapshot helper; empty for unbounded FIFOs or
    /// before the first pop).
    pub fn pop_window(&self) -> Vec<u64> {
        if self.capacity == usize::MAX || self.pop_ring.is_empty() {
            return Vec::new();
        }
        let keep = self.pop_ring.len() as u64;
        let w = self.popped.min(keep);
        (self.popped - w..self.popped).map(|q| self.pop_ring[(q % keep) as usize]).collect()
    }

    /// Occupancy-histogram counts (zeros until profiling is enabled) —
    /// snapshot helper for the fast-forward statistics replay.
    pub fn hist_counts(&self) -> &[u64] {
        &self.hist
    }

    /// Finalize this FIFO after a fast-forward: restore the queued
    /// tokens' arrival times (`arrivals`, front to back, pre-shifted by
    /// the skipped span), rebuild the back-pressure pop window from the
    /// matched snapshot's `window` (pop times for the absolute token
    /// indices ending at the current `popped`, oldest first,
    /// pre-shifted), and fold `periods ×` the per-period histogram delta
    /// into the profile histogram.
    pub fn apply_fast_forward(
        &mut self,
        arrivals: &[u64],
        window: &[u64],
        hist_delta: &[u64],
        periods: u64,
    ) {
        assert_eq!(arrivals.len(), self.len, "fast-forward occupancy mismatch");
        for (k, &t) in arrivals.iter().enumerate() {
            let idx = (self.head + k) % self.ring.len();
            self.ring[idx].0 = t;
        }
        if self.capacity != usize::MAX && !window.is_empty() {
            if self.pop_ring.is_empty() {
                self.pop_ring = vec![0; self.capacity + 1];
            }
            let keep = self.pop_ring.len() as u64;
            debug_assert!(window.len() as u64 <= keep);
            for (o, &t) in window.iter().enumerate() {
                let q = self.popped - window.len() as u64 + o as u64;
                self.pop_ring[(q % keep) as usize] = t;
            }
        }
        if !self.hist.is_empty() {
            for (h, &d) in self.hist.iter_mut().zip(hist_delta) {
                *h += periods * d;
            }
        }
    }

    /// Arrival cycle of the k-th (0-based, relative to current front)
    /// queued token, if present.
    pub fn arrival(&self, k: usize) -> Option<u64> {
        if k >= self.len {
            return None;
        }
        Some(self.ring[(self.head + k) % self.ring.len()].0)
    }

    /// Pop the front token, recording the consumer's `cycle`.
    pub fn pop(&mut self, cycle: u64) -> (u64, TokenId) {
        assert!(self.len > 0, "pop from empty FIFO");
        let (t, tok) = self.ring[self.head];
        self.head = (self.head + 1) % self.ring.len();
        self.len -= 1;
        if self.capacity != usize::MAX {
            if self.pop_ring.is_empty() {
                self.pop_ring = vec![0; self.capacity + 1];
            }
            let keep = self.pop_ring.len() as u64;
            self.pop_ring[(self.popped % keep) as usize] = cycle;
        }
        self.popped += 1;
        (t, tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::arena::TokenArena;

    #[test]
    fn fifo_order_and_counts() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(2);
        let t1 = arena.alloc_from(&[1]);
        let t2 = arena.alloc_from(&[2]);
        f.push(10, t1);
        f.push(11, t2);
        assert!(!f.has_space());
        let (t, v) = f.pop(20);
        assert_eq!(t, 10);
        assert_eq!(arena.get(v), &[1]);
        assert_eq!(f.popped, 1);
        assert_eq!(f.len(), 1);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    fn backpressure_timing() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(2);
        f.push(0, arena.alloc_from(&[1]));
        f.push(0, arena.alloc_from(&[2]));
        // full: producer must wait for a pop
        assert_eq!(f.next_push_ready(), None);
        f.pop(35);
        // token 0 popped at 35 ⇒ pushing token 2 is legal from cycle 35
        assert_eq!(f.next_push_ready(), Some(35));
    }

    #[test]
    fn unbounded_never_blocks() {
        let mut arena = TokenArena::new();
        let tok = arena.alloc_from(&[0]);
        let mut f = SimFifo::unbounded();
        for i in 0..10_000 {
            assert_eq!(f.next_push_ready(), Some(0));
            arena.retain(tok);
            f.push(i, tok);
        }
        assert_eq!(f.pushed, 10_000);
    }

    #[test]
    fn arrival_peek() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(8);
        f.push(5, arena.alloc_from(&[1]));
        f.push(9, arena.alloc_from(&[2]));
        assert_eq!(f.arrival(0), Some(5));
        assert_eq!(f.arrival(1), Some(9));
        assert_eq!(f.arrival(2), None);
    }

    #[test]
    fn ring_growth_preserves_order_across_wrap() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(usize::MAX);
        // interleave pushes and pops so head sits mid-ring when growth
        // happens, exercising the un-wrap path
        let mut expect = std::collections::VecDeque::new();
        let mut next = 0i32;
        for round in 0..50 {
            for _ in 0..(round % 7) + 1 {
                f.push(next as u64, arena.alloc_from(&[next]));
                expect.push_back(next);
                next += 1;
            }
            for _ in 0..(round % 3) {
                if let Some(want) = expect.pop_front() {
                    let (_, tok) = f.pop(0);
                    assert_eq!(arena.get(tok), &[want]);
                    arena.release(tok);
                }
            }
        }
        while let Some(want) = expect.pop_front() {
            let (_, tok) = f.pop(0);
            assert_eq!(arena.get(tok), &[want]);
            arena.release(tok);
        }
        assert!(f.is_empty());
    }

    #[test]
    fn occupancy_buckets_are_log2_ranges() {
        assert_eq!(occupancy_bucket(0), 0);
        assert_eq!(occupancy_bucket(1), 0);
        assert_eq!(occupancy_bucket(2), 1);
        assert_eq!(occupancy_bucket(3), 2);
        assert_eq!(occupancy_bucket(4), 2);
        assert_eq!(occupancy_bucket(5), 3);
        assert_eq!(occupancy_bucket(1 << 20), HIST_BUCKETS - 1);
        assert_eq!(bucket_label(0), "<=1");
        assert_eq!(bucket_label(2), "3-4");
        assert_eq!(bucket_label(HIST_BUCKETS - 1), ">16384");
    }

    #[test]
    fn histogram_counts_pushes_only_when_enabled() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(8);
        f.push(0, arena.alloc_from(&[1]));
        assert!(f.occupancy_histogram().is_none(), "disabled by default");
        f.enable_profile();
        f.push(0, arena.alloc_from(&[2])); // occupancy 2 -> bucket 1
        f.push(0, arena.alloc_from(&[3])); // occupancy 3 -> bucket 2
        let h = f.occupancy_histogram().unwrap();
        assert_eq!(h[1], 1);
        assert_eq!(h[2], 1);
        assert_eq!(h.iter().sum::<u64>(), 2);
        f.reset();
        assert!(f.occupancy_histogram().is_none(), "reset zeroes counts");
        f.push(0, arena.alloc_from(&[4]));
        assert_eq!(f.occupancy_histogram().unwrap()[0], 1, "still enabled after reset");
    }

    #[test]
    fn reset_clears_state_but_keeps_ring() {
        let mut arena = TokenArena::new();
        let mut f = SimFifo::new(4);
        for i in 0..4 {
            f.push(i, arena.alloc_from(&[i as i32]));
        }
        f.pop(9);
        f.reset();
        assert!(f.is_empty());
        assert_eq!(f.pushed, 0);
        assert_eq!(f.max_occupancy, 0);
        assert_eq!(f.next_push_ready(), Some(0));
        f.push(1, arena.alloc_from(&[42]));
        assert_eq!(f.len(), 1);
    }
}
