//! Runtime FIFO with timestamped tokens and occupancy accounting.

use std::collections::VecDeque;

/// A token: the values of one stream element group (e.g. one pixel's C
/// channels), widened to i32 (int8 payloads stay in int8 range).
pub type Token = Vec<i32>;

/// Runtime state of one channel.
#[derive(Debug)]
pub struct SimFifo {
    /// Capacity in tokens (∞ for Sequential-style full-tensor buffers).
    pub capacity: usize,
    /// Tokens currently in flight: (push_cycle, value).
    queue: VecDeque<(u64, Token)>,
    /// Total tokens ever pushed.
    pub pushed: u64,
    /// Total tokens ever popped.
    pub popped: u64,
    /// Pop cycle of recent tokens, indexed by absolute token number —
    /// producers consult this for back-pressure (a push of token `i`
    /// must wait until token `i - capacity` was popped). Only the last
    /// `capacity + 1` entries are retained.
    pop_times: VecDeque<(u64, u64)>,
    /// High-water mark of occupancy (for FIFO sizing diagnostics).
    pub max_occupancy: usize,
}

impl SimFifo {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            queue: VecDeque::new(),
            pushed: 0,
            popped: 0,
            pop_times: VecDeque::new(),
            max_occupancy: 0,
        }
    }

    pub fn unbounded() -> Self {
        Self::new(usize::MAX)
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Is there space for one more token (structurally)?
    pub fn has_space(&self) -> bool {
        self.queue.len() < self.capacity
    }

    /// Earliest cycle at which the next push may happen given
    /// back-pressure: the pop time of token `pushed - capacity`.
    /// `None` while the FIFO is structurally full (consumer hasn't popped
    /// yet — the producer must re-try after the consumer runs).
    pub fn next_push_ready(&self) -> Option<u64> {
        if self.capacity == usize::MAX || self.pushed < self.capacity as u64 {
            return Some(0);
        }
        if !self.has_space() {
            return None;
        }
        let need = self.pushed - self.capacity as u64; // token index that freed our slot
        self.pop_times
            .iter()
            .find(|(idx, _)| *idx == need)
            .map(|(_, t)| *t)
            .or(Some(0)) // already trimmed ⇒ long past
    }

    pub fn push(&mut self, cycle: u64, tok: Token) {
        debug_assert!(self.has_space(), "push into full FIFO");
        self.queue.push_back((cycle, tok));
        self.pushed += 1;
        self.max_occupancy = self.max_occupancy.max(self.queue.len());
    }

    /// Arrival cycle of the k-th (0-based, relative to current front)
    /// queued token, if present.
    pub fn arrival(&self, k: usize) -> Option<u64> {
        self.queue.get(k).map(|(t, _)| *t)
    }

    /// Pop the front token, recording the consumer's `cycle`.
    pub fn pop(&mut self, cycle: u64) -> (u64, Token) {
        let (t, tok) = self.queue.pop_front().expect("pop from empty FIFO");
        let idx = self.popped;
        self.popped += 1;
        self.pop_times.push_back((idx, cycle));
        let keep = if self.capacity == usize::MAX { 4 } else { self.capacity + 1 };
        while self.pop_times.len() > keep {
            self.pop_times.pop_front();
        }
        (t, tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_counts() {
        let mut f = SimFifo::new(2);
        f.push(10, vec![1]);
        f.push(11, vec![2]);
        assert!(!f.has_space());
        let (t, v) = f.pop(20);
        assert_eq!((t, v), (10, vec![1]));
        assert_eq!(f.popped, 1);
        assert_eq!(f.len(), 1);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    fn backpressure_timing() {
        let mut f = SimFifo::new(2);
        f.push(0, vec![1]);
        f.push(0, vec![2]);
        // full: producer must wait for a pop
        assert_eq!(f.next_push_ready(), None);
        f.pop(35);
        // token 0 popped at 35 ⇒ pushing token 2 is legal from cycle 35
        assert_eq!(f.next_push_ready(), Some(35));
    }

    #[test]
    fn unbounded_never_blocks() {
        let mut f = SimFifo::unbounded();
        for i in 0..10_000 {
            assert_eq!(f.next_push_ready(), Some(0));
            f.push(i, vec![i as i32]);
        }
        assert_eq!(f.pushed, 10_000);
    }

    #[test]
    fn arrival_peek() {
        let mut f = SimFifo::new(8);
        f.push(5, vec![1]);
        f.push(9, vec![2]);
        assert_eq!(f.arrival(0), Some(5));
        assert_eq!(f.arrival(1), Some(9));
        assert_eq!(f.arrival(2), None);
    }
}
