//! The per-run token arena: one flat `i32` payload store plus
//! refcounted `(offset, len)` slots.
//!
//! Every token that flows through the engine is a [`TokenId`] — a
//! 4-byte handle into the arena — instead of an owned `Vec<i32>`.
//! Pushing a token into a FIFO moves the handle; broadcasting to a
//! second consumer bumps a refcount; popping and consuming releases it.
//! Released slots go onto per-length free lists and are handed straight
//! back out by the next [`TokenArena::alloc`] of the same length, so a
//! steady-state simulation performs **zero** heap allocation per firing:
//! the payload store grows to the high-water mark of live tokens during
//! the first few thousand firings and is flat from then on. A
//! [`crate::sim::SimContext`] keeps its arena across runs (`reset`
//! empties the slots but keeps the capacity), which is what makes
//! re-simulating the same cell design per grid cell allocation-free.

/// Handle to one token in a [`TokenArena`]. The `Default` value is a
/// dangling filler for ring-buffer storage — never dereference it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TokenId(u32);

#[derive(Debug, Clone, Copy)]
struct Slot {
    offset: u32,
    len: u32,
    refs: u32,
}

/// Flat refcounted token store. See the module docs.
#[derive(Debug, Default)]
pub struct TokenArena {
    data: Vec<i32>,
    slots: Vec<Slot>,
    /// Free slots bucketed by payload length — token lengths are
    /// per-channel constants, so there are only a handful of buckets.
    free_by_len: Vec<(u32, Vec<u32>)>,
    /// Total allocations served (free-list reuses included).
    pub allocs: u64,
    /// Allocations that had to grow the payload store.
    pub fresh: u64,
}

impl TokenArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop every slot but keep the payload/slot capacity for the next
    /// run — after one warm run, subsequent runs allocate nothing.
    pub fn reset(&mut self) {
        self.data.clear();
        self.slots.clear();
        for (_, bucket) in &mut self.free_by_len {
            bucket.clear();
        }
        self.allocs = 0;
        self.fresh = 0;
    }

    /// Live (refs > 0) slots — diagnostics and leak tests.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.refs > 0).count()
    }

    /// Payload-store high-water mark (i32 values) since the last reset —
    /// the arena footprint metric surfaced by `sim.arena_high_water`.
    pub fn high_water(&self) -> usize {
        self.data.len()
    }

    /// Allocate a token of `len` values with refcount 1. The payload is
    /// **uninitialized** (possibly a recycled slot's old values): the
    /// caller must fully overwrite it via [`Self::slice_mut`].
    pub fn alloc(&mut self, len: usize) -> TokenId {
        self.allocs += 1;
        let len32 = len as u32;
        if let Some((_, bucket)) = self.free_by_len.iter_mut().find(|(l, _)| *l == len32) {
            if let Some(id) = bucket.pop() {
                self.slots[id as usize].refs = 1;
                return TokenId(id);
            }
        }
        self.fresh += 1;
        let offset = self.data.len() as u32;
        self.data.resize(self.data.len() + len, 0);
        let id = self.slots.len() as u32;
        self.slots.push(Slot { offset, len: len32, refs: 1 });
        TokenId(id)
    }

    /// Batched reservation: allocate `n` tokens of `len` values each
    /// (refcount 1, payloads **uninitialized** as in [`Self::alloc`])
    /// into `out` — the row-granular firing path reserves one output
    /// row's worth of slots in a single call.
    pub fn alloc_many(&mut self, len: usize, n: usize, out: &mut Vec<TokenId>) {
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            out.push(self.alloc(len));
        }
    }

    /// Allocate and fill from `values` in one step.
    pub fn alloc_from(&mut self, values: &[i32]) -> TokenId {
        let id = self.alloc(values.len());
        self.slice_mut(id).copy_from_slice(values);
        id
    }

    #[inline]
    fn span(&self, id: TokenId) -> (usize, usize) {
        let s = self.slots[id.0 as usize];
        debug_assert!(s.refs > 0, "access to a released token");
        (s.offset as usize, s.len as usize)
    }

    /// Read a token's payload.
    #[inline]
    pub fn get(&self, id: TokenId) -> &[i32] {
        let (o, l) = self.span(id);
        &self.data[o..o + l]
    }

    /// Mutate a token's payload (the producer filling a fresh slot).
    #[inline]
    pub fn slice_mut(&mut self, id: TokenId) -> &mut [i32] {
        let (o, l) = self.span(id);
        &mut self.data[o..o + l]
    }

    /// Writable view of `out` plus a read view of `a` — the in-place
    /// firing path for unary payloads. Slots own disjoint payload
    /// ranges by construction, so this is safe whenever `out != a`.
    #[inline]
    pub fn write_and_read(&mut self, out: TokenId, a: TokenId) -> (&mut [i32], &[i32]) {
        let (oo, ol) = self.span(out);
        let (ao, al) = self.span(a);
        assert!(out != a, "in-place firing must not write its own input");
        debug_assert!(oo + ol <= ao || ao + al <= oo, "slots must not overlap");
        let base = self.data.as_mut_ptr();
        // SAFETY: distinct live slots occupy disjoint ranges of `data`
        // (ranges are assigned once, at slot creation, and recycled only
        // whole), and both ranges are in bounds.
        unsafe {
            (
                std::slice::from_raw_parts_mut(base.add(oo), ol),
                std::slice::from_raw_parts(base.add(ao), al),
            )
        }
    }

    /// Writable view of `out` plus read views of `a` and `b` (binary
    /// payloads). `a == b` is allowed (a diamond can deliver the same
    /// broadcast token on both inputs); `out` must differ from both.
    #[inline]
    pub fn write_and_read2(
        &mut self,
        out: TokenId,
        a: TokenId,
        b: TokenId,
    ) -> (&mut [i32], &[i32], &[i32]) {
        let (oo, ol) = self.span(out);
        let (ao, al) = self.span(a);
        let (bo, bl) = self.span(b);
        assert!(out != a && out != b, "in-place firing must not write its own input");
        debug_assert!(oo + ol <= ao || ao + al <= oo, "slots must not overlap");
        debug_assert!(oo + ol <= bo || bo + bl <= oo, "slots must not overlap");
        let base = self.data.as_mut_ptr();
        // SAFETY: as in `write_and_read`; the two read views may alias
        // each other (shared reads), never the write view.
        unsafe {
            (
                std::slice::from_raw_parts_mut(base.add(oo), ol),
                std::slice::from_raw_parts(base.add(ao), al),
                std::slice::from_raw_parts(base.add(bo), bl),
            )
        }
    }

    /// Add one reference (broadcast fan-out to an extra consumer).
    #[inline]
    pub fn retain(&mut self, id: TokenId) {
        let s = &mut self.slots[id.0 as usize];
        debug_assert!(s.refs > 0, "retain of a released token");
        s.refs += 1;
    }

    /// Drop one reference; at zero the slot is recycled.
    #[inline]
    pub fn release(&mut self, id: TokenId) {
        let s = &mut self.slots[id.0 as usize];
        debug_assert!(s.refs > 0, "double release");
        s.refs -= 1;
        if s.refs == 0 {
            let len = s.len;
            match self.free_by_len.iter_mut().find(|(l, _)| *l == len) {
                Some((_, bucket)) => bucket.push(id.0),
                None => self.free_by_len.push((len, vec![id.0])),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_read_write_roundtrip() {
        let mut a = TokenArena::new();
        let t = a.alloc_from(&[1, 2, 3]);
        assert_eq!(a.get(t), &[1, 2, 3]);
        a.slice_mut(t)[1] = 9;
        assert_eq!(a.get(t), &[1, 9, 3]);
        assert_eq!(a.live(), 1);
    }

    #[test]
    fn release_recycles_same_length_slots() {
        let mut a = TokenArena::new();
        let t = a.alloc_from(&[1, 2, 3, 4]);
        a.release(t);
        assert_eq!(a.live(), 0);
        let u = a.alloc(4);
        assert_eq!(u, t, "same-length alloc must reuse the freed slot");
        assert_eq!(a.fresh, 1, "second alloc must not grow the store");
        // different length: fresh slot, distinct range
        let v = a.alloc(2);
        assert_ne!(v, u);
        assert_eq!(a.fresh, 2);
    }

    #[test]
    fn retain_keeps_the_slot_alive_across_one_release() {
        let mut a = TokenArena::new();
        let t = a.alloc_from(&[7]);
        a.retain(t);
        a.release(t);
        assert_eq!(a.get(t), &[7], "one ref left: still readable");
        a.release(t);
        assert_eq!(a.live(), 0);
    }

    #[test]
    fn steady_state_allocates_nothing_fresh() {
        let mut a = TokenArena::new();
        for round in 0..100 {
            let t = a.alloc_from(&[round, round]);
            let u = a.alloc_from(&[round]);
            a.release(t);
            a.release(u);
        }
        assert_eq!(a.fresh, 2, "one fresh slot per distinct length");
        assert_eq!(a.allocs, 200);
    }

    #[test]
    fn in_place_views_are_disjoint_and_shared_reads_alias() {
        let mut a = TokenArena::new();
        let x = a.alloc_from(&[1, 2]);
        let y = a.alloc_from(&[10, 20]);
        let out = a.alloc(2);
        let (o, xa, yb) = a.write_and_read2(out, x, y);
        for i in 0..2 {
            o[i] = xa[i] + yb[i];
        }
        assert_eq!(a.get(out), &[11, 22]);
        // the same token on both read ports (diamond broadcast)
        let out2 = a.alloc(2);
        let (o, xa, xb) = a.write_and_read2(out2, x, x);
        for i in 0..2 {
            o[i] = xa[i] * xb[i];
        }
        assert_eq!(a.get(out2), &[1, 4]);
    }

    #[test]
    fn reset_keeps_capacity_but_drops_slots() {
        let mut a = TokenArena::new();
        for _ in 0..10 {
            a.alloc(8);
        }
        a.reset();
        assert_eq!(a.live(), 0);
        assert_eq!(a.allocs, 0);
        let t = a.alloc_from(&[5; 8]);
        assert_eq!(a.get(t), &[5; 8]);
    }
}
