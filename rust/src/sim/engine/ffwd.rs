//! Steady-state detector + fast-forward for the dataflow engine.
//!
//! The paper's streaming pipelines reach a periodic equilibrium almost
//! immediately: after the line-buffer fill, every FIFO occupancy,
//! firing phase and stall pattern repeats with a fixed period (one
//! input scanline for conv chains). This module detects that
//! equilibrium and skips it.
//!
//! Mechanism: at scanline-aligned checkpoints (top of the sweep loop)
//! the engine's *timing-relevant* state is snapshotted — per-FIFO
//! occupancy + arrival times, per-node firing phases, consumption gaps
//! and timestamps, all relative to the sink clock so the summary is
//! shift-invariant. When a snapshot matches an earlier one modulo a
//! uniform cycle shift `dt`, the engine's evolution from now on
//! provably mirrors the recorded period shifted by `dt` (the transition
//! function reads nothing else), so the remaining whole periods are
//! **replayed functionally** — token values still flow token-by-token
//! through the real procs/arena/FIFOs, because outputs must stay
//! bit-exact — while every timestamp and statistic is advanced in O(1)
//! per period. Fill, drain and any transient that breaks the match
//! conditions fall back to exact execution automatically.
//!
//! The replay is also where batched firing pays off: inputs for a whole
//! output row are streamed in first, then [`SlidingProc::fire_row_into`]
//! produces the row in one pass (no timestamps to attribute, so no
//! per-pixel bookkeeping is lost).

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use anyhow::{ensure, Result};

use crate::dataflow::design::Design;
use crate::sim::arena::TokenId;
use crate::sim::process::{NodeProc, SlidingProc};

use super::{FfStats, SimContext, AXI_BYTES_PER_CYCLE};

/// Checkpoint budget per run: past this many snapshots without finding
/// a period the detector turns itself off (the run is aperiodic or too
/// irregular — don't keep paying the snapshot cost).
const MAX_SNAPSHOTS: usize = 160;

/// Detector working state, embedded in [`SimContext`].
pub(super) struct FfState {
    snapshots: Vec<Snapshot>,
    /// `fed` at the last checkpoint — the next one triggers a scanline
    /// later.
    last_cp_fed: u64,
    /// Input tokens per scanline (checkpoint cadence); 1 when the input
    /// isn't a rank-3 image.
    scan_stride: u64,
    /// Cumulative feeder pushes whose time was set by the AXI port
    /// (strictly later than FIFO back-pressure allowed) — the feeder
    /// periodicity condition needs to know this.
    pub(super) axi_bound: u64,
    pub(super) stats: FfStats,
    enabled: bool,
}

impl FfState {
    pub(super) fn new(design: &Design, tok_len: usize) -> Self {
        let shape = &design.graph.inputs()[0].ty.shape;
        let scan_stride = if shape.len() == 3 && tok_len > 0 && (shape[1] * shape[2]) % tok_len == 0
        {
            (((shape[1] * shape[2]) / tok_len).max(1)) as u64
        } else {
            1
        };
        Self {
            snapshots: Vec::new(),
            last_cp_fed: 0,
            scan_stride,
            axi_bound: 0,
            stats: FfStats::default(),
            enabled: true,
        }
    }

    pub(super) fn reset(&mut self) {
        self.snapshots.clear();
        self.last_cp_fed = 0;
        self.axi_bound = 0;
        self.stats = FfStats::default();
        self.enabled = true;
    }
}

/// One node's timing-relevant state at a checkpoint.
struct NodeSnap {
    firings: u64,
    t_free: u64,
    complete: u64,
    last_fire: u64,
    stall_in: u64,
    stall_out: u64,
    last_in: Vec<u64>,
    consumed: Vec<u64>,
}

/// One FIFO's timing-relevant state at a checkpoint.
struct FifoSnap {
    len: usize,
    pushed: u64,
    popped: u64,
    /// Arrival times of the queued tokens, front to back.
    arrivals: Vec<u64>,
    /// Pop times of the last `min(popped, capacity+1)` pops, oldest
    /// first — the readable region of the back-pressure pop ring.
    window: Vec<u64>,
    /// Occupancy-histogram counts (empty unless profiling).
    hist: Vec<u64>,
}

/// Full engine state summary at one checkpoint. Counters are absolute;
/// the `hash` folds only shift-invariant views so that two states one
/// steady period apart collide.
struct Snapshot {
    hash: u64,
    fed: u64,
    drained: u64,
    last_drain: u64,
    axi_bound: u64,
    nodes: Vec<NodeSnap>,
    fifos: Vec<FifoSnap>,
    stall_wait: Vec<u64>,
    stall_full: Vec<u64>,
}

/// Per-period deltas (j − i) plus the fix-up payload cloned out of the
/// matched snapshots, so the replay can mutate the context freely.
struct FfPlan {
    dfed: u64,
    ddrained: u64,
    daxi: u64,
    node_df: Vec<u64>,
    node_dc: Vec<Vec<u64>>,
    node_dstall_in: Vec<u64>,
    node_dstall_out: Vec<u64>,
    chan_dwait: Vec<u64>,
    chan_dfull: Vec<u64>,
    /// Queued arrival times at j (unshifted; fix-up adds the skip span).
    fifo_arrivals: Vec<Vec<u64>>,
    /// Pop-ring window at j (unshifted).
    fifo_window: Vec<Vec<u64>>,
    /// Per-period histogram increments.
    fifo_dhist: Vec<Vec<u64>>,
}

/// First and one-past-last output row of a sliding node for which
/// `needed()` is exactly linear in whole rows (`needed(k + w_out) =
/// needed(k) + stride·w`): no top-padding saturation below `r_lo`, no
/// bottom clamp at or above `r_hi`. Fast-forward only ever replays rows
/// inside `[r_lo, r_hi)` — outside, the consumption pattern changes
/// shape and the period match would be unsound.
fn sliding_linear_rows(p: &SlidingProc) -> (u64, u64) {
    let keff = (p.k - 1) * p.dilation;
    let r_lo = if keff >= p.pad { 0 } else { (p.pad - keff).div_ceil(p.stride) };
    let r_hi = (p.h + p.pad).saturating_sub(keff).div_ceil(p.stride);
    (r_lo as u64, r_hi as u64)
}

/// Pop time of absolute token index `q` as recorded in a snapshot's
/// window, if that index is inside the recorded range.
fn window_at(s: &FifoSnap, q: u64) -> Option<u64> {
    let w = s.window.len() as u64;
    let start = s.popped - w;
    if q < start || q >= s.popped {
        return None;
    }
    Some(s.window[(q - start) as usize])
}

impl<'d> SimContext<'d> {
    /// Step 0 of the sweep loop: checkpoint if a scanline of input went
    /// by, match against history, and if a whole number of steady
    /// periods fits in the remaining work, replay them. Returns whether
    /// any fast-forward progress was made.
    pub(super) fn maybe_fast_forward(
        &mut self,
        input: &[i32],
        fed: &mut u64,
        drained: &mut u64,
        last_drain: &mut u64,
        total_firings: &mut u64,
        output: &mut Vec<i32>,
    ) -> Result<bool> {
        if !self.ff.enabled || *fed < self.ff.last_cp_fed + self.ff.scan_stride {
            return Ok(false);
        }
        let cur = self.take_snapshot(*fed, *drained, *last_drain);
        self.ff.stats.checkpoints += 1;
        self.ff.last_cp_fed = *fed;

        let mut plan: Option<(FfPlan, u64, u64)> = None;
        for past in &self.ff.snapshots {
            if past.hash != cur.hash {
                continue;
            }
            let Some(dt) = self.verify_period(past, &cur) else { continue };
            let n_p = self.whole_periods(past, &cur);
            if n_p == 0 {
                continue;
            }
            plan = Some((extract_plan(past, &cur), dt, n_p));
            break;
        }
        self.ff.snapshots.push(cur);
        if self.ff.snapshots.len() >= MAX_SNAPSHOTS {
            self.ff.enabled = false;
        }
        let Some((plan, dt, n_p)) = plan else { return Ok(false) };

        self.replay_periods(input, &plan, n_p, fed, drained, total_firings, output)?;
        self.apply_timing(&plan, n_p, dt, last_drain);
        self.ff.last_cp_fed = *fed;
        Ok(true)
    }

    /// Capture the timing-relevant state (see module docs).
    fn take_snapshot(&self, fed: u64, drained: u64, last_drain: u64) -> Snapshot {
        let nodes = self
            .nodes
            .iter()
            .map(|ns| NodeSnap {
                firings: ns.firings,
                t_free: ns.t_free,
                complete: ns.complete,
                last_fire: ns.trace.last_fire,
                stall_in: ns.trace.stall_in,
                stall_out: ns.trace.stall_out,
                last_in: ns.last_in_time.clone(),
                consumed: ns.consumed.clone(),
            })
            .collect();
        let fifos = self
            .fifos
            .iter()
            .map(|f| FifoSnap {
                len: f.len(),
                pushed: f.pushed,
                popped: f.popped,
                arrivals: f.queued_arrivals(),
                window: f.pop_window(),
                hist: f.hist_counts().to_vec(),
            })
            .collect();
        let mut s = Snapshot {
            hash: 0,
            fed,
            drained,
            last_drain,
            axi_bound: self.ff.axi_bound,
            nodes,
            fifos,
            stall_wait: self.chan_stall_wait.clone(),
            stall_full: self.chan_stall_full.clone(),
        };
        s.hash = self.state_hash(&s);
        s
    }

    /// Shift-invariant hash: timestamps relative to the sink clock,
    /// firing counts as phases, consumption as gaps-to-need. Two states
    /// exactly one steady period apart hash equal; the full
    /// [`Self::verify_period`] check runs only on hash collisions.
    fn state_hash(&self, s: &Snapshot) -> u64 {
        let mut h = DefaultHasher::new();
        let ld = s.last_drain;
        for f in &s.fifos {
            f.len.hash(&mut h);
            for &a in &f.arrivals {
                a.wrapping_sub(ld).hash(&mut h);
            }
        }
        for (nid, n) in s.nodes.iter().enumerate() {
            let done = n.firings == self.design.nodes[nid].geo.out_tokens;
            done.hash(&mut h);
            if done {
                // frozen absolute state
                (n.firings, n.t_free, n.complete).hash(&mut h);
                continue;
            }
            let phase = match &self.procs[nid] {
                NodeProc::Sliding(p) => n.firings % p.w_out as u64,
                _ => 0,
            };
            phase.hash(&mut h);
            for (slot, &c) in n.consumed.iter().enumerate() {
                (self.procs[nid].needed(slot, n.firings) - c).hash(&mut h);
            }
            n.t_free.wrapping_sub(ld).hash(&mut h);
            n.complete.wrapping_sub(ld).hash(&mut h);
            n.last_fire.wrapping_sub(ld).hash(&mut h);
            for &t in &n.last_in {
                t.wrapping_sub(ld).hash(&mut h);
            }
        }
        h.finish()
    }

    /// Is state `b` exactly state `a` advanced by one steady period?
    /// Checks every input the sweep transition function reads, so a
    /// `Some(dt)` is a proof that execution from `b` mirrors the
    /// recorded `a → b` evolution shifted by `dt` — as long as replayed
    /// sliding rows stay inside their linear region (the caller caps
    /// periods accordingly).
    fn verify_period(&self, a: &Snapshot, b: &Snapshot) -> Option<u64> {
        if b.last_drain <= a.last_drain || b.drained <= a.drained {
            return None;
        }
        let dt = b.last_drain - a.last_drain;

        // Feeder: its push times depend on the absolute AXI schedule,
        // which is not shift-invariant. Periodicity holds iff either
        // the AXI rate advances by exactly dt per period (phase
        // preserved), or no push in the period was AXI-bound and the
        // AXI clock gains no ground on the FIFO clock (stays behind).
        if a.fed < self.in_tokens_total {
            if b.fed <= a.fed {
                return None;
            }
            let bytes = (b.fed - a.fed) * self.token_bytes;
            let rate_matched =
                bytes % AXI_BYTES_PER_CYCLE == 0 && bytes / AXI_BYTES_PER_CYCLE == dt;
            let fifo_bound =
                b.axi_bound == a.axi_bound && bytes.div_ceil(AXI_BYTES_PER_CYCLE) <= dt;
            if !(rate_matched || fifo_bound) {
                return None;
            }
        }

        for nid in 0..a.nodes.len() {
            let (x, y) = (&a.nodes[nid], &b.nodes[nid]);
            if x.firings == self.design.nodes[nid].geo.out_tokens {
                continue; // done at a ⇒ frozen ever since
            }
            let df = y.firings - x.firings;
            if df == 0 {
                // a node idle across the period must be idle in every
                // future period: frozen in place
                if x.t_free != y.t_free
                    || x.complete != y.complete
                    || x.last_fire != y.last_fire
                    || x.last_in != y.last_in
                    || x.consumed != y.consumed
                {
                    return None;
                }
                continue;
            }
            if x.firings == 0 {
                // replaying firing 0 would skip `first_fire` attribution
                return None;
            }
            if y.t_free != x.t_free + dt
                || y.complete != x.complete + dt
                || y.last_fire != x.last_fire + dt
            {
                return None;
            }
            for s in 0..x.last_in.len() {
                if y.last_in[s] != x.last_in[s] + dt {
                    return None;
                }
                let gx = self.procs[nid].needed(s, x.firings) - x.consumed[s];
                let gy = self.procs[nid].needed(s, y.firings) - y.consumed[s];
                if gx != gy {
                    return None;
                }
            }
            if let NodeProc::Sliding(p) = &self.procs[nid] {
                let w_out = p.w_out as u64;
                let (r_lo, _) = sliding_linear_rows(p);
                // whole rows per period, starting inside the linear
                // region — otherwise needed()'s increments change shape
                // across periods
                if df % w_out != 0 || x.firings / w_out < r_lo {
                    return None;
                }
            }
        }

        for cid in 0..a.fifos.len() {
            let (fx, fy) = (&a.fifos[cid], &b.fifos[cid]);
            if fx.len != fy.len {
                return None;
            }
            for k in 0..fx.len {
                if fy.arrivals[k] != fx.arrivals[k] + dt {
                    return None;
                }
            }
            // Back-pressure pop ring: every entry a future push may
            // read must mirror its counterpart one period earlier.
            // Channels that never outgrow their capacity never read
            // the ring at all.
            let cap = self.fifos[cid].capacity;
            if cap != usize::MAX && self.design.channels[cid].tokens_total > cap as u64 {
                if fx.pushed < cap as u64 {
                    return None; // still in the free (pre-ring) regime at a
                }
                let dpop = fy.popped - fx.popped;
                for q in fy.pushed.saturating_sub(cap as u64)..fy.popped {
                    let (Some(tb), Some(ta)) = (window_at(fy, q), window_at(fx, q - dpop))
                    else {
                        return None;
                    };
                    if tb != ta + dt {
                        return None;
                    }
                }
            }
        }
        Some(dt)
    }

    /// How many whole periods fit before any counter overruns its total
    /// or a sliding node leaves its linear row region.
    fn whole_periods(&self, a: &Snapshot, b: &Snapshot) -> u64 {
        let mut n_p = (self.out_tokens_total - b.drained) / (b.drained - a.drained);
        if b.fed > a.fed {
            n_p = n_p.min((self.in_tokens_total - b.fed) / (b.fed - a.fed));
        }
        for nid in 0..a.nodes.len() {
            let df = b.nodes[nid].firings - a.nodes[nid].firings;
            if df == 0 {
                continue;
            }
            let out_tokens = self.design.nodes[nid].geo.out_tokens;
            n_p = n_p.min((out_tokens - b.nodes[nid].firings) / df);
            if let NodeProc::Sliding(p) = &self.procs[nid] {
                let (_, r_hi) = sliding_linear_rows(p);
                let limit = r_hi * p.w_out as u64;
                n_p = n_p.min(limit.saturating_sub(b.nodes[nid].firings) / df);
            }
        }
        n_p
    }

    /// Replay `n_p` whole periods functionally: real tokens through the
    /// real procs, but no timestamping — timing is applied afterwards
    /// by [`Self::apply_timing`]. Node order is topological, so each
    /// producer finishes all its periods before any consumer streams.
    #[allow(clippy::too_many_arguments)]
    fn replay_periods(
        &mut self,
        input: &[i32],
        plan: &FfPlan,
        n_p: u64,
        fed: &mut u64,
        drained: &mut u64,
        total_firings: &mut u64,
        output: &mut Vec<i32>,
    ) -> Result<()> {
        let design = self.design;

        // 1) feeder
        for _ in 0..n_p * plan.dfed {
            ensure!(*fed < self.in_tokens_total, "fast-forward: feeder overrun");
            let base = *fed as usize * self.tok_len;
            let tok = self.arena.alloc_from(&input[base..base + self.tok_len]);
            let (last, rest) = self.input_chans.split_last().unwrap();
            for &c in rest {
                self.arena.retain(tok);
                self.fifos[c].replay_push(tok);
            }
            self.fifos[*last].replay_push(tok);
            *fed += 1;
        }

        // 2) nodes
        let mut row_buf: Vec<TokenId> = Vec::new();
        for nid in 0..self.nodes.len() {
            let df = plan.node_df[nid];
            if df == 0 {
                continue;
            }
            let dn = &design.nodes[nid];
            let target = self.nodes[nid].firings + n_p * df;
            let c_targets: Vec<u64> = self.nodes[nid]
                .consumed
                .iter()
                .zip(&plan.node_dc[nid])
                .map(|(&c, &dc)| c + n_p * dc)
                .collect();
            let batch_w = match &self.procs[nid] {
                NodeProc::Sliding(p) if self.cfg.batch_fire => Some(p.w_out as u64),
                _ => None,
            };
            while self.nodes[nid].firings < target {
                let k = self.nodes[nid].firings;
                let fire_n = match batch_w {
                    Some(w) if k % w == 0 && k + w <= target => w,
                    _ => 1,
                };
                // stream inputs through the last firing of this step
                for (slot, &cid) in dn.in_channels.iter().enumerate() {
                    let need = self.procs[nid].needed(slot, k + fire_n - 1);
                    while self.nodes[nid].consumed[slot] < need {
                        ensure!(
                            !self.fifos[cid.0].is_empty(),
                            "fast-forward: replay underrun on {}",
                            design.channels[cid.0].name
                        );
                        let tok = self.fifos[cid.0].replay_pop();
                        self.procs[nid].accept(slot, tok, &mut self.arena);
                        self.nodes[nid].consumed[slot] += 1;
                    }
                }
                let (last, rest) = dn.out_channels.split_last().unwrap();
                if fire_n > 1 {
                    match &mut self.procs[nid] {
                        NodeProc::Sliding(p) => p.fire_row_into(k, &mut self.arena, &mut row_buf),
                        _ => unreachable!("only sliding nodes batch-fire"),
                    }
                    for &v in &row_buf {
                        for &cid in rest {
                            self.arena.retain(v);
                            self.fifos[cid.0].replay_push(v);
                        }
                        self.fifos[last.0].replay_push(v);
                    }
                    self.ff.stats.batched_firings += fire_n;
                } else {
                    let v = self.procs[nid].fire_into(k, &mut self.arena);
                    for &cid in rest {
                        self.arena.retain(v);
                        self.fifos[cid.0].replay_push(v);
                    }
                    self.fifos[last.0].replay_push(v);
                }
                self.nodes[nid].firings += fire_n;
                *total_firings += fire_n;
            }
            // top up eager consumption to the mirrored state (the exact
            // engine streams ahead of the current firing's need)
            for (slot, &cid) in dn.in_channels.iter().enumerate() {
                while self.nodes[nid].consumed[slot] < c_targets[slot] {
                    ensure!(
                        !self.fifos[cid.0].is_empty(),
                        "fast-forward: top-up underrun on {}",
                        design.channels[cid.0].name
                    );
                    let tok = self.fifos[cid.0].replay_pop();
                    self.procs[nid].accept(slot, tok, &mut self.arena);
                    self.nodes[nid].consumed[slot] += 1;
                }
            }
        }

        // 3) sink
        for _ in 0..n_p * plan.ddrained {
            ensure!(!self.fifos[self.out_chan].is_empty(), "fast-forward: sink underrun");
            let tok = self.fifos[self.out_chan].replay_pop();
            output.extend_from_slice(self.arena.get(tok));
            self.arena.release(tok);
            *drained += 1;
        }
        Ok(())
    }

    /// Apply the skipped periods' timing and statistics: every live
    /// timestamp shifts by `n_p·dt`, every cumulative statistic grows by
    /// `n_p ×` its per-period delta.
    fn apply_timing(&mut self, plan: &FfPlan, n_p: u64, dt: u64, last_drain: &mut u64) {
        let shift = n_p * dt;
        *last_drain += shift;
        for nid in 0..self.nodes.len() {
            let ns = &mut self.nodes[nid];
            if plan.node_df[nid] > 0 {
                ns.t_free += shift;
                ns.complete += shift;
                ns.trace.last_fire += shift;
                for t in &mut ns.last_in_time {
                    *t += shift;
                }
            }
            ns.trace.stall_in += n_p * plan.node_dstall_in[nid];
            ns.trace.stall_out += n_p * plan.node_dstall_out[nid];
        }
        for (c, d) in self.chan_stall_wait.iter_mut().zip(&plan.chan_dwait) {
            *c += n_p * d;
        }
        for (c, d) in self.chan_stall_full.iter_mut().zip(&plan.chan_dfull) {
            *c += n_p * d;
        }
        for cid in 0..self.fifos.len() {
            let arrivals: Vec<u64> = plan.fifo_arrivals[cid].iter().map(|&t| t + shift).collect();
            let window: Vec<u64> = plan.fifo_window[cid].iter().map(|&t| t + shift).collect();
            self.fifos[cid].apply_fast_forward(&arrivals, &window, &plan.fifo_dhist[cid], n_p);
        }
        self.ff.axi_bound += n_p * plan.daxi;
        self.ff.stats.periods += n_p;
        self.ff.stats.skipped_cycles += shift;
    }
}

/// Clone the per-period deltas and fix-up payload out of the matched
/// snapshot pair (so the borrow on the snapshot store can end before
/// the replay mutates the context).
fn extract_plan(a: &Snapshot, b: &Snapshot) -> FfPlan {
    FfPlan {
        dfed: b.fed - a.fed,
        ddrained: b.drained - a.drained,
        daxi: b.axi_bound - a.axi_bound,
        node_df: a
            .nodes
            .iter()
            .zip(&b.nodes)
            .map(|(x, y)| y.firings - x.firings)
            .collect(),
        node_dc: a
            .nodes
            .iter()
            .zip(&b.nodes)
            .map(|(x, y)| x.consumed.iter().zip(&y.consumed).map(|(&cx, &cy)| cy - cx).collect())
            .collect(),
        node_dstall_in: a
            .nodes
            .iter()
            .zip(&b.nodes)
            .map(|(x, y)| y.stall_in - x.stall_in)
            .collect(),
        node_dstall_out: a
            .nodes
            .iter()
            .zip(&b.nodes)
            .map(|(x, y)| y.stall_out - x.stall_out)
            .collect(),
        chan_dwait: a.stall_wait.iter().zip(&b.stall_wait).map(|(&x, &y)| y - x).collect(),
        chan_dfull: a.stall_full.iter().zip(&b.stall_full).map(|(&x, &y)| y - x).collect(),
        fifo_arrivals: b.fifos.iter().map(|f| f.arrivals.clone()).collect(),
        fifo_window: b.fifos.iter().map(|f| f.window.clone()).collect(),
        fifo_dhist: a
            .fifos
            .iter()
            .zip(&b.fifos)
            .map(|(x, y)| x.hist.iter().zip(&y.hist).map(|(&hx, &hy)| hy - hx).collect())
            .collect(),
    }
}
