//! Per-node execution traces and report rendering.

use crate::util::tables::TextTable;

/// Timing summary of one node across a simulation.
#[derive(Debug, Clone, Default)]
pub struct NodeTrace {
    pub name: String,
    pub firings: u64,
    pub first_fire: u64,
    pub last_fire: u64,
    /// Cycle at which the node's last result left its pipeline.
    pub complete: u64,
    /// Cycles spent waiting on input tokens (beyond pipeline readiness).
    pub stall_in: u64,
    /// Cycles spent waiting on output FIFO space.
    pub stall_out: u64,
}

impl NodeTrace {
    /// Average cycles between firings (∞-safe).
    pub fn avg_interval(&self) -> f64 {
        if self.firings <= 1 {
            return 0.0;
        }
        (self.last_fire - self.first_fire) as f64 / (self.firings - 1) as f64
    }
}

/// Render node traces as an aligned table.
pub fn render_traces(traces: &[NodeTrace]) -> String {
    let mut t = TextTable::new(vec![
        "node", "firings", "first", "last", "complete", "avg II", "stall-in", "stall-out",
    ]);
    for tr in traces {
        t.row(vec![
            tr.name.clone(),
            tr.firings.to_string(),
            tr.first_fire.to_string(),
            tr.last_fire.to_string(),
            tr.complete.to_string(),
            format!("{:.2}", tr.avg_interval()),
            tr.stall_in.to_string(),
            tr.stall_out.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_interval() {
        let t = NodeTrace { firings: 11, first_fire: 100, last_fire: 200, ..Default::default() };
        assert!((t.avg_interval() - 10.0).abs() < 1e-9);
        let one = NodeTrace { firings: 1, ..Default::default() };
        assert_eq!(one.avg_interval(), 0.0);
    }

    #[test]
    fn render_contains_nodes() {
        let t = vec![NodeTrace { name: "conv0".into(), firings: 4, ..Default::default() }];
        let s = render_traces(&t);
        assert!(s.contains("conv0"));
        assert!(s.contains("stall-in"));
    }
}
