//! The retained naive reference engine.
//!
//! A deliberately straightforward implementation of the exact same
//! simulation semantics as [`crate::sim::engine`]: owned `Vec<i32>`
//! tokens, `VecDeque`-backed FIFOs, per-firing allocation, per-consumer
//! clones on broadcast, and the untransposed `(F, K, K, C)` weight walk
//! in the MAC loop. It exists for two reasons:
//!
//! 1. **Correctness pinning** — the arena engine is property-tested
//!    against this path on random graphs: identical outputs, identical
//!    cycle counts, identical FIFO high-water marks
//!    (`tests/properties.rs`). The *data plane* (token storage, FIFO
//!    mechanics, firing computation) is genuinely independent here; the
//!    scheduling sweep loop is deliberately a structural copy of
//!    `SimContext::run`, so the pin proves the arena/ring/in-place
//!    machinery preserves the contract — it does not double-check the
//!    scheduling policy itself. A change to the scheduling semantics
//!    must be mirrored in both loops (the property test will fail
//!    loudly until it is).
//! 2. **Performance baseline** — `benches/compiler_perf.rs` reports the
//!    arena engine's firings/s against this path in `BENCH_sim.json`
//!    (`speedup_vs_naive`), timed the way the pre-PR engine ran: proc
//!    build per call, allocation per firing.
//!
//! Keep this code boring. Optimizations belong in the arena engine.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::Design;
use crate::ir::generic::Payload;

use super::engine::{SimMode, SimReport, AXI_BYTES_PER_CYCLE};
use super::process::{apply_payload, build_proc, NodeProc, WeightBank};
use super::trace::NodeTrace;

type Token = Vec<i32>;

/// Owned-token FIFO — the pre-arena data plane.
struct NaiveFifo {
    capacity: usize,
    queue: VecDeque<(u64, Token)>,
    pushed: u64,
    popped: u64,
    pop_times: VecDeque<(u64, u64)>,
    max_occupancy: usize,
}

impl NaiveFifo {
    fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            queue: VecDeque::new(),
            pushed: 0,
            popped: 0,
            pop_times: VecDeque::new(),
            max_occupancy: 0,
        }
    }

    fn len(&self) -> usize {
        self.queue.len()
    }

    fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn has_space(&self) -> bool {
        self.queue.len() < self.capacity
    }

    fn next_push_ready(&self) -> Option<u64> {
        if self.capacity == usize::MAX || self.pushed < self.capacity as u64 {
            return Some(0);
        }
        if !self.has_space() {
            return None;
        }
        let need = self.pushed - self.capacity as u64;
        self.pop_times
            .iter()
            .find(|(idx, _)| *idx == need)
            .map(|(_, t)| *t)
            .or(Some(0))
    }

    fn push(&mut self, cycle: u64, tok: Token) {
        self.queue.push_back((cycle, tok));
        self.pushed += 1;
        self.max_occupancy = self.max_occupancy.max(self.queue.len());
    }

    fn arrival(&self, k: usize) -> Option<u64> {
        self.queue.get(k).map(|(t, _)| *t)
    }

    fn pop(&mut self, cycle: u64) -> (u64, Token) {
        let (t, tok) = self.queue.pop_front().expect("pop from empty FIFO");
        let idx = self.popped;
        self.popped += 1;
        self.pop_times.push_back((idx, cycle));
        let keep = if self.capacity == usize::MAX { 4 } else { self.capacity + 1 };
        while self.pop_times.len() > keep {
            self.pop_times.pop_front();
        }
        (t, tok)
    }
}

/// Naive per-node behaviour: same functional contract as
/// [`crate::sim::process::NodeProc`], with owned tokens and the
/// straightforward weight walk.
enum NaiveProc {
    Sliding {
        h: usize,
        w: usize,
        c: usize,
        w_out: usize,
        f: usize,
        k: usize,
        stride: usize,
        dilation: usize,
        pad: usize,
        /// (F, K, K, C) — deliberately untransposed.
        weights: Vec<i32>,
        payload: Payload,
        buf: Vec<i32>,
    },
    Reduction {
        n: usize,
        weights: Vec<i32>,
        cur: Option<Token>,
    },
    Parallel {
        payload: Payload,
        pending: Vec<VecDeque<Token>>,
    },
}

impl NaiveProc {
    /// Derive the naive proc from the arena-engine's builder so the two
    /// paths can never disagree about geometry or weights.
    fn from_node(d: &Design, nid: usize, bank: &WeightBank) -> Result<Self> {
        Ok(match build_proc(d, nid, bank)? {
            NodeProc::Sliding(p) => NaiveProc::Sliding {
                h: p.h,
                w: p.w,
                c: p.c,
                w_out: p.w_out,
                f: p.f,
                k: p.k,
                stride: p.stride,
                dilation: p.dilation,
                pad: p.pad,
                weights: p.weights.to_vec(),
                payload: p.payload,
                buf: Vec::new(),
            },
            NodeProc::Reduction(p) => NaiveProc::Reduction {
                n: p.n,
                weights: p.weights.to_vec(),
                cur: None,
            },
            NodeProc::Parallel(p) => NaiveProc::Parallel {
                payload: p.payload,
                pending: (0..p.arity).map(|_| VecDeque::new()).collect(),
            },
        })
    }

    fn needed(&self, slot: usize, fire_k: u64) -> u64 {
        let _ = slot;
        match self {
            NaiveProc::Sliding { h, w, w_out, k, stride, dilation, pad, .. } => {
                let r = (fire_k as usize) / w_out;
                let cx = (fire_k as usize) % w_out;
                let keff = (k - 1) * dilation;
                let raw_r = (r * stride + keff).saturating_sub(*pad);
                if raw_r >= *h {
                    return (h * w) as u64;
                }
                let in_c = (cx * stride + keff).saturating_sub(*pad).min(w - 1);
                (raw_r * w + in_c + 1) as u64
            }
            NaiveProc::Reduction { .. } | NaiveProc::Parallel { .. } => fire_k + 1,
        }
    }

    fn accept(&mut self, slot: usize, tok: Token) {
        match self {
            NaiveProc::Sliding { buf, .. } => buf.extend_from_slice(&tok),
            NaiveProc::Reduction { cur, .. } => *cur = Some(tok),
            NaiveProc::Parallel { pending, .. } => pending[slot].push_back(tok),
        }
    }

    fn fire(&mut self, fire_k: u64) -> Token {
        match self {
            NaiveProc::Sliding {
                h,
                w,
                c,
                w_out,
                f,
                k,
                stride,
                dilation,
                pad,
                weights,
                payload,
                buf,
            } => {
                let r = (fire_k as usize) / *w_out;
                let cx = (fire_k as usize) % *w_out;
                match payload {
                    Payload::MulAcc => {
                        // the textbook loop nest: filter-major, strided
                        // weight reads, no zero skipping
                        let mut out = vec![0i32; *f];
                        for (ff, o) in out.iter_mut().enumerate() {
                            for kh in 0..*k {
                                for kw in 0..*k {
                                    let ir = r * *stride + kh * *dilation;
                                    let ic = cx * *stride + kw * *dilation;
                                    if ir < *pad || ic < *pad {
                                        continue;
                                    }
                                    let (ir, ic) = (ir - *pad, ic - *pad);
                                    if ir >= *h || ic >= *w {
                                        continue;
                                    }
                                    for cc in 0..*c {
                                        let x = buf[(ir * *w + ic) * *c + cc];
                                        let wv = weights[((ff * *k + kh) * *k + kw) * *c + cc];
                                        *o = o.wrapping_add(wv.wrapping_mul(x));
                                    }
                                }
                            }
                        }
                        out
                    }
                    Payload::MaxReduce => {
                        let mut out = vec![i32::MIN; *f];
                        for kh in 0..*k {
                            for kw in 0..*k {
                                let ir = r * *stride + kh * *dilation;
                                let ic = cx * *stride + kw * *dilation;
                                if ir < *pad || ic < *pad {
                                    continue;
                                }
                                let (ir, ic) = (ir - *pad, ic - *pad);
                                if ir >= *h || ic >= *w {
                                    continue;
                                }
                                for cc in 0..*c {
                                    out[cc] = out[cc].max(buf[(ir * *w + ic) * *c + cc]);
                                }
                            }
                        }
                        out
                    }
                    other => panic!("sliding node with payload {other:?}"),
                }
            }
            NaiveProc::Reduction { n, weights, cur, .. } => {
                let x = cur.take().expect("fire before accept");
                let mut out = vec![0i32; *n];
                for (kk, &xv) in x.iter().enumerate() {
                    for (nn, o) in out.iter_mut().enumerate() {
                        *o = o.wrapping_add(weights[kk * *n + nn].wrapping_mul(xv));
                    }
                }
                out
            }
            NaiveProc::Parallel { payload, pending, .. } => {
                let toks: Vec<Token> = pending
                    .iter_mut()
                    .map(|q| q.pop_front().expect("missing token"))
                    .collect();
                let refs: Vec<&[i32]> = toks.iter().map(|t| t.as_slice()).collect();
                apply_payload(*payload, &refs)
            }
        }
    }
}

struct NodeState {
    proc: NaiveProc,
    firings: u64,
    t_free: u64,
    complete: u64,
    trace: NodeTrace,
    consumed: Vec<u64>,
    last_in_time: Vec<u64>,
}

/// Simulate `design` through the naive reference data plane. Must
/// produce a report **identical** to [`crate::sim::simulate`] in every
/// observable field (outputs, cycles, traces, high-water marks,
/// firings, token ops) — that equality is the arena engine's pin.
pub fn simulate_naive(design: &Design, input: &[i32], mode: SimMode) -> Result<SimReport> {
    let g = &design.graph;
    let in_t = g.inputs()[0];
    ensure!(
        input.len() == in_t.ty.numel(),
        "input has {} values, graph expects {}",
        input.len(),
        in_t.ty.numel()
    );

    let mut fifos: Vec<NaiveFifo> = design
        .channels
        .iter()
        .map(|c| match mode {
            SimMode::Sequential => NaiveFifo::new(usize::MAX),
            SimMode::Dataflow => NaiveFifo::new(c.depth),
        })
        .collect();

    let bank = WeightBank::build(design)?;
    let mut nodes: Vec<NodeState> = (0..design.nodes.len())
        .map(|i| {
            Ok(NodeState {
                proc: NaiveProc::from_node(design, i, &bank)?,
                firings: 0,
                t_free: 0,
                complete: 0,
                trace: NodeTrace { name: design.nodes[i].name.clone(), ..Default::default() },
                consumed: vec![0; design.nodes[i].in_channels.len()],
                last_in_time: vec![0; design.nodes[i].in_channels.len()],
            })
        })
        .collect::<Result<_>>()?;

    let input_chans: Vec<usize> = design
        .channels
        .iter()
        .filter(|c| c.src == Endpoint::GraphInput)
        .map(|c| c.id.0)
        .collect();
    ensure!(!input_chans.is_empty(), "no input channels");
    let tok_len = design.channels[input_chans[0]].token_len;
    let in_tokens_total = design.channels[input_chans[0]].tokens_total;
    ensure!(
        in_tokens_total as usize * tok_len == input.len(),
        "input tokenization mismatch"
    );
    let token_bytes = (tok_len as u64 * design.channels[input_chans[0]].elem_bits).div_ceil(8);
    let mut fed: u64 = 0;

    let out_chan = design.output_channel()?.id.0;
    let out_tokens_total = design.channels[out_chan].tokens_total;
    let out_token_bytes =
        (design.channels[out_chan].token_len as u64 * design.channels[out_chan].elem_bits)
            .div_ceil(8);
    let mut output: Vec<i32> =
        Vec::with_capacity(out_tokens_total as usize * design.channels[out_chan].token_len);
    let mut drained: u64 = 0;
    let mut last_drain: u64 = 0;
    let mut total_firings: u64 = 0;

    let preds: Vec<Vec<usize>> = design
        .nodes
        .iter()
        .map(|n| {
            n.in_channels
                .iter()
                .filter_map(|&c| match design.channel(c).src {
                    Endpoint::Node(p) => Some(p),
                    _ => None,
                })
                .collect()
        })
        .collect();

    loop {
        let mut progress = false;

        // 1) feeder
        while fed < in_tokens_total {
            if !input_chans.iter().all(|&c| fifos[c].has_space()) {
                break;
            }
            let axi_t = ((fed + 1) * token_bytes).div_ceil(AXI_BYTES_PER_CYCLE);
            let t = input_chans
                .iter()
                .filter_map(|&c| fifos[c].next_push_ready())
                .fold(axi_t, u64::max);
            let base = fed as usize * tok_len;
            let tok: Token = input[base..base + tok_len].to_vec();
            for &c in &input_chans {
                fifos[c].push(t, tok.clone());
            }
            fed += 1;
            progress = true;
        }

        // 2) nodes
        for nid in 0..nodes.len() {
            let dn = &design.nodes[nid];
            let barrier = match mode {
                SimMode::Sequential => {
                    let mut b = 0;
                    let mut ready = true;
                    for &p in &preds[nid] {
                        if nodes[p].firings < design.nodes[p].geo.out_tokens {
                            ready = false;
                            break;
                        }
                        b = b.max(nodes[p].complete);
                    }
                    if !ready {
                        continue;
                    }
                    b
                }
                SimMode::Dataflow => 0,
            };

            'fire: while nodes[nid].firings < dn.geo.out_tokens {
                let k = nodes[nid].firings;
                for (slot, &cid) in dn.in_channels.iter().enumerate() {
                    let cpt = design.channel(cid).cycles_per_token();
                    let needed = nodes[nid].proc.needed(slot, k);
                    while nodes[nid].consumed[slot] < needed && !fifos[cid.0].is_empty() {
                        let arr = fifos[cid.0].arrival(0).unwrap();
                        let t_pop = (arr + cpt).max(nodes[nid].last_in_time[slot] + cpt);
                        let (_, tok) = fifos[cid.0].pop(t_pop);
                        nodes[nid].proc.accept(slot, tok);
                        nodes[nid].consumed[slot] += 1;
                        nodes[nid].last_in_time[slot] = t_pop;
                        progress = true;
                    }
                    if nodes[nid].consumed[slot] < needed {
                        break 'fire;
                    }
                }
                let t_in: u64 = dn
                    .in_channels
                    .iter()
                    .enumerate()
                    .map(|(slot, _)| nodes[nid].last_in_time[slot])
                    .max()
                    .unwrap_or(0);

                let mut t_out: u64 = 0;
                for &cid in &dn.out_channels {
                    match fifos[cid.0].next_push_ready() {
                        Some(t) => t_out = t_out.max(t),
                        None => break 'fire,
                    }
                }

                let base_ready = nodes[nid].t_free.max(barrier);
                let t = base_ready.max(t_in).max(t_out);
                if t_in > base_ready.max(t_out) {
                    nodes[nid].trace.stall_in += t_in - base_ready.max(t_out);
                }
                if t_out > base_ready.max(t_in) {
                    nodes[nid].trace.stall_out += t_out - base_ready.max(t_in);
                }

                let value = nodes[nid].proc.fire(k);
                let t_vis = t + dn.timing.depth;
                let (last, rest) = dn.out_channels.split_last().unwrap();
                for &cid in rest {
                    fifos[cid.0].push(t_vis, value.clone());
                }
                fifos[last.0].push(t_vis, value);
                let interval = dn.compute_interval();
                nodes[nid].t_free = t + interval;
                nodes[nid].firings += 1;
                total_firings += 1;
                if k == 0 {
                    nodes[nid].trace.first_fire = t;
                }
                nodes[nid].trace.last_fire = t;
                nodes[nid].complete = t_vis;
                progress = true;
            }
        }

        // 3) sink
        while !fifos[out_chan].is_empty() {
            let arr = fifos[out_chan].arrival(0).unwrap();
            let axi_t = last_drain + out_token_bytes.div_ceil(AXI_BYTES_PER_CYCLE);
            let t = arr.max(axi_t);
            let (_, tok) = fifos[out_chan].pop(t);
            output.extend_from_slice(&tok);
            drained += 1;
            last_drain = t;
            progress = true;
        }

        if drained == out_tokens_total {
            break;
        }
        if !progress {
            let mut blocked = Vec::new();
            if fed < in_tokens_total {
                blocked.push(format!("feeder: {fed}/{in_tokens_total} tokens delivered"));
            }
            for (nid, ns) in nodes.iter().enumerate() {
                let dn = &design.nodes[nid];
                if ns.firings < dn.geo.out_tokens {
                    let waits: Vec<String> = dn
                        .in_channels
                        .iter()
                        .enumerate()
                        .map(|(s, &c)| {
                            format!(
                                "{}: have {} need {}",
                                design.channel(c).name,
                                ns.consumed[s] + fifos[c.0].len() as u64,
                                ns.proc.needed(s, ns.firings)
                            )
                        })
                        .collect();
                    let full: Vec<String> = dn
                        .out_channels
                        .iter()
                        .filter(|&&c| !fifos[c.0].has_space())
                        .map(|&c| format!("{} full", design.channel(c).name))
                        .collect();
                    blocked.push(format!(
                        "{} at firing {}/{} [{} | {}]",
                        dn.name,
                        ns.firings,
                        dn.geo.out_tokens,
                        waits.join(", "),
                        full.join(", ")
                    ));
                }
            }
            return Ok(SimReport {
                cycles: 0,
                output,
                traces: finish_traces(nodes),
                fifo_high_water: high_water(design, &fifos),
                deadlock: Some(blocked),
                total_firings,
                token_ops: fifos.iter().map(|f| f.pushed + f.popped).sum(),
                fifo_profile: None,
            });
        }
    }

    let token_ops = fifos.iter().map(|f| f.pushed + f.popped).sum();
    Ok(SimReport {
        cycles: last_drain,
        output,
        traces: finish_traces(nodes),
        fifo_high_water: high_water(design, &fifos),
        deadlock: None,
        total_firings,
        token_ops,
        fifo_profile: None,
    })
}

/// Shared trace finalize — the deadlock branch populates
/// `firings`/`complete` exactly like the success branch.
fn finish_traces(nodes: Vec<NodeState>) -> Vec<NodeTrace> {
    nodes
        .into_iter()
        .map(|mut n| {
            n.trace.firings = n.firings;
            n.trace.complete = n.complete;
            n.trace
        })
        .collect()
}

fn high_water(design: &Design, fifos: &[NaiveFifo]) -> Vec<(String, usize)> {
    design
        .channels
        .iter()
        .zip(fifos)
        .map(|(c, f)| (c.name.clone(), f.max_occupancy))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;
    use crate::sim::simulate;
    use crate::util::prng;

    fn det_input(g: &crate::ir::graph::ModelGraph) -> Vec<i32> {
        prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect()
    }

    fn assert_reports_match(a: &SimReport, b: &SimReport, tag: &str) {
        assert_eq!(a.output, b.output, "{tag}: output");
        assert_eq!(a.cycles, b.cycles, "{tag}: cycles");
        assert_eq!(a.total_firings, b.total_firings, "{tag}: firings");
        assert_eq!(a.token_ops, b.token_ops, "{tag}: token ops");
        assert_eq!(a.fifo_high_water, b.fifo_high_water, "{tag}: high water");
        assert_eq!(a.deadlock.is_some(), b.deadlock.is_some(), "{tag}: deadlock");
        for (ta, tb) in a.traces.iter().zip(&b.traces) {
            assert_eq!(ta.firings, tb.firings, "{tag}/{}: trace firings", ta.name);
            assert_eq!(ta.first_fire, tb.first_fire, "{tag}/{}", ta.name);
            assert_eq!(ta.last_fire, tb.last_fire, "{tag}/{}", ta.name);
            assert_eq!(ta.complete, tb.complete, "{tag}/{}", ta.name);
            assert_eq!(ta.stall_in, tb.stall_in, "{tag}/{}", ta.name);
            assert_eq!(ta.stall_out, tb.stall_out, "{tag}/{}", ta.name);
        }
    }

    #[test]
    fn naive_matches_arena_engine_on_paper_kernels() {
        for (name, size) in [("conv_relu", 16usize), ("cascade", 16), ("linear", 0)] {
            let g = models::paper_kernel(name, size).unwrap();
            let d = build_streaming_design(&g).unwrap();
            let x = det_input(&g);
            for mode in [SimMode::Dataflow, SimMode::Sequential] {
                let a = simulate(&d, &x, mode).unwrap();
                let n = simulate_naive(&d, &x, mode).unwrap();
                assert_reports_match(&a, &n, &format!("{name}/{mode:?}"));
            }
        }
    }

    #[test]
    fn naive_matches_arena_engine_on_deadlock() {
        // Undersized diamond FIFOs: both engines must deadlock at the
        // same place with fully finalized traces.
        let g = models::residual(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let a = simulate(&d, &x, SimMode::Dataflow).unwrap();
        let n = simulate_naive(&d, &x, SimMode::Dataflow).unwrap();
        assert!(a.deadlock.is_some() && n.deadlock.is_some());
        assert_eq!(a.deadlock, n.deadlock, "blocked-node reports must agree");
        assert_reports_match(&a, &n, "residual deadlock");
    }
}
