//! Cycle-level KPN dataflow simulator — the substitute for Vitis HLS
//! synthesis reports (DESIGN.md substitution table).
//!
//! The simulator executes a [`crate::dataflow::Design`] *functionally*
//! (bit-exact int8/int32 semantics, same contract as `ref.py`) while
//! tracking time at **firing granularity**: every node firing gets a
//! cycle timestamp derived from input-token arrival times, the node's
//! initiation interval / pipeline depth, FIFO back-pressure (blocking
//! writes against finite depths) and — in `Sequential` style — a barrier
//! after every producer. Cycle counts therefore include line-buffer
//! warm-up, DATAFLOW overlap and diamond stalls exactly where a real
//! streaming design pays them, at a simulation cost of O(tokens), not
//! O(cycles).
//!
//! Deadlocks (undersized diamond FIFOs) are detected, not hidden: if no
//! node can make progress and the sink is not done, the engine reports
//! the blocked nodes and their wait reasons.

pub mod arena;
pub mod fifo;
pub mod process;
pub mod engine;
pub mod naive;
pub mod trace;

pub use engine::{simulate, FfStats, SimConfig, SimContext, SimMode, SimReport};
pub use process::WeightBank;
