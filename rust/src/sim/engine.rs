//! The discrete-event engine: feeder → nodes → sink, at firing
//! granularity with timestamped tokens.
//!
//! The data plane is zero-copy: token payloads live in a per-context
//! [`TokenArena`], FIFOs queue 12-byte handle+timestamp pairs, and
//! broadcast fan-out bumps a refcount instead of cloning. All mutable
//! state lives in a reusable [`SimContext`] — building one pays for
//! `build_proc` (weight transposition included) exactly once per
//! design; every subsequent [`SimContext::run`] resets and reuses the
//! arena, the FIFO rings and the line-buffer allocations, which is what
//! makes per-cell tiled simulation allocation-free after the first cell.

use anyhow::{ensure, Result};

use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::{Design, DesignStyle};

use super::arena::TokenArena;
use super::fifo::SimFifo;
use super::process::{build_proc, NodeProc, WeightBank};
use super::trace::NodeTrace;

mod ffwd;

/// Host-interface model: a 128-bit AXI port moves 16 bytes per cycle in
/// each direction (KV260 DDR4 class). Bounds feeder and sink rates.
pub const AXI_BYTES_PER_CYCLE: u64 = 16;

/// Scheduling discipline (derived from the design style by default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Task-level DATAFLOW: all nodes run concurrently.
    Dataflow,
    /// Vanilla: a node starts only after all its producers finished;
    /// channels are backed by full tensors (unbounded FIFOs).
    Sequential,
}

impl SimMode {
    pub fn of(style: DesignStyle) -> Self {
        match style {
            DesignStyle::Dataflow => SimMode::Dataflow,
            DesignStyle::Sequential => SimMode::Sequential,
        }
    }
}

/// Knobs for the simulator fast path. Both stages are **on by
/// default** — they are bit-exact against the naive oracle (asserted by
/// the equivalence property suite) — and both can be disabled for a
/// fully step-by-step run (`--exact-sim`, [`SimConfig::exact`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimConfig {
    /// Steady-state detection + fast-forward: once the engine's timing
    /// state repeats modulo a uniform cycle shift, whole periods are
    /// replayed functionally and their timing applied analytically.
    pub fast_forward: bool,
    /// Row-batched firing inside the fast-forward replay: sliding nodes
    /// produce a whole output row per step over the arena's flat
    /// slices. No effect on the exact (cycle-attributing) path.
    pub batch_fire: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { fast_forward: true, batch_fire: true }
    }
}

impl SimConfig {
    /// Fully exact execution: no fast-forward, no batched firing — the
    /// PR-6 arena engine behaviour, byte for byte.
    pub fn exact() -> Self {
        Self { fast_forward: false, batch_fire: false }
    }
}

/// What the steady-state accelerator did during one run
/// ([`SimReport::ff`]; all zeros on exact runs and whenever no period
/// was detected).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FfStats {
    /// Whole steady-state periods skipped analytically.
    pub periods: u64,
    /// Simulated cycles covered by those periods (the report's `cycles`
    /// still includes them — they were advanced in O(1), not executed).
    pub skipped_cycles: u64,
    /// Firings executed through the row-batched replay kernel.
    pub batched_firings: u64,
    /// Scanline checkpoints snapshotted by the detector.
    pub checkpoints: u64,
}

/// Back-pressure profile of one channel: how deep the FIFO ran and how
/// many cycles it bounded its neighbours.
#[derive(Debug, Clone, Default)]
pub struct ChannelProfile {
    pub name: String,
    /// Configured capacity in tokens (`usize::MAX` = unbounded).
    pub capacity: usize,
    pub max_occupancy: usize,
    pub pushed: u64,
    /// Log2 occupancy histogram (see [`crate::sim::fifo::occupancy_bucket`]);
    /// empty if no push happened.
    pub hist: Vec<u64>,
    /// Cycles the consumer stalled waiting for this channel's tokens.
    pub stall_wait: u64,
    /// Cycles the producer stalled because this channel was full.
    pub stall_full: u64,
}

/// Per-FIFO back-pressure profile for one run ([`SimReport::fifo_profile`];
/// populated only when [`SimContext::enable_profile`] was called).
#[derive(Debug, Clone, Default)]
pub struct FifoProfile {
    pub channels: Vec<ChannelProfile>,
}

impl FifoProfile {
    /// The channel that bounds throughput: most producer-blocking cycles,
    /// falling back to most consumer-wait cycles.
    pub fn bounding_channel(&self) -> Option<&ChannelProfile> {
        let by_full = self.channels.iter().max_by_key(|c| c.stall_full);
        match by_full {
            Some(c) if c.stall_full > 0 => Some(c),
            _ => self.channels.iter().filter(|c| c.stall_wait > 0).max_by_key(|c| c.stall_wait),
        }
    }

    /// Merge another run's profile into this one (tiled cells accumulate
    /// into a whole-design profile; channel sets must match).
    pub fn merge(&mut self, other: &FifoProfile) {
        if self.channels.is_empty() {
            self.channels = other.channels.clone();
            return;
        }
        for (a, b) in self.channels.iter_mut().zip(&other.channels) {
            a.max_occupancy = a.max_occupancy.max(b.max_occupancy);
            a.pushed += b.pushed;
            a.stall_wait += b.stall_wait;
            a.stall_full += b.stall_full;
            if a.hist.len() < b.hist.len() {
                a.hist.resize(b.hist.len(), 0);
            }
            for (ha, hb) in a.hist.iter_mut().zip(&b.hist) {
                *ha += hb;
            }
        }
    }

    /// Render the `--profile` back-pressure section: one row per
    /// channel plus a bounding-channel headline.
    pub fn render(&self) -> String {
        use crate::sim::fifo::bucket_label;
        use crate::util::tables::TextTable;
        let mut t =
            TextTable::new(vec!["channel", "cap", "max occ", "pushed", "full", "wait", "occupancy"]);
        for c in &self.channels {
            let cap =
                if c.capacity == usize::MAX { "inf".to_string() } else { c.capacity.to_string() };
            let hist = c
                .hist
                .iter()
                .enumerate()
                .filter(|(_, n)| **n > 0)
                .map(|(b, n)| format!("{}:{n}", bucket_label(b)))
                .collect::<Vec<_>>()
                .join(" ");
            t.row(vec![
                c.name.clone(),
                cap,
                c.max_occupancy.to_string(),
                c.pushed.to_string(),
                c.stall_full.to_string(),
                c.stall_wait.to_string(),
                hist,
            ]);
        }
        let mut out = t.render();
        match self.bounding_channel() {
            Some(c) => {
                out.push_str(&format!(
                    "bounding channel: {} ({} cycles blocked-full, {} cycles consumer-wait)\n",
                    c.name, c.stall_full, c.stall_wait
                ));
            }
            None => out.push_str("no back-pressure observed\n"),
        }
        out
    }
}

/// Simulation result.
#[derive(Debug)]
pub struct SimReport {
    /// Total cycles until the last output token reached the host.
    pub cycles: u64,
    /// Output tensor values (row-major, int8 range unless the graph
    /// output is an accumulator).
    pub output: Vec<i32>,
    pub traces: Vec<NodeTrace>,
    /// Max occupancy per channel (FIFO sizing diagnostics).
    pub fifo_high_water: Vec<(String, usize)>,
    /// `Some(blocked-node descriptions)` if the design deadlocked.
    pub deadlock: Option<Vec<String>>,
    /// Total firings across all nodes (simulator throughput metric).
    pub total_firings: u64,
    /// Total FIFO operations (pushes + pops) across all channels —
    /// the data-plane throughput metric for `BENCH_sim.json`.
    pub token_ops: u64,
    /// Per-FIFO back-pressure profile; `None` unless
    /// [`SimContext::enable_profile`] armed the run.
    pub fifo_profile: Option<FifoProfile>,
    /// Steady-state fast-forward statistics for this run.
    pub ff: FfStats,
}

impl SimReport {
    /// Panic-with-context helper for tests/examples.
    pub fn expect_complete(self) -> Self {
        if let Some(blocked) = &self.deadlock {
            panic!("simulation deadlocked:\n  {}", blocked.join("\n  "));
        }
        self
    }

    pub fn macs_per_cycle(&self, total_macs: u64) -> f64 {
        total_macs as f64 / self.cycles.max(1) as f64
    }
}

#[derive(Default)]
struct NodeState {
    firings: u64,
    t_free: u64,
    complete: u64,
    trace: NodeTrace,
    consumed: Vec<u64>,
    /// Cycle the most recent token finished streaming in, per input —
    /// tokens are consumed *eagerly* (into the line buffer / pending
    /// registers) at stream rate, which is exactly what the paper's
    /// line-buffer architecture buys: the FIFO itself stays shallow.
    last_in_time: Vec<u64>,
}

/// Reusable simulation state for one design: procs (weights transposed
/// once), FIFO rings, the token arena and per-node bookkeeping. Build
/// with [`SimContext::new`], then [`SimContext::run`] any number of
/// inputs — each run resets the state but keeps every allocation.
pub struct SimContext<'d> {
    design: &'d Design,
    mode: SimMode,
    arena: TokenArena,
    fifos: Vec<SimFifo>,
    procs: Vec<NodeProc>,
    nodes: Vec<NodeState>,
    /// Cached per-channel stream rate (cycles per token).
    cpt: Vec<u64>,
    /// Sequential-barrier predecessors per node.
    preds: Vec<Vec<usize>>,
    input_chans: Vec<usize>,
    tok_len: usize,
    in_tokens_total: u64,
    token_bytes: u64,
    out_chan: usize,
    out_tokens_total: u64,
    out_token_bytes: u64,
    /// Back-pressure profiling armed? Adds per-channel stall attribution
    /// and FIFO occupancy histograms to each run's report.
    profile: bool,
    /// Per-channel consumer-wait cycles (profiling only).
    chan_stall_wait: Vec<u64>,
    /// Per-channel producer-blocked-full cycles (profiling only).
    chan_stall_full: Vec<u64>,
    /// Fast-path knobs (steady-state fast-forward, batched firing).
    cfg: SimConfig,
    /// Steady-state detector working state (checkpoints, stats).
    ff: ffwd::FfState,
}

impl<'d> SimContext<'d> {
    pub fn new(design: &'d Design, mode: SimMode) -> Result<Self> {
        Self::with_bank(design, mode, &WeightBank::build(design)?)
    }

    /// Build a context whose procs share weight storage with every
    /// other context built from the same `bank` (one transposition per
    /// design, however many worker contexts the tiled pool holds).
    pub fn with_bank(design: &'d Design, mode: SimMode, bank: &WeightBank) -> Result<Self> {
        let procs: Vec<NodeProc> =
            (0..design.nodes.len()).map(|i| build_proc(design, i, bank)).collect::<Result<_>>()?;
        let fifos: Vec<SimFifo> = design
            .channels
            .iter()
            .map(|c| match mode {
                SimMode::Sequential => SimFifo::unbounded(),
                SimMode::Dataflow => SimFifo::new(c.depth),
            })
            .collect();
        let nodes = design
            .nodes
            .iter()
            .map(|n| NodeState {
                consumed: vec![0; n.in_channels.len()],
                last_in_time: vec![0; n.in_channels.len()],
                ..Default::default()
            })
            .collect();
        let cpt = design.channels.iter().map(|c| c.cycles_per_token()).collect();
        let preds = design
            .nodes
            .iter()
            .map(|n| {
                n.in_channels
                    .iter()
                    .filter_map(|&c| match design.channel(c).src {
                        Endpoint::Node(p) => Some(p),
                        _ => None,
                    })
                    .collect()
            })
            .collect();

        let input_chans: Vec<usize> = design
            .channels
            .iter()
            .filter(|c| c.src == Endpoint::GraphInput)
            .map(|c| c.id.0)
            .collect();
        ensure!(!input_chans.is_empty(), "no input channels");
        let in0 = &design.channels[input_chans[0]];
        let (tok_len, in_tokens_total) = (in0.token_len, in0.tokens_total);
        let token_bytes = (tok_len as u64 * in0.elem_bits).div_ceil(8);
        let out_chan = design.output_channel()?.id.0;
        let out = &design.channels[out_chan];
        let out_token_bytes = (out.token_len as u64 * out.elem_bits).div_ceil(8);
        Ok(Self {
            design,
            mode,
            arena: TokenArena::new(),
            fifos,
            procs,
            nodes,
            cpt,
            preds,
            input_chans,
            tok_len,
            in_tokens_total,
            token_bytes,
            out_chan,
            out_tokens_total: out.tokens_total,
            out_token_bytes,
            profile: false,
            chan_stall_wait: Vec::new(),
            chan_stall_full: Vec::new(),
            cfg: SimConfig::default(),
            ff: ffwd::FfState::new(design, tok_len),
        })
    }

    /// Override the fast-path knobs (defaults: everything on).
    pub fn set_config(&mut self, cfg: SimConfig) {
        self.cfg = cfg;
    }

    /// The active fast-path knobs.
    pub fn config(&self) -> SimConfig {
        self.cfg
    }

    /// Do this context's procs share (by pointer) their weight storage
    /// with `other`'s? True exactly when both were built from the same
    /// [`WeightBank`] — the bytes-shared diagnostic for the tiled
    /// context pool.
    pub fn shares_weights_with(&self, other: &SimContext<'_>) -> bool {
        self.procs.len() == other.procs.len()
            && self
                .procs
                .iter()
                .zip(&other.procs)
                .all(|(a, b)| a.weights_addr() == b.weights_addr())
    }

    /// Arm per-FIFO back-pressure profiling: every subsequent run
    /// records occupancy histograms and per-channel stall attribution
    /// into [`SimReport::fifo_profile`]. Off by default — the disabled
    /// cost is one branch per firing.
    pub fn enable_profile(&mut self) {
        self.profile = true;
        self.chan_stall_wait = vec![0; self.fifos.len()];
        self.chan_stall_full = vec![0; self.fifos.len()];
        for f in &mut self.fifos {
            f.enable_profile();
        }
    }

    /// Clear all per-run state (arena, FIFOs, procs, node bookkeeping)
    /// while keeping every allocation and the transposed weights.
    pub fn reset(&mut self) {
        self.arena.reset();
        for f in &mut self.fifos {
            f.reset();
        }
        for p in &mut self.procs {
            p.reset();
        }
        for (ns, n) in self.nodes.iter_mut().zip(&self.design.nodes) {
            ns.firings = 0;
            ns.t_free = 0;
            ns.complete = 0;
            ns.trace = NodeTrace { name: n.name.clone(), ..Default::default() };
            ns.consumed.iter_mut().for_each(|v| *v = 0);
            ns.last_in_time.iter_mut().for_each(|v| *v = 0);
        }
        self.chan_stall_wait.iter_mut().for_each(|v| *v = 0);
        self.chan_stall_full.iter_mut().for_each(|v| *v = 0);
        self.ff.reset();
    }

    /// The design this context simulates.
    pub fn design(&self) -> &'d Design {
        self.design
    }

    /// Finalize traces — shared by the success and deadlock paths, so
    /// deadlock reports carry per-node `firings`/`complete` too.
    fn finish_traces(&mut self) -> Vec<NodeTrace> {
        self.nodes
            .iter_mut()
            .map(|n| {
                n.trace.firings = n.firings;
                n.trace.complete = n.complete;
                std::mem::take(&mut n.trace)
            })
            .collect()
    }

    fn high_water(&self) -> Vec<(String, usize)> {
        self.design
            .channels
            .iter()
            .zip(&self.fifos)
            .map(|(c, f)| (c.name.clone(), f.max_occupancy))
            .collect()
    }

    fn token_ops(&self) -> u64 {
        self.fifos.iter().map(|f| f.pushed + f.popped).sum()
    }

    /// Assemble the per-FIFO back-pressure profile (profiling runs only).
    fn fifo_profile(&self) -> Option<FifoProfile> {
        if !self.profile {
            return None;
        }
        let channels = self
            .design
            .channels
            .iter()
            .zip(&self.fifos)
            .enumerate()
            .map(|(i, (c, f))| ChannelProfile {
                name: c.name.clone(),
                capacity: f.capacity,
                max_occupancy: f.max_occupancy,
                pushed: f.pushed,
                hist: f.occupancy_histogram().map(|h| h.to_vec()).unwrap_or_default(),
                stall_wait: self.chan_stall_wait[i],
                stall_full: self.chan_stall_full[i],
            })
            .collect();
        Some(FifoProfile { channels })
    }

    /// Flush this run's totals into the global metrics registry (coarse:
    /// once per run, not per firing).
    fn flush_metrics(&self, total_firings: u64, token_ops: u64) {
        let m = crate::obs::metrics::global();
        m.incr("sim.runs");
        m.add("sim.firings", total_firings);
        m.add("sim.token_ops", token_ops);
        m.gauge_max("sim.arena_high_water", self.arena.high_water() as u64);
        let ff = self.ff.stats;
        if ff.periods > 0 {
            m.add("sim.ff_periods", ff.periods);
            m.add("sim.ff_cycles", ff.skipped_cycles);
        }
        if ff.batched_firings > 0 {
            m.add("sim.batched_firings", ff.batched_firings);
        }
    }

    /// Simulate the design on a host input tensor (row-major int8
    /// values, widened to i32). Resets the context first, so a context
    /// can be reused across any number of runs.
    pub fn run(&mut self, input: &[i32]) -> Result<SimReport> {
        self.reset();
        let design = self.design;
        let in_t = design.graph.inputs()[0];
        ensure!(
            input.len() == in_t.ty.numel(),
            "input has {} values, graph expects {}",
            input.len(),
            in_t.ty.numel()
        );
        ensure!(
            self.in_tokens_total as usize * self.tok_len == input.len(),
            "input tokenization mismatch"
        );

        let mut fed: u64 = 0;
        let mut output: Vec<i32> = Vec::with_capacity(
            self.out_tokens_total as usize * design.channels[self.out_chan].token_len,
        );
        let mut drained: u64 = 0;
        let mut last_drain: u64 = 0;
        let mut total_firings: u64 = 0;

        let ff_active = self.cfg.fast_forward && self.mode == SimMode::Dataflow;

        // --- sweep loop --------------------------------------------------
        loop {
            let mut progress = false;

            // 0) steady-state detector: snapshot the timing state about
            // once per input scanline; on a repeat (modulo a uniform
            // cycle shift), replay the remaining whole periods
            // functionally and advance all timing in O(1) per period.
            if ff_active
                && self.maybe_fast_forward(
                    input,
                    &mut fed,
                    &mut drained,
                    &mut last_drain,
                    &mut total_firings,
                    &mut output,
                )?
            {
                progress = true;
            }

            // 1) feeder: deliver input tokens (AXI-limited, broadcast).
            while fed < self.in_tokens_total {
                if !self.input_chans.iter().all(|&c| self.fifos[c].has_space()) {
                    break;
                }
                let axi_t = ((fed + 1) * self.token_bytes).div_ceil(AXI_BYTES_PER_CYCLE);
                let fifo_t = self
                    .input_chans
                    .iter()
                    .filter_map(|&c| self.fifos[c].next_push_ready())
                    .fold(0, u64::max);
                // the detector needs to know whether the host port or
                // FIFO back-pressure set this push's time (feeder
                // periodicity condition)
                if axi_t > fifo_t {
                    self.ff.axi_bound += 1;
                }
                let t = axi_t.max(fifo_t);
                let base = fed as usize * self.tok_len;
                let tok = self.arena.alloc_from(&input[base..base + self.tok_len]);
                let (last, rest) = self.input_chans.split_last().unwrap();
                for &c in rest {
                    self.arena.retain(tok);
                    self.fifos[c].push(t, tok);
                }
                self.fifos[*last].push(t, tok);
                fed += 1;
                progress = true;
            }

            // 2) nodes, in topological order.
            for nid in 0..self.nodes.len() {
                let dn = &design.nodes[nid];
                let barrier = match self.mode {
                    SimMode::Sequential => {
                        let mut b = 0;
                        let mut ready = true;
                        for &p in &self.preds[nid] {
                            if self.nodes[p].firings < design.nodes[p].geo.out_tokens {
                                ready = false;
                                break;
                            }
                            b = b.max(self.nodes[p].complete);
                        }
                        if !ready {
                            continue;
                        }
                        b
                    }
                    SimMode::Dataflow => 0,
                };

                'fire: while self.nodes[nid].firings < dn.geo.out_tokens {
                    let k = self.nodes[nid].firings;

                    // (a) eagerly stream available tokens in (≤ needed for
                    // this firing), at one token per `cycles_per_token` —
                    // the line-buffer fill. Frees FIFO slots so shallow
                    // streams suffice.
                    for (slot, &cid) in dn.in_channels.iter().enumerate() {
                        let cpt = self.cpt[cid.0];
                        let needed = self.procs[nid].needed(slot, k);
                        while self.nodes[nid].consumed[slot] < needed
                            && !self.fifos[cid.0].is_empty()
                        {
                            let arr = self.fifos[cid.0].arrival(0).unwrap();
                            let t_pop =
                                (arr + cpt).max(self.nodes[nid].last_in_time[slot] + cpt);
                            let (_, tok) = self.fifos[cid.0].pop(t_pop);
                            self.procs[nid].accept(slot, tok, &mut self.arena);
                            self.nodes[nid].consumed[slot] += 1;
                            self.nodes[nid].last_in_time[slot] = t_pop;
                            progress = true;
                        }
                        if self.nodes[nid].consumed[slot] < needed {
                            break 'fire; // blocked on input tokens
                        }
                    }
                    let t_in: u64 = dn
                        .in_channels
                        .iter()
                        .enumerate()
                        .map(|(slot, _)| self.nodes[nid].last_in_time[slot])
                        .max()
                        .unwrap_or(0);

                    // (b) output space?
                    let mut t_out: u64 = 0;
                    let mut t_out_chan = usize::MAX;
                    for &cid in &dn.out_channels {
                        match self.fifos[cid.0].next_push_ready() {
                            Some(t) => {
                                if t >= t_out {
                                    t_out = t;
                                    t_out_chan = cid.0;
                                }
                            }
                            None => break 'fire, // blocked on output space
                        }
                    }

                    // (c) fire
                    let base_ready = self.nodes[nid].t_free.max(barrier);
                    let t = base_ready.max(t_in).max(t_out);
                    // stall attribution
                    if t_in > base_ready.max(t_out) {
                        let stall = t_in - base_ready.max(t_out);
                        self.nodes[nid].trace.stall_in += stall;
                        if self.profile {
                            // charge the input channel whose token arrived
                            // last — the one that bounded this firing
                            if let Some(slot) = (0..dn.in_channels.len())
                                .max_by_key(|&s| self.nodes[nid].last_in_time[s])
                            {
                                self.chan_stall_wait[dn.in_channels[slot].0] += stall;
                            }
                        }
                    }
                    if t_out > base_ready.max(t_in) {
                        let stall = t_out - base_ready.max(t_in);
                        self.nodes[nid].trace.stall_out += stall;
                        if self.profile && t_out_chan != usize::MAX {
                            self.chan_stall_full[t_out_chan] += stall;
                        }
                    }

                    let value = self.procs[nid].fire_into(k, &mut self.arena);
                    let t_vis = t + dn.timing.depth;
                    // broadcast: retain for all but the last consumer (the
                    // common single-consumer case moves the handle)
                    let (last, rest) = dn.out_channels.split_last().unwrap();
                    for &cid in rest {
                        self.arena.retain(value);
                        self.fifos[cid.0].push(t_vis, value);
                    }
                    self.fifos[last.0].push(t_vis, value);
                    let interval = dn.compute_interval();
                    self.nodes[nid].t_free = t + interval;
                    self.nodes[nid].firings += 1;
                    total_firings += 1;
                    if k == 0 {
                        self.nodes[nid].trace.first_fire = t;
                    }
                    self.nodes[nid].trace.last_fire = t;
                    self.nodes[nid].complete = t_vis;
                    progress = true;
                }
            }

            // 3) sink: drain the output channel (AXI-limited).
            while !self.fifos[self.out_chan].is_empty() {
                let arr = self.fifos[self.out_chan].arrival(0).unwrap();
                let axi_t = last_drain + self.out_token_bytes.div_ceil(AXI_BYTES_PER_CYCLE);
                let t = arr.max(axi_t);
                let (_, tok) = self.fifos[self.out_chan].pop(t);
                output.extend_from_slice(self.arena.get(tok));
                self.arena.release(tok);
                drained += 1;
                last_drain = t;
                progress = true;
            }

            if drained == self.out_tokens_total {
                break;
            }
            if !progress {
                // deadlock: report who is stuck and why
                let mut blocked = Vec::new();
                if fed < self.in_tokens_total {
                    blocked.push(format!(
                        "feeder: {fed}/{} tokens delivered",
                        self.in_tokens_total
                    ));
                }
                for (nid, ns) in self.nodes.iter().enumerate() {
                    let dn = &design.nodes[nid];
                    if ns.firings < dn.geo.out_tokens {
                        let waits: Vec<String> = dn
                            .in_channels
                            .iter()
                            .enumerate()
                            .map(|(s, &c)| {
                                format!(
                                    "{}: have {} need {}",
                                    design.channel(c).name,
                                    ns.consumed[s] + self.fifos[c.0].len() as u64,
                                    self.procs[nid].needed(s, ns.firings)
                                )
                            })
                            .collect();
                        let full: Vec<String> = dn
                            .out_channels
                            .iter()
                            .filter(|&&c| !self.fifos[c.0].has_space())
                            .map(|&c| format!("{} full", design.channel(c).name))
                            .collect();
                        blocked.push(format!(
                            "{} at firing {}/{} [{} | {}]",
                            dn.name,
                            ns.firings,
                            dn.geo.out_tokens,
                            waits.join(", "),
                            full.join(", ")
                        ));
                    }
                }
                let token_ops = self.token_ops();
                self.flush_metrics(total_firings, token_ops);
                return Ok(SimReport {
                    cycles: 0,
                    output,
                    traces: self.finish_traces(),
                    fifo_high_water: self.high_water(),
                    deadlock: Some(blocked),
                    total_firings,
                    token_ops,
                    fifo_profile: self.fifo_profile(),
                    ff: self.ff.stats,
                });
            }
        }

        let token_ops = self.token_ops();
        self.flush_metrics(total_firings, token_ops);
        Ok(SimReport {
            cycles: last_drain,
            output,
            traces: self.finish_traces(),
            fifo_high_water: self.high_water(),
            deadlock: None,
            total_firings,
            token_ops,
            fifo_profile: self.fifo_profile(),
            ff: self.ff.stats,
        })
    }
}

/// Simulate `design` on a host input tensor (row-major int8 values,
/// widened to i32). One-shot wrapper over [`SimContext`] — callers that
/// simulate the same design repeatedly (per grid cell, per input) should
/// build one context and [`SimContext::run`] it instead.
pub fn simulate(design: &Design, input: &[i32], mode: SimMode) -> Result<SimReport> {
    SimContext::new(design, mode)?.run(input)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::dse::ilp::{solve, DseConfig};
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use crate::util::prng;

    fn det_input(g: &crate::ir::graph::ModelGraph) -> Vec<i32> {
        let n = g.inputs()[0].ty.numel();
        prng::det_tensor(prng::SEED_INPUT, n).iter().map(|&v| v as i32).collect()
    }

    /// Reference conv+relu+requant on (n,n,c) input with (f,3,3,c)
    /// weights — independent of the simulator's line-buffer machinery.
    fn ref_conv_relu(n: usize, c: usize, f: usize, x: &[i32], w: &[i8]) -> Vec<i32> {
        let mut out = vec![0i32; n * n * f];
        for r in 0..n {
            for cx in 0..n {
                for ff in 0..f {
                    let mut acc = 0i64;
                    for kh in 0..3 {
                        for kw in 0..3 {
                            let (ir, ic) = (r + kh, cx + kw);
                            if ir < 1 || ic < 1 || ir > n || ic > n {
                                continue;
                            }
                            let (ir, ic) = (ir - 1, ic - 1);
                            for cc in 0..c {
                                let xv = x[(ir * n + ic) * c + cc] as i64;
                                let wv = w[((ff * 3 + kh) * 3 + kw) * c + cc] as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    let v = (acc.max(0) as i32) >> 6;
                    out[(r * n + cx) * f + ff] = v.clamp(-128, 127);
                }
            }
        }
        out
    }

    #[test]
    fn conv_relu_functional_matches_reference() {
        let g = models::conv_relu(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let w = g.weights()[0].data.clone().unwrap();
        let want = ref_conv_relu(16, 8, 8, &x, &w);
        assert_eq!(rep.output, want);
    }

    #[test]
    fn dataflow_and_sequential_agree_functionally() {
        for (name, size) in [("cascade", 16), ("linear", 0)] {
            let g = models::paper_kernel(name, size).unwrap();
            let d = build_streaming_design(&g).unwrap();
            let x = det_input(&g);
            let a = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
            let b = simulate(&d, &x, SimMode::Sequential).unwrap().expect_complete();
            assert_eq!(a.output, b.output, "{name}: functional mismatch across modes");
            assert!(
                a.cycles <= b.cycles,
                "{name}: dataflow ({}) must not be slower than sequential ({})",
                a.cycles,
                b.cycles
            );
        }
    }

    #[test]
    fn context_reuse_is_deterministic_and_leak_free() {
        // The SimContext contract: run() after run() reproduces the
        // one-shot result exactly, and no token leaks across runs.
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let one_shot = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        for round in 0..3 {
            let rep = ctx.run(&x).unwrap().expect_complete();
            assert_eq!(rep.output, one_shot.output, "round {round}: output");
            assert_eq!(rep.cycles, one_shot.cycles, "round {round}: cycles");
            assert_eq!(rep.total_firings, one_shot.total_firings);
            assert_eq!(rep.fifo_high_water, one_shot.fifo_high_water);
        }
        // different inputs through the same context stay independent
        let x2: Vec<i32> = x.iter().map(|v| v.wrapping_neg()).collect();
        let rep2 = ctx.run(&x2).unwrap().expect_complete();
        let fresh = simulate(&d, &x2, SimMode::Dataflow).unwrap().expect_complete();
        assert_eq!(rep2.output, fresh.output, "reused context must not carry state");
    }

    #[test]
    fn residual_deadlocks_without_fifo_sizing_and_completes_with_it() {
        let g = models::residual(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        // default shallow FIFOs: the skip path must deadlock
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap();
        assert!(rep.deadlock.is_some(), "expected deadlock with unsized FIFOs");
        // the deadlock report still accounts firings per node (the old
        // engine left them zeroed on this branch)
        for tr in &rep.traces {
            assert!(tr.firings > 0 || tr.first_fire == 0, "trace {} unfinalized", tr.name);
        }
        let fired: u64 = rep.traces.iter().map(|t| t.firings).sum();
        assert_eq!(fired, rep.total_firings, "deadlock traces must account firings");

        // after DSE (which sizes FIFOs) it completes
        let mut d2 = build_streaming_design(&g).unwrap();
        solve(&mut d2, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        let rep2 = simulate(&d2, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert!(rep2.cycles > 0);
    }

    #[test]
    fn dse_speeds_up_simulated_design() {
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let d_scalar = build_streaming_design(&g).unwrap();
        let slow = simulate(&d_scalar, &x, SimMode::Dataflow).unwrap().expect_complete();
        let mut d_fast = build_streaming_design(&g).unwrap();
        solve(&mut d_fast, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        let fast = simulate(&d_fast, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert_eq!(slow.output, fast.output, "unrolling must not change values");
        assert!(
            fast.cycles * 50 < slow.cycles,
            "DSE speedup too small: {} vs {}",
            fast.cycles,
            slow.cycles
        );
        // full streaming at II=1: about one output pixel per cycle
        assert!(fast.cycles < 3 * 32 * 32, "MING conv should be ~pixel-rate");
    }

    #[test]
    fn tiled_execution_is_bit_exact_against_untiled() {
        // The tiling subsystem's core contract at the simulator level:
        // running the cell design per halo-overlapped 2-D window and
        // stitching cores reproduces the untiled output exactly —
        // including the stride-2 pooled extension CNN, which needs the
        // grid's coordinate remapping.
        use crate::dse::ilp::DseConfig;
        use crate::tiling::{compile_tiled_fixed, simulate_tiled};
        for (name, rows, cols) in [
            ("conv_relu", 1usize, 4usize),
            ("cascade", 2, 2),
            ("residual", 1, 2),
        ] {
            let g = models::paper_kernel(name, 32).unwrap();
            let x = det_input(&g);
            let d = build_streaming_design(&g).unwrap();
            let want = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete().output;
            let tc =
                compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), rows, cols)
                    .unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "{name} tiled/untiled mismatch");
        }
        let g = models::tiny_cnn(32, 4, 8);
        let x = det_input(&g);
        let d = build_streaming_design(&g).unwrap();
        let want = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete().output;
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2, 2).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want, "tiny_cnn tiled/untiled mismatch");
    }

    #[test]
    fn traces_account_all_firings() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        for (tr, n) in rep.traces.iter().zip(&d.nodes) {
            assert_eq!(tr.firings, n.geo.out_tokens, "node {}", tr.name);
            assert!(tr.complete >= tr.last_fire);
        }
        assert_eq!(rep.total_firings, d.nodes.iter().map(|n| n.geo.out_tokens).sum::<u64>());
        assert!(rep.token_ops > 0, "token-op accounting must be live");
    }

    #[test]
    fn fifo_high_water_within_capacity() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        for ((name, hw), c) in rep.fifo_high_water.iter().zip(&d.channels) {
            assert!(*hw <= c.depth, "channel {name} overflowed: {hw} > {}", c.depth);
        }
    }

    #[test]
    fn backpressure_profile_is_opt_in_and_consistent() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let plain = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert!(plain.fifo_profile.is_none(), "profiling must be opt-in");

        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        ctx.enable_profile();
        let rep = ctx.run(&x).unwrap().expect_complete();
        assert_eq!(rep.output, plain.output, "profiling must not change results");
        assert_eq!(rep.cycles, plain.cycles, "profiling must not change timing");
        let prof = rep.fifo_profile.expect("profile armed");
        assert_eq!(prof.channels.len(), d.channels.len());
        for c in &prof.channels {
            let hist_total: u64 = c.hist.iter().sum();
            assert_eq!(hist_total, c.pushed, "channel {}: histogram covers every push", c.name);
        }
        // stall attribution sums match the per-node trace totals
        let node_wait: u64 = rep.traces.iter().map(|t| t.stall_in).sum();
        let node_full: u64 = rep.traces.iter().map(|t| t.stall_out).sum();
        let chan_wait: u64 = prof.channels.iter().map(|c| c.stall_wait).sum();
        let chan_full: u64 = prof.channels.iter().map(|c| c.stall_full).sum();
        assert_eq!(chan_wait, node_wait, "consumer stalls attribute to channels");
        assert_eq!(chan_full, node_full, "producer stalls attribute to channels");
        assert!(prof.render().contains("channel"), "render smoke");
    }

    /// Field-for-field report equality, including trace timing — the
    /// fast-forward acceptance bar.
    fn assert_ff_matches_exact(fast: &SimReport, exact: &SimReport, tag: &str) {
        assert_eq!(fast.output, exact.output, "{tag}: output");
        assert_eq!(fast.cycles, exact.cycles, "{tag}: cycles");
        assert_eq!(fast.total_firings, exact.total_firings, "{tag}: firings");
        assert_eq!(fast.token_ops, exact.token_ops, "{tag}: token ops");
        assert_eq!(fast.fifo_high_water, exact.fifo_high_water, "{tag}: high water");
        assert_eq!(fast.deadlock, exact.deadlock, "{tag}: deadlock");
        for (a, b) in fast.traces.iter().zip(&exact.traces) {
            assert_eq!(a.firings, b.firings, "{tag}/{}: firings", a.name);
            assert_eq!(a.first_fire, b.first_fire, "{tag}/{}: first_fire", a.name);
            assert_eq!(a.last_fire, b.last_fire, "{tag}/{}: last_fire", a.name);
            assert_eq!(a.complete, b.complete, "{tag}/{}: complete", a.name);
            assert_eq!(a.stall_in, b.stall_in, "{tag}/{}: stall_in", a.name);
            assert_eq!(a.stall_out, b.stall_out, "{tag}/{}: stall_out", a.name);
        }
    }

    #[test]
    fn fast_forward_matches_exact_and_skips_periods() {
        let g = models::conv_relu(64, 4, 4);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);

        let mut ectx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        ectx.set_config(SimConfig::exact());
        let exact = ectx.run(&x).unwrap().expect_complete();
        assert_eq!(exact.ff, FfStats::default(), "exact config must not fast-forward");

        let fast = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert!(fast.ff.periods > 0, "steady conv chain must be detected as periodic");
        assert!(fast.ff.skipped_cycles > 0, "periods must cover simulated cycles");
        assert!(fast.ff.batched_firings > 0, "replay must use the row-batched kernel");
        assert!(fast.ff.checkpoints > 0);
        assert_ff_matches_exact(&fast, &exact, "conv_relu@64");
    }

    #[test]
    fn fast_forward_is_bit_exact_on_reused_context() {
        // period detection state must fully reset between runs
        let g = models::cascade(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        let first = ctx.run(&x).unwrap().expect_complete();
        let second = ctx.run(&x).unwrap().expect_complete();
        assert_eq!(first.ff, second.ff, "detector state must reset across runs");
        assert_eq!(first.output, second.output);
        assert_eq!(first.cycles, second.cycles);
    }

    #[test]
    fn fast_forward_profile_stall_attribution_stays_exact() {
        // Satellite invariant: under fast-forward, per-channel stall
        // attribution, histograms and occupancy stay byte-identical to
        // the exact profiled run, and stalls still sum to trace totals.
        let g = models::cascade(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);

        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        ctx.enable_profile();
        let fast = ctx.run(&x).unwrap().expect_complete();
        let mut ectx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        ectx.set_config(SimConfig::exact());
        ectx.enable_profile();
        let exact = ectx.run(&x).unwrap().expect_complete();
        assert!(fast.ff.periods > 0, "cascade must reach steady state");
        assert_ff_matches_exact(&fast, &exact, "cascade profile");

        let pf = fast.fifo_profile.expect("profile armed");
        let pe = exact.fifo_profile.expect("profile armed");
        for (a, b) in pf.channels.iter().zip(&pe.channels) {
            assert_eq!(a.stall_wait, b.stall_wait, "{}: stall_wait", a.name);
            assert_eq!(a.stall_full, b.stall_full, "{}: stall_full", a.name);
            assert_eq!(a.pushed, b.pushed, "{}: pushed", a.name);
            assert_eq!(a.max_occupancy, b.max_occupancy, "{}: max occ", a.name);
            assert_eq!(a.hist, b.hist, "{}: histogram", a.name);
        }
        for c in &pf.channels {
            let hist_total: u64 = c.hist.iter().sum();
            assert_eq!(hist_total, c.pushed, "{}: histogram covers every push", c.name);
        }
        let node_wait: u64 = fast.traces.iter().map(|t| t.stall_in).sum();
        let node_full: u64 = fast.traces.iter().map(|t| t.stall_out).sum();
        let chan_wait: u64 = pf.channels.iter().map(|c| c.stall_wait).sum();
        let chan_full: u64 = pf.channels.iter().map(|c| c.stall_full).sum();
        assert_eq!(chan_wait, node_wait, "consumer stalls attribute to channels");
        assert_eq!(chan_full, node_full, "producer stalls attribute to channels");
    }

    #[test]
    fn fifo_profile_merge_accumulates() {
        let mk = |pushed, full| FifoProfile {
            channels: vec![ChannelProfile {
                name: "c0".into(),
                capacity: 4,
                max_occupancy: 2,
                pushed,
                hist: vec![pushed, 0],
                stall_wait: 1,
                stall_full: full,
            }],
        };
        let mut acc = FifoProfile::default();
        acc.merge(&mk(10, 5));
        acc.merge(&mk(7, 0));
        assert_eq!(acc.channels[0].pushed, 17);
        assert_eq!(acc.channels[0].stall_full, 5);
        assert_eq!(acc.channels[0].hist[0], 17);
        assert_eq!(acc.bounding_channel().unwrap().name, "c0");
    }

    #[test]
    fn bad_input_length_rejected() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        assert!(simulate(&d, &[0i32; 3], SimMode::Dataflow).is_err());
    }
}
