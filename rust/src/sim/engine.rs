//! The discrete-event engine: feeder → nodes → sink, at firing
//! granularity with timestamped tokens.

use anyhow::{ensure, Result};

use crate::dataflow::channel::Endpoint;
use crate::dataflow::design::{Design, DesignStyle};

use super::fifo::{SimFifo, Token};
use super::process::{build_proc, NodeProc};
use super::trace::NodeTrace;

/// Host-interface model: a 128-bit AXI port moves 16 bytes per cycle in
/// each direction (KV260 DDR4 class). Bounds feeder and sink rates.
pub const AXI_BYTES_PER_CYCLE: u64 = 16;

/// Scheduling discipline (derived from the design style by default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Task-level DATAFLOW: all nodes run concurrently.
    Dataflow,
    /// Vanilla: a node starts only after all its producers finished;
    /// channels are backed by full tensors (unbounded FIFOs).
    Sequential,
}

impl SimMode {
    pub fn of(style: DesignStyle) -> Self {
        match style {
            DesignStyle::Dataflow => SimMode::Dataflow,
            DesignStyle::Sequential => SimMode::Sequential,
        }
    }
}

/// Simulation result.
#[derive(Debug)]
pub struct SimReport {
    /// Total cycles until the last output token reached the host.
    pub cycles: u64,
    /// Output tensor values (row-major, int8 range unless the graph
    /// output is an accumulator).
    pub output: Vec<i32>,
    pub traces: Vec<NodeTrace>,
    /// Max occupancy per channel (FIFO sizing diagnostics).
    pub fifo_high_water: Vec<(String, usize)>,
    /// `Some(blocked-node descriptions)` if the design deadlocked.
    pub deadlock: Option<Vec<String>>,
    /// Total firings across all nodes (simulator throughput metric).
    pub total_firings: u64,
}

impl SimReport {
    /// Panic-with-context helper for tests/examples.
    pub fn expect_complete(self) -> Self {
        if let Some(blocked) = &self.deadlock {
            panic!("simulation deadlocked:\n  {}", blocked.join("\n  "));
        }
        self
    }

    pub fn macs_per_cycle(&self, total_macs: u64) -> f64 {
        total_macs as f64 / self.cycles.max(1) as f64
    }
}

struct NodeState {
    proc: NodeProc,
    firings: u64,
    t_free: u64,
    complete: u64,
    trace: NodeTrace,
    consumed: Vec<u64>,
    /// Cycle the most recent token finished streaming in, per input —
    /// tokens are consumed *eagerly* (into the line buffer / pending
    /// registers) at stream rate, which is exactly what the paper's
    /// line-buffer architecture buys: the FIFO itself stays shallow.
    last_in_time: Vec<u64>,
}

/// Simulate `design` on a host input tensor (row-major int8 values,
/// widened to i32).
pub fn simulate(design: &Design, input: &[i32], mode: SimMode) -> Result<SimReport> {
    let g = &design.graph;
    let in_t = g.inputs()[0];
    ensure!(
        input.len() == in_t.ty.numel(),
        "input has {} values, graph expects {}",
        input.len(),
        in_t.ty.numel()
    );

    // --- runtime state -------------------------------------------------
    let mut fifos: Vec<SimFifo> = design
        .channels
        .iter()
        .map(|c| match mode {
            SimMode::Sequential => SimFifo::unbounded(),
            SimMode::Dataflow => SimFifo::new(c.depth),
        })
        .collect();

    let mut nodes: Vec<NodeState> = (0..design.nodes.len())
        .map(|i| {
            Ok(NodeState {
                proc: build_proc(design, i)?,
                firings: 0,
                t_free: 0,
                complete: 0,
                trace: NodeTrace { name: design.nodes[i].name.clone(), ..Default::default() },
                consumed: vec![0; design.nodes[i].in_channels.len()],
                last_in_time: vec![0; design.nodes[i].in_channels.len()],
            })
        })
        .collect::<Result<_>>()?;

    // Input tokenization (shared by all graph-input channels).
    let input_chans: Vec<usize> = design
        .channels
        .iter()
        .filter(|c| c.src == Endpoint::GraphInput)
        .map(|c| c.id.0)
        .collect();
    ensure!(!input_chans.is_empty(), "no input channels");
    let tok_len = design.channels[input_chans[0]].token_len;
    let in_tokens_total = design.channels[input_chans[0]].tokens_total;
    ensure!(
        in_tokens_total as usize * tok_len == input.len(),
        "input tokenization mismatch"
    );
    let token_bytes = (tok_len as u64 * design.channels[input_chans[0]].elem_bits).div_ceil(8);
    let mut fed: u64 = 0;

    let out_chan = design.output_channel()?.id.0;
    let out_tokens_total = design.channels[out_chan].tokens_total;
    let out_token_bytes =
        (design.channels[out_chan].token_len as u64 * design.channels[out_chan].elem_bits)
            .div_ceil(8);
    let mut output: Vec<i32> = Vec::with_capacity(
        out_tokens_total as usize * design.channels[out_chan].token_len,
    );
    let mut drained: u64 = 0;
    let mut last_drain: u64 = 0;
    let mut total_firings: u64 = 0;

    // Sequential barrier: node may not start before all producers finish.
    let preds: Vec<Vec<usize>> = design
        .nodes
        .iter()
        .map(|n| {
            n.in_channels
                .iter()
                .filter_map(|&c| match design.channel(c).src {
                    Endpoint::Node(p) => Some(p),
                    _ => None,
                })
                .collect()
        })
        .collect();

    // --- sweep loop -----------------------------------------------------
    loop {
        let mut progress = false;

        // 1) feeder: deliver input tokens (AXI-limited, broadcast).
        while fed < in_tokens_total {
            if !input_chans.iter().all(|&c| fifos[c].has_space()) {
                break;
            }
            let axi_t = ((fed + 1) * token_bytes).div_ceil(AXI_BYTES_PER_CYCLE);
            let t = input_chans
                .iter()
                .filter_map(|&c| fifos[c].next_push_ready())
                .fold(axi_t, u64::max);
            let base = fed as usize * tok_len;
            let tok: Token = input[base..base + tok_len].to_vec();
            for &c in &input_chans {
                fifos[c].push(t, tok.clone());
            }
            fed += 1;
            progress = true;
        }

        // 2) nodes, in topological order.
        for nid in 0..nodes.len() {
            let dn = &design.nodes[nid];
            let barrier = match mode {
                SimMode::Sequential => {
                    let mut b = 0;
                    let mut ready = true;
                    for &p in &preds[nid] {
                        if nodes[p].firings < design.nodes[p].geo.out_tokens {
                            ready = false;
                            break;
                        }
                        b = b.max(nodes[p].complete);
                    }
                    if !ready {
                        continue;
                    }
                    b
                }
                SimMode::Dataflow => 0,
            };

            'fire: while nodes[nid].firings < dn.geo.out_tokens {
                let k = nodes[nid].firings;
                let needed = nodes[nid].proc.needed(k);

                // (a) eagerly stream available tokens in (≤ needed for this
                // firing), at one token per `cycles_per_token` — the line-
                // buffer fill. Frees FIFO slots so shallow streams suffice.
                for (slot, &cid) in dn.in_channels.iter().enumerate() {
                    let cpt = design.channel(cid).cycles_per_token();
                    while nodes[nid].consumed[slot] < needed[slot] && !fifos[cid.0].is_empty() {
                        let arr = fifos[cid.0].arrival(0).unwrap();
                        let t_pop = (arr + cpt).max(nodes[nid].last_in_time[slot] + cpt);
                        let (_, tok) = fifos[cid.0].pop(t_pop);
                        nodes[nid].proc.accept(slot, tok);
                        nodes[nid].consumed[slot] += 1;
                        nodes[nid].last_in_time[slot] = t_pop;
                        progress = true;
                    }
                    if nodes[nid].consumed[slot] < needed[slot] {
                        break 'fire; // blocked on input tokens
                    }
                }
                let t_in: u64 = dn
                    .in_channels
                    .iter()
                    .enumerate()
                    .map(|(slot, _)| nodes[nid].last_in_time[slot])
                    .max()
                    .unwrap_or(0);

                // (b) output space?
                let mut t_out: u64 = 0;
                for &cid in &dn.out_channels {
                    match fifos[cid.0].next_push_ready() {
                        Some(t) => t_out = t_out.max(t),
                        None => break 'fire, // blocked on output space
                    }
                }

                // (c) fire
                let base_ready = nodes[nid].t_free.max(barrier);
                let t = base_ready.max(t_in).max(t_out);
                // stall attribution
                if t_in > base_ready.max(t_out) {
                    nodes[nid].trace.stall_in += t_in - base_ready.max(t_out);
                }
                if t_out > base_ready.max(t_in) {
                    nodes[nid].trace.stall_out += t_out - base_ready.max(t_in);
                }

                let value = nodes[nid].proc.fire(k);
                let t_vis = t + dn.timing.depth;
                // broadcast: clone for all but the last consumer (the
                // common single-consumer case moves the token)
                let (last, rest) = dn.out_channels.split_last().unwrap();
                for &cid in rest {
                    fifos[cid.0].push(t_vis, value.clone());
                }
                fifos[last.0].push(t_vis, value);
                let interval = dn.compute_interval();
                nodes[nid].t_free = t + interval;
                nodes[nid].firings += 1;
                total_firings += 1;
                if k == 0 {
                    nodes[nid].trace.first_fire = t;
                }
                nodes[nid].trace.last_fire = t;
                nodes[nid].complete = t_vis;
                progress = true;
            }
        }

        // 3) sink: drain the output channel (AXI-limited).
        while !fifos[out_chan].is_empty() {
            let arr = fifos[out_chan].arrival(0).unwrap();
            let axi_t = last_drain + out_token_bytes.div_ceil(AXI_BYTES_PER_CYCLE);
            let t = arr.max(axi_t);
            let (_, tok) = fifos[out_chan].pop(t);
            output.extend_from_slice(&tok);
            drained += 1;
            last_drain = t;
            progress = true;
        }

        if drained == out_tokens_total {
            break;
        }
        if !progress {
            // deadlock: report who is stuck and why
            let mut blocked = Vec::new();
            if fed < in_tokens_total {
                blocked.push(format!("feeder: {fed}/{in_tokens_total} tokens delivered"));
            }
            for (nid, ns) in nodes.iter().enumerate() {
                let dn = &design.nodes[nid];
                if ns.firings < dn.geo.out_tokens {
                    let needed = ns.proc.needed(ns.firings);
                    let waits: Vec<String> = dn
                        .in_channels
                        .iter()
                        .enumerate()
                        .map(|(s, &c)| {
                            format!(
                                "{}: have {} need {}",
                                design.channel(c).name,
                                ns.consumed[s] + fifos[c.0].len() as u64,
                                needed[s]
                            )
                        })
                        .collect();
                    let full: Vec<String> = dn
                        .out_channels
                        .iter()
                        .filter(|&&c| !fifos[c.0].has_space())
                        .map(|&c| format!("{} full", design.channel(c).name))
                        .collect();
                    blocked.push(format!(
                        "{} at firing {}/{} [{} | {}]",
                        dn.name,
                        ns.firings,
                        dn.geo.out_tokens,
                        waits.join(", "),
                        full.join(", ")
                    ));
                }
            }
            return Ok(SimReport {
                cycles: 0,
                output,
                traces: nodes.into_iter().map(|n| n.trace).collect(),
                fifo_high_water: high_water(design, &fifos),
                deadlock: Some(blocked),
                total_firings,
            });
        }
    }

    Ok(SimReport {
        cycles: last_drain,
        output,
        traces: nodes
            .into_iter()
            .map(|mut n| {
                n.trace.firings = n.firings;
                n.trace.complete = n.complete;
                n.trace
            })
            .collect(),
        fifo_high_water: high_water(design, &fifos),
        deadlock: None,
        total_firings,
    })
}

fn high_water(design: &Design, fifos: &[SimFifo]) -> Vec<(String, usize)> {
    design
        .channels
        .iter()
        .zip(fifos)
        .map(|(c, f)| (c.name.clone(), f.max_occupancy))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::dse::ilp::{solve, DseConfig};
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use crate::util::prng;

    fn det_input(g: &crate::ir::graph::ModelGraph) -> Vec<i32> {
        let n = g.inputs()[0].ty.numel();
        prng::det_tensor(prng::SEED_INPUT, n).iter().map(|&v| v as i32).collect()
    }

    /// Reference conv+relu+requant on (n,n,c) input with (f,3,3,c)
    /// weights — independent of the simulator's line-buffer machinery.
    fn ref_conv_relu(n: usize, c: usize, f: usize, x: &[i32], w: &[i8]) -> Vec<i32> {
        let mut out = vec![0i32; n * n * f];
        for r in 0..n {
            for cx in 0..n {
                for ff in 0..f {
                    let mut acc = 0i64;
                    for kh in 0..3 {
                        for kw in 0..3 {
                            let (ir, ic) = (r + kh, cx + kw);
                            if ir < 1 || ic < 1 || ir > n || ic > n {
                                continue;
                            }
                            let (ir, ic) = (ir - 1, ic - 1);
                            for cc in 0..c {
                                let xv = x[(ir * n + ic) * c + cc] as i64;
                                let wv = w[((ff * 3 + kh) * 3 + kw) * c + cc] as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    let v = (acc.max(0) as i32) >> 6;
                    out[(r * n + cx) * f + ff] = v.clamp(-128, 127);
                }
            }
        }
        out
    }

    #[test]
    fn conv_relu_functional_matches_reference() {
        let g = models::conv_relu(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let w = g.weights()[0].data.clone().unwrap();
        let want = ref_conv_relu(16, 8, 8, &x, &w);
        assert_eq!(rep.output, want);
    }

    #[test]
    fn dataflow_and_sequential_agree_functionally() {
        for (name, size) in [("cascade", 16), ("linear", 0)] {
            let g = models::paper_kernel(name, size).unwrap();
            let d = build_streaming_design(&g).unwrap();
            let x = det_input(&g);
            let a = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
            let b = simulate(&d, &x, SimMode::Sequential).unwrap().expect_complete();
            assert_eq!(a.output, b.output, "{name}: functional mismatch across modes");
            assert!(
                a.cycles <= b.cycles,
                "{name}: dataflow ({}) must not be slower than sequential ({})",
                a.cycles,
                b.cycles
            );
        }
    }

    #[test]
    fn residual_deadlocks_without_fifo_sizing_and_completes_with_it() {
        let g = models::residual(32, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        // default shallow FIFOs: the skip path must deadlock
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap();
        assert!(rep.deadlock.is_some(), "expected deadlock with unsized FIFOs");

        // after DSE (which sizes FIFOs) it completes
        let mut d2 = build_streaming_design(&g).unwrap();
        solve(&mut d2, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        let rep2 = simulate(&d2, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert!(rep2.cycles > 0);
    }

    #[test]
    fn dse_speeds_up_simulated_design() {
        let g = models::conv_relu(32, 8, 8);
        let x = det_input(&g);
        let d_scalar = build_streaming_design(&g).unwrap();
        let slow = simulate(&d_scalar, &x, SimMode::Dataflow).unwrap().expect_complete();
        let mut d_fast = build_streaming_design(&g).unwrap();
        solve(&mut d_fast, &DseConfig::new(DeviceSpec::kv260())).unwrap();
        let fast = simulate(&d_fast, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert_eq!(slow.output, fast.output, "unrolling must not change values");
        assert!(
            fast.cycles * 50 < slow.cycles,
            "DSE speedup too small: {} vs {}",
            fast.cycles,
            slow.cycles
        );
        // full streaming at II=1: about one output pixel per cycle
        assert!(fast.cycles < 3 * 32 * 32, "MING conv should be ~pixel-rate");
    }

    #[test]
    fn tiled_execution_is_bit_exact_against_untiled() {
        // The tiling subsystem's core contract at the simulator level:
        // running the cell design per halo-overlapped 2-D window and
        // stitching cores reproduces the untiled output exactly —
        // including the stride-2 pooled extension CNN, which needs the
        // grid's coordinate remapping.
        use crate::dse::ilp::DseConfig;
        use crate::tiling::{compile_tiled_fixed, simulate_tiled};
        for (name, rows, cols) in [
            ("conv_relu", 1usize, 4usize),
            ("cascade", 2, 2),
            ("residual", 1, 2),
        ] {
            let g = models::paper_kernel(name, 32).unwrap();
            let x = det_input(&g);
            let d = build_streaming_design(&g).unwrap();
            let want = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete().output;
            let tc =
                compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), rows, cols)
                    .unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            assert_eq!(rep.output, want, "{name} tiled/untiled mismatch");
        }
        let g = models::tiny_cnn(32, 4, 8);
        let x = det_input(&g);
        let d = build_streaming_design(&g).unwrap();
        let want = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete().output;
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2, 2).unwrap();
        let rep = simulate_tiled(&tc, &x).unwrap();
        assert_eq!(rep.output, want, "tiny_cnn tiled/untiled mismatch");
    }

    #[test]
    fn traces_account_all_firings() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        for (tr, n) in rep.traces.iter().zip(&d.nodes) {
            assert_eq!(tr.firings, n.geo.out_tokens, "node {}", tr.name);
            assert!(tr.complete >= tr.last_fire);
        }
        assert_eq!(rep.total_firings, d.nodes.iter().map(|n| n.geo.out_tokens).sum::<u64>());
    }

    #[test]
    fn fifo_high_water_within_capacity() {
        let g = models::cascade(16, 8, 8);
        let d = build_streaming_design(&g).unwrap();
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        for ((name, hw), c) in rep.fifo_high_water.iter().zip(&d.channels) {
            assert!(*hw <= c.depth, "channel {name} overflowed: {hw} > {}", c.depth);
        }
    }

    #[test]
    fn bad_input_length_rejected() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        assert!(simulate(&d, &[0i32; 3], SimMode::Dataflow).is_err());
    }
}
