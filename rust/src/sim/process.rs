//! Functional node behaviours — bit-exact int8/int32 semantics matching
//! `python/compile/kernels/ref.py` (the golden-model contract).
//!
//! A [`NodeProc`] answers three questions for the engine:
//! 1. how many cumulative input tokens each input needs before firing k,
//! 2. what to do with tokens as they arrive (`accept` — e.g. fill the
//!    line buffer), and
//! 3. the value of output token k (`fire`).

use std::collections::VecDeque;

use anyhow::{bail, ensure, Context, Result};

use crate::analysis::classify::KernelClass;
use crate::dataflow::design::Design;
use crate::ir::generic::Payload;
use crate::ir::graph::TensorKind;

use super::fifo::Token;

pub const I8_MIN: i32 = -128;
pub const I8_MAX: i32 = 127;

fn sat_i8(v: i32) -> i32 {
    v.clamp(I8_MIN, I8_MAX)
}

/// Apply a pure-parallel payload to per-lane values.
pub fn apply_payload(p: Payload, ins: &[&Token]) -> Token {
    let n = ins[0].len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let a = ins[0][i];
        let v = match p {
            Payload::Relu => a.max(0),
            Payload::Requant { shift } => sat_i8(a >> shift),
            Payload::ReluRequant { shift } => sat_i8(a.max(0) >> shift),
            Payload::AddSat => sat_i8(a + ins[1][i]),
            Payload::Copy => a,
            Payload::MulAcc | Payload::MaxReduce => unreachable!("not pure-parallel"),
        };
        out.push(v);
    }
    out
}

/// Functional behaviour of one dataflow node.
pub enum NodeProc {
    Sliding(SlidingProc),
    Reduction(ReductionProc),
    Parallel(ParallelProc),
}

impl NodeProc {
    /// Cumulative tokens needed on each input before firing `k`.
    pub fn needed(&self, k: u64) -> Vec<u64> {
        match self {
            NodeProc::Sliding(p) => vec![p.needed(k)],
            NodeProc::Reduction(_) => vec![k + 1],
            NodeProc::Parallel(p) => vec![k + 1; p.arity],
        }
    }

    pub fn accept(&mut self, slot: usize, tok: Token) {
        match self {
            NodeProc::Sliding(p) => p.accept(tok),
            NodeProc::Reduction(p) => p.accept(tok),
            NodeProc::Parallel(p) => p.accept(slot, tok),
        }
    }

    pub fn fire(&mut self, k: u64) -> Token {
        match self {
            NodeProc::Sliding(p) => p.fire(k),
            NodeProc::Reduction(p) => p.fire(),
            NodeProc::Parallel(p) => p.fire(),
        }
    }
}

/// Transpose conv weights (F,K,K,C) -> (K,K,C,F) for the contiguous
/// inner loop of `SlidingProc::fire`.
pub fn transpose_fkkc_to_kkcf(w: &[i32], f: usize, k: usize, c: usize) -> Vec<i32> {
    if w.is_empty() {
        return Vec::new(); // weight-less sliding window (maxpool)
    }
    debug_assert_eq!(w.len(), f * k * k * c);
    let mut out = vec![0i32; w.len()];
    for ff in 0..f {
        for kh in 0..k {
            for kw in 0..k {
                for cc in 0..c {
                    out[((kh * k + kw) * c + cc) * f + ff] =
                        w[((ff * k + kh) * k + kw) * c + cc];
                }
            }
        }
    }
    out
}

/// Sliding-window node (conv2d / maxpool): line-buffer fill + window
/// gather + dot product / max-reduce per output pixel.
pub struct SlidingProc {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub w_out: usize,
    pub f: usize,
    pub k: usize,
    pub stride: usize,
    pub dilation: usize,
    pub pad: usize,
    /// Flattened weights (F, K, K, C) as i32; empty for maxpool.
    pub weights: Vec<i32>,
    /// Weights transposed to (K, K, C, F) so the per-(kh,kw,cc) inner
    /// loop reads a contiguous F-vector — the simulator's hottest loop
    /// (see EXPERIMENTS.md §Perf).
    weights_t: Vec<i32>,
    pub payload: Payload,
    /// Consumed input values (row-major (h, w, c)); the engine's FIFO
    /// back-pressure bounds how far this runs ahead — functionally we
    /// retain everything for simplicity (simulation memory, not BRAM).
    buf: Vec<i32>,
}

impl SlidingProc {
    fn needed(&self, k: u64) -> u64 {
        // output pixel (r, cx) needs input through pixel
        // (r·s + (K-1)·δ − pad, cx·s + (K-1)·δ − pad), clamped into range.
        let r = (k as usize) / self.w_out;
        let cx = (k as usize) % self.w_out;
        let keff = (self.k - 1) * self.dilation;
        let raw_r = (r * self.stride + keff).saturating_sub(self.pad);
        if raw_r >= self.h {
            // bottom zero-padding: the window already hangs off the end,
            // so the whole input is (and stays) required — keeps needed()
            // monotone across the clamped final rows.
            return (self.h * self.w) as u64;
        }
        let in_c = (cx * self.stride + keff).saturating_sub(self.pad).min(self.w - 1);
        (raw_r * self.w + in_c + 1) as u64
    }

    fn accept(&mut self, tok: Token) {
        debug_assert_eq!(tok.len(), self.c);
        self.buf.extend_from_slice(&tok);
    }

    fn fire(&mut self, k: u64) -> Token {
        let r = (k as usize) / self.w_out;
        let cx = (k as usize) % self.w_out;
        match self.payload {
            Payload::MulAcc => {
                let mut out = vec![0i32; self.f];
                for kh in 0..self.k {
                    for kw in 0..self.k {
                        let ir = r * self.stride + kh * self.dilation;
                        let ic = cx * self.stride + kw * self.dilation;
                        // padding: indices are offset by `pad`
                        if ir < self.pad || ic < self.pad {
                            continue;
                        }
                        let (ir, ic) = (ir - self.pad, ic - self.pad);
                        if ir >= self.h || ic >= self.w {
                            continue;
                        }
                        let base = (ir * self.w + ic) * self.c;
                        let px = &self.buf[base..base + self.c];
                        let wbase = (kh * self.k + kw) * self.c * self.f;
                        // contiguous F-vector per (kh,kw,cc): auto-vectorizes
                        for (cc, &x) in px.iter().enumerate() {
                            if x == 0 {
                                continue;
                            }
                            let wrow = &self.weights_t[wbase + cc * self.f..wbase + (cc + 1) * self.f];
                            for (o, &wv) in out.iter_mut().zip(wrow) {
                                *o += wv * x;
                            }
                        }
                    }
                }
                out
            }
            Payload::MaxReduce => {
                let mut out = vec![i32::MIN; self.f]; // f == c for pooling
                for kh in 0..self.k {
                    for kw in 0..self.k {
                        let ir = r * self.stride + kh * self.dilation;
                        let ic = cx * self.stride + kw * self.dilation;
                        if ir < self.pad || ic < self.pad {
                            continue;
                        }
                        let (ir, ic) = (ir - self.pad, ic - self.pad);
                        if ir >= self.h || ic >= self.w {
                            continue;
                        }
                        let base = (ir * self.w + ic) * self.c;
                        for cc in 0..self.c {
                            out[cc] = out[cc].max(self.buf[base + cc]);
                        }
                    }
                }
                out
            }
            other => panic!("sliding node with payload {other:?}"),
        }
    }
}

/// Regular-reduction node (linear): one activation row in, one output
/// row out, weights resident.
pub struct ReductionProc {
    pub k: usize,
    pub n: usize,
    /// (K, N) weights as i32.
    pub weights: Vec<i32>,
    cur: Option<Token>,
}

impl ReductionProc {
    fn accept(&mut self, tok: Token) {
        debug_assert_eq!(tok.len(), self.k);
        debug_assert!(self.cur.is_none(), "reduction row overwritten before fire");
        self.cur = Some(tok);
    }

    fn fire(&mut self) -> Token {
        let x = self.cur.take().expect("fire before accept");
        let mut out = vec![0i32; self.n];
        for (kk, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let row = &self.weights[kk * self.n..(kk + 1) * self.n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xv * wv;
            }
        }
        out
    }
}

/// Pure-parallel node: elementwise payload over 1–2 input streams.
pub struct ParallelProc {
    pub payload: Payload,
    pub arity: usize,
    pending: Vec<VecDeque<Token>>,
}

impl ParallelProc {
    fn accept(&mut self, slot: usize, tok: Token) {
        self.pending[slot].push_back(tok);
    }

    fn fire(&mut self) -> Token {
        let toks: Vec<Token> =
            self.pending.iter_mut().map(|q| q.pop_front().expect("missing token")).collect();
        let refs: Vec<&Token> = toks.iter().collect();
        apply_payload(self.payload, &refs)
    }
}

/// Build the functional behaviour of node `nid` of a design.
pub fn build_proc(d: &Design, nid: usize) -> Result<NodeProc> {
    let node = &d.nodes[nid];
    let op = &d.graph.ops[node.op_index];
    match node.geo.class {
        KernelClass::SlidingWindow(sw) => {
            let in_t = d.graph.tensor(op.inputs[0]);
            ensure!(in_t.ty.rank() == 3, "sliding input must be (H,W,C)");
            let (h, w, c) = (in_t.ty.shape[0], in_t.ty.shape[1], in_t.ty.shape[2]);
            let out_t = d.graph.tensor(op.output);
            let w_out = out_t.ty.shape[1];
            let f = *out_t.ty.shape.last().unwrap();
            let k = op.dims[sw.reduction_dim];
            let weights: Vec<i32> = op
                .inputs
                .iter()
                .find(|&&t| d.graph.tensor(t).kind == TensorKind::Weight)
                .map(|&t| {
                    d.graph
                        .tensor(t)
                        .data
                        .as_ref()
                        .expect("weight without data")
                        .iter()
                        .map(|&v| v as i32)
                        .collect()
                })
                .unwrap_or_default();
            if op.payload == Payload::MulAcc {
                ensure!(weights.len() == f * k * k * c, "conv weight size mismatch");
            }
            let weights_t = transpose_fkkc_to_kkcf(&weights, f, k, c);
            Ok(NodeProc::Sliding(SlidingProc {
                h,
                w,
                c,
                w_out,
                f,
                k,
                stride: sw.stride as usize,
                dilation: sw.dilation as usize,
                pad: op.pad,
                weights,
                weights_t,
                payload: op.payload,
                buf: Vec::new(),
            }))
        }
        KernelClass::RegularReduction => {
            let wt = op
                .inputs
                .iter()
                .find(|&&t| d.graph.tensor(t).kind == TensorKind::Weight)
                .context("reduction node without weights")?;
            let wt = d.graph.tensor(*wt);
            ensure!(wt.ty.rank() == 2, "linear weights must be (K,N)");
            let (k, n) = (wt.ty.shape[0], wt.ty.shape[1]);
            Ok(NodeProc::Reduction(ReductionProc {
                k,
                n,
                weights: wt.data.as_ref().unwrap().iter().map(|&v| v as i32).collect(),
                cur: None,
            }))
        }
        KernelClass::PureParallel => {
            let arity = node.in_channels.len();
            match op.payload {
                Payload::Relu
                | Payload::Requant { .. }
                | Payload::ReluRequant { .. }
                | Payload::AddSat
                | Payload::Copy => {}
                other => bail!("pure-parallel node with payload {other:?}"),
            }
            Ok(NodeProc::Parallel(ParallelProc {
                payload: op.payload,
                arity,
                pending: (0..arity).map(|_| VecDeque::new()).collect(),
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn payload_semantics_match_ref_contract() {
        // floor-rounding arithmetic shift and clamping, as in ref.py
        let acc: Token = vec![-65, -64, -1, 0, 1, 63, 64, 65];
        let got = apply_payload(Payload::Requant { shift: 6 }, &[&acc]);
        assert_eq!(got, vec![-2, -1, -1, 0, 0, 0, 1, 1]);
        let big: Token = vec![1 << 20, -(1 << 20)];
        assert_eq!(apply_payload(Payload::Requant { shift: 6 }, &[&big]), vec![127, -128]);
        assert_eq!(
            apply_payload(Payload::ReluRequant { shift: 6 }, &[&big]),
            vec![127, 0]
        );
        let a: Token = vec![100, -100];
        let b: Token = vec![100, -100];
        assert_eq!(apply_payload(Payload::AddSat, &[&a, &b]), vec![127, -128]);
    }

    #[test]
    fn sliding_needed_is_monotone_and_bounded() {
        let g = models::conv_relu(16, 4, 4);
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Sliding(p) = build_proc(&d, 0).unwrap() else { panic!() };
        let total = 16 * 16;
        let mut last = 0;
        for k in 0..total as u64 {
            let n = p.needed(k);
            assert!(n >= last, "needed() must be monotone");
            assert!(n <= total as u64);
            last = n;
        }
        // last pixel needs the whole input
        assert_eq!(last, total as u64);
        // first pixel needs one padded row + a bit (pad=1)
        assert!(p.needed(0) <= 2 * 16);
    }

    #[test]
    fn conv_fire_matches_direct_computation() {
        // 4x4 input, 1 channel, 1 filter of all-ones, pad 1: output (1,1)
        // (interior) = sum of the 3x3 neighbourhood.
        let g = models::conv_relu(4, 1, 1);
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Sliding(mut p) = build_proc(&d, 0).unwrap() else { panic!() };
        p.weights = vec![1; 9];
        p.weights_t = vec![1; 9];
        let vals: Vec<i32> = (0..16).collect();
        for v in &vals {
            p.accept(vec![*v]);
        }
        // output pixel (1,1) covers input rows 0..3, cols 0..3
        let k = (1 * 4 + 1) as u64;
        let got = p.fire(k);
        let want: i32 = [0, 1, 2, 4, 5, 6, 8, 9, 10].iter().map(|&i| vals[i as usize]).sum();
        assert_eq!(got, vec![want]);
        // corner pixel (0,0): zero-padded window sums indices {0,1,4,5}
        let got0 = p.fire(0);
        assert_eq!(got0, vec![0 + 1 + 4 + 5]);
    }

    #[test]
    fn reduction_fire_is_matvec() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Reduction(mut p) = build_proc(&d, 0).unwrap() else { panic!() };
        // x = e0 (first unit vector): out = first row of W
        let mut x = vec![0i32; p.k];
        x[0] = 1;
        p.accept(x);
        let got = p.fire();
        let want: Vec<i32> = p.weights[..p.n].to_vec();
        assert_eq!(got, want);
    }

    #[test]
    fn build_proc_for_all_paper_nodes() {
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(16)).unwrap();
            let d = build_streaming_design(&g).unwrap();
            for nid in 0..d.nodes.len() {
                build_proc(&d, nid).unwrap_or_else(|e| panic!("{name}/{nid}: {e}"));
            }
        }
    }
}
