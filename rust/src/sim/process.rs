//! Functional node behaviours — bit-exact int8/int32 semantics matching
//! `python/compile/kernels/ref.py` (the golden-model contract).
//!
//! A [`NodeProc`] answers three questions for the engine:
//! 1. how many cumulative input tokens an input needs before firing k,
//! 2. what to do with tokens as they arrive (`accept` — e.g. fill the
//!    line buffer), and
//! 3. the value of output token k (`fire_into` — written straight into
//!    an arena slot, no per-firing allocation).
//!
//! Procs are built once per design ([`build_proc`], called from
//! [`crate::sim::SimContext::new`]) and **reused across runs**:
//! [`NodeProc::reset`] clears the per-run state (line buffers, pending
//! queues) while keeping the transposed weights and every allocation,
//! so re-simulating the same design — the per-cell loop of
//! `simulate_tiled` — costs no weight re-transposition and no heap
//! traffic.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::analysis::classify::KernelClass;
use crate::dataflow::design::Design;
use crate::ir::generic::Payload;
use crate::ir::graph::TensorKind;

use super::arena::{TokenArena, TokenId};

pub const I8_MIN: i32 = -128;
pub const I8_MAX: i32 = 127;

fn sat_i8(v: i32) -> i32 {
    v.clamp(I8_MIN, I8_MAX)
}

/// Apply a pure-parallel payload lane-wise, writing into `out`.
///
/// `out` may be a recycled (uninitialized) arena slot, so every lane
/// must be written: the lane counts are asserted up front rather than
/// letting `zip` truncate silently.
pub fn apply_payload_into(p: Payload, a: &[i32], b: Option<&[i32]>, out: &mut [i32]) {
    // hard asserts (release too): zip truncation over a recycled slot
    // would silently leak stale payload values into the output
    assert_eq!(a.len(), out.len(), "payload lane-count mismatch");
    if let Some(b) = b {
        assert_eq!(b.len(), out.len(), "payload lane-count mismatch");
    }
    match p {
        Payload::Relu => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.max(0);
            }
        }
        Payload::Requant { shift } => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = sat_i8(x >> shift);
            }
        }
        Payload::ReluRequant { shift } => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = sat_i8(x.max(0) >> shift);
            }
        }
        Payload::AddSat => {
            let b = b.expect("AddSat needs two inputs");
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = sat_i8(x + y);
            }
        }
        Payload::Copy => out.copy_from_slice(a),
        Payload::MulAcc | Payload::MaxReduce => unreachable!("not pure-parallel"),
    }
}

/// Allocating convenience wrapper over [`apply_payload_into`] (tests
/// and reference paths; the engine uses the in-place form).
pub fn apply_payload(p: Payload, ins: &[&[i32]]) -> Vec<i32> {
    let mut out = vec![0i32; ins[0].len()];
    apply_payload_into(p, ins[0], ins.get(1).copied(), &mut out);
    out
}

/// Functional behaviour of one dataflow node.
pub enum NodeProc {
    Sliding(SlidingProc),
    Reduction(ReductionProc),
    Parallel(ParallelProc),
}

impl NodeProc {
    /// Cumulative tokens needed on input `slot` before firing `k`.
    #[inline]
    pub fn needed(&self, slot: usize, k: u64) -> u64 {
        let _ = slot;
        match self {
            NodeProc::Sliding(p) => p.needed(k),
            NodeProc::Reduction(_) | NodeProc::Parallel(_) => k + 1,
        }
    }

    /// Consume one token (ownership of the handle moves here: the proc
    /// either copies the payload and releases, or parks the handle
    /// until its firing releases it).
    pub fn accept(&mut self, slot: usize, tok: TokenId, arena: &mut TokenArena) {
        match self {
            NodeProc::Sliding(p) => p.accept(tok, arena),
            NodeProc::Reduction(p) => p.accept(tok),
            NodeProc::Parallel(p) => p.accept(slot, tok),
        }
    }

    /// Produce output token `k` into a fresh arena slot (refcount 1).
    pub fn fire_into(&mut self, k: u64, arena: &mut TokenArena) -> TokenId {
        match self {
            NodeProc::Sliding(p) => p.fire_into(k, arena),
            NodeProc::Reduction(p) => p.fire_into(arena),
            NodeProc::Parallel(p) => p.fire_into(arena),
        }
    }

    /// Address of this proc's (shared) weight storage, if any — the
    /// bank-sharing diagnostic behind
    /// [`crate::sim::SimContext::shares_weights_with`].
    pub fn weights_addr(&self) -> Option<usize> {
        match self {
            NodeProc::Sliding(p) if !p.weights.is_empty() => Some(p.weights.as_ptr() as usize),
            NodeProc::Reduction(p) => Some(p.weights.as_ptr() as usize),
            _ => None,
        }
    }

    /// Clear per-run state, keeping weights and buffer capacity.
    pub fn reset(&mut self) {
        match self {
            NodeProc::Sliding(p) => p.buf.clear(),
            NodeProc::Reduction(p) => p.cur = None,
            NodeProc::Parallel(p) => {
                for q in &mut p.pending {
                    q.clear();
                }
            }
        }
    }
}

/// Read-only, reference-counted weight storage for one design: the raw
/// and transposed weights of every node, extracted and transposed
/// **once** and shared by every [`crate::sim::SimContext`] built via
/// [`crate::sim::SimContext::with_bank`]. The tiled context pool builds
/// one bank per design, so `ctx_builds`-worth of duplicate
/// transposition work and weight memory collapses to a single copy.
pub struct WeightBank {
    nodes: Vec<BankEntry>,
}

struct BankEntry {
    /// Untransposed weights as stored in the graph (empty if weightless).
    raw: Arc<[i32]>,
    /// (K,K,C,F) transposition for sliding nodes; empty otherwise.
    transposed: Arc<[i32]>,
}

impl WeightBank {
    /// Extract and transpose every node's weights.
    pub fn build(d: &Design) -> Result<WeightBank> {
        let nodes = (0..d.nodes.len())
            .map(|nid| {
                let node = &d.nodes[nid];
                let op = &d.graph.ops[node.op_index];
                let raw: Vec<i32> = op
                    .inputs
                    .iter()
                    .find(|&&t| d.graph.tensor(t).kind == TensorKind::Weight)
                    .map(|&t| {
                        d.graph
                            .tensor(t)
                            .data
                            .as_ref()
                            .expect("weight without data")
                            .iter()
                            .map(|&v| v as i32)
                            .collect()
                    })
                    .unwrap_or_default();
                let transposed = match node.geo.class {
                    KernelClass::SlidingWindow(sw) => {
                        let in_t = d.graph.tensor(op.inputs[0]);
                        ensure!(in_t.ty.rank() == 3, "sliding input must be (H,W,C)");
                        let c = in_t.ty.shape[2];
                        let out_t = d.graph.tensor(op.output);
                        let f = *out_t.ty.shape.last().unwrap();
                        let k = op.dims[sw.reduction_dim];
                        if op.payload == Payload::MulAcc {
                            ensure!(raw.len() == f * k * k * c, "conv weight size mismatch");
                        }
                        transpose_fkkc_to_kkcf(&raw, f, k, c)
                    }
                    _ => Vec::new(),
                };
                Ok(BankEntry { raw: raw.into(), transposed: transposed.into() })
            })
            .collect::<Result<_>>()?;
        Ok(WeightBank { nodes })
    }

    /// Total i32 weight values held (raw + transposed) — diagnostics.
    pub fn values(&self) -> usize {
        self.nodes.iter().map(|e| e.raw.len() + e.transposed.len()).sum()
    }
}

/// Transpose conv weights (F,K,K,C) -> (K,K,C,F) for the contiguous
/// inner loop of `SlidingProc::fire_into`.
pub fn transpose_fkkc_to_kkcf(w: &[i32], f: usize, k: usize, c: usize) -> Vec<i32> {
    if w.is_empty() {
        return Vec::new(); // weight-less sliding window (maxpool)
    }
    debug_assert_eq!(w.len(), f * k * k * c);
    let mut out = vec![0i32; w.len()];
    for ff in 0..f {
        for kh in 0..k {
            for kw in 0..k {
                for cc in 0..c {
                    out[((kh * k + kw) * c + cc) * f + ff] =
                        w[((ff * k + kh) * k + kw) * c + cc];
                }
            }
        }
    }
    out
}

/// Sliding-window node (conv2d / maxpool): line-buffer fill + window
/// gather + dot product / max-reduce per output pixel.
pub struct SlidingProc {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub w_out: usize,
    pub f: usize,
    pub k: usize,
    pub stride: usize,
    pub dilation: usize,
    pub pad: usize,
    /// Flattened weights (F, K, K, C) as i32; empty for maxpool. Shared
    /// (refcounted) with every other context built from the same
    /// [`WeightBank`].
    pub weights: Arc<[i32]>,
    /// Weights transposed to (K, K, C, F) so the per-(kh,kw,cc) inner
    /// loop reads a contiguous F-vector — the simulator's hottest loop
    /// (see EXPERIMENTS.md §Perf). Transposed **once per design** and
    /// shared via the [`WeightBank`].
    pub(crate) weights_t: Arc<[i32]>,
    pub payload: Payload,
    /// Consumed input values (row-major (h, w, c)); the engine's FIFO
    /// back-pressure bounds how far this runs ahead — functionally we
    /// retain everything for simplicity (simulation memory, not BRAM).
    /// Capacity survives `reset`, so cell re-runs never reallocate.
    buf: Vec<i32>,
    /// Row-granular output scratch for [`Self::fire_row_into`]
    /// (`w_out * f` values, capacity kept across runs).
    row_scratch: Vec<i32>,
}

impl SlidingProc {
    fn needed(&self, k: u64) -> u64 {
        // output pixel (r, cx) needs input through pixel
        // (r·s + (K-1)·δ − pad, cx·s + (K-1)·δ − pad), clamped into range.
        let r = (k as usize) / self.w_out;
        let cx = (k as usize) % self.w_out;
        let keff = (self.k - 1) * self.dilation;
        let raw_r = (r * self.stride + keff).saturating_sub(self.pad);
        if raw_r >= self.h {
            // bottom zero-padding: the window already hangs off the end,
            // so the whole input is (and stays) required — keeps needed()
            // monotone across the clamped final rows.
            return (self.h * self.w) as u64;
        }
        let in_c = (cx * self.stride + keff).saturating_sub(self.pad).min(self.w - 1);
        (raw_r * self.w + in_c + 1) as u64
    }

    fn accept(&mut self, tok: TokenId, arena: &mut TokenArena) {
        debug_assert_eq!(arena.get(tok).len(), self.c);
        self.buf.extend_from_slice(arena.get(tok));
        arena.release(tok);
    }

    /// One (kh, kw) tap of the MAC window: `px · W[kh][kw]` accumulated
    /// into `out` as slice-chunked dot products — the weight row for
    /// each channel is a contiguous F-vector, so the inner loop is a
    /// single auto-vectorizable multiply-accumulate over `out`.
    #[inline]
    fn mac_tap(out: &mut [i32], px: &[i32], wtap: &[i32], f: usize) {
        for (cc, &x) in px.iter().enumerate() {
            if x == 0 {
                continue;
            }
            let wrow = &wtap[cc * f..(cc + 1) * f];
            for (o, &wv) in out.iter_mut().zip(wrow) {
                *o = o.wrapping_add(wv.wrapping_mul(x));
            }
        }
    }

    fn fire_into(&mut self, k: u64, arena: &mut TokenArena) -> TokenId {
        let r = (k as usize) / self.w_out;
        let cx = (k as usize) % self.w_out;
        let id = arena.alloc(self.f);
        // `out` is a fresh slot; sliding fires read only proc-owned
        // state (buf, weights), so a plain mutable view suffices.
        let out = arena.slice_mut(id);
        match self.payload {
            Payload::MulAcc => {
                out.fill(0);
                for kh in 0..self.k {
                    for kw in 0..self.k {
                        let ir = r * self.stride + kh * self.dilation;
                        let ic = cx * self.stride + kw * self.dilation;
                        // padding: indices are offset by `pad`
                        if ir < self.pad || ic < self.pad {
                            continue;
                        }
                        let (ir, ic) = (ir - self.pad, ic - self.pad);
                        if ir >= self.h || ic >= self.w {
                            continue;
                        }
                        let base = (ir * self.w + ic) * self.c;
                        let px = &self.buf[base..base + self.c];
                        let wbase = (kh * self.k + kw) * self.c * self.f;
                        let wtap = &self.weights_t[wbase..wbase + self.c * self.f];
                        Self::mac_tap(out, px, wtap, self.f);
                    }
                }
            }
            Payload::MaxReduce => {
                out.fill(i32::MIN); // f == c for pooling
                for kh in 0..self.k {
                    for kw in 0..self.k {
                        let ir = r * self.stride + kh * self.dilation;
                        let ic = cx * self.stride + kw * self.dilation;
                        if ir < self.pad || ic < self.pad {
                            continue;
                        }
                        let (ir, ic) = (ir - self.pad, ic - self.pad);
                        if ir >= self.h || ic >= self.w {
                            continue;
                        }
                        let base = (ir * self.w + ic) * self.c;
                        for (o, &v) in out.iter_mut().zip(&self.buf[base..base + self.c]) {
                            *o = (*o).max(v);
                        }
                    }
                }
            }
            other => panic!("sliding node with payload {other:?}"),
        }
        id
    }

    /// Batched firing for the fast-forward replay path: compute one
    /// whole output row — pixels `k .. k + w_out`, `k` row-aligned — in
    /// a single pass and hand back `w_out` freshly allocated tokens.
    ///
    /// The win over per-pixel [`Self::fire_into`]: the pad/bounds
    /// branches move out of the inner loop (each `(kh, kw)` tap
    /// precomputes its valid output-column range), the weight F-vector
    /// of a tap is reused across the whole row, and the arena
    /// reservation is batched ([`TokenArena::alloc_many`]). Requires the
    /// line buffer to be filled through `needed(k + w_out - 1)` — the
    /// replay streams inputs first. Bit-exact with `w_out` calls to
    /// `fire_into` (asserted by the unit test and the oracle property
    /// suite).
    pub(crate) fn fire_row_into(&mut self, k: u64, arena: &mut TokenArena, out: &mut Vec<TokenId>) {
        debug_assert_eq!(k as usize % self.w_out, 0, "row firing must start row-aligned");
        let r = (k as usize) / self.w_out;
        let (w_out, f, c, w) = (self.w_out, self.f, self.c, self.w);
        let fill = match self.payload {
            Payload::MulAcc => 0,
            Payload::MaxReduce => i32::MIN,
            other => panic!("sliding node with payload {other:?}"),
        };
        self.row_scratch.clear();
        self.row_scratch.resize(w_out * f, fill);
        let scratch = &mut self.row_scratch[..];
        let buf = &self.buf[..];
        let wt = &self.weights_t[..];
        for kh in 0..self.k {
            let ir = r * self.stride + kh * self.dilation;
            if ir < self.pad || ir - self.pad >= self.h {
                continue;
            }
            let ir = ir - self.pad;
            for kw in 0..self.k {
                // valid columns: pad <= cx*stride + kw*dilation <= pad + w - 1
                let off = kw * self.dilation;
                let cx_lo = if off >= self.pad {
                    0
                } else {
                    (self.pad - off).div_ceil(self.stride)
                };
                let hi_raw = self.pad + w - 1;
                if off > hi_raw {
                    continue;
                }
                let cx_hi = ((hi_raw - off) / self.stride + 1).min(w_out);
                if cx_lo >= cx_hi {
                    continue;
                }
                let wtap = if wt.is_empty() {
                    &[][..]
                } else {
                    let wbase = (kh * self.k + kw) * c * f;
                    &wt[wbase..wbase + c * f]
                };
                for cx in cx_lo..cx_hi {
                    let ic = cx * self.stride + off - self.pad;
                    let px = &buf[(ir * w + ic) * c..(ir * w + ic) * c + c];
                    let o = &mut scratch[cx * f..(cx + 1) * f];
                    match self.payload {
                        Payload::MulAcc => Self::mac_tap(o, px, wtap, f),
                        _ => {
                            for (ov, &v) in o.iter_mut().zip(px) {
                                *ov = (*ov).max(v);
                            }
                        }
                    }
                }
            }
        }
        arena.alloc_many(f, w_out, out);
        for (cx, &id) in out.iter().enumerate() {
            arena.slice_mut(id).copy_from_slice(&self.row_scratch[cx * f..(cx + 1) * f]);
        }
    }
}

/// Regular-reduction node (linear): one activation row in, one output
/// row out, weights resident.
pub struct ReductionProc {
    pub k: usize,
    pub n: usize,
    /// (K, N) weights as i32, shared via the [`WeightBank`].
    pub weights: Arc<[i32]>,
    cur: Option<TokenId>,
}

impl ReductionProc {
    fn accept(&mut self, tok: TokenId) {
        debug_assert!(self.cur.is_none(), "reduction row overwritten before fire");
        self.cur = Some(tok);
    }

    fn fire_into(&mut self, arena: &mut TokenArena) -> TokenId {
        let xid = self.cur.take().expect("fire before accept");
        let id = arena.alloc(self.n);
        let (out, x) = arena.write_and_read(id, xid);
        debug_assert_eq!(x.len(), self.k);
        out.fill(0);
        for (kk, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let row = &self.weights[kk * self.n..(kk + 1) * self.n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o = o.wrapping_add(wv.wrapping_mul(xv));
            }
        }
        arena.release(xid);
        id
    }
}

/// Pure-parallel node: elementwise payload over 1–2 input streams.
pub struct ParallelProc {
    pub payload: Payload,
    pub arity: usize,
    pending: Vec<VecDeque<TokenId>>,
}

impl ParallelProc {
    fn accept(&mut self, slot: usize, tok: TokenId) {
        self.pending[slot].push_back(tok);
    }

    fn fire_into(&mut self, arena: &mut TokenArena) -> TokenId {
        let a = self.pending[0].pop_front().expect("missing token");
        match self.arity {
            1 => {
                let id = arena.alloc(arena.get(a).len());
                let (out, x) = arena.write_and_read(id, a);
                apply_payload_into(self.payload, x, None, out);
                arena.release(a);
                id
            }
            2 => {
                let b = self.pending[1].pop_front().expect("missing token");
                let id = arena.alloc(arena.get(a).len());
                let (out, x, y) = arena.write_and_read2(id, a, b);
                apply_payload_into(self.payload, x, Some(y), out);
                arena.release(a);
                arena.release(b);
                id
            }
            n => panic!("pure-parallel node with arity {n}"),
        }
    }
}

/// Build the functional behaviour of node `nid` of a design, sourcing
/// weights from the (shared) `bank` instead of re-extracting and
/// re-transposing them per context.
pub fn build_proc(d: &Design, nid: usize, bank: &WeightBank) -> Result<NodeProc> {
    let node = &d.nodes[nid];
    let op = &d.graph.ops[node.op_index];
    let entry = &bank.nodes[nid];
    match node.geo.class {
        KernelClass::SlidingWindow(sw) => {
            let in_t = d.graph.tensor(op.inputs[0]);
            ensure!(in_t.ty.rank() == 3, "sliding input must be (H,W,C)");
            let (h, w, c) = (in_t.ty.shape[0], in_t.ty.shape[1], in_t.ty.shape[2]);
            let out_t = d.graph.tensor(op.output);
            let w_out = out_t.ty.shape[1];
            let f = *out_t.ty.shape.last().unwrap();
            let k = op.dims[sw.reduction_dim];
            Ok(NodeProc::Sliding(SlidingProc {
                h,
                w,
                c,
                w_out,
                f,
                k,
                stride: sw.stride as usize,
                dilation: sw.dilation as usize,
                pad: op.pad,
                weights: entry.raw.clone(),
                weights_t: entry.transposed.clone(),
                payload: op.payload,
                buf: Vec::new(),
                row_scratch: Vec::new(),
            }))
        }
        KernelClass::RegularReduction => {
            let wt = op
                .inputs
                .iter()
                .find(|&&t| d.graph.tensor(t).kind == TensorKind::Weight)
                .context("reduction node without weights")?;
            let wt = d.graph.tensor(*wt);
            ensure!(wt.ty.rank() == 2, "linear weights must be (K,N)");
            let (k, n) = (wt.ty.shape[0], wt.ty.shape[1]);
            Ok(NodeProc::Reduction(ReductionProc { k, n, weights: entry.raw.clone(), cur: None }))
        }
        KernelClass::PureParallel => {
            let arity = node.in_channels.len();
            match op.payload {
                Payload::Relu
                | Payload::Requant { .. }
                | Payload::ReluRequant { .. }
                | Payload::AddSat
                | Payload::Copy => {}
                other => bail!("pure-parallel node with payload {other:?}"),
            }
            ensure!((1..=2).contains(&arity), "pure-parallel arity must be 1 or 2");
            Ok(NodeProc::Parallel(ParallelProc {
                payload: op.payload,
                arity,
                pending: (0..arity).map(|_| VecDeque::new()).collect(),
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    fn test_proc(d: &Design, nid: usize) -> NodeProc {
        build_proc(d, nid, &WeightBank::build(d).unwrap()).unwrap()
    }

    #[test]
    fn payload_semantics_match_ref_contract() {
        // floor-rounding arithmetic shift and clamping, as in ref.py
        let acc = [-65, -64, -1, 0, 1, 63, 64, 65];
        let got = apply_payload(Payload::Requant { shift: 6 }, &[&acc]);
        assert_eq!(got, vec![-2, -1, -1, 0, 0, 0, 1, 1]);
        let big = [1 << 20, -(1 << 20)];
        assert_eq!(apply_payload(Payload::Requant { shift: 6 }, &[&big]), vec![127, -128]);
        assert_eq!(
            apply_payload(Payload::ReluRequant { shift: 6 }, &[&big]),
            vec![127, 0]
        );
        let a = [100, -100];
        let b = [100, -100];
        assert_eq!(apply_payload(Payload::AddSat, &[&a, &b]), vec![127, -128]);
    }

    #[test]
    fn sliding_needed_is_monotone_and_bounded() {
        let g = models::conv_relu(16, 4, 4);
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Sliding(p) = test_proc(&d, 0) else { panic!() };
        let total = 16 * 16;
        let mut last = 0;
        for k in 0..total as u64 {
            let n = p.needed(k);
            assert!(n >= last, "needed() must be monotone");
            assert!(n <= total as u64);
            last = n;
        }
        // last pixel needs the whole input
        assert_eq!(last, total as u64);
        // first pixel needs one padded row + a bit (pad=1)
        assert!(p.needed(0) <= 2 * 16);
    }

    #[test]
    fn conv_fire_matches_direct_computation() {
        // 4x4 input, 1 channel, 1 filter of all-ones, pad 1: output (1,1)
        // (interior) = sum of the 3x3 neighbourhood.
        let g = models::conv_relu(4, 1, 1);
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Sliding(mut p) = test_proc(&d, 0) else { panic!() };
        p.weights = vec![1; 9].into();
        p.weights_t = vec![1; 9].into();
        let mut arena = TokenArena::new();
        let vals: Vec<i32> = (0..16).collect();
        for v in &vals {
            let t = arena.alloc_from(&[*v]);
            p.accept(t, &mut arena);
        }
        // output pixel (1,1) covers input rows 0..3, cols 0..3
        let k = (1 * 4 + 1) as u64;
        let got = p.fire_into(k, &mut arena);
        let want: i32 = [0, 1, 2, 4, 5, 6, 8, 9, 10].iter().map(|&i| vals[i as usize]).sum();
        assert_eq!(arena.get(got), &[want]);
        // corner pixel (0,0): zero-padded window sums indices {0,1,4,5}
        let got0 = p.fire_into(0, &mut arena);
        assert_eq!(arena.get(got0), &[0 + 1 + 4 + 5]);
    }

    #[test]
    fn reduction_fire_is_matvec() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Reduction(mut p) = test_proc(&d, 0) else { panic!() };
        // x = e0 (first unit vector): out = first row of W
        let mut arena = TokenArena::new();
        let mut x = vec![0i32; p.k];
        x[0] = 1;
        let t = arena.alloc_from(&x);
        p.accept(t);
        let got = p.fire_into(&mut arena);
        let want: Vec<i32> = p.weights[..p.n].to_vec();
        assert_eq!(arena.get(got), &want[..]);
        assert_eq!(arena.live(), 1, "input token must be released on fire");
    }

    #[test]
    fn parallel_fire_consumes_and_releases_inputs() {
        let mut p = ParallelProc {
            payload: Payload::AddSat,
            arity: 2,
            pending: vec![VecDeque::new(), VecDeque::new()],
        };
        let mut arena = TokenArena::new();
        let a = arena.alloc_from(&[100, -100]);
        let b = arena.alloc_from(&[100, -100]);
        p.accept(0, a);
        p.accept(1, b);
        let out = p.fire_into(&mut arena);
        assert_eq!(arena.get(out), &[127, -128]);
        assert_eq!(arena.live(), 1, "both inputs released");
    }

    #[test]
    fn fire_row_matches_per_pixel_fires() {
        // the batched replay kernel must be bit-exact with w_out
        // per-pixel fires, padding rows and columns included
        let g = models::conv_relu(8, 3, 5);
        let d = build_streaming_design(&g).unwrap();
        let NodeProc::Sliding(mut a) = test_proc(&d, 0) else { panic!() };
        let NodeProc::Sliding(mut b) = test_proc(&d, 0) else { panic!() };
        let mut arena = TokenArena::new();
        let vals = crate::util::prng::det_tensor(crate::util::prng::SEED_INPUT, 8 * 8 * 3);
        for px in vals.chunks(3) {
            let px: Vec<i32> = px.iter().map(|&v| v as i32).collect();
            let ta = arena.alloc_from(&px);
            a.accept(ta, &mut arena);
            let tb = arena.alloc_from(&px);
            b.accept(tb, &mut arena);
        }
        let mut row = Vec::new();
        for r in 0..8u64 {
            let k = r * a.w_out as u64;
            b.fire_row_into(k, &mut arena, &mut row);
            assert_eq!(row.len(), a.w_out);
            for (cx, &tok) in row.iter().enumerate() {
                let want = a.fire_into(k + cx as u64, &mut arena);
                assert_eq!(arena.get(tok), arena.get(want), "conv row {r} col {cx}");
                arena.release(want);
            }
            for &tok in &row {
                arena.release(tok);
            }
        }
    }

    #[test]
    fn fire_row_matches_per_pixel_fires_for_strided_pool() {
        let mk = || SlidingProc {
            h: 8,
            w: 8,
            c: 4,
            w_out: 4,
            f: 4,
            k: 2,
            stride: 2,
            dilation: 1,
            pad: 0,
            weights: Vec::<i32>::new().into(),
            weights_t: Vec::<i32>::new().into(),
            payload: Payload::MaxReduce,
            buf: Vec::new(),
            row_scratch: Vec::new(),
        };
        let (mut a, mut b) = (mk(), mk());
        let mut arena = TokenArena::new();
        let vals = crate::util::prng::det_tensor(crate::util::prng::SEED_INPUT, 8 * 8 * 4);
        for px in vals.chunks(4) {
            let px: Vec<i32> = px.iter().map(|&v| v as i32).collect();
            let ta = arena.alloc_from(&px);
            a.accept(ta, &mut arena);
            let tb = arena.alloc_from(&px);
            b.accept(tb, &mut arena);
        }
        let mut row = Vec::new();
        for r in 0..4u64 {
            let k = r * 4;
            b.fire_row_into(k, &mut arena, &mut row);
            for (cx, &tok) in row.iter().enumerate() {
                let want = a.fire_into(k + cx as u64, &mut arena);
                assert_eq!(arena.get(tok), arena.get(want), "pool row {r} col {cx}");
                arena.release(want);
            }
            for &tok in &row {
                arena.release(tok);
            }
        }
    }

    #[test]
    fn reset_clears_state_and_keeps_weights() {
        let g = models::conv_relu(8, 2, 2);
        let d = build_streaming_design(&g).unwrap();
        let mut proc = test_proc(&d, 0);
        let mut arena = TokenArena::new();
        let t = arena.alloc_from(&[1, 2]);
        proc.accept(0, t, &mut arena);
        proc.reset();
        let NodeProc::Sliding(p) = &proc else { panic!() };
        assert!(p.buf.is_empty());
        assert!(!p.weights_t.is_empty(), "weights survive reset");
    }

    #[test]
    fn build_proc_for_all_paper_nodes() {
        for (name, size) in models::table2_workloads() {
            let g = models::paper_kernel(name, size.max(16)).unwrap();
            let d = build_streaming_design(&g).unwrap();
            let bank = WeightBank::build(&d).unwrap();
            for nid in 0..d.nodes.len() {
                build_proc(&d, nid, &bank).unwrap_or_else(|e| panic!("{name}/{nid}: {e}"));
            }
        }
    }
}
