//! PJRT runtime: loads the AOT artifacts produced by `python/compile`
//! (JAX/Pallas kernels lowered to HLO text) and executes them on the
//! PJRT CPU client via the `xla` crate. This is the request-path side of
//! the three-layer architecture — Python never runs here.
//!
//! The golden model ([`golden::GoldenModel`]) is MING's substitute for
//! on-board functional validation: the cycle-level simulator's output is
//! compared element-exact against the JAX/Pallas computation.

pub mod pjrt;
pub mod golden;

pub use golden::GoldenModel;
pub use pjrt::{HloExecutable, PjrtRuntime};
