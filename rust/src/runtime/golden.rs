//! The golden model: run an AOT artifact for a paper kernel and compare
//! against the simulator's functional output, element-exact.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use super::pjrt::{HloExecutable, PjrtRuntime};

/// Artifact metadata (written by `aot.py` as `<key>.meta`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
}

fn parse_meta(text: &str) -> Result<ArtifactMeta> {
    let mut in_shape = None;
    let mut out_shape = None;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        let shape = || -> Result<Vec<usize>> {
            v.split(',').map(|s| Ok(s.trim().parse::<usize>()?)).collect()
        };
        match k.trim() {
            "in_shape" => in_shape = Some(shape()?),
            "out_shape" => out_shape = Some(shape()?),
            _ => {}
        }
    }
    Ok(ArtifactMeta {
        in_shape: in_shape.context("meta missing in_shape")?,
        out_shape: out_shape.context("meta missing out_shape")?,
    })
}

/// Loads and caches compiled golden-model executables per artifact key
/// (`conv_relu_32`, `linear_0`, …).
pub struct GoldenModel {
    dir: PathBuf,
    rt: PjrtRuntime,
    cache: Mutex<HashMap<String, (ArtifactMeta, HloExecutable)>>,
}

impl GoldenModel {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        ensure!(dir.is_dir(), "artifact dir {} missing (run `make artifacts`)", dir.display());
        Ok(Self { dir, rt: PjrtRuntime::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    /// Default location relative to the crate root.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Artifact key for a paper kernel at a given size.
    pub fn key(kernel: &str, size: usize) -> String {
        format!("{kernel}_{size}")
    }

    pub fn available(&self, key: &str) -> bool {
        self.dir.join(format!("{key}.hlo.txt")).exists()
    }

    /// Run the golden model for `key` on a flattened i32 input.
    pub fn run(&self, key: &str, input: &[i32]) -> Result<Vec<i32>> {
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(key) {
            let hlo = self.dir.join(format!("{key}.hlo.txt"));
            let meta_path = self.dir.join(format!("{key}.meta"));
            let meta = parse_meta(
                &std::fs::read_to_string(&meta_path)
                    .with_context(|| format!("reading {}", meta_path.display()))?,
            )?;
            let exe = self.rt.load_hlo_text(&hlo)?;
            cache.insert(key.to_string(), (meta, exe));
        }
        let (meta, exe) = cache.get(key).unwrap();
        exe.run_i32(input, &meta.in_shape)
    }

    /// Compare a simulator output against the golden model, returning the
    /// number of mismatching elements (0 = bit-exact agreement).
    pub fn verify(&self, key: &str, input: &[i32], sim_output: &[i32]) -> Result<usize> {
        let want = self.run(key, input)?;
        ensure!(
            want.len() == sim_output.len(),
            "golden output {} values, sim produced {}",
            want.len(),
            sim_output.len()
        );
        Ok(want.iter().zip(sim_output).filter(|(a, b)| a != b).count())
    }

    /// Verify a grid-tiled execution against the untiled golden model:
    /// the stitched strip outputs must agree element-exact, same as a
    /// flat design (the tile schedule is an implementation detail the
    /// golden contract must not see).
    pub fn verify_tiled(
        &self,
        key: &str,
        input: &[i32],
        tiled: &crate::tiling::TiledSimReport,
    ) -> Result<usize> {
        self.verify(key, input, &tiled.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::framework::{compile_with, FrameworkKind};
    use crate::ir::builder::models;
    use crate::resources::device::DeviceSpec;
    use crate::sim::{simulate, SimMode};
    use crate::util::prng;

    #[test]
    fn meta_parsing() {
        let m = parse_meta("in_shape=32,32,8\nout_shape=32,32,8\nrequant_shift=6\n").unwrap();
        assert_eq!(m.in_shape, vec![32, 32, 8]);
        assert_eq!(m.out_shape, vec![32, 32, 8]);
        assert!(parse_meta("nonsense").is_err());
    }

    /// The central end-to-end correctness statement: the streaming design
    /// simulated cycle-by-cycle produces *bit-exactly* what the
    /// JAX/Pallas golden model computes, for every paper kernel.
    #[test]
    fn simulator_matches_golden_model_for_all_small_kernels() {
        let Ok(gm) = GoldenModel::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for (kernel, size) in [
            ("conv_relu", 32usize),
            ("cascade", 32),
            ("residual", 32),
            ("linear", 0),
            ("feedforward", 0),
        ] {
            let key = GoldenModel::key(kernel, size);
            if !gm.available(&key) {
                eprintln!("skipping {key}: artifact missing");
                continue;
            }
            let g = models::paper_kernel(kernel, size).unwrap();
            let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
                .iter()
                .map(|&v| v as i32)
                .collect();
            let d = compile_with(FrameworkKind::Ming, &g, &DeviceSpec::kv260()).unwrap();
            let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
            let mismatches = gm.verify(&key, &x, &rep.output).unwrap();
            assert_eq!(mismatches, 0, "{key}: simulator disagrees with golden model");
        }
    }

    /// Tiled execution must be transparent to the golden contract: the
    /// stitched cells of a grid-tiled design agree bit-exactly with
    /// the JAX/Pallas model of the *untiled* kernel.
    #[test]
    fn tiled_simulation_matches_golden_model() {
        use crate::dse::ilp::DseConfig;
        use crate::tiling::{compile_tiled_fixed, simulate_tiled};
        let Ok(gm) = GoldenModel::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for (kernel, size, rows, cols) in
            [("conv_relu", 32usize, 1usize, 4usize), ("cascade", 32, 2, 2)]
        {
            let key = GoldenModel::key(kernel, size);
            if !gm.available(&key) {
                eprintln!("skipping {key}: artifact missing");
                continue;
            }
            let g = models::paper_kernel(kernel, size).unwrap();
            let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
                .iter()
                .map(|&v| v as i32)
                .collect();
            let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), rows, cols)
                .unwrap();
            let rep = simulate_tiled(&tc, &x).unwrap();
            let mismatches = gm.verify_tiled(&key, &x, &rep).unwrap();
            assert_eq!(mismatches, 0, "{key}: tiled execution disagrees with golden model");
        }
    }
}
