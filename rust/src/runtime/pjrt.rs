//! Thin wrapper over the `xla` crate: HLO-text → compiled executable →
//! i32 tensor in / i32 tensor out.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `python/compile/aot.py`).

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client (one per process is plenty).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<HloExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(HloExecutable { exe })
    }
}

/// One compiled HLO module (single i32 input, 1-tuple i32 output — the
/// `aot.py` convention).
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl HloExecutable {
    /// Execute with a row-major i32 input of the given shape; returns the
    /// flattened i32 output.
    pub fn run_i32(&self, input: &[i32], shape: &[usize]) -> Result<Vec<i32>> {
        let numel: usize = shape.iter().product();
        anyhow::ensure!(input.len() == numel, "input length {} != shape {:?}", input.len(), shape);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims).context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("executing HLO")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True ⇒ 1-tuple output.
        let out = result.to_tuple1().context("unwrapping 1-tuple")?;
        out.to_vec::<i32>().context("reading output values")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn cpu_client_boots() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn load_and_run_linear_artifact() {
        let path = artifacts_dir().join("linear_0.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).unwrap();
        let input = vec![0i32; 512 * 128];
        let out = exe.run_i32(&input, &[512, 128]).unwrap();
        assert_eq!(out.len(), 512 * 128);
        // zero input through relu+requant is all zeros
        assert!(out.iter().all(|&v| v == 0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let path = artifacts_dir().join("linear_0.hlo.txt");
        if !path.exists() {
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).unwrap();
        assert!(exe.run_i32(&[1, 2, 3], &[512, 128]).is_err());
    }
}
