//! Minimal criterion-style timing harness for the `harness = false` bench
//! targets (criterion is not vendored in this environment). Provides
//! warmup + repeated measurement with mean / stddev / min reporting.

use std::time::{Duration, Instant};

/// Result of one benchmark: wall-clock stats over the measured iterations.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>10} ± {:>9}  (min {:>10}, max {:>10}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
/// Returns per-iteration stats. `f`'s return value is black-boxed.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    let sum: Duration = samples.iter().sum();
    let mean = sum / iters as u32;
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_secs_f64() - mean.as_secs_f64();
            d * d
        })
        .sum::<f64>()
        / iters as f64;
    BenchStats {
        name: name.to_string(),
        iters,
        mean,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: *samples.iter().min().unwrap(),
        max: *samples.iter().max().unwrap(),
    }
}

/// Auto-select iteration count so a bench takes roughly `budget`.
pub fn bench_auto<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchStats {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 1000.0) as usize;
    bench(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(5)), "5ns");
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }
}
