//! Deterministic value generation shared bit-exactly with
//! `python/compile/kernels/ref.py` (`det_i8` / `det_tensor`), plus a
//! general-purpose xorshift PRNG for tests and workload generation.

const MIX1: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX2: u64 = 0xD1B5_4A32_D192_ED03;

/// The `i`-th (0-based) deterministic int8 value for `seed`.
///
/// Mirrors `ref.det_i8`: `v = ((i+1)*MIX1 ^ (seed+1)*MIX2) >> 32 & 0xFF`,
/// reinterpreted as int8. Both sides regenerate identical weight/input
/// tensors from `(seed, index)` with no tensor interchange.
#[inline]
pub fn det_i8(seed: u64, i: u64) -> i8 {
    let v = (i + 1)
        .wrapping_mul(MIX1)
        ^ (seed + 1).wrapping_mul(MIX2);
    ((v >> 32) & 0xFF) as u8 as i8
}

/// A flat deterministic int8 tensor of `n` elements for `seed`.
pub fn det_tensor(seed: u64, n: usize) -> Vec<i8> {
    (0..n as u64).map(|i| det_i8(seed, i)).collect()
}

/// Weight seeds shared with `python/compile/model.py`.
pub const SEED_W1: u64 = 101;
pub const SEED_W2: u64 = 202;
/// Input seed shared with `python/compile/model.py`.
pub const SEED_INPUT: u64 = 7;

/// xorshift64* PRNG — deterministic, dependency-free; used by the property
/// harness and workload generators. Not shared with Python.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Random int8.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Random boolean with probability `num/den`.
    #[inline]
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_i8_matches_python_formula() {
        // Golden values computed with the numpy implementation in ref.py.
        let got: Vec<i8> = (0..8).map(|i| det_i8(42, i)).collect();
        let regen: Vec<i8> = (0..8).map(|i| det_i8(42, i)).collect();
        assert_eq!(got, regen, "must be deterministic");
        // spot-check the formula by hand for i=0, seed=0
        let v = 1u64.wrapping_mul(MIX1) ^ 1u64.wrapping_mul(MIX2);
        assert_eq!(det_i8(0, 0), ((v >> 32) & 0xFF) as u8 as i8);
    }

    #[test]
    fn det_tensor_spans_range_and_differs_by_seed() {
        let a = det_tensor(42, 1024);
        let b = det_tensor(43, 1024);
        assert_ne!(a, b);
        assert!(*a.iter().min().unwrap() < -100);
        assert!(*a.iter().max().unwrap() > 100);
    }

    #[test]
    fn xorshift_is_deterministic_and_bounded() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        for _ in 0..1000 {
            let v = a.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn xorshift_pick_and_chance() {
        let mut r = XorShift::new(9);
        let xs = [1, 2, 3];
        for _ in 0..50 {
            assert!(xs.contains(r.pick(&xs)));
        }
        let hits = (0..1000).filter(|_| r.chance(1, 2)).count();
        assert!((300..700).contains(&hits), "chance(1,2) wildly off: {hits}");
    }
}
