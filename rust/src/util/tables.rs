//! Aligned plain-text table rendering for CLI reports and bench output
//! (the paper's Tables II–IV are reproduced through this).

/// A simple column-aligned table with a header row.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(c);
                for _ in c.len()..widths[i] {
                    s.push(' ');
                }
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a f64 with `digits` significant-ish decimals, trimming zeros.
pub fn fnum(x: f64, digits: usize) -> String {
    let s = format!("{x:.digits$}");
    if s.contains('.') {
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(vec!["name", "v"]);
        t.row(vec!["a", "1"]).row(vec!["longer", "23"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "name    v");
        assert_eq!(lines[2], "a       1");
        assert_eq!(lines[3], "longer  23");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        TextTable::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn fnum_trims() {
        assert_eq!(fnum(1.500, 2), "1.5");
        assert_eq!(fnum(2.0, 2), "2");
        assert_eq!(fnum(0.123456, 3), "0.123");
    }
}
