//! Small self-contained substrates: deterministic PRNG shared with the
//! Python layer, a mini property-testing harness (stand-in for proptest —
//! not vendored in this environment), aligned text tables, and a bench
//! timing helper used by the `cargo bench` targets.

pub mod prng;
pub mod prop;
pub mod tables;
pub mod bench;
