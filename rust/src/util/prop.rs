//! Mini property-testing harness (proptest is not vendored in this
//! environment). Runs a property over `cases` PRNG-driven inputs and, on
//! failure, performs greedy shrinking via a caller-provided shrink step.
//!
//! ```no_run
//! use ming::util::prop::{forall, Gen};
//! forall("add commutes", 100, |g| (g.rng.range(0, 50), g.rng.range(0, 50)),
//!        |&(a, b)| a + b == b + a);
//! ```

use crate::util::prng::XorShift;

/// Generation context handed to input generators.
pub struct Gen {
    pub rng: XorShift,
    /// Index of the current case (0-based); useful for size ramping.
    pub case: usize,
}

/// Run `prop` over `cases` generated inputs; panics with a reproducer
/// message on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        // Per-case seed so any failure is reproducible in isolation.
        let seed = 0x5EED_0000 + case as u64;
        let mut g = Gen { rng: XorShift::new(seed), case };
        let input = gen(&mut g);
        if !prop(&input) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {input:?}");
        }
    }
}

/// Like [`forall`] but with greedy shrinking: on failure, `shrink` proposes
/// smaller candidates; the smallest still-failing input is reported.
pub fn forall_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Gen) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut g = Gen { rng: XorShift::new(seed), case };
        let input = gen(&mut g);
        if prop(&input) {
            continue;
        }
        // Greedy descent: keep taking the first failing shrink candidate.
        let mut worst = input.clone();
        'outer: loop {
            for cand in shrink(&worst) {
                if !prop(&cand) {
                    worst = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed on case {case} (seed {seed:#x}):\n  original: {input:?}\n  shrunk:   {worst:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall("count", 25, |g| g.rng.range(0, 10), |_| {
            n += 1;
            true
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_input() {
        forall("fails", 10, |g| g.rng.range(0, 100), |&x| x > 1000);
    }

    #[test]
    #[should_panic(expected = "shrunk:   2")]
    fn shrinking_finds_minimal_counterexample() {
        // Property "x < 2" fails for any x >= 2; shrinking by decrement
        // must land exactly on 2.
        forall_shrink(
            "min2",
            5,
            |g| g.rng.range(50, 100),
            |&x| if x > 0 { vec![x - 1] } else { vec![] },
            |&x| x < 2,
        );
    }
}
