//! `ming` — CLI for the MING paper-reproduction stack.
//!
//! Subcommands:
//!   compile   lower one kernel with one framework; print reports, emit HLS C++
//!   simulate  cycle-level simulation (+ golden verification if artifacts exist)
//!   sweep     the full Table-II sweep (kernel × framework)
//!   table2|table3|table4|fig3   regenerate the paper's tables/figure series
//!   merge-sweep  stitch sharded sweep spools into the Table-II report
//!   verify    golden-model verification for all kernels with artifacts
//!   import    compile a JSON model file (the ONNX-stand-in front-end)
//!   cache-stats  census of a --design-cache dir (entries, bytes, verdicts, GC log)
//!
//! Scale-out flags (sweep commands): `--design-cache <dir>` reuses
//! solved designs content-addressed by (graph, device, config)
//! fingerprint; `--shard i/n --spool <dir>` runs one deterministic
//! slice of the sweep and spools JSONL results for `merge-sweep`;
//! `--workers N` sizes the process-wide work-stealing scheduler.
//!
//! (Hand-rolled argument parsing: clap is not vendored in this environment.)

use std::collections::{BTreeSet, HashMap};
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::codegen::emit::emit_tiled_design;
use ming::codegen::{emit_design, emit_testbench, emit_tiled_testbench};
use ming::coordinator::cache::DesignCache;
use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, Shard, SweepConfig};
use ming::coordinator::spool;
use ming::coordinator::{sched, StageTimes};
use ming::dse::ilp::{solve_with_tiling_fallback, Compiled, DseConfig};
use ming::dataflow::build::build_streaming_design;
use ming::dataflow::design::Design;
use ming::ir::builder::models;
use ming::ir::json::import_model;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::runtime::golden::GoldenModel;
use ming::sim::{simulate, FfStats, SimConfig, SimContext, SimMode};
use ming::sim::trace::render_traces;
use ming::tiling::{simulate_tiled_parallel_with, simulate_tiled_with, TiledCompilation};
use ming::util::prng;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1).peekable();
    let cmd = it.next().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            // a flag followed by another flag (or by nothing) is boolean
            let val = match it.peek() {
                Some(next) if !next.starts_with("--") => it.next().unwrap(),
                _ => "true".into(),
            };
            flags.insert(name.to_string(), val);
        } else {
            bail!("unexpected argument {a:?} (flags are --name value)");
        }
    }
    Ok(Args { cmd, flags })
}

impl Args {
    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag: present (as `--flag` or `--flag true`) = true.
    fn get_bool(&self, name: &str) -> Result<bool> {
        match self.flags.get(name).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(other) => bail!("--{name} expects true/false, got {other:?}"),
        }
    }

    /// The shared design cache, when `--design-cache <dir>` is given.
    /// `--cache-gc <max-entries>` runs an mtime-LRU sweep of the cache
    /// dir at service start, before any lookups.
    fn design_cache(&self) -> Result<Option<Arc<DesignCache>>> {
        let cache = match self.flags.get("design-cache") {
            Some(dir) => Arc::new(DesignCache::at_dir(dir)?),
            None => {
                ensure!(
                    !self.flags.contains_key("cache-gc"),
                    "--cache-gc requires --design-cache <dir>"
                );
                return Ok(None);
            }
        };
        if let Some(max) = self.flags.get("cache-gc") {
            let max: usize = max.parse().context("--cache-gc expects a max entry count")?;
            let (kept, evicted) = cache.gc(max)?;
            eprintln!(
                "design cache gc: kept {kept} entr{} (newest first), evicted {evicted}",
                if kept == 1 { "y" } else { "ies" }
            );
        }
        Ok(Some(cache))
    }

    /// DSE config for one-shot commands: device + optional cache +
    /// solver parallelism (`--workers N`; `--workers 1` takes the exact
    /// serial code path). Also hands the cache back so the command can
    /// print its stats summary when it finishes (the one-shot commands
    /// used to drop the `Arc` into the config and stay silent about
    /// hits/misses).
    fn dse_config(&self, dev: &DeviceSpec) -> Result<(DseConfig, Option<Arc<DesignCache>>)> {
        let cache = self.design_cache()?;
        let mut cfg = DseConfig::new(dev.clone());
        if let Some(c) = &cache {
            cfg = cfg.with_cache(Arc::clone(c));
        }
        cfg = cfg.with_workers(self.workers()?);
        // Per-invocation warm-start state: within one command the
        // tile-grid search re-probes recurring cell geometries, so even
        // a one-shot compile benefits from front memoization — and it
        // is provably solution-invariant, so it is always on.
        cfg = cfg.with_warm_start(Arc::new(ming::dse::WarmStart::new()));
        Ok((cfg, cache))
    }

    /// Sweep shard (defaults to the full sweep).
    fn shard(&self) -> Result<Shard> {
        match self.flags.get("shard") {
            Some(s) => Shard::parse(s),
            None => Ok(Shard::full()),
        }
    }

    /// Parallelism from `--workers N` (machine-sized by default),
    /// rejected at parse time when invalid — `--workers 0` is an error
    /// here, not a silent clamp to 1 — and wired into the process-wide
    /// scheduler ([`sched::configure`]) before its first use.
    fn workers(&self) -> Result<usize> {
        let n = match self.flags.get("workers") {
            Some(raw) => {
                let n: usize = raw.parse().context("--workers expects a positive integer")?;
                ensure!(
                    n >= 1,
                    "--workers 0 is invalid: there is no zero-worker mode. \
                     Use --workers 1 for a fully serial run."
                );
                n
            }
            None => sched::default_size(),
        };
        sched::configure(n);
        Ok(n)
    }

    /// The compile service: `--workers N` parallelism + optional design
    /// cache, over the global work-stealing scheduler.
    fn service(&self) -> Result<CompileService> {
        let mut svc = CompileService::new(self.workers()?);
        if let Some(cache) = self.design_cache()? {
            svc = svc.with_cache(cache);
        }
        Ok(svc)
    }

    fn device(&self) -> Result<DeviceSpec> {
        if self.flags.contains_key("bram-reserve") {
            // Kept as a no-op so existing invocations don't break: the
            // unified resource model prices weight-ROM and FIFO BRAM
            // exactly, so nothing needs to be held back any more.
            eprintln!(
                "warning: --bram-reserve is deprecated and ignored (the resource \
                 model accounts FIFO/ROM BRAM exactly)"
            );
        }
        let name = self.get("device", "kv260");
        let mut dev =
            DeviceSpec::by_name(&name).with_context(|| format!("unknown device {name:?}"))?;
        if let Some(cap) = self.flags.get("dsp-limit") {
            dev = dev.with_dsp_limit(cap.parse()?);
        }
        if let Some(cap) = self.flags.get("bram-limit") {
            dev = dev.with_bram_limit(cap.parse()?);
        }
        if let Some(frac) = self.flags.get("max-bram-frac") {
            let f: f64 = frac.parse()?;
            if !(f > 0.0 && f <= 1.0) {
                bail!("--max-bram-frac must be in (0, 1], got {f}");
            }
            dev = dev.with_bram_limit((dev.bram18k as f64 * f).round() as u64);
        }
        Ok(dev)
    }

    fn framework(&self) -> Result<FrameworkKind> {
        let name = self.get("framework", "ming");
        FrameworkKind::parse(&name).with_context(|| format!("unknown framework {name:?}"))
    }

    /// Reject flags a command does not implement instead of silently
    /// ignoring them — `ming table4 --shard 0/2 --spool d` would
    /// otherwise burn the full sweep on every machine and spool nothing.
    fn forbid_flags(&self, cmd: &str, names: &[&str]) -> Result<()> {
        for n in names {
            ensure!(!self.flags.contains_key(*n), "--{n} is not supported by `{cmd}`");
        }
        Ok(())
    }
}

/// Scale-out flags only the sweep commands (`sweep`/`table2`/`table3`)
/// implement. `compile`, `import`, and `simulate` carve out `--workers`
/// (parallel DSE / tiled simulation) and forbid only the rest.
const SWEEP_ONLY_FLAGS: &[&str] = &["workers", "shard", "spool", "estimate-only"];

/// The sweep-only flags minus `--workers`, for the commands above.
const SWEEP_ONLY_FLAGS_SANS_WORKERS: &[&str] = &["shard", "spool", "estimate-only"];

/// Cache-stats summary for every cache-enabled command (sweeps already
/// print it in `run_sweep_cmd`; the one-shot commands go through here).
fn print_cache_summary(cache: &Option<Arc<DesignCache>>) {
    if let Some(c) = cache {
        eprintln!("{}", c.summary());
    }
}

fn det_input(g: &ming::ir::graph::ModelGraph) -> Vec<i32> {
    prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect()
}

fn print_nodes(d: &Design) {
    println!("nodes:");
    for n in &d.nodes {
        println!(
            "  {:<12} {:<18} lanes={:<5} II={} up={} ur={}",
            n.name,
            n.geo.class.name(),
            n.timing.mac_lanes,
            n.timing.ii,
            n.timing.unroll_par,
            n.timing.unroll_red
        );
    }
}

fn report_tiled_compile(a: &Args, tc: &TiledCompilation, dev: &DeviceSpec) -> Result<()> {
    println!("untiled DSE infeasible — halo-aware tile-grid fallback engaged");
    println!("{}", tc.describe());
    let r = estimate(&tc.cell, dev);
    println!("cell resources: {r}");
    println!("estimated tiled latency: {} cycles (gather overlapped)", tc.estimated_cycles());
    print_nodes(&tc.cell);
    if let Some(path) = a.flags.get("emit") {
        std::fs::write(path, emit_tiled_design(tc))?;
        println!("wrote tiled HLS C++ to {path}");
    }
    if let Some(path) = a.flags.get("emit-tb") {
        // The seam checks need an oracle that is *independent* of the
        // grid plan: the untiled design is always functionally simulable
        // (BRAM infeasibility is a resource property, not a simulation
        // limit), so its output is the expected vector. A planner bug
        // that corrupts the tiled simulation and the emitted HLS
        // identically still gets caught. The oracle simulates the whole
        // map once and the bench embeds full input/expected vectors, so
        // gate on workload size — the oversized showcases (vgg3@512,
        // conv_pool@512: 10^12-MAC scale) are estimate-only everywhere.
        const EMIT_TB_MAX_MACS: u64 = 2_000_000_000;
        let macs = tc.graph.total_macs();
        if macs > EMIT_TB_MAX_MACS {
            println!(
                "note: --emit-tb skipped — {macs} MACs exceeds the {EMIT_TB_MAX_MACS} \
                 oracle-simulation limit (use a smaller size for seam testbenches)"
            );
        } else {
            let x = det_input(&tc.graph);
            let flat = build_streaming_design(&tc.graph)?;
            let want = simulate(&flat, &x, SimMode::of(flat.style))?.expect_complete();
            std::fs::write(path, emit_tiled_testbench(tc, &x, &want.output))?;
            println!("wrote per-boundary tiled testbench to {path}");
        }
    }
    Ok(())
}

fn cmd_compile(a: &Args) -> Result<()> {
    // `compile` takes --workers (parallel branch-and-bound and
    // speculative grid search) but none of the sharding/spooling flags.
    a.forbid_flags("compile", SWEEP_ONLY_FLAGS_SANS_WORKERS)?;
    let kernel = a.get("kernel", "conv_relu");
    let size: usize = a.get("size", "32").parse()?;
    let dev = a.device()?;
    let fw = a.framework()?;
    let g = models::paper_kernel(&kernel, size)?;
    let (cfg, cache) = a.dse_config(&dev)?;
    // MING gets the tile-grid feasibility fallback; baselines do not.
    let d = if fw == FrameworkKind::Ming {
        match solve_with_tiling_fallback(&g, &cfg)? {
            Compiled::Flat(d, _) => *d,
            Compiled::Tiled(tc) => {
                println!(
                    "kernel {kernel}@{size}  framework {}  device {}",
                    fw.name(),
                    dev.name
                );
                let r = report_tiled_compile(a, &tc, &dev);
                print_cache_summary(&cache);
                return r;
            }
        }
    } else {
        compile_with(fw, &g, &dev)?
    };
    let r = estimate(&d, &dev);
    println!("kernel {kernel}@{size}  framework {}  device {}", fw.name(), dev.name);
    println!("resources: {r}");
    print_nodes(&d);
    if let Some(path) = a.flags.get("emit") {
        std::fs::write(path, emit_design(&d))?;
        println!("wrote HLS C++ to {path}");
    }
    if let Some(path) = a.flags.get("emit-tb") {
        let x = det_input(&g);
        let rep = simulate(&d, &x, SimMode::of(d.style))?.expect_complete();
        std::fs::write(path, emit_testbench(&d, &x, Some(&rep.output)))?;
        println!("wrote testbench to {path}");
    }
    print_cache_summary(&cache);
    Ok(())
}

fn golden_check(kernel: &str, size: usize, x: &[i32], output: &[i32]) -> Result<()> {
    if let Ok(gm) = GoldenModel::open_default() {
        let key = GoldenModel::key(kernel, size);
        if gm.available(&key) {
            let bad = gm.verify(&key, x, output)?;
            println!(
                "golden check [{key}]: {}",
                if bad == 0 { "OK (bit-exact)".into() } else { format!("{bad} mismatches") }
            );
        }
    }
    Ok(())
}

/// One-line `--profile` summary of what the steady-state accelerator
/// covered: skipped periods, skipped cycles, and how much of the run was
/// executed exactly (fill/drain/transients).
fn print_ff_summary(ff: &FfStats, cycles: u64) {
    if ff.periods == 0 {
        println!("fast-forward: no steady-state period detected ({} checkpoints)", ff.checkpoints);
        return;
    }
    let exact_pct = 100.0 * cycles.saturating_sub(ff.skipped_cycles) as f64 / cycles.max(1) as f64;
    println!(
        "fast-forward: {} periods, {} cycles skipped ({:.1}% of the run simulated exactly)",
        ff.periods, ff.skipped_cycles, exact_pct
    );
}

fn cmd_simulate(a: &Args) -> Result<()> {
    // `simulate` takes --workers (parallel tiled execution) but none of
    // the sweep-only sharding/spooling flags.
    a.forbid_flags("simulate", SWEEP_ONLY_FLAGS_SANS_WORKERS)?;
    let kernel = a.get("kernel", "conv_relu");
    let size: usize = a.get("size", "32").parse()?;
    let sim_cfg = if a.get_bool("exact-sim")? { SimConfig::exact() } else { SimConfig::default() };
    let dev = a.device()?;
    let fw = a.framework()?;
    // validate --workers up front so a bad value errors on the flat
    // path too (the fan-out itself is only used by tiled designs)
    let workers = a.workers()?;
    let g = models::paper_kernel(&kernel, size)?;
    let (cfg, cache) = a.dse_config(&dev)?;
    let d = if fw == FrameworkKind::Ming {
        match solve_with_tiling_fallback(&g, &cfg)? {
            Compiled::Flat(d, _) => *d,
            Compiled::Tiled(tc) => {
                println!("untiled DSE infeasible — simulating the grid-tiled design");
                println!("{}", tc.grid.describe());
                let x = det_input(&g);
                let rep = if workers > 1 {
                    println!(
                        "fanning {} cells across {} workers",
                        tc.grid.n_cells(),
                        workers.min(tc.grid.n_cells())
                    );
                    simulate_tiled_parallel_with(&tc, &x, sched::global(), sim_cfg)?
                } else {
                    simulate_tiled_with(&tc, &x, sim_cfg)?
                };
                println!(
                    "cycles: {}  ({:.4} MCycles over {} cells, {:.2} MAC/cycle)",
                    rep.cycles,
                    rep.cycles as f64 / 1e6,
                    rep.tile_cycles.len(),
                    g.total_macs() as f64 / rep.cycles.max(1) as f64
                );
                if ming::obs::trace::global().is_profiling() {
                    print_ff_summary(&rep.ff, rep.cycles);
                }
                let r = golden_check(&kernel, size, &x, &rep.output);
                print_cache_summary(&cache);
                return r;
            }
        }
    } else {
        compile_with(fw, &g, &dev)?
    };
    let x = det_input(&g);
    // under --profile, run with per-FIFO back-pressure accounting so the
    // sim section below can attribute stalls to channels
    let profiling = ming::obs::trace::global().is_profiling();
    let mut ctx = SimContext::new(&d, SimMode::of(d.style))?;
    ctx.set_config(sim_cfg);
    if profiling {
        ctx.enable_profile();
    }
    let rep = ctx.run(&x)?;
    if let Some(blocked) = &rep.deadlock {
        println!("DEADLOCK:\n  {}", blocked.join("\n  "));
        return Ok(());
    }
    println!(
        "cycles: {}  ({:.4} MCycles, {:.2} MAC/cycle)",
        rep.cycles,
        rep.cycles as f64 / 1e6,
        rep.macs_per_cycle(d.total_macs())
    );
    if profiling {
        print_ff_summary(&rep.ff, rep.cycles);
    }
    println!("{}", render_traces(&rep.traces));
    if let Some(fp) = &rep.fifo_profile {
        println!("back-pressure profile:\n{}", fp.render());
    }
    // golden verification when artifacts are available
    let r = golden_check(&kernel, size, &x, &rep.output);
    print_cache_summary(&cache);
    r
}

/// Shared sweep driver: run `cfg` (one shard of it) on `svc`, spooling
/// to `--spool` when given, and return the cells for rendering (`None`
/// when a *partial* shard only spooled — the full table then comes from
/// `merge-sweep`; a full-shard spooled run renders its complete table).
fn run_sweep_cmd(
    a: &Args,
    svc: &CompileService,
    cfg: &SweepConfig,
    report: &str,
) -> Result<Option<Vec<Cell>>> {
    let shard = a.shard()?;
    // one canonical job list per command — every seq/total/id below
    // derives from it (and run_shard re-derives the identical list)
    let jobs = CompileService::jobs(cfg);
    let total = jobs.len();
    let out = match a.flags.get("spool") {
        Some(dir) => {
            let dir = std::path::Path::new(dir);
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spool dir {}", dir.display()))?;
            let sweep = CompileService::sweep_id(cfg);
            let path = spool::shard_file(dir, shard);
            let (existing, torn) = spool::read_spool_file(&path)?;
            if torn > 0 {
                eprintln!("warning: skipped {torn} torn line(s) in {}", path.display());
            }
            if existing.iter().any(|r| r.sweep != sweep) {
                bail!(
                    "spool {} holds records from a different sweep (other command, \
                     device or config) — use one spool dir per sweep",
                    path.display()
                );
            }
            // only *successful* records count as done — failed jobs are
            // retried on resume (their old failure records lose to the
            // retry's success at merge time)
            let done: BTreeSet<usize> =
                existing.iter().filter(|r| r.outcome.is_ok()).map(|r| r.seq).collect();
            let ids: Vec<String> = jobs.iter().map(|j| j.id()).collect();
            // stream one record per finished job (crash loses at most
            // the jobs in flight; the spool is what makes runs resumable)
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening spool {}", path.display()))?;
            let mut write_err: Option<std::io::Error> = None;
            let results = svc.run_shard_streaming(cfg, shard, &done, |seq, outcome| {
                let line = spool::record_line(sweep, report, seq, total, &ids[seq], outcome);
                if let Err(e) = writeln!(f, "{line}").and_then(|()| f.flush()) {
                    write_err.get_or_insert(e);
                }
            });
            if let Some(e) = write_err {
                // The compute already happened — don't throw it away.
                // Warn loudly (the spool is incomplete; a resume will
                // re-run whatever is missing) and fall back to rendering
                // the in-memory results like an unspooled run.
                eprintln!(
                    "warning: spool write to {} failed mid-sweep ({e}); the spool is \
                     INCOMPLETE — do not merge it without re-running; rendering the \
                     in-memory results instead",
                    path.display()
                );
                let cells = results
                    .iter()
                    .filter_map(|(_, r)| match r {
                        Ok(jr) => Some(report::cell(jr)),
                        Err(e) => {
                            eprintln!("job failed: {e}");
                            None
                        }
                    })
                    .collect();
                return Ok(Some(cells));
            }
            println!(
                "shard {shard}: spooled {} new job(s) ({} resumed, {total} total in sweep) \
                 to {}",
                results.len(),
                done.len(),
                path.display()
            );
            if shard.is_full() {
                // the spool now holds the whole sweep — render it, so
                // `--spool` adds durability without hiding the table
                let (records, _) = spool::read_spool_file(&path)?;
                let merged = spool::merge(records)?;
                for (seq, id, msg) in &merged.failures {
                    eprintln!("job failed (seq {seq}, {id}): {msg}");
                }
                ensure!(
                    merged.missing.is_empty(),
                    "spool {} is missing {} job(s) after a full-shard run: seqs {:?}",
                    path.display(),
                    merged.missing.len(),
                    merged.missing
                );
                Some(merged.cells)
            } else {
                None
            }
        }
        None => {
            let results = svc.run_shard(cfg, shard, &BTreeSet::new());
            if !shard.is_full() {
                eprintln!(
                    "note: rendering shard {shard} only ({} of {total} jobs); \
                     use --spool + merge-sweep for the full table",
                    results.len()
                );
            }
            let cells = results
                .iter()
                .filter_map(|(_, r)| match r {
                    Ok(jr) => Some(report::cell(jr)),
                    Err(e) => {
                        eprintln!("job failed: {e}");
                        None
                    }
                })
                .collect();
            Some(cells)
        }
    };
    if let Some(cache) = svc.cache() {
        eprintln!("{}", cache.summary());
    }
    Ok(out)
}

fn cmd_table2(a: &Args) -> Result<()> {
    let dev = a.device()?;
    let mut cfg = SweepConfig::table2(dev);
    cfg.estimate_only = a.get_bool("estimate-only")?;
    let svc = a.service()?;
    if let Some(cells) = run_sweep_cmd(a, &svc, &cfg, "table2")? {
        println!("{}", report::render_table2(&cells));
    }
    Ok(())
}

fn cmd_table3(a: &Args) -> Result<()> {
    // table3 is estimate-only by definition (post-PnR fabric columns);
    // an explicit flag would be silently overridden, so reject it
    a.forbid_flags("table3", &["estimate-only"])?;
    let dev = a.device()?;
    let cfg = SweepConfig {
        workloads: vec![
            ("conv_relu".into(), 32),
            ("cascade".into(), 32),
            ("residual".into(), 32),
        ],
        frameworks: FrameworkKind::all().to_vec(),
        device: dev,
        estimate_only: true,
    };
    let svc = a.service()?;
    if let Some(cells) = run_sweep_cmd(a, &svc, &cfg, "table3")? {
        println!("{}", report::render_table3(&cells));
    }
    Ok(())
}

/// Stitch sharded sweep spools back into the unsharded reports.
fn cmd_merge_sweep(a: &Args) -> Result<()> {
    a.forbid_flags("merge-sweep", &["workers", "shard", "design-cache", "cache-gc", "estimate-only"])?;
    let dir = a.flags.get("spool").context("--spool <dir> required")?;
    let (records, torn) = spool::read_spool_dir(std::path::Path::new(dir))?;
    if torn > 0 {
        eprintln!("warning: skipped {torn} torn spool line(s)");
    }
    let merged = spool::merge(records)?;
    for (seq, id, msg) in &merged.failures {
        eprintln!("job failed (seq {seq}, {id}): {msg}");
    }
    if !merged.missing.is_empty() {
        eprintln!(
            "warning: {} job(s) missing from the spool (run the missing shards, \
             then merge again): seqs {:?}",
            merged.missing.len(),
            merged.missing
        );
    }
    // The spool records know which report they were swept for; an
    // explicit --report must agree (catches merging the wrong dir).
    let recorded = merged.report.clone().unwrap_or_else(|| "table2".into());
    let kind = match a.flags.get("report") {
        Some(requested) => {
            ensure!(
                *requested == recorded,
                "--report {requested} but the spool was swept for {recorded}"
            );
            requested.clone()
        }
        None => recorded,
    };
    match kind.as_str() {
        "table2" => println!("{}", report::render_table2(&merged.cells)),
        "table3" => println!("{}", report::render_table3(&merged.cells)),
        other => bail!("spool records an unknown report kind {other:?}"),
    }
    Ok(())
}

fn cmd_table4(a: &Args) -> Result<()> {
    a.forbid_flags("table4", SWEEP_ONLY_FLAGS)?;
    a.forbid_flags("table4", &["design-cache", "cache-gc"])?;
    let base_dev = a.device()?;
    let g = models::paper_kernel("conv_relu", 32)?;
    let x = det_input(&g);
    // vanilla baseline cycles
    let dv = compile_with(FrameworkKind::Vanilla, &g, &base_dev)?;
    let base = simulate(&dv, &x, SimMode::of(dv.style))?.expect_complete();
    let base_mc = base.cycles as f64 / 1e6;
    let mut rows = Vec::new();
    for cap in [base_dev.dsp, 250, 50] {
        let dev = base_dev.with_dsp_limit(cap);
        let d = compile_with(FrameworkKind::Ming, &g, &dev)?;
        let rep = simulate(&d, &x, SimMode::Dataflow)?.expect_complete();
        let r = estimate(&d, &dev);
        rows.push((
            cap,
            Cell {
                kernel: "conv_relu".into(),
                size: 32,
                framework: FrameworkKind::Ming,
                mcycles: rep.cycles as f64 / 1e6,
                bram: r.bram18k,
                bram_rom: r.bram_weights,
                bram_fifo: r.bram_fifos,
                dsp: r.dsp,
                lut_pct: r.lut_pct(),
                lutram_pct: r.lutram_pct(),
                ff_pct: r.ff_pct(),
                fits: r.fits(),
                tiles: 1,
                stages: StageTimes::default(),
                error: None,
            },
            base_mc,
        ));
    }
    println!("{}", report::render_table4(&rows));
    Ok(())
}

fn cmd_fig3(a: &Args) -> Result<()> {
    a.forbid_flags("fig3", SWEEP_ONLY_FLAGS)?;
    a.forbid_flags("fig3", &["design-cache", "cache-gc"])?;
    let dev = a.device()?;
    let mut series: HashMap<&'static str, Vec<(usize, u64)>> = HashMap::new();
    for n in [32usize, 64, 96, 128, 160, 192, 224] {
        let g = models::conv_relu(n, models::CONV_C, models::CONV_F);
        for (name, fw) in [("streamhls", FrameworkKind::StreamHls), ("ming", FrameworkKind::Ming)] {
            let d = compile_with(fw, &g, &dev)?;
            let r = estimate(&d, &dev);
            series.entry(name).or_default().push((n, r.bram18k));
        }
    }
    println!("{}", report::render_fig3(&series));
    Ok(())
}

fn cmd_verify(a: &Args) -> Result<()> {
    a.forbid_flags("verify", SWEEP_ONLY_FLAGS)?;
    a.forbid_flags("verify", &["design-cache", "cache-gc"])?;
    let gm = GoldenModel::open_default()?;
    let dev = DeviceSpec::kv260();
    let mut all_ok = true;
    for (kernel, size) in models::table2_workloads() {
        let key = GoldenModel::key(kernel, size);
        if !gm.available(&key) {
            println!("{key:<18} SKIP (artifact missing)");
            continue;
        }
        let g = models::paper_kernel(kernel, size)?;
        let x = det_input(&g);
        let d = compile_with(FrameworkKind::Ming, &g, &dev)?;
        let rep = simulate(&d, &x, SimMode::Dataflow)?.expect_complete();
        let bad = gm.verify(&key, &x, &rep.output)?;
        println!("{key:<18} {}", if bad == 0 { "OK".into() } else { format!("{bad} MISMATCHES") });
        all_ok &= bad == 0;
    }
    if !all_ok {
        bail!("golden verification failed");
    }
    Ok(())
}

fn cmd_import(a: &Args) -> Result<()> {
    // `import` cold-compiles an external model: --workers feeds the
    // parallel solver exactly like `compile`.
    a.forbid_flags("import", SWEEP_ONLY_FLAGS_SANS_WORKERS)?;
    let path = a.flags.get("model").context("--model <file.json> required")?;
    let text = std::fs::read_to_string(path)?;
    let g = import_model(&text)?;
    println!("imported {} ({} ops, {} MACs)", g.name, g.ops.len(), g.total_macs());
    if let Some(hint) = &g.tiling {
        println!("tiling hint: {hint:?}");
    }
    let dev = a.device()?;
    let (cfg, cache) = a.dse_config(&dev)?;
    match solve_with_tiling_fallback(&g, &cfg)? {
        Compiled::Flat(d, _) => {
            let r = estimate(&d, &dev);
            println!("resources: {r}");
            if let Some(out) = a.flags.get("emit") {
                std::fs::write(out, emit_design(&d))?;
                println!("wrote HLS C++ to {out}");
            }
        }
        Compiled::Tiled(tc) => {
            println!("{}", tc.describe());
            let r = estimate(&tc.cell, &dev);
            println!("cell resources: {r}");
            if let Some(out) = a.flags.get("emit") {
                std::fs::write(out, emit_tiled_design(&tc))?;
                println!("wrote tiled HLS C++ to {out}");
            }
        }
    }
    print_cache_summary(&cache);
    Ok(())
}

/// `ming cache-stats --design-cache DIR` — census of a design-cache
/// dir: entry/byte counts, negative verdicts, unreadable files, and the
/// GC eviction history. Inspection only: no lookups, no counter churn
/// (`--cache-gc` composes if a sweep is wanted first).
fn cmd_cache_stats(a: &Args) -> Result<()> {
    ensure!(
        a.flags.contains_key("design-cache"),
        "cache-stats requires --design-cache <dir>"
    );
    a.forbid_flags("cache-stats", SWEEP_ONLY_FLAGS)?;
    let cache = a.design_cache()?.expect("checked above");
    let dir = cache.dir().expect("--design-cache always has a dir");
    let ds = cache.disk_stats()?;
    println!("design cache at {}:", dir.display());
    println!("  entries:     {}", ds.entries);
    println!("  bytes:       {}", ds.bytes);
    println!("  infeasible:  {} (negative verdicts)", ds.infeasible);
    println!("  unreadable:  {}", ds.unreadable);
    let hist = cache.eviction_history();
    if hist.is_empty() {
        println!("  evictions:   none recorded");
    } else {
        println!("  evictions ({} gc run{}):", hist.len(), if hist.len() == 1 { "" } else { "s" });
        for line in &hist {
            println!("    {line}");
        }
    }
    Ok(())
}

fn help() {
    println!(
        "ming — MING CNN-to-edge HLS framework (paper reproduction)\n\n\
         USAGE: ming <command> [--flag value ...]\n\n\
         COMMANDS\n\
         \x20 compile   --kernel K --size N [--framework F] [--device D] [--workers N]\n\
         \x20           [--emit f.cpp] [--emit-tb tb.cpp]\n\
         \x20           MING falls back to stride-aware 2-D tile-grid decomposition when the\n\
         \x20           DSE is infeasible; --emit-tb then writes a per-boundary seam testbench\n\
         \x20 simulate  --kernel K --size N [--framework F] [--device D] [--workers N]\n\
         \x20           [--exact-sim]\n\
         \x20           tiled designs fan grid cells across the worker pool;\n\
         \x20           --exact-sim disables the (bit-exact) steady-state\n\
         \x20           fast-forward + batched firing and runs step by step\n\
         \x20 table2    [--device D] [--estimate-only]   full Table-II sweep\n\
         \x20 table3    [--device D]        post-PnR fabric table\n\
         \x20 table4    [--device D]        DSP-constraint sweep\n\
         \x20 fig3      [--device D]        BRAM-vs-input-size series\n\
         \x20 merge-sweep --spool DIR [--report table2|table3]\n\
         \x20           stitch sharded sweep spools into the unsharded report\n\
         \x20 verify                        golden-model check (needs `make artifacts`)\n\
         \x20 import    --model m.json [--emit f.cpp] [--workers N]\n\
         \x20 cache-stats --design-cache DIR\n\
         \x20           census of a design-cache dir: entries, bytes, infeasible\n\
         \x20           verdicts, unreadable files, and the GC eviction history\n\n\
         SCALE-OUT (compile/simulate/import + sweep commands)\n\
         \x20 --design-cache DIR  reuse solved designs across runs/processes\n\
         \x20                     (content-addressed by graph+device fingerprint;\n\
         \x20                      infeasible verdicts are negative-cached too)\n\
         \x20 --cache-gc N        mtime-LRU sweep of the cache dir at start,\n\
         \x20                     keeping the N most recent entries\n\
         \x20 --workers N         width of the process-wide work-stealing scheduler:\n\
         \x20                     sweep jobs, tiled simulation, and the cold-path DSE\n\
         \x20                     (parallel branch-and-bound + speculative grid search)\n\
         \x20                     all share its cores; --workers 1 = exact serial path,\n\
         \x20                     --workers 0 is rejected (N must be >= 1)\n\
         \x20 --shard i/n         run the i-th of n deterministic sweep slices\n\
         \x20 --spool DIR         append JSONL results for merge-sweep / resume\n\
         \x20                     (already-spooled jobs are skipped on re-run)\n\n\
         OBSERVABILITY (every command)\n\
         \x20 --trace-out F.json  write a Chrome-trace-format span timeline of the\n\
         \x20                     run (load in Perfetto / chrome://tracing; sweep\n\
         \x20                     workers render as per-thread lanes)\n\
         \x20 --profile           print a phase-time + counter table at exit;\n\
         \x20                     `simulate` additionally attributes per-FIFO\n\
         \x20                     back-pressure (occupancy histograms, stalls)\n\
         \x20                     and prints a fast-forward summary (periods,\n\
         \x20                     cycles skipped, % simulated exactly)\n\n\
         kernels: conv_relu cascade residual linear feedforward vgg3 conv_pool\n\
         frameworks: vanilla scalehls streamhls ming\n\
         devices: kv260 zcu104 u250  (+ --dsp-limit N, --bram-limit N, --max-bram-frac F)\n\
         \x20 (--bram-reserve N is deprecated and ignored: the unified resource model\n\
         \x20  prices line-buffer, weight-ROM and FIFO BRAM exactly)"
    );
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    // Observability flags are global: arm the sink before dispatch so
    // every subsystem's spans/counters land in one place, and emit the
    // trace/profile after — even for failing runs (where they help most).
    let profile = match args.get_bool("profile") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let trace_out = args.flags.get("trace-out").cloned();
    let sink = ming::obs::trace::global();
    if trace_out.is_some() {
        sink.set_tracing(true);
        sink.set_thread_label("coordinator");
    }
    sink.set_profiling(profile);
    let before = profile.then(|| ming::obs::metrics::global().snapshot());
    let r = match args.cmd.as_str() {
        "compile" => cmd_compile(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" | "table2" => cmd_table2(&args),
        "merge-sweep" => cmd_merge_sweep(&args),
        "table3" => cmd_table3(&args),
        "table4" => cmd_table4(&args),
        "fig3" => cmd_fig3(&args),
        "verify" => cmd_verify(&args),
        "import" => cmd_import(&args),
        "cache-stats" => cmd_cache_stats(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            help();
            return ExitCode::FAILURE;
        }
    };
    if let Some(before) = before {
        let delta = ming::obs::metrics::global().snapshot().delta(&before);
        if !delta.is_empty() {
            println!("profile:");
        }
        print!("{}", ming::obs::render_profile(&delta));
    }
    if let Some(path) = &trace_out {
        match sink.write_chrome_trace(std::path::Path::new(path)) {
            Ok(()) => eprintln!("wrote {} trace event(s) to {path}", sink.event_count()),
            Err(e) => eprintln!("error: writing trace to {path}: {e}"),
        }
    }
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
