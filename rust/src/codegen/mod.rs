//! HLS code generation — the `emithls` dialect equivalent.
//!
//! Emits synthesizable Vitis-HLS C++ from a [`crate::dataflow::Design`]:
//! one function per dataflow node, `hls::stream` channels, line-buffer
//! arrays, and automatically inserted pragmas (STREAM, UNROLL, PIPELINE,
//! DATAFLOW, ARRAY_PARTITION, BIND_STORAGE — paper §III-C). The output
//! is what MING would hand to Vitis; in this reproduction it is validated
//! structurally (tests assert the pragma placement the paper prescribes)
//! and behaviourally by the cycle simulator, which executes the same
//! design object.

pub mod pragmas;
pub mod emit;
pub mod testbench;

pub use emit::{emit_design, emit_tiled_design};
pub use testbench::{emit_testbench, emit_tiled_testbench};
