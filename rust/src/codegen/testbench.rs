//! C++ testbench emission: drives the generated top function with the
//! deterministic PRNG inputs (same `det_i8` formula as Rust/Python) and
//! checks against an embedded expected-output vector produced by the
//! cycle simulator — so csim of the generated design validates against
//! the same golden data as everything else.

use std::fmt::Write as _;

use crate::dataflow::design::Design;

/// Emit a standalone testbench. `expected` is the simulator's output
/// (pass `None` to emit a bench that only prints outputs).
pub fn emit_testbench(d: &Design, input: &[i32], expected: Option<&[i32]>) -> String {
    let mut o = String::new();
    let in_ty = d.graph.inputs()[0].ty.dtype.cpp();
    let out_ty = d.graph.outputs()[0].ty.dtype.cpp();
    let in_n = d.graph.inputs()[0].ty.numel();
    let out_n = d.graph.outputs()[0].ty.numel();
    assert_eq!(input.len(), in_n, "testbench input length mismatch");

    let _ = writeln!(
        o,
        "// Auto-generated MING testbench for {}\n\
         #include <cstdio>\n#include <cstdint>\n#include <cstdlib>\n",
        d.graph.name
    );
    let _ = writeln!(
        o,
        "extern \"C\" void {}_top(const {in_ty} *host_in, {out_ty} *host_out);\n",
        d.graph.name
    );
    let _ = write!(o, "static const {in_ty} tb_input[{in_n}] = {{");
    for (i, v) in input.iter().enumerate() {
        if i % 24 == 0 {
            let _ = write!(o, "\n    ");
        }
        let _ = write!(o, "{v}, ");
    }
    let _ = writeln!(o, "\n}};\n");
    if let Some(exp) = expected {
        assert_eq!(exp.len(), out_n, "testbench expected length mismatch");
        let _ = write!(o, "static const {out_ty} tb_expected[{out_n}] = {{");
        for (i, v) in exp.iter().enumerate() {
            if i % 24 == 0 {
                let _ = write!(o, "\n    ");
            }
            let _ = write!(o, "{v}, ");
        }
        let _ = writeln!(o, "\n}};\n");
    }
    let _ = writeln!(o, "int main() {{");
    let _ = writeln!(o, "    static {out_ty} out[{out_n}];");
    let _ = writeln!(o, "    {}_top(tb_input, out);", d.graph.name);
    if expected.is_some() {
        let _ = writeln!(
            o,
            "    long bad = 0;\n\
             \x20   for (long i = 0; i < {out_n}; ++i)\n\
             \x20       if (out[i] != tb_expected[i]) {{ if (bad < 10) printf(\"mismatch @%ld: %d != %d\\n\", i, (int)out[i], (int)tb_expected[i]); ++bad; }}\n\
             \x20   printf(\"%ld mismatches\\n\", bad);\n\
             \x20   return bad == 0 ? 0 : 1;"
        );
    } else {
        let _ = writeln!(
            o,
            "    for (long i = 0; i < 16 && i < {out_n}; ++i) printf(\"%d \", (int)out[i]);\n\
             \x20   printf(\"\\n\");\n    return 0;"
        );
    }
    let _ = writeln!(o, "}}");
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn testbench_embeds_vectors_and_check() {
        let g = models::conv_relu(8, 2, 2);
        let d = build_streaming_design(&g).unwrap();
        let input = vec![1i32; 8 * 8 * 2];
        let expected = vec![0i32; 8 * 8 * 2];
        let tb = emit_testbench(&d, &input, Some(&expected));
        assert!(tb.contains("tb_input[128]"));
        assert!(tb.contains("tb_expected[128]"));
        assert!(tb.contains("conv_relu_8_top(tb_input, out)"));
        assert!(tb.contains("mismatches"));
    }

    #[test]
    fn print_only_bench_without_expected() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let tb = emit_testbench(&d, &vec![0i32; 512 * 128], None);
        assert!(!tb.contains("tb_expected"));
        assert!(tb.contains("printf"));
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn wrong_input_length_panics() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        emit_testbench(&d, &[1, 2, 3], None);
    }
}
