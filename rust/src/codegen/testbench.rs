//! C++ testbench emission: drives the generated top function with the
//! deterministic PRNG inputs (same `det_i8` formula as Rust/Python) and
//! checks against an embedded expected-output vector produced by the
//! cycle simulator — so csim of the generated design validates against
//! the same golden data as everything else.

use std::fmt::Write as _;

use crate::dataflow::design::Design;

/// Emit a standalone testbench. `expected` is the simulator's output
/// (pass `None` to emit a bench that only prints outputs).
pub fn emit_testbench(d: &Design, input: &[i32], expected: Option<&[i32]>) -> String {
    let mut o = String::new();
    let in_ty = d.graph.inputs()[0].ty.dtype.cpp();
    let out_ty = d.graph.outputs()[0].ty.dtype.cpp();
    let in_n = d.graph.inputs()[0].ty.numel();
    let out_n = d.graph.outputs()[0].ty.numel();
    assert_eq!(input.len(), in_n, "testbench input length mismatch");

    let _ = writeln!(
        o,
        "// Auto-generated MING testbench for {}\n\
         #include <cstdio>\n#include <cstdint>\n#include <cstdlib>\n",
        d.graph.name
    );
    let _ = writeln!(
        o,
        "extern \"C\" void {}_top(const {in_ty} *host_in, {out_ty} *host_out);\n",
        d.graph.name
    );
    let _ = write!(o, "static const {in_ty} tb_input[{in_n}] = {{");
    for (i, v) in input.iter().enumerate() {
        if i % 24 == 0 {
            let _ = write!(o, "\n    ");
        }
        let _ = write!(o, "{v}, ");
    }
    let _ = writeln!(o, "\n}};\n");
    if let Some(exp) = expected {
        assert_eq!(exp.len(), out_n, "testbench expected length mismatch");
        let _ = write!(o, "static const {out_ty} tb_expected[{out_n}] = {{");
        for (i, v) in exp.iter().enumerate() {
            if i % 24 == 0 {
                let _ = write!(o, "\n    ");
            }
            let _ = write!(o, "{v}, ");
        }
        let _ = writeln!(o, "\n}};\n");
    }
    let _ = writeln!(o, "int main() {{");
    let _ = writeln!(o, "    static {out_ty} out[{out_n}];");
    let _ = writeln!(o, "    {}_top(tb_input, out);", d.graph.name);
    if expected.is_some() {
        let _ = writeln!(
            o,
            "    long bad = 0;\n\
             \x20   for (long i = 0; i < {out_n}; ++i)\n\
             \x20       if (out[i] != tb_expected[i]) {{ if (bad < 10) printf(\"mismatch @%ld: %d != %d\\n\", i, (int)out[i], (int)tb_expected[i]); ++bad; }}\n\
             \x20   printf(\"%ld mismatches\\n\", bad);\n\
             \x20   return bad == 0 ? 0 : 1;"
        );
    } else {
        let _ = writeln!(
            o,
            "    for (long i = 0; i < 16 && i < {out_n}; ++i) printf(\"%d \", (int)out[i]);\n\
             \x20   printf(\"\\n\");\n    return 0;"
        );
    }
    let _ = writeln!(o, "}}");
    o
}

fn emit_i32_array(o: &mut String, ty: &str, name: &str, vals: &[i32]) {
    let _ = write!(o, "static const {ty} {name}[{}] = {{", vals.len());
    for (i, v) in vals.iter().enumerate() {
        if i % 24 == 0 {
            let _ = write!(o, "\n    ");
        }
        let _ = write!(o, "{v}, ");
    }
    let _ = writeln!(o, "\n}};\n");
}

/// Emit a testbench for a grid-tiled design (`emit_tiled_design`'s
/// `*_tiled_top`). Beyond the full-output comparison, the bench checks
/// every interior halo seam of the grid explicitly: for each boundary
/// between adjacent cells it sweeps a band of output positions around
/// the seam — the exact region where inward-shifted windows, crop
/// offsets, or stride misalignment would corrupt values first — and
/// reports per-boundary mismatch counts before the global verdict.
///
/// `expected` must come from an oracle *independent of the grid plan*
/// (the untiled design's simulation, or the JAX/Pallas golden model) —
/// a tiled-simulation output would track the same `Seg` tables the
/// emitted HLS uses and mask planner bugs. The CLI's `--emit-tb` path
/// simulates the untiled design for exactly this reason.
pub fn emit_tiled_testbench(
    tc: &crate::tiling::TiledCompilation,
    input: &[i32],
    expected: &[i32],
) -> String {
    let g = &tc.graph;
    let grid = &tc.grid;
    let in_ty = g.inputs()[0].ty.dtype.cpp();
    let out_ty = g.outputs()[0].ty.dtype.cpp();
    let in_n = g.inputs()[0].ty.numel();
    let out_n = g.outputs()[0].ty.numel();
    assert_eq!(input.len(), in_n, "testbench input length mismatch");
    assert_eq!(expected.len(), out_n, "testbench expected length mismatch");

    let (h_out, w_out) = (grid.h.out_extent, grid.w.out_extent);
    let f = *g.outputs()[0].ty.shape.last().unwrap();
    // seam band per axis: the dependency cone radius in output
    // coordinates (at least one position each side)
    let band = |a: &crate::tiling::GridAxis| a.cone.radius().div_ceil(a.cone.scale).max(1);
    let (band_h, band_w) = (band(&grid.h), band(&grid.w));
    let row_seams: Vec<String> =
        grid.h.segs.iter().skip(1).map(|s| s.out_lo.to_string()).collect();
    let col_seams: Vec<String> =
        grid.w.segs.iter().skip(1).map(|s| s.out_lo.to_string()).collect();

    let mut o = String::new();
    let _ = writeln!(
        o,
        "// Auto-generated MING tiled testbench for {} ({}x{} grid)\n\
         #include <cstdio>\n#include <cstdint>\n#include <cstdlib>\n",
        g.name,
        grid.rows(),
        grid.cols()
    );
    let _ = writeln!(
        o,
        "extern \"C\" void {}_tiled_top(const {in_ty} *host_in, {out_ty} *host_out);\n",
        g.name
    );
    emit_i32_array(&mut o, in_ty, "tb_input", input);
    emit_i32_array(&mut o, out_ty, "tb_expected", expected);
    let _ = writeln!(
        o,
        "static const int row_seams[{}] = {{{}}};",
        row_seams.len().max(1),
        if row_seams.is_empty() { "0".to_string() } else { row_seams.join(", ") }
    );
    let _ = writeln!(
        o,
        "static const int col_seams[{}] = {{{}}};\n",
        col_seams.len().max(1),
        if col_seams.is_empty() { "0".to_string() } else { col_seams.join(", ") }
    );
    let _ = writeln!(
        o,
        "static {out_ty} out[{out_n}];\n\
         \n\
         // mismatches inside an output band [r0,r1) x [c0,c1)\n\
         static long check_band(int r0, int r1, int c0, int c1) {{\n\
         \x20   long bad = 0;\n\
         \x20   for (int r = r0 < 0 ? 0 : r0; r < (r1 > {h_out} ? {h_out} : r1); ++r)\n\
         \x20       for (int c = c0 < 0 ? 0 : c0; c < (c1 > {w_out} ? {w_out} : c1); ++c)\n\
         \x20           for (int k = 0; k < {f}; ++k) {{\n\
         \x20               long i = ((long)r * {w_out} + c) * {f} + k;\n\
         \x20               if (out[i] != tb_expected[i]) ++bad;\n\
         \x20           }}\n\
         \x20   return bad;\n\
         }}\n"
    );
    let _ = writeln!(o, "int main() {{");
    let _ = writeln!(o, "    {}_tiled_top(tb_input, out);", g.name);
    let _ = writeln!(o, "    long seam_bad = 0;");
    let _ = writeln!(
        o,
        "    // horizontal halo seams (between row cells): +/-{band_h} output rows\n\
         \x20   for (int s = 0; s < {}; ++s) {{\n\
         \x20       long bad = check_band(row_seams[s] - {band_h}, row_seams[s] + {band_h}, \
         0, {w_out});\n\
         \x20       printf(\"seam row@%d: %ld mismatches in +/-{band_h} band\\n\", \
         row_seams[s], bad);\n\
         \x20       seam_bad += bad;\n\
         \x20   }}",
        row_seams.len()
    );
    let _ = writeln!(
        o,
        "    // vertical halo seams (between column cells): +/-{band_w} output cols\n\
         \x20   for (int s = 0; s < {}; ++s) {{\n\
         \x20       long bad = check_band(0, {h_out}, col_seams[s] - {band_w}, \
         col_seams[s] + {band_w});\n\
         \x20       printf(\"seam col@%d: %ld mismatches in +/-{band_w} band\\n\", \
         col_seams[s], bad);\n\
         \x20       seam_bad += bad;\n\
         \x20   }}",
        col_seams.len()
    );
    let _ = writeln!(
        o,
        "    long bad = check_band(0, {h_out}, 0, {w_out});\n\
         \x20   printf(\"%ld seam mismatches, %ld total mismatches\\n\", seam_bad, bad);\n\
         \x20   return bad == 0 ? 0 : 1;\n\
         }}"
    );
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::build::build_streaming_design;
    use crate::ir::builder::models;

    #[test]
    fn testbench_embeds_vectors_and_check() {
        let g = models::conv_relu(8, 2, 2);
        let d = build_streaming_design(&g).unwrap();
        let input = vec![1i32; 8 * 8 * 2];
        let expected = vec![0i32; 8 * 8 * 2];
        let tb = emit_testbench(&d, &input, Some(&expected));
        assert!(tb.contains("tb_input[128]"));
        assert!(tb.contains("tb_expected[128]"));
        assert!(tb.contains("conv_relu_8_top(tb_input, out)"));
        assert!(tb.contains("mismatches"));
    }

    #[test]
    fn print_only_bench_without_expected() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        let tb = emit_testbench(&d, &vec![0i32; 512 * 128], None);
        assert!(!tb.contains("tb_expected"));
        assert!(tb.contains("printf"));
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn wrong_input_length_panics() {
        let g = models::linear();
        let d = build_streaming_design(&g).unwrap();
        emit_testbench(&d, &[1, 2, 3], None);
    }

    fn untiled_oracle(g: &crate::ir::graph::ModelGraph, x: &[i32]) -> Vec<i32> {
        use crate::sim::{simulate, SimMode};
        let d = build_streaming_design(g).unwrap();
        simulate(&d, x, SimMode::of(d.style)).unwrap().expect_complete().output
    }

    #[test]
    fn tiled_testbench_checks_every_halo_seam() {
        use crate::dse::ilp::DseConfig;
        use crate::resources::device::DeviceSpec;
        use crate::tiling::compile_tiled_fixed;
        let g = models::conv_relu(32, 8, 8);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 2, 4).unwrap();
        let input: Vec<i32> = (0..32 * 32 * 8).map(|i| (i % 13) as i32 - 6).collect();
        let want = untiled_oracle(&g, &input);
        let tb = emit_tiled_testbench(&tc, &input, &want);
        assert!(tb.contains("conv_relu_32_tiled_top(tb_input, out)"));
        assert!(tb.contains("tb_expected"));
        // 2 rows -> 1 interior row seam at out_lo 16; 4 cols -> 3 seams
        assert!(tb.contains("static const int row_seams[1] = {16};"), "{tb}");
        assert!(tb.contains("static const int col_seams[3] = {8, 16, 24};"), "{tb}");
        assert!(tb.contains("seam row@%d"));
        assert!(tb.contains("seam col@%d"));
        assert!(tb.contains("check_band"));
    }

    #[test]
    fn tiled_testbench_bands_follow_the_stride_cone() {
        use crate::dse::ilp::DseConfig;
        use crate::resources::device::DeviceSpec;
        use crate::tiling::compile_tiled_fixed;
        let g = models::conv_pool_conv(64, 8);
        let tc = compile_tiled_fixed(&g, &DseConfig::new(DeviceSpec::kv260()), 1, 2).unwrap();
        let input: Vec<i32> = (0..64 * 64 * 8).map(|i| (i % 11) as i32 - 5).collect();
        let want = untiled_oracle(&g, &input);
        let tb = emit_tiled_testbench(&tc, &input, &want);
        // cone (3, 4) at stride 2 -> band of ceil(4/2) = 2 output cols
        assert!(tb.contains("static const int col_seams[1] = {16};"), "{tb}");
        assert!(tb.contains("+/-2 band"), "{tb}");
        // no interior row seams: a single filler entry, zero iterations
        assert!(tb.contains("static const int row_seams[1] = {0};"), "{tb}");
    }
}
