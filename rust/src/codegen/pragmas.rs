//! HLS pragma model (paper §III-C's essential directives).
//!
//! Storage-related pragmas (ARRAY_PARTITION, BIND_STORAGE) are *derived*
//! from [`BufferAlloc`]s via [`buffer_pragmas`] — the same allocations
//! the unified resource model prices — rather than recomputed inline by
//! the emitter, so the pragmas in the generated C++ always describe the
//! storage the solver charged for.

use std::fmt;

use crate::dataflow::buffers::{BufferAlloc, BufferRole, Storage};

/// Array partition styles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    Complete,
    Cyclic,
    Block,
}

impl PartitionKind {
    fn name(self) -> &'static str {
        match self {
            PartitionKind::Complete => "complete",
            PartitionKind::Cyclic => "cyclic",
            PartitionKind::Block => "block",
        }
    }
}

/// Storage implementations for BIND_STORAGE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageImpl {
    Bram,
    Lutram,
    Srl,
}

impl StorageImpl {
    fn name(self) -> &'static str {
        match self {
            StorageImpl::Bram => "bram",
            StorageImpl::Lutram => "lutram",
            StorageImpl::Srl => "srl",
        }
    }
}

/// The HLS pragmas MING inserts automatically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Pragma {
    /// `#pragma HLS DATAFLOW`
    Dataflow,
    /// `#pragma HLS PIPELINE II=n`
    Pipeline { ii: u64 },
    /// `#pragma HLS UNROLL factor=n` (full unroll when factor omitted)
    Unroll { factor: Option<u64> },
    /// `#pragma HLS STREAM variable=v depth=d`
    Stream { var: String, depth: usize },
    /// `#pragma HLS STREAM variable=v off` — pin an array crossing
    /// DATAFLOW processes to a PIPO (ping-pong two-bank) buffer instead
    /// of a FIFO, so whole-window producers/consumers overlap.
    StreamOff { var: String },
    /// `#pragma HLS ARRAY_PARTITION variable=v <kind> factor=f dim=d`
    ArrayPartition { var: String, kind: PartitionKind, factor: u64, dim: u32 },
    /// `#pragma HLS BIND_STORAGE variable=v type={ram_1p|rom_1p} impl=<impl>`
    BindStorage { var: String, storage: StorageImpl, rom: bool },
    /// `#pragma HLS INTERFACE mode=m port=p`
    Interface { mode: String, port: String },
    /// `#pragma HLS INLINE off`
    InlineOff,
}

impl fmt::Display for Pragma {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pragma::Dataflow => write!(f, "#pragma HLS DATAFLOW"),
            Pragma::Pipeline { ii } => write!(f, "#pragma HLS PIPELINE II={ii}"),
            Pragma::Unroll { factor: Some(n) } => write!(f, "#pragma HLS UNROLL factor={n}"),
            Pragma::Unroll { factor: None } => write!(f, "#pragma HLS UNROLL"),
            Pragma::Stream { var, depth } => {
                write!(f, "#pragma HLS STREAM variable={var} depth={depth}")
            }
            Pragma::StreamOff { var } => {
                write!(f, "#pragma HLS STREAM variable={var} off")
            }
            Pragma::ArrayPartition { var, kind, factor, dim } => write!(
                f,
                "#pragma HLS ARRAY_PARTITION variable={var} {} factor={factor} dim={dim}",
                kind.name()
            ),
            Pragma::BindStorage { var, storage, rom } => write!(
                f,
                "#pragma HLS BIND_STORAGE variable={var} type={} impl={}",
                if *rom { "rom_1p" } else { "ram_1p" },
                storage.name()
            ),
            Pragma::Interface { mode, port } => {
                write!(f, "#pragma HLS INTERFACE mode={mode} port={port}")
            }
            Pragma::InlineOff => write!(f, "#pragma HLS INLINE off"),
        }
    }
}

/// The BIND_STORAGE `impl` for a buffer's storage binding; `None` for
/// register (FF) arrays, which take no storage pragma.
pub fn storage_impl(s: Storage) -> Option<StorageImpl> {
    match s {
        Storage::Bram | Storage::Rom => Some(StorageImpl::Bram),
        Storage::Lutram => Some(StorageImpl::Lutram),
        Storage::Ff => None,
    }
}

/// The storage pragmas describing one buffer allocation, applied to the
/// emitted array `var` along `dim`: a cyclic ARRAY_PARTITION at the
/// allocation's partition factor plus the BIND_STORAGE binding (ROM type
/// for weight constants). This is the single path from the resource
/// model's storage decisions to the generated directives.
pub fn buffer_pragmas(var: &str, b: &BufferAlloc, dim: u32) -> Vec<Pragma> {
    let mut out = vec![Pragma::ArrayPartition {
        var: var.to_string(),
        kind: PartitionKind::Cyclic,
        factor: b.partitions.max(1),
        dim,
    }];
    if let Some(imp) = storage_impl(b.storage) {
        out.push(Pragma::BindStorage {
            var: var.to_string(),
            storage: imp,
            rom: b.role == BufferRole::Weights,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_match_vitis_syntax() {
        assert_eq!(Pragma::Dataflow.to_string(), "#pragma HLS DATAFLOW");
        assert_eq!(Pragma::Pipeline { ii: 1 }.to_string(), "#pragma HLS PIPELINE II=1");
        assert_eq!(
            Pragma::Unroll { factor: Some(8) }.to_string(),
            "#pragma HLS UNROLL factor=8"
        );
        assert_eq!(
            Pragma::Stream { var: "s0".into(), depth: 64 }.to_string(),
            "#pragma HLS STREAM variable=s0 depth=64"
        );
        assert_eq!(
            Pragma::ArrayPartition {
                var: "lb".into(),
                kind: PartitionKind::Cyclic,
                factor: 8,
                dim: 2
            }
            .to_string(),
            "#pragma HLS ARRAY_PARTITION variable=lb cyclic factor=8 dim=2"
        );
        assert_eq!(
            Pragma::BindStorage { var: "lb".into(), storage: StorageImpl::Bram, rom: false }
                .to_string(),
            "#pragma HLS BIND_STORAGE variable=lb type=ram_1p impl=bram"
        );
        assert_eq!(
            Pragma::BindStorage { var: "w1".into(), storage: StorageImpl::Lutram, rom: true }
                .to_string(),
            "#pragma HLS BIND_STORAGE variable=w1 type=rom_1p impl=lutram"
        );
    }

    #[test]
    fn buffer_pragmas_follow_the_allocation() {
        let b = BufferAlloc {
            name: "conv0_w1".into(),
            role: BufferRole::Weights,
            bits: 18_432,
            partitions: 8,
            storage: Storage::Rom,
            node: Some(0),
        };
        let ps = buffer_pragmas("w1", &b, 1);
        let text: Vec<String> = ps.iter().map(|p| p.to_string()).collect();
        assert_eq!(
            text,
            vec![
                "#pragma HLS ARRAY_PARTITION variable=w1 cyclic factor=8 dim=1",
                "#pragma HLS BIND_STORAGE variable=w1 type=rom_1p impl=bram",
            ]
        );
        // register arrays bind no storage pragma
        let ff = BufferAlloc {
            name: "win".into(),
            role: BufferRole::WindowBuffer,
            bits: 64,
            partitions: 8,
            storage: Storage::Ff,
            node: Some(0),
        };
        assert_eq!(buffer_pragmas("window", &ff, 0).len(), 1);
    }
}
