//! HLS pragma model (paper §III-C's essential directives).

use std::fmt;

/// Array partition styles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    Complete,
    Cyclic,
    Block,
}

impl PartitionKind {
    fn name(self) -> &'static str {
        match self {
            PartitionKind::Complete => "complete",
            PartitionKind::Cyclic => "cyclic",
            PartitionKind::Block => "block",
        }
    }
}

/// Storage implementations for BIND_STORAGE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageImpl {
    Bram,
    Lutram,
    Srl,
}

impl StorageImpl {
    fn name(self) -> &'static str {
        match self {
            StorageImpl::Bram => "bram",
            StorageImpl::Lutram => "lutram",
            StorageImpl::Srl => "srl",
        }
    }
}

/// The HLS pragmas MING inserts automatically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Pragma {
    /// `#pragma HLS DATAFLOW`
    Dataflow,
    /// `#pragma HLS PIPELINE II=n`
    Pipeline { ii: u64 },
    /// `#pragma HLS UNROLL factor=n` (full unroll when factor omitted)
    Unroll { factor: Option<u64> },
    /// `#pragma HLS STREAM variable=v depth=d`
    Stream { var: String, depth: usize },
    /// `#pragma HLS ARRAY_PARTITION variable=v <kind> factor=f dim=d`
    ArrayPartition { var: String, kind: PartitionKind, factor: u64, dim: u32 },
    /// `#pragma HLS BIND_STORAGE variable=v type=ram_1p impl=<impl>`
    BindStorage { var: String, storage: StorageImpl },
    /// `#pragma HLS INTERFACE mode=m port=p`
    Interface { mode: String, port: String },
    /// `#pragma HLS INLINE off`
    InlineOff,
}

impl fmt::Display for Pragma {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pragma::Dataflow => write!(f, "#pragma HLS DATAFLOW"),
            Pragma::Pipeline { ii } => write!(f, "#pragma HLS PIPELINE II={ii}"),
            Pragma::Unroll { factor: Some(n) } => write!(f, "#pragma HLS UNROLL factor={n}"),
            Pragma::Unroll { factor: None } => write!(f, "#pragma HLS UNROLL"),
            Pragma::Stream { var, depth } => {
                write!(f, "#pragma HLS STREAM variable={var} depth={depth}")
            }
            Pragma::ArrayPartition { var, kind, factor, dim } => write!(
                f,
                "#pragma HLS ARRAY_PARTITION variable={var} {} factor={factor} dim={dim}",
                kind.name()
            ),
            Pragma::BindStorage { var, storage } => write!(
                f,
                "#pragma HLS BIND_STORAGE variable={var} type=ram_1p impl={}",
                storage.name()
            ),
            Pragma::Interface { mode, port } => {
                write!(f, "#pragma HLS INTERFACE mode={mode} port={port}")
            }
            Pragma::InlineOff => write!(f, "#pragma HLS INLINE off"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_match_vitis_syntax() {
        assert_eq!(Pragma::Dataflow.to_string(), "#pragma HLS DATAFLOW");
        assert_eq!(Pragma::Pipeline { ii: 1 }.to_string(), "#pragma HLS PIPELINE II=1");
        assert_eq!(
            Pragma::Unroll { factor: Some(8) }.to_string(),
            "#pragma HLS UNROLL factor=8"
        );
        assert_eq!(
            Pragma::Stream { var: "s0".into(), depth: 64 }.to_string(),
            "#pragma HLS STREAM variable=s0 depth=64"
        );
        assert_eq!(
            Pragma::ArrayPartition {
                var: "lb".into(),
                kind: PartitionKind::Cyclic,
                factor: 8,
                dim: 2
            }
            .to_string(),
            "#pragma HLS ARRAY_PARTITION variable=lb cyclic factor=8 dim=2"
        );
        assert_eq!(
            Pragma::BindStorage { var: "lb".into(), storage: StorageImpl::Bram }.to_string(),
            "#pragma HLS BIND_STORAGE variable=lb type=ram_1p impl=bram"
        );
    }
}
