//! # MING — an automated CNN-to-edge HLS framework (paper reproduction)
//!
//! Rust re-implementation of *MING: An Automated CNN-to-Edge MLIR HLS
//! framework* (Bi, Schütze, Castrillon; CS.AR 2026), built as the L3 layer
//! of a three-layer Rust + JAX + Pallas stack:
//!
//! * **`ir`** — a `linalg.generic`-style IR (affine indexing maps, iterator
//!   types, structured payloads) plus builders for the paper's CNN ops.
//! * **`analysis`** — the paper's Algorithm 1 (sliding-window detection with
//!   stride/dilation extraction) and Algorithm 2 (iterator classification
//!   into P/R/O/W sets), and kernel-class assignment.
//! * **`dataflow`** — construction of the fully streaming KPN architecture:
//!   FIFO channels, line buffers, window buffers; no intermediate tensors.
//! * **`resources`** — the hardware model: BRAM18K packing, DSP-per-MAC for
//!   integer arithmetic, LUT/LUTRAM/FF fabric estimation, device database
//!   (Kria KV260 et al.), and the unified per-candidate resource model
//!   (line-buffer + weight-ROM + FIFO BRAM) shared by the DSE, the tiling
//!   subsystem, reports and codegen — solver accounting equals built-design
//!   accounting by construction.
//! * **`dse`** — the lightweight ILP of paper Eq. (1): minimize Σ cycles
//!   subject to unroll|trip, DSP, BRAM and stream-matching constraints,
//!   solved exactly by branch-and-bound over divisor lattices; FIFO depth
//!   sizing from first-output-cycle estimates (deadlock avoidance for
//!   diamonds).
//! * **`tiling`** — stride-aware 2-D tile grids for oversized layers:
//!   when the DSE has no feasible point (line buffers exceed BRAM even
//!   at minimal unroll), the workload is decomposed into a rows × cols
//!   grid of halo-overlapped cells sharing one reusable cell design,
//!   with per-op coordinate remapping so strided convs and pooled
//!   chains propagate halos and crop offsets correctly — verified
//!   bit-exact against the untiled/golden computation.
//! * **`codegen`** — the `emithls` equivalent: Vitis-HLS C++ emission with
//!   automatic STREAM / UNROLL / PIPELINE / DATAFLOW / ARRAY_PARTITION /
//!   BIND_STORAGE pragma insertion.
//! * **`sim`** — the Vitis-HLS substitute: a timestamped-token KPN simulator
//!   that executes designs functionally (bit-exact int8 semantics) while
//!   modeling II, pipeline depth, line-buffer warm-up, FIFO back-pressure
//!   and DATAFLOW overlap, producing the cycle counts the paper reads from
//!   HLS reports.
//! * **`baselines`** — re-implementations of the comparison frameworks'
//!   design *strategies*: Vanilla (Vitis auto), ScaleHLS-like, and
//!   StreamHLS-like, all lowered onto the same simulator/estimator.
//! * **`runtime`** — PJRT execution of the AOT-lowered JAX/Pallas golden
//!   model (HLO text artifacts) for functional verification.
//! * **`coordinator`** — a staged, cache-backed compile service: kernel ×
//!   framework × size sweeps over a worker pool, content-addressed design
//!   reuse (`coordinator::cache`, keyed by `ir::fingerprint`), deterministic
//!   round-robin sharding across processes with mergeable/resumable JSONL
//!   spools (`coordinator::spool`), and the paper-table formatters.
//! * **`obs`** — pipeline-wide observability: nested span tracing with
//!   Chrome trace-event export (`--trace-out`, Perfetto-loadable), a
//!   unified registry of named atomic counters/gauges, and the
//!   `--profile` phase-time/counter table.
//!
//! See `DESIGN.md` for the substitution map (what the paper ran on Vitis +
//! a Kria KV260 board vs. what this repo builds) and `EXPERIMENTS.md` for
//! paper-vs-measured numbers.

pub mod util;
pub mod obs;
pub mod ir;
pub mod analysis;
pub mod dataflow;
pub mod resources;
pub mod dse;
pub mod tiling;
pub mod codegen;
pub mod sim;
pub mod baselines;
pub mod runtime;
pub mod coordinator;

/// Convenience prelude re-exporting the types most users need.
pub mod prelude {
    pub use crate::analysis::classify::{classify, KernelClass};
    pub use crate::baselines::framework::{Framework, FrameworkKind};
    pub use crate::coordinator::cache::DesignCache;
    pub use crate::coordinator::service::{CompileService, Shard, SweepConfig};
    pub use crate::dataflow::build::build_streaming_design;
    pub use crate::dse::ilp::DseConfig;
    pub use crate::ir::builder::{models, GraphBuilder};
    pub use crate::ir::graph::ModelGraph;
    pub use crate::resources::device::DeviceSpec;
    pub use crate::resources::model::{ResourceModel, ResourceVec};
    pub use crate::resources::report::UtilizationReport;
    pub use crate::sim::engine::{SimMode, SimReport};
    pub use crate::tiling::{compile_tiled, simulate_tiled, TileGrid, TiledCompilation};
}
