//! Bench: the unified work-stealing scheduler on a straggler-dominated
//! sweep — several tiny kernels plus the oversized `vgg3@512` job,
//! whose tile-grid search and DSE subtrees dwarf everything else:
//!
//!   * **baseline**: the pre-scheduler behaviour, reproduced exactly —
//!     locality submission order ([`JobOrder::Submission`]) and nested
//!     parallelism pinned to 1 ([`CompileService::with_nested_worker_cap`]),
//!     so the straggler grinds on one worker while its siblings idle
//!     past the sweep tail;
//!   * **stealing**: the default configuration — makespan-aware (LPT)
//!     ordering starts the straggler first, and idle workers steal its
//!     nested DSE subtrees and grid-cell solves (`sched.steals` counts
//!     the migrations);
//!   * **lpt-vs-submission**: the stealing pool with submission order,
//!     isolating what the LPT ordering itself buys.
//!
//! All three runs must render the identical table — the scheduler moves
//! work between cores, never between answers.
//!
//! Emits `BENCH_sched.json` (uploaded as a CI artifact) and gates
//! against the committed `BENCH_sched_baseline.json` floors (0.8x
//! baseline, `MING_BENCH_NO_GATE=1` escape hatch). The speedup gates
//! only arm on machines with >= 4 cores.
//!
//! Run: `cargo bench --bench sched_perf`

use std::time::{Duration, Instant};

use ming::baselines::framework::FrameworkKind;
use ming::coordinator::report;
use ming::coordinator::service::{CompileService, JobOrder, SweepConfig};
use ming::coordinator::{JobResult, Scheduler};
use ming::ir::json;
use ming::resources::device::DeviceSpec;

/// Min wall-time of `iters` runs (min is the noise-robust statistic for
/// scheduling comparisons; it also lands on each service's warm
/// steady state, so both sides amortize their cold solves equally).
fn min_wall<T>(iters: usize, mut f: impl FnMut() -> T) -> Duration {
    let mut best = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed());
    }
    best
}

/// The straggler sweep: six small MING cells plus the grid-tiled
/// `vgg3@512` job (estimate-only — the wall time here is compile + DSE
/// + grid search, which is where the scheduler earns its keep).
fn straggler_sweep() -> SweepConfig {
    SweepConfig {
        workloads: vec![
            ("conv_relu".into(), 32),
            ("cascade".into(), 32),
            ("residual".into(), 32),
            ("linear".into(), 0),
            ("feedforward".into(), 32),
            ("conv_relu".into(), 48),
            ("vgg3".into(), 512),
        ],
        frameworks: vec![FrameworkKind::Ming],
        device: DeviceSpec::kv260(),
        estimate_only: true,
    }
}

fn render(results: &[Result<JobResult, String>]) -> String {
    let cells: Vec<_> =
        results.iter().filter_map(|r| r.as_ref().ok().map(report::cell)).collect();
    report::render_table2(&cells)
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers = 4usize;
    let cfg = straggler_sweep();
    let jobs = CompileService::jobs(&cfg).len();
    let m = ming::obs::metrics::global();

    // --- baseline: chunked/pinned (submission order, nested cap 1) ----
    let base_sched = Scheduler::new(workers);
    let base_svc = CompileService::new(workers)
        .with_scheduler(base_sched.handle())
        .with_job_order(JobOrder::Submission)
        .with_nested_worker_cap(1);
    let mut base_table = String::new();
    let base_wall = min_wall(2, || {
        let results = base_svc.run_sweep(&cfg);
        assert!(results.iter().all(|r| r.is_ok()), "baseline sweep must succeed");
        base_table = render(&results);
    });

    // --- stealing: LPT order + nested groups on the shared pool -------
    let steal_sched = Scheduler::new(workers);
    let steal_svc = CompileService::new(workers).with_scheduler(steal_sched.handle());
    let steals0 = m.get("sched.steals");
    let mut steal_table = String::new();
    let steal_wall = min_wall(2, || {
        let results = steal_svc.run_sweep(&cfg);
        assert!(results.iter().all(|r| r.is_ok()), "stealing sweep must succeed");
        steal_table = render(&results);
    });
    let steals = m.get("sched.steals") - steals0;
    assert!(steals > 0, "the straggler's nested tasks must migrate");
    assert_eq!(base_table, steal_table, "stealing changed the rendered table");

    // --- lpt vs submission, both on the stealing pool -----------------
    let sub_svc = CompileService::new(workers)
        .with_scheduler(steal_sched.handle())
        .with_job_order(JobOrder::Submission);
    let mut sub_table = String::new();
    let sub_wall = min_wall(2, || {
        let results = sub_svc.run_sweep(&cfg);
        assert!(results.iter().all(|r| r.is_ok()), "submission-order sweep must succeed");
        sub_table = render(&results);
    });
    assert_eq!(base_table, sub_table, "job order changed the rendered table");

    let makespan_speedup = base_wall.as_secs_f64() / steal_wall.as_secs_f64().max(1e-9);
    let lpt_speedup = sub_wall.as_secs_f64() / steal_wall.as_secs_f64().max(1e-9);
    println!(
        "straggler sweep ({jobs} jobs, {workers} workers, {cores} cores):\n\
         \x20 chunked/pinned: {:>8.1} ms\n\
         \x20 stealing (lpt): {:>8.1} ms  = {makespan_speedup:.2}x makespan \
         ({steals} tasks stolen)\n\
         \x20 stealing (sub): {:>8.1} ms  (lpt ordering alone: {lpt_speedup:.2}x)",
        base_wall.as_secs_f64() * 1e3,
        steal_wall.as_secs_f64() * 1e3,
        sub_wall.as_secs_f64() * 1e3,
    );

    let json_out = format!(
        "{{\"bench\":\"sched\",\"jobs\":{jobs},\"workers\":{workers},\"cores\":{cores},\
         \"baseline_ms\":{:.3},\"stealing_ms\":{:.3},\"submission_ms\":{:.3},\
         \"makespan_speedup\":{makespan_speedup:.2},\"lpt_speedup\":{lpt_speedup:.2},\
         \"steals\":{steals}}}",
        base_wall.as_secs_f64() * 1e3,
        steal_wall.as_secs_f64() * 1e3,
        sub_wall.as_secs_f64() * 1e3,
    );
    std::fs::write("BENCH_sched.json", format!("{json_out}\n"))
        .expect("writing BENCH_sched.json");
    println!("wrote BENCH_sched.json");

    // --- perf-regression gate (BENCH_sched_baseline.json) -------------
    // Committed floors, deliberately conservative: fail only when a
    // gated speedup drops below 80% of its baseline. Both gates compare
    // thread schedules, so they only arm with >= 4 real cores.
    // Re-baseline by copying numbers from a CI BENCH_sched.json artifact.
    if std::env::var_os("MING_BENCH_NO_GATE").is_some() {
        println!("perf gate: skipped (MING_BENCH_NO_GATE=1)");
    } else if cores < 4 {
        println!("perf gate: skipped ({cores} cores < 4)");
    } else if let Ok(text) = std::fs::read_to_string("BENCH_sched_baseline.json") {
        let base = json::parse(&text).expect("BENCH_sched_baseline.json must parse");
        let baseline = |path: &str| -> f64 {
            base.get(path)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|e| panic!("baseline {path}: {e}"))
        };
        let mut failed = false;
        for (path, cur) in
            [("makespan_speedup", makespan_speedup), ("lpt_speedup", lpt_speedup)]
        {
            let floor = baseline(path) * 0.8;
            if cur < floor {
                eprintln!("perf gate FAIL {path}: {cur:.2} < floor {floor:.2} (0.8x baseline)");
                failed = true;
            } else {
                println!("perf gate ok   {path}: {cur:.2} >= floor {floor:.2}");
            }
        }
        assert!(!failed, "scheduler regressed >20% vs BENCH_sched_baseline.json");
    } else {
        println!("perf gate: BENCH_sched_baseline.json not found, skipping");
    }
}
