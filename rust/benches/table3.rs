//! Bench: regenerate the paper's Table III (post-PnR LUT/LUTRAM/FF % for
//! the 32×32 kernels across ScaleHLS / StreamHLS / MING).
//!
//! Run: `cargo bench --bench table3`

use ming::baselines::framework::FrameworkKind;
use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, SweepConfig};
use ming::resources::device::DeviceSpec;
use ming::util::bench::bench;

fn cells(dev: &DeviceSpec) -> Vec<Cell> {
    let cfg = SweepConfig {
        workloads: vec![
            ("conv_relu".into(), 32),
            ("cascade".into(), 32),
            ("residual".into(), 32),
        ],
        frameworks: FrameworkKind::all().to_vec(),
        device: dev.clone(),
        estimate_only: true,
    };
    CompileService::default()
        .run_sweep(&cfg)
        .iter()
        .filter_map(|r| r.as_ref().ok().map(report::cell))
        .collect()
}

fn main() {
    let dev = DeviceSpec::kv260();
    let c = cells(&dev);
    println!("=== Table III (reproduction) ===");
    println!("{}", report::render_table3(&c));

    // shape claim: MING consumes the least fabric on every kernel
    for kernel in ["conv_relu", "cascade", "residual"] {
        let of = |fw: FrameworkKind| {
            c.iter().find(|x| x.kernel == kernel && x.framework == fw).unwrap()
        };
        let ming = of(FrameworkKind::Ming);
        for fw in [FrameworkKind::ScaleHls, FrameworkKind::StreamHls] {
            let other = of(fw);
            assert!(
                ming.lut_pct <= other.lut_pct + 1e-9,
                "{kernel}: MING LUT% {} must not exceed {} ({})",
                ming.lut_pct,
                other.lut_pct,
                fw.name()
            );
        }
    }
    println!("shape checks passed (MING lowest fabric on all kernels)\n");

    let s = bench("table3_estimates", 1, 10, || cells(&dev));
    println!("{}", s.summary());
}
