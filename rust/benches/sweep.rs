//! Bench: sweep wall-time with and without the content-addressed design
//! cache, emitting `BENCH_sweep.json` (wall-time + cache hit rate +
//! span-tracing overhead + DSE warm-start reuse counters) for CI
//! tracking. Also proves the cold sweep — full and 2-way sharded —
//! reuses node fronts across problems (`dse.front_hits > 0`).
//!
//! Run: `cargo bench --bench sweep`

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Instant;

use ming::coordinator::cache::DesignCache;
use ming::coordinator::sched;
use ming::coordinator::service::{CompileService, Shard, SweepConfig};
use ming::resources::device::DeviceSpec;
use ming::util::bench::fmt_dur;

fn main() {
    let mut cfg = SweepConfig::table2(DeviceSpec::kv260());
    cfg.estimate_only = true; // wall-time here is compile+DSE, not simulation

    // cold: empty cache, every problem solved for real — but the
    // service's per-sweep DSE warm-start store is already live, so the
    // cold run itself reuses node fronts across structurally-identical
    // layers and seeds incumbents between same-shape problems
    let cache = Arc::new(DesignCache::in_memory());
    let svc = CompileService::new(sched::default_size()).with_cache(cache.clone());
    let m = ming::obs::metrics::global();
    let fh0 = m.get("dse.front_hits");
    let ws0 = m.get("dse.warm_seeds");
    let t0 = Instant::now();
    let cold_results = svc.run_sweep(&cfg);
    let cold = t0.elapsed();
    let dse_front_hits = m.get("dse.front_hits") - fh0;
    let dse_warm_seeds = m.get("dse.warm_seeds") - ws0;
    let cold_stats = cache.stats();
    assert!(cold_results.iter().all(|r| r.is_ok()), "table2 estimate sweep must succeed");
    assert!(cold_stats.solves > 0, "cold sweep must solve");
    assert!(dse_front_hits > 0, "cold sweep must reuse node fronts across problems");

    // warm: same cache, the acceptance invariant is zero ILP solves
    let t1 = Instant::now();
    let warm_results = svc.run_sweep(&cfg);
    let warm = t1.elapsed();
    let warm_stats = cache.stats();
    assert_eq!(warm_results.len(), cold_results.len());
    assert_eq!(
        warm_stats.solves, cold_stats.solves,
        "warm sweep must perform zero ILP solves"
    );

    // traced warm: same warm cache with span tracing + profiling armed —
    // the delta against the untraced warm run is the instrumentation
    // overhead (the issue budget: a few percent traced, ~0 disabled,
    // which the untraced runs above already paid if it weren't ~0)
    let sink = ming::obs::trace::global();
    let metrics0 = ming::obs::metrics::global().snapshot();
    sink.set_tracing(true);
    sink.set_profiling(true);
    let t2 = Instant::now();
    let traced_results = svc.run_sweep(&cfg);
    let traced = t2.elapsed();
    sink.set_tracing(false);
    sink.set_profiling(false);
    assert_eq!(traced_results.len(), warm_results.len());
    let trace_events = sink.event_count();
    assert!(trace_events > 0, "traced sweep must record spans");
    let traced_delta = ming::obs::metrics::global().snapshot().delta(&metrics0);
    let overhead_pct = (traced.as_secs_f64() / warm.as_secs_f64().max(1e-9) - 1.0) * 100.0;

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-9);
    // hit rate of the *warm run alone* (counter deltas) — the cumulative
    // lifetime rate would be diluted by the cold run's mandatory misses
    let warm_hits = warm_stats.hits - cold_stats.hits;
    let warm_misses = warm_stats.misses - cold_stats.misses;
    let hit_rate = warm_hits as f64 / (warm_hits + warm_misses).max(1) as f64;
    println!(
        "sweep (table2, estimate-only, {} jobs, {} workers):",
        cold_results.len(),
        svc.workers()
    );
    println!("  cold: {:>10}  (ilp solves: {})", fmt_dur(cold), cold_stats.solves);
    println!(
        "  warm: {:>10}  (ilp solves: +{}, {warm_hits} hits / {warm_misses} misses, \
         cache speedup {speedup:.1}x)",
        fmt_dur(warm),
        warm_stats.solves - cold_stats.solves
    );
    println!(
        "  traced: {:>8}  ({trace_events} span events, {overhead_pct:+.1}% vs warm, \
         sched busy {} ms)",
        fmt_dur(traced),
        traced_delta.get("sched.busy_us") / 1000,
    );
    println!("  {}", cache.summary());
    println!(
        "  dse warm-start (cold run): {dse_front_hits} front hits, {dse_warm_seeds} \
         incumbent seeds"
    );

    // sharded cold sweep: each shard runs in a fresh service (its own
    // warm-start store, no design cache), as two processes would — the
    // front cache must still pay off inside every shard
    let shard_hits: u64 = (0..2)
        .map(|index| {
            let shard_svc = CompileService::new(sched::default_size());
            let before = m.get("dse.front_hits");
            let results =
                shard_svc.run_shard(&cfg, Shard { index, count: 2 }, &BTreeSet::new());
            assert!(
                results.iter().all(|(_, r)| r.is_ok()),
                "shard {index}/2 estimate sweep must succeed"
            );
            m.get("dse.front_hits") - before
        })
        .sum();
    assert!(shard_hits > 0, "cold sharded sweep must hit the front cache");
    println!("  dse warm-start (2-shard cold run): {shard_hits} front hits");

    let json = format!(
        "{{\"bench\":\"sweep\",\"jobs\":{},\"workers\":{},\
         \"cold_ms\":{:.3},\"warm_ms\":{:.3},\"cache_speedup\":{speedup:.2},\
         \"warm_hits\":{warm_hits},\"warm_misses\":{warm_misses},\
         \"stores\":{},\"ilp_solves_cold\":{},\
         \"ilp_solves_warm\":0,\"warm_hit_rate\":{hit_rate:.4},\
         \"dse_front_hits\":{dse_front_hits},\"dse_warm_seeds\":{dse_warm_seeds},\
         \"dse_shard_front_hits\":{shard_hits},\
         \"traced_ms\":{:.3},\"trace_overhead_pct\":{overhead_pct:.2},\
         \"trace_events\":{trace_events},\"sched_busy_us\":{},\"sched_idle_us\":{}}}",
        cold_results.len(),
        svc.workers(),
        cold.as_secs_f64() * 1e3,
        warm.as_secs_f64() * 1e3,
        warm_stats.stores,
        cold_stats.solves,
        traced.as_secs_f64() * 1e3,
        traced_delta.get("sched.busy_us"),
        traced_delta.get("sched.idle_us"),
    );
    std::fs::write("BENCH_sweep.json", format!("{json}\n")).expect("writing BENCH_sweep.json");
    println!("wrote BENCH_sweep.json");
}
