//! Bench: sweep wall-time with and without the content-addressed design
//! cache, emitting `BENCH_sweep.json` (wall-time + cache hit rate) for
//! CI tracking.
//!
//! Run: `cargo bench --bench sweep`

use std::sync::Arc;
use std::time::Instant;

use ming::coordinator::cache::DesignCache;
use ming::coordinator::service::{CompileService, SweepConfig};
use ming::coordinator::WorkerPool;
use ming::resources::device::DeviceSpec;
use ming::util::bench::fmt_dur;

fn main() {
    let mut cfg = SweepConfig::table2(DeviceSpec::kv260());
    cfg.estimate_only = true; // wall-time here is compile+DSE, not simulation

    // cold: empty cache, every problem solved for real
    let cache = Arc::new(DesignCache::in_memory());
    let svc = CompileService::new(WorkerPool::default_size()).with_cache(cache.clone());
    let t0 = Instant::now();
    let cold_results = svc.run_sweep(&cfg);
    let cold = t0.elapsed();
    let cold_stats = cache.stats();
    assert!(cold_results.iter().all(|r| r.is_ok()), "table2 estimate sweep must succeed");
    assert!(cold_stats.solves > 0, "cold sweep must solve");

    // warm: same cache, the acceptance invariant is zero ILP solves
    let t1 = Instant::now();
    let warm_results = svc.run_sweep(&cfg);
    let warm = t1.elapsed();
    let warm_stats = cache.stats();
    assert_eq!(warm_results.len(), cold_results.len());
    assert_eq!(
        warm_stats.solves, cold_stats.solves,
        "warm sweep must perform zero ILP solves"
    );

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-9);
    // hit rate of the *warm run alone* (counter deltas) — the cumulative
    // lifetime rate would be diluted by the cold run's mandatory misses
    let warm_hits = warm_stats.hits - cold_stats.hits;
    let warm_misses = warm_stats.misses - cold_stats.misses;
    let hit_rate = warm_hits as f64 / (warm_hits + warm_misses).max(1) as f64;
    println!(
        "sweep (table2, estimate-only, {} jobs, {} workers):",
        cold_results.len(),
        svc.workers()
    );
    println!("  cold: {:>10}  (ilp solves: {})", fmt_dur(cold), cold_stats.solves);
    println!(
        "  warm: {:>10}  (ilp solves: +{}, {warm_hits} hits / {warm_misses} misses, \
         cache speedup {speedup:.1}x)",
        fmt_dur(warm),
        warm_stats.solves - cold_stats.solves
    );
    println!("  {}", cache.summary());

    let json = format!(
        "{{\"bench\":\"sweep\",\"jobs\":{},\"workers\":{},\
         \"cold_ms\":{:.3},\"warm_ms\":{:.3},\"cache_speedup\":{speedup:.2},\
         \"warm_hits\":{warm_hits},\"warm_misses\":{warm_misses},\
         \"stores\":{},\"ilp_solves_cold\":{},\
         \"ilp_solves_warm\":0,\"warm_hit_rate\":{hit_rate:.4}}}",
        cold_results.len(),
        svc.workers(),
        cold.as_secs_f64() * 1e3,
        warm.as_secs_f64() * 1e3,
        warm_stats.stores,
        cold_stats.solves,
    );
    std::fs::write("BENCH_sweep.json", format!("{json}\n")).expect("writing BENCH_sweep.json");
    println!("wrote BENCH_sweep.json");
}
