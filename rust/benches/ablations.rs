//! Ablation benches for MING's design choices (DESIGN.md experiment
//! index): quantify what each mechanism contributes by disabling it.
//!
//!   A. DATAFLOW overlap (vs sequential execution of the same design)
//!   B. Streaming line buffers (vs StreamHLS-style materialization) —
//!      the BRAM win
//!   C. BRAM-aware DSE (vs DSP-only DSE, the StreamHLS formulation) —
//!      feasibility on linears
//!   D. FIFO sizing from first-output estimates (vs fixed shallow FIFOs)
//!      — diamond deadlock avoidance
//!   E. II=1 streaming (vs WAR-hazard II=2) — the ScaleHLS gap
//!
//! Run: `cargo bench --bench ablations`

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dse::ilp::{solve, DseConfig};
use ming::dataflow::build::build_streaming_design;
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::util::prng;
use ming::util::tables::TextTable;

fn det_input(g: &ming::ir::graph::ModelGraph) -> Vec<i32> {
    prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect()
}

fn main() {
    let dev = DeviceSpec::kv260();
    let mut t = TextTable::new(vec!["ablation", "config", "metric", "value"]);

    // A. DATAFLOW overlap: same MING design, dataflow vs sequential.
    {
        let g = models::cascade(32, models::CONV_C, models::CONV_F);
        let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let x = det_input(&g);
        let df = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let seq = simulate(&d, &x, SimMode::Sequential).unwrap().expect_complete();
        assert_eq!(df.output, seq.output);
        assert!(df.cycles < seq.cycles);
        t.row(vec!["A overlap".into(), "dataflow".into(), "cycles".into(), df.cycles.to_string()]);
        t.row(vec!["A overlap".into(), "sequential".into(), "cycles".into(), seq.cycles.to_string()]);
        t.row(vec![
            "A overlap".into(),
            "gain".into(),
            "x".into(),
            format!("{:.2}", seq.cycles as f64 / df.cycles as f64),
        ]);
    }

    // B. Line buffers vs materialized intermediates: BRAM at 224².
    {
        let g = models::conv_relu(224, models::CONV_C, models::CONV_F);
        let ming = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let mat = compile_with(FrameworkKind::StreamHls, &g, &dev).unwrap();
        let b_ming = estimate(&ming, &dev).bram18k;
        let b_mat = estimate(&mat, &dev).bram18k;
        assert!(b_ming * 20 < b_mat);
        t.row(vec!["B line-buffer".into(), "streaming".into(), "BRAM".into(), b_ming.to_string()]);
        t.row(vec!["B line-buffer".into(), "materialized".into(), "BRAM".into(), b_mat.to_string()]);
    }

    // C. BRAM-aware DSE vs DSP-only: feasibility of the linear kernel.
    {
        let g = models::linear();
        // BRAM-aware (MING)
        let mut d1 = build_streaming_design(&g).unwrap();
        solve(&mut d1, &DseConfig::new(dev.clone())).unwrap();
        let r1 = estimate(&d1, &dev);
        // DSP-only: pretend BRAM is unlimited during DSE, then check on
        // the real device (the StreamHLS formulation).
        let mut d2 = build_streaming_design(&g).unwrap();
        let fake = DeviceSpec { bram18k: u64::MAX / 4, ..dev.clone() };
        solve(&mut d2, &DseConfig::new(fake)).unwrap();
        let r2 = estimate(&d2, &dev);
        assert!(r1.fits());
        t.row(vec!["C bram-aware".into(), "BRAM+DSP DSE".into(), "BRAM".into(), r1.bram18k.to_string()]);
        t.row(vec![
            "C bram-aware".into(),
            "DSP-only DSE".into(),
            "BRAM".into(),
            format!("{} (fits: {})", r2.bram18k, r2.fits()),
        ]);
    }

    // D. FIFO sizing: residual with vs without the sizing pass.
    {
        let g = models::residual(32, models::CONV_C, models::CONV_F);
        let x = det_input(&g);
        let unsized_d = build_streaming_design(&g).unwrap(); // no DSE/sizing
        let rep = simulate(&unsized_d, &x, SimMode::Dataflow).unwrap();
        assert!(rep.deadlock.is_some());
        t.row::<String>(vec!["D fifo-sizing".into(), "without".into(), "result".into(), "DEADLOCK".into()]);
        let sized = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let rep2 = simulate(&sized, &x, SimMode::Dataflow).unwrap().expect_complete();
        t.row(vec![
            "D fifo-sizing".into(),
            "with".into(),
            "cycles".into(),
            rep2.cycles.to_string(),
        ]);
    }

    // E. II=1 streaming vs WAR-limited II=2 on the same unrolls.
    {
        let g = models::conv_relu(32, models::CONV_C, models::CONV_F);
        let x = det_input(&g);
        let d1 = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let mut d2 = d1.clone();
        for n in &mut d2.nodes {
            n.timing.ii = 2; // inject the WAR hazard
        }
        let r1 = simulate(&d1, &x, SimMode::Dataflow).unwrap().expect_complete();
        let r2 = simulate(&d2, &x, SimMode::Dataflow).unwrap().expect_complete();
        assert!(r2.cycles > r1.cycles);
        t.row(vec!["E ii".into(), "II=1".into(), "cycles".into(), r1.cycles.to_string()]);
        t.row(vec!["E ii".into(), "II=2 (WAR)".into(), "cycles".into(), r2.cycles.to_string()]);
    }

    println!("=== MING design-choice ablations ===");
    println!("{}", t.render());
    println!("all ablation assertions passed");
}
