//! Bench: compiler-stack hot paths (the §Perf targets in EXPERIMENTS.md):
//!   * kernel analysis (Algorithms 1+2) throughput,
//!   * streaming-architecture construction,
//!   * DSE solve (branch & bound),
//!   * cycle-level simulation throughput (firings/s and token ops/s),
//!   * PJRT golden-model execution (when artifacts exist).
//!
//! Run: `cargo bench --bench compiler_perf`

use ming::analysis::classify::classify;
use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dse::ilp::{solve, DseConfig};
use ming::dataflow::build::build_streaming_design;
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::runtime::golden::GoldenModel;
use ming::sim::{simulate, SimMode};
use ming::util::bench::bench;
use ming::util::prng;

fn main() {
    let dev = DeviceSpec::kv260();

    // --- analysis ---------------------------------------------------------
    let g = models::residual(224, models::CONV_C, models::CONV_F);
    let s = bench("analysis_classify_residual224", 5, 200, || {
        g.ops.iter().map(classify).count()
    });
    println!("{}", s.summary());

    // --- build ------------------------------------------------------------
    let s = bench("build_streaming_residual224", 5, 100, || {
        build_streaming_design(&g).unwrap()
    });
    println!("{}", s.summary());

    // --- DSE --------------------------------------------------------------
    for (name, size) in [("residual", 32usize), ("feedforward", 0)] {
        let gg = models::paper_kernel(name, size).unwrap();
        let s = bench(&format!("dse_solve_{name}"), 3, 50, || {
            let mut d = build_streaming_design(&gg).unwrap();
            solve(&mut d, &DseConfig::new(dev.clone())).unwrap()
        });
        println!("{}", s.summary());
    }

    // --- simulation throughput ---------------------------------------------
    for (name, size) in [("conv_relu", 224usize), ("cascade", 224), ("linear", 0)] {
        let gg = models::paper_kernel(name, size).unwrap();
        let d = compile_with(FrameworkKind::Ming, &gg, &dev).unwrap();
        let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, gg.inputs()[0].ty.numel())
            .iter()
            .map(|&v| v as i32)
            .collect();
        let mut firings = 0u64;
        let s = bench(&format!("simulate_ming_{name}_{size}"), 1, 5, || {
            let rep = simulate(&d, &x, SimMode::Dataflow).unwrap();
            firings = rep.total_firings;
            rep.cycles
        });
        let per_sec = firings as f64 / s.mean.as_secs_f64();
        println!("{}  [{:.1}M firings/s]", s.summary(), per_sec / 1e6);
    }

    // --- golden model (PJRT) ------------------------------------------------
    if let Ok(gm) = GoldenModel::open_default() {
        if gm.available("conv_relu_32") {
            let x: Vec<i32> =
                prng::det_tensor(prng::SEED_INPUT, 32 * 32 * 8).iter().map(|&v| v as i32).collect();
            // first call compiles; bench the warm path
            gm.run("conv_relu_32", &x).unwrap();
            let s = bench("pjrt_golden_conv_relu_32", 2, 20, || {
                gm.run("conv_relu_32", &x).unwrap()
            });
            println!("{}", s.summary());
        }
    } else {
        println!("pjrt_golden_*: skipped (run `make artifacts`)");
    }
}
